//! One benchmark per paper table/figure: times the full regeneration of
//! each experiment through the harness (ensures `repro all` stays cheap
//! and pins the cost of every reproduction path).

use std::time::Duration;

use tpu_pipeline::cli::{self, Args};
use tpu_pipeline::util::bench::{black_box, Bencher};

fn run(cmd: &str) -> String {
    let argv: Vec<String> = cmd.split_whitespace().map(String::from).collect();
    cli::run(&Args::parse(&argv).unwrap()).unwrap()
}

fn main() {
    let mut b = Bencher::new().with_budget(Duration::from_millis(250), Duration::from_millis(60));
    for cmd in [
        "fig2a", "fig2a --kind conv", "fig2b", "fig2c", "table1", "table2",
        "fig4", "fig4 --kind conv", "fig-batch", "fig-batch --kind conv",
        "table3", "table3b", "table4", "table5", "table6",
        "fig5", "fig5 --kind conv", "fig6", "fig6 --kind conv", "headline",
    ] {
        b.bench(&format!("repro/{}", cmd.replace(" --kind ", "_").replace(" --", "_")), || {
            run(black_box(cmd))
        });
    }
    b.report("tables & figures regeneration");
}
