//! Benchmarks for the segmentation strategies, especially the exhaustive
//! profiled search (the paper's contribution) and its scaling with chain
//! length — the search space is C(l-1, s-1).

use std::time::Duration;

use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::model::synthetic::{conv_model, fc_model, fc_model_custom};
use tpu_pipeline::profiler::{best_partition, threshold_search, SegmentCostTable};
use tpu_pipeline::segment::strategy::Strategy;
use tpu_pipeline::util::bench::{black_box, Bencher};

fn main() {
    let cfg = SystemConfig::default();
    let mut b = Bencher::new().with_budget(Duration::from_millis(300), Duration::from_millis(80));

    let fc = fc_model(2100);
    let conv = conv_model(652);

    b.bench("cost_table/fc_5layers", || SegmentCostTable::build(black_box(&fc), &cfg));

    for s in [2usize, 3, 4] {
        b.bench(&format!("profiled_exhaustive/fc_5layers_s{s}"), || {
            best_partition(black_box(&fc), &cfg, s, 50)
        });
    }
    b.bench("profiled_exhaustive/conv_5layers_s4", || {
        best_partition(black_box(&conv), &cfg, 4, 50)
    });
    b.bench("threshold_search/fc_5layers_s3", || {
        threshold_search(black_box(&fc), &cfg, 3, 50, 1e-3)
    });

    // search-space scaling: 20-layer chain, s=4 -> C(19,3) = 969 partitions
    let deep = fc_model_custom(256, 20, 64, 10);
    b.bench("profiled_exhaustive/fc_20layers_s4_969parts", || {
        best_partition(black_box(&deep), &cfg, 4, 50)
    });

    b.bench("memory_balanced/fc_5layers_s3", || {
        Strategy::MemoryBalanced.partition(black_box(&fc), 3, &cfg)
    });

    b.report("segmentation");
}
