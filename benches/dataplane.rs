//! Benchmarks for the zero-copy batched data plane (DESIGN.md §12): the
//! 4-stage synthetic pipeline served batch-at-once through arena slabs
//! versus the retired per-request transfer granularity (one channel
//! handoff and one fresh allocation per request per stage — the PR 4
//! path, reproduced via `serve_batch_chunked(.., 1)` over backends that
//! only implement the per-item `run`).  Also times the batched channel
//! primitives (`send_many`/`recv_many_deadline`) against per-item
//! send/recv, and the arena's take/share/recycle cycle.
//!
//! The acceptance bar for the data-plane rework is the first two
//! scenarios: `pipeline4/batched_b50` must sustain at least 2x the
//! requests/sec of `pipeline4/per_request_b50`.  The binary prints the
//! measured ratio under the table, records both scenarios in
//! BENCH_dataplane.json for the CI regression gate, and **exits nonzero
//! below the bar** so the bench job fails if the batched path ever
//! regresses toward per-request cost.

use std::time::{Duration, Instant};

use anyhow::Result;
use tpu_pipeline::coordinator::queue::bounded;
use tpu_pipeline::coordinator::{
    Arena, BreakerConfig, Pipeline, PipelineConfig, ReplicaRouter, Request, StageBackend,
    StageFactory, StageSim, Tensor,
};
use tpu_pipeline::metrics::DataPlaneMetrics;
use tpu_pipeline::obs::{SpanKind, Tracer};
use tpu_pipeline::scheduler::{synthetic_transform, synthetic_transform_into};
use tpu_pipeline::util::bench::{black_box, Bencher};
use tpu_pipeline::util::rng::Rng;

const STAGES: usize = 4;
const ELEMS: usize = 256;
const BATCH: usize = 50;

/// Batch-native stage: one keyed mixing transform per item, written
/// directly into the output slab (zero allocations).
struct BatchedStage {
    salt: u64,
}

impl StageBackend for BatchedStage {
    fn run(&mut self, input: &[i8]) -> Result<Vec<i8>> {
        Ok(synthetic_transform(self.salt, input, input.len()))
    }

    fn run_batch(&mut self, n: usize, input: &[i8], output: &mut [i8]) -> Result<()> {
        let len = input.len() / n;
        for i in 0..n {
            synthetic_transform_into(
                self.salt,
                &input[i * len..(i + 1) * len],
                &mut output[i * len..(i + 1) * len],
            );
        }
        Ok(())
    }
}

/// Per-item stage: the same transform, but only through the allocating
/// `run` contract — the default `run_batch` copies each fresh vector into
/// the slab, mimicking the pre-arena per-request execution cost.
struct PerItemStage {
    salt: u64,
}

impl StageBackend for PerItemStage {
    fn run(&mut self, input: &[i8]) -> Result<Vec<i8>> {
        Ok(synthetic_transform(self.salt, input, input.len()))
    }
}

fn spawn_pipeline(batched: bool) -> Pipeline {
    let factories: Vec<StageFactory> = (0..STAGES)
        .map(|i| {
            let salt = 0x9E37_79B9 + i as u64;
            if batched {
                Box::new(move || {
                    Ok(Box::new(BatchedStage { salt }) as Box<dyn StageBackend>)
                }) as StageFactory
            } else {
                Box::new(move || {
                    Ok(Box::new(PerItemStage { salt }) as Box<dyn StageBackend>)
                }) as StageFactory
            }
        })
        .collect();
    let sims: Vec<StageSim> = (0..STAGES)
        .map(|_| StageSim { exec_s: 1e-7, hop_out_s: 1e-8, overhead_s: 1e-8 })
        .collect();
    Pipeline::spawn(factories, sims, &PipelineConfig::default()).unwrap()
}

fn requests() -> Vec<Request> {
    let mut rng = Rng::new(0xDA7A);
    (0..BATCH as u64).map(|id| Request::new(id, rng.i8_vec(ELEMS))).collect()
}

fn main() {
    // BENCH_QUICK shrinks the budget (the CI bench job's quick mode);
    // BENCH_JSON_DIR makes report() emit BENCH_dataplane.json for the
    // regression gate (scripts/bench_check.py, DESIGN.md §11)
    let mut b = Bencher::new()
        .with_budget(Duration::from_millis(250), Duration::from_millis(60))
        .quick_from_env();

    // fixed-work calibration scenario for machine-normalized regression
    // ratios (shared bit-identical loop, see Bencher::bench_calibration)
    b.bench_calibration();

    // ---- the headline pair: batched slabs vs per-request granularity
    let reqs = requests();
    let p_batched = spawn_pipeline(true);
    let p_legacy = spawn_pipeline(false);
    p_batched.wait_ready().unwrap();
    p_legacy.wait_ready().unwrap();
    // warm both arenas so the measurement sees steady state
    drop(p_batched.serve_batch(reqs.clone()).unwrap());
    drop(p_legacy.serve_batch_chunked(reqs.clone(), 1).unwrap());

    b.bench("pipeline4/batched_b50", || {
        p_batched.serve_batch(black_box(reqs.clone())).unwrap()
    });
    b.bench("pipeline4/per_request_b50", || {
        p_legacy.serve_batch_chunked(black_box(reqs.clone()), 1).unwrap()
    });

    // ---- channel primitives: whole-flush transfer vs per-item locking
    b.bench("queue/per_item_1k", || {
        let (tx, rx) = bounded(1024);
        for i in 0..1000u64 {
            tx.send(i).unwrap();
        }
        let mut n = 0usize;
        while rx.try_recv().is_some() {
            n += 1;
        }
        n
    });
    b.bench("queue/batched_1k", || {
        let (tx, rx) = bounded(1024);
        tx.send_many(0..1000u64).unwrap();
        let mut out = Vec::with_capacity(1000);
        rx.recv_many_deadline(Instant::now(), 1000, &mut out);
        out.len()
    });

    // ---- arena cycle: take -> share -> view -> recycle
    let arena = Arena::new(std::sync::Arc::new(DataPlaneMetrics::default()));
    drop(arena.take(BATCH * ELEMS)); // warm the size class
    b.bench("arena/take_share_recycle", || {
        let slab = arena.take(BATCH * ELEMS).share();
        Tensor::slice(&slab, 0, ELEMS)
    });

    // ---- reliability off-paths (DESIGN.md §17): deadline checks and the
    // replica watchdog ride the regression gate so their cost when *unused*
    // stays one branch.  `deadline_check/none_1k` is the per-handoff check
    // on deadline-free requests; `deadline_check/stamped_1k` the stamped
    // (unexpired) variant; the router pair measures a healthy 2-replica
    // dispatch with the breaker absent vs armed — the watchdog's off-path.
    let now = Instant::now();
    let free = Request::new(0, vec![0i8; 8]);
    let stamped = Request::new(1, vec![0i8; 8]).with_deadline(now + Duration::from_secs(3600));
    b.bench("deadline_check/none_1k", || {
        let mut n = 0u32;
        for _ in 0..1000 {
            if !black_box(&free).expired_at(now) {
                n += 1;
            }
        }
        n
    });
    b.bench("deadline_check/stamped_1k", || {
        let mut n = 0u32;
        for _ in 0..1000 {
            if !black_box(&stamped).expired_at(now) {
                n += 1;
            }
        }
        n
    });

    let no_breaker = ReplicaRouter::new(vec![spawn_pipeline(true), spawn_pipeline(true)]);
    let armed = ReplicaRouter::new(vec![spawn_pipeline(true), spawn_pipeline(true)])
        .with_breaker(BreakerConfig::default());
    drop(no_breaker.serve_batch(reqs.clone()).unwrap()); // warm the arenas
    drop(armed.serve_batch(reqs.clone()).unwrap());
    b.bench("router2/no_breaker_b50", || {
        no_breaker.serve_batch(black_box(reqs.clone())).unwrap()
    });
    b.bench("router2/breaker_healthy_b50", || {
        armed.serve_batch(black_box(reqs.clone())).unwrap()
    });

    // ---- tracer overhead (DESIGN.md §13): the disabled path must be one
    // branch on a None option; the enabled path one lock-free ring store
    // (degrading to the counted-drop path once the bounded ring fills —
    // the tracer's worst case, which is exactly the backstop this gate
    // wants cheap).  Both land in BENCH_dataplane.json so a regression
    // that puts allocation or locking on either path shows up in CI.
    let tracer = std::sync::Arc::new(Tracer::new());
    let sink = tracer.handle_with_capacity(1 << 16);
    let enabled: Option<(tpu_pipeline::obs::SpanSink, u32)> = Some((sink, 2));
    let disabled: Option<(tpu_pipeline::obs::SpanSink, u32)> = None;
    b.bench("obs/span_record_enabled_1k", || {
        for i in 0..1000u64 {
            if let Some((s, track)) = black_box(&enabled) {
                s.record(SpanKind::Stage, *track, i, i, 1);
            }
        }
    });
    b.bench("obs/span_record_disabled_1k", || {
        let mut n = 0u64;
        for i in 0..1000u64 {
            if let Some((s, track)) = black_box(&disabled) {
                s.record(SpanKind::Stage, *track, i, i, 1);
            } else {
                n += 1;
            }
        }
        n
    });

    b.report("dataplane");

    // the data-plane acceptance ratio, from the rows just measured
    let mean = |name: &str| {
        b.rows()
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.mean_s)
            .expect("scenario measured")
    };
    let batched = mean("pipeline4/batched_b50");
    let per_request = mean("pipeline4/per_request_b50");
    let ratio = per_request / batched;
    println!(
        "\nbatched data plane: {:.0} req/s vs {:.0} req/s per-request path -> {ratio:.2}x",
        BATCH as f64 / batched,
        BATCH as f64 / per_request,
    );

    p_batched.shutdown();
    p_legacy.shutdown();
    no_breaker.shutdown();
    armed.shutdown();

    // enforce the bar, not just print it: a regression below 2x fails the
    // bench binary (and therefore the CI bench job)
    if ratio < 2.0 {
        eprintln!("FAIL: batched data plane below the 2x acceptance bar ({ratio:.2}x)");
        std::process::exit(1);
    }
}
