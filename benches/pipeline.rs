//! Benchmarks for the discrete-event pipeline simulator — the inner loop
//! of the profiled partition search (it runs C(l-1,s-1) x batch x stages
//! times per sweep point).

use std::time::Duration;

use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::link::Link;
use tpu_pipeline::model::synthetic::fc_model;
use tpu_pipeline::pipeline::{simulate, simulate_partition, SimOptions, StageSpec};
use tpu_pipeline::segment::uniform_cuts;
use tpu_pipeline::util::bench::{black_box, Bencher};

fn main() {
    let cfg = SystemConfig::default();
    let link = Link::new(cfg.link.clone());
    let mut b = Bencher::new().with_budget(Duration::from_millis(300), Duration::from_millis(80));

    let stages: Vec<StageSpec> = (0..4)
        .map(|i| StageSpec { exec_s: 1e-3 * (i + 1) as f64, in_bytes: 4096, out_bytes: 4096 })
        .collect();

    for batch in [1usize, 50, 500] {
        b.bench(&format!("simulate/4stages_batch{batch}"), || {
            simulate(
                black_box(&stages),
                &link,
                &SimOptions { batch, queue_capacity: None, record_gantt: false },
            )
        });
    }
    b.bench("simulate/4stages_batch50_gantt", || {
        simulate(
            black_box(&stages),
            &link,
            &SimOptions { batch: 50, queue_capacity: None, record_gantt: true },
        )
    });
    b.bench("simulate/4stages_batch50_bounded2", || {
        simulate(
            black_box(&stages),
            &link,
            &SimOptions { batch: 50, queue_capacity: Some(2), record_gantt: false },
        )
    });

    let m = fc_model(2100);
    let part = uniform_cuts(5, 3);
    b.bench("simulate_partition/fc_n2100_3seg_batch50", || {
        simulate_partition(
            black_box(&m),
            &part,
            &cfg,
            &SimOptions { batch: 50, ..Default::default() },
        )
    });

    b.report("pipeline");
}
