//! Benchmarks for the TPU-pool allocator: candidate generation (the
//! per-model profiled search) and the full admission + placement auction,
//! swept over M models x N TPUs — the scheduler runs on every
//! registration change, so replanning latency matters for a serving
//! control plane.

use std::time::Duration;

use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::scheduler::{
    allocate, candidates_for, AllocatorConfig, ModelRegistry,
};
use tpu_pipeline::util::bench::{black_box, Bencher};

const MODEL_POOL: [&str; 6] = ["fc_small", "fc_big", "fc_huge", "conv_a", "conv_b", "pyramid"];

fn registry(m: usize) -> ModelRegistry {
    let mut reg = ModelRegistry::new();
    for name in MODEL_POOL.iter().take(m) {
        reg.register_named(name).unwrap();
    }
    reg
}

fn main() {
    let cfg = SystemConfig::default();
    // BENCH_QUICK shrinks the budget (the CI bench job's quick mode);
    // BENCH_JSON_DIR makes report() emit BENCH_scheduler.json for the
    // regression gate (scripts/bench_check.py, DESIGN.md §11)
    let mut b = Bencher::new()
        .with_budget(Duration::from_millis(250), Duration::from_millis(60))
        .quick_from_env();

    // fixed-work calibration scenario: bench_check.py divides every
    // scenario by it so the regression gate compares machine-normalized
    // ratios, not absolute wall times
    b.bench_calibration();

    // per-model candidate search (placement + profiled simulation)
    for name in ["fc_small", "fc_huge", "conv_b"] {
        let model = tpu_pipeline::scheduler::resolve_model(name).unwrap();
        let alloc = AllocatorConfig::default();
        b.bench(&format!("candidates/{name}"), || {
            candidates_for(black_box(&model), &cfg, &alloc)
        });
    }

    // full pool auction: M models x N TPUs
    for m in [1usize, 2, 4, 6] {
        let reg = registry(m);
        for n in [2usize, 4, 8] {
            let alloc = AllocatorConfig { total_tpus: n, ..Default::default() };
            b.bench(&format!("allocate/m{m}_n{n}"), || {
                allocate(black_box(&reg), &cfg, &alloc).unwrap()
            });
        }
    }

    // the unified sharing-aware search: per-device slices widen the
    // branching factor, so its replanning latency is tracked separately
    for m in [2usize, 4] {
        let reg = registry(m);
        let alloc = AllocatorConfig {
            total_tpus: 4,
            allow_sharing: true,
            ..Default::default()
        };
        b.bench(&format!("allocate_sharing/m{m}_n4"), || {
            allocate(black_box(&reg), &cfg, &alloc).unwrap()
        });
    }

    // cache-aware placement: the parameter-cache budget adds warm/cold
    // pricing and the post-placement co-residency packing pass on top of
    // the sharing search, so its extra cost is tracked against the flat
    // allocate_sharing scenarios above
    for m in [2usize, 4] {
        let reg = registry(m);
        let alloc = AllocatorConfig {
            total_tpus: 4,
            allow_sharing: true,
            cache_budget_bytes: 64 << 20,
            prefetch: true,
            ..Default::default()
        };
        b.bench(&format!("allocate_cache/m{m}_n4"), || {
            allocate(black_box(&reg), &cfg, &alloc).unwrap()
        });
    }

    // online calibration: the detector arithmetic alone (per-window cost
    // of the live calibrate_tick, minus the re-plan), and the full
    // closed-loop simulation including the drift-triggered re-plans
    {
        use tpu_pipeline::scheduler::{CalibrateConfig, CalibrateScenario, Calibrator};

        b.bench("calibrate/end_window_m4", || {
            let mut cal = Calibrator::new(CalibrateConfig::default());
            for w in 0..4u64 {
                for name in ["fc_small", "fc_big", "conv_a", "conv_b"] {
                    for i in 0..64u64 {
                        // seeded spread across histogram buckets
                        let lat = 1e-3 * (1.0 + ((w * 64 + i) % 7) as f64 * 0.1);
                        cal.observe(name, black_box(lat));
                    }
                }
                black_box(cal.end_window());
            }
            cal.window()
        });

        let reg = registry(2);
        let alloc = AllocatorConfig { total_tpus: 4, ..Default::default() };
        let mut drifting = CalibrateScenario::new(11);
        drifting.drifted = vec!["fc_small".to_string()];
        for (label, scenario) in
            [("steady", CalibrateScenario::new(11)), ("drift", drifting)]
        {
            b.bench(&format!("calibrate/sim_{label}_m2_n4"), || {
                tpu_pipeline::scheduler::simulate_calibration(
                    black_box(&reg),
                    &cfg,
                    &alloc,
                    &scenario,
                )
                .unwrap()
            });
        }
    }

    b.report("scheduler");
}
