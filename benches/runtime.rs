//! Benchmarks for the PJRT hot path: artifact load/compile (startup cost)
//! and per-inference execution (the L3 serving inner loop).
//!
//! Requires `make artifacts`; prints a notice and exits cleanly otherwise.

use std::time::Duration;

use tpu_pipeline::runtime::{run_chain, TpuRuntime};
use tpu_pipeline::serving::default_artifact_dir;
use tpu_pipeline::util::bench::{black_box, Bencher};
use tpu_pipeline::util::rng::Rng;

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("runtime bench skipped: no artifacts at {dir:?} (run `make artifacts`)");
        return;
    }
    let rt = TpuRuntime::new(&dir).expect("PJRT CPU client");
    let manifest = rt.manifest().unwrap();
    let mut b = Bencher::new().with_budget(Duration::from_millis(400), Duration::from_millis(100));

    let entry = manifest.model("fc_n256").unwrap();
    let seg_meta = entry.segment(0, 5).unwrap();
    b.bench("compile/fc_n256_whole", || rt.load_segment(black_box(seg_meta)).unwrap());

    let whole = rt.load_segment(seg_meta).unwrap();
    let mut rng = Rng::new(5);
    let input = rng.i8_vec(64);
    b.bench("execute/fc_n256_whole", || whole.run(black_box(&input)).unwrap());

    let big = manifest.model("fc_n512").unwrap();
    let big_whole = rt.load_segment(big.segment(0, 5).unwrap()).unwrap();
    b.bench("execute/fc_n512_whole", || big_whole.run(black_box(&input)).unwrap());

    let segs: Vec<_> = big
        .segments_for_cuts(&[1, 2, 3])
        .unwrap()
        .into_iter()
        .map(|s| rt.load_segment(s).unwrap())
        .collect();
    b.bench("execute/fc_n512_4seg_chain", || run_chain(black_box(&segs), &input).unwrap());

    let conv = manifest.model("conv_f32").unwrap();
    let conv_whole = rt.load_segment(conv.segment(0, 5).unwrap()).unwrap();
    let conv_input = rng.i8_vec(32 * 32 * 3);
    b.bench("execute/conv_f32_whole", || conv_whole.run(black_box(&conv_input)).unwrap());

    b.report("runtime (PJRT CPU)");
}
