//! Benchmarks for the compiler placement model and memory reports
//! (the substrate behind Tables I–VI).

use std::time::Duration;

use tpu_pipeline::compiler::{place, place_partition};
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::model::synthetic::{conv_model, fc_model};
use tpu_pipeline::segment::uniform_cuts;
use tpu_pipeline::util::bench::{black_box, Bencher};

fn main() {
    let cfg = SystemConfig::default();
    let mut b = Bencher::new().with_budget(Duration::from_millis(300), Duration::from_millis(80));

    let fc = fc_model(2100);
    let conv = conv_model(652);
    b.bench("place/fc_n2100", || place(black_box(&fc.layers), &cfg.device));
    b.bench("place/conv_f652", || place(black_box(&conv.layers), &cfg.device));

    let part = uniform_cuts(5, 4);
    b.bench("place_partition/fc_4seg", || {
        let segs = part.segments(&fc);
        place_partition(black_box(&segs), &cfg.device)
    });

    // a long-chain model (placement is O(L))
    let deep = tpu_pipeline::model::synthetic::fc_model_custom(512, 64, 64, 10);
    b.bench("place/fc_deep_64layers", || place(black_box(&deep.layers), &cfg.device));

    b.bench("sweep/single_tpu_fc_full_grid", || {
        tpu_pipeline::sweep::single_tpu_sweep(tpu_pipeline::sweep::Kind::Fc, &cfg)
    });

    b.report("placement");
}
