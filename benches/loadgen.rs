//! Benchmarks for the open-loop workload path: seeded arrival-schedule
//! generation and the deterministic batcher+pipeline queueing simulation
//! that `repro loadgen` reports — this runs on every loadgen invocation
//! and inside tests, so its cost at realistic request counts matters.

use std::time::Duration;

use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::coordinator::batcher::BatchPolicy;
use tpu_pipeline::scheduler::resolve_model;
use tpu_pipeline::segment::strategy::Strategy;
use tpu_pipeline::serving::stage_sims;
use tpu_pipeline::util::bench::{black_box, Bencher};
use tpu_pipeline::workload::{arrival_times, simulate_open_loop, Arrivals};

fn main() {
    let cfg = SystemConfig::default();
    let mut b = Bencher::new().with_budget(Duration::from_millis(250), Duration::from_millis(60));

    // seeded schedule generation
    let poisson = Arrivals::Poisson { rate_hz: 1000.0 };
    b.bench("arrivals/poisson_10k", || arrival_times(black_box(&poisson), 10_000, 7));
    let bursty = Arrivals::Bursty { rate_hz: 2000.0, on_s: 0.05, off_s: 0.05 };
    b.bench("arrivals/bursty_10k", || arrival_times(black_box(&bursty), 10_000, 7));

    // open-loop queueing sim over a real planned partition
    let model = resolve_model("fc_small").unwrap();
    let partition = Strategy::Uniform.partition(&model, 2, &cfg);
    let sims = stage_sims(&model, &partition, &cfg);
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) };
    for (name, arrivals) in [
        ("poisson", Arrivals::Poisson { rate_hz: 800.0 }),
        ("bursty", Arrivals::Bursty { rate_hz: 1600.0, on_s: 0.02, off_s: 0.02 }),
        ("closed", Arrivals::Closed { concurrency: 8, think_s: 1e-4 }),
    ] {
        b.bench(&format!("open_loop_sim/{name}_2k"), || {
            simulate_open_loop(black_box(&arrivals), 2000, 7, &policy, &sims)
        });
    }

    b.report("loadgen");
}
