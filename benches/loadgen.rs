//! Benchmarks for the open-loop workload path: seeded arrival-schedule
//! generation and the deterministic batcher+pipeline queueing simulation
//! that `repro loadgen` reports — this runs on every loadgen invocation
//! and inside tests, so its cost at realistic request counts matters.

use std::time::Duration;

use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::coordinator::batcher::BatchPolicy;
use tpu_pipeline::scheduler::resolve_model;
use tpu_pipeline::segment::strategy::Strategy;
use tpu_pipeline::serving::stage_sims;
use tpu_pipeline::util::bench::{black_box, Bencher};
use tpu_pipeline::workload::{
    arrival_times, simulate_deployment, simulate_open_loop, Arrivals, DeploymentSim,
};

fn main() {
    let cfg = SystemConfig::default();
    // BENCH_QUICK shrinks the budget (the CI bench job's quick mode);
    // BENCH_JSON_DIR makes report() emit BENCH_loadgen.json for the
    // regression gate (scripts/bench_check.py, DESIGN.md §11)
    let mut b = Bencher::new()
        .with_budget(Duration::from_millis(250), Duration::from_millis(60))
        .quick_from_env();

    // fixed-work calibration scenario for machine-normalized regression
    // ratios (Bencher::bench_calibration keeps both binaries' loops
    // bit-identical)
    b.bench_calibration();

    // seeded schedule generation
    let poisson = Arrivals::Poisson { rate_hz: 1000.0 };
    b.bench("arrivals/poisson_10k", || arrival_times(black_box(&poisson), 10_000, 7));
    let bursty = Arrivals::Bursty { rate_hz: 2000.0, on_s: 0.05, off_s: 0.05 };
    b.bench("arrivals/bursty_10k", || arrival_times(black_box(&bursty), 10_000, 7));

    // open-loop queueing sim over a real planned partition
    let model = resolve_model("fc_small").unwrap();
    let partition = Strategy::Uniform.partition(&model, 2, &cfg);
    let sims = stage_sims(&model, &partition, &cfg);
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) };
    for (name, arrivals) in [
        ("poisson", Arrivals::Poisson { rate_hz: 800.0 }),
        ("bursty", Arrivals::Bursty { rate_hz: 1600.0, on_s: 0.02, off_s: 0.02 }),
        ("closed", Arrivals::Closed { concurrency: 8, think_s: 1e-4 }),
    ] {
        b.bench(&format!("open_loop_sim/{name}_2k"), || {
            simulate_open_loop(black_box(&arrivals), 2000, 7, &policy, &sims)
        });
    }

    // time-shared deployment with quantum-gated swap accounting (the
    // sharing path `repro loadgen --allow-sharing --quantum-us` takes)
    let dilated: Vec<_> = stage_sims(&model, &partition, &cfg)
        .into_iter()
        .map(|mut s| {
            s.exec_s *= 2.0;
            s
        })
        .collect();
    for (name, quantum_s) in [("per_flush", 0.0), ("quantum_5ms", 5e-3)] {
        let dep = DeploymentSim {
            sims: dilated.clone(),
            replicas: 1,
            switch_s: vec![2e-3; dilated.len()],
            quantum_s,
        };
        b.bench(&format!("shared_sim/{name}_2k"), || {
            simulate_deployment(
                black_box(&Arrivals::Poisson { rate_hz: 800.0 }),
                2000,
                7,
                &policy,
                &dep,
            )
        });
    }

    b.report("loadgen");
}
