#!/usr/bin/env python3
"""Gate a BENCH_*.json bench report against its checked-in baseline.

Usage: bench_check.py CURRENT_JSON BASELINE_JSON [--threshold 1.25]

The JSON schema (DESIGN.md §11) is emitted by the in-repo bench harness
(`util::bench::Bencher::report` with BENCH_JSON_DIR set):

    {
      "bench": "scheduler",
      "quick": true,
      "scenarios": {
        "allocate/m2_n4": {"iters": 123, "mean_s": 1.2e-3, "p50_s": ...,
                           "p95_s": ..., "min_s": ...}
      }
    }

For every scenario present in the baseline, the gate fails when the
current mean is more than THRESHOLD times the baseline mean.  When both
documents carry a `calibration/...` scenario (fixed PRNG work), the
ratio is machine-normalized by the calibration ratio first, so a slower
CI runner does not raise false regressions.

An empty baseline (`"scenarios": {}`) is the bootstrap state: if
`--fallback` names a readable, non-empty report (the CI bench job passes
the previous run's artifacts restored from cache — a *rolling* baseline),
the gate compares against that instead; otherwise it deactivates.  The
rolling mode is advisory about coverage: scenarios missing from the
current run are notes, not failures (a rename would otherwise fail once
per rename).  Against the checked-in baseline, scenarios present only in
the current run are reported as notes (new benchmarks) and scenarios
present only in the baseline are failures (a benchmark silently
disappeared).
"""

import argparse
import json
import os
import sys

CALIBRATION_PREFIX = "calibration/"


def load(path):
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_check: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def calibration_mean(scenarios):
    for name, row in scenarios.items():
        if name.startswith(CALIBRATION_PREFIX):
            return row["mean_s"]
    return None


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="freshly generated BENCH_*.json")
    ap.add_argument("baseline", help="checked-in baseline BENCH_*.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=1.25,
        help="fail when current/baseline mean exceeds this (default 1.25 = +25%%)",
    )
    ap.add_argument(
        "--fallback",
        default=None,
        help="rolling baseline (previous run's report) used when the checked-in "
        "baseline has no scenarios",
    )
    args = ap.parse_args()

    current = load(args.current)
    baseline = load(args.baseline)
    cur_sc = current.get("scenarios", {})
    base_sc = baseline.get("scenarios", {})
    rolling = False

    if not base_sc and args.fallback and os.path.exists(args.fallback):
        # a corrupt/truncated rolling baseline (e.g. an interrupted cache
        # save) must deactivate the gate like a missing one, not wedge CI
        try:
            with open(args.fallback, "r", encoding="utf-8") as f:
                fb = json.load(f)
        except (OSError, ValueError) as e:
            print(f"bench_check: unreadable rolling baseline {args.fallback}: {e}")
            fb = {}
        fb_sc = fb.get("scenarios", {})
        if fb_sc:
            print(
                f"bench_check: rolling-only mode (checked-in baseline "
                f"{args.baseline} is the empty bootstrap) — gating against the "
                f"rolling baseline {args.fallback}; run `make bench-baseline` "
                "on the reference runner and commit the result to arm the "
                "absolute pin"
            )
            base_sc = fb_sc
            rolling = True

    if not base_sc:
        print(
            f"bench_check: rolling-only mode with no rolling baseline either — "
            f"regression gate INACTIVE ({args.baseline} is the empty bootstrap; "
            "populate it with `make bench-baseline` on the reference runner, "
            "or let the CI rolling baseline accumulate from the next run)"
        )
        return 0

    cur_cal = calibration_mean(cur_sc)
    base_cal = calibration_mean(base_sc)
    normalized = bool(cur_cal and base_cal)

    failures = []
    checked = 0
    for name in sorted(base_sc):
        if name.startswith(CALIBRATION_PREFIX):
            continue
        brow = base_sc[name]
        crow = cur_sc.get(name)
        if crow is None:
            if rolling:
                print(f"note: {name} was in the previous run but not this one")
            else:
                failures.append(f"{name}: in the baseline but missing from the current run")
            continue
        ratio = crow["mean_s"] / brow["mean_s"]
        if normalized:
            ratio /= cur_cal / base_cal
        checked += 1
        tag = " (machine-normalized)" if normalized else ""
        if ratio > args.threshold:
            failures.append(
                f"{name}: {ratio:.2f}x slower than baseline{tag} "
                f"({crow['mean_s']:.3e}s vs {brow['mean_s']:.3e}s)"
            )
        else:
            print(f"ok {name}: {ratio:.2f}x{tag}")

    for name in sorted(set(cur_sc) - set(base_sc)):
        if not name.startswith(CALIBRATION_PREFIX):
            print(f"note: {name} has no baseline entry (refresh with `make bench-baseline`)")

    if failures:
        print(
            f"bench_check: {len(failures)} regression(s) past {args.threshold:.2f}x:",
            file=sys.stderr,
        )
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"bench_check: {checked} scenario(s) within {args.threshold:.2f}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
