//! In-tree shim for the subset of the `anyhow` API this workspace uses
//! (the offline vendor set carries no crates.io closure).
//!
//! Supported surface:
//!
//! * [`Error`] — message + context chain; `Display` shows the outermost
//!   context, `{:#}` (alternate) shows the whole chain joined by `": "`,
//!   `Debug` shows the chain as a `Caused by:` list, like real anyhow.
//! * [`Result<T>`] with the `E = Error` default.
//! * [`Context::context`] / [`Context::with_context`] on `Result` (any
//!   std-error or `anyhow::Error` payload) and on `Option`.
//! * `anyhow!`, `bail!`, `ensure!` macros (format-string forms).
//! * `From<E: std::error::Error + Send + Sync + 'static>` so `?` converts
//!   std errors automatically.

use std::fmt;

/// The error type: an outermost message plus the chain of causes it wraps
/// (outermost first, original error last).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, outermost first
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        // keep the std error's own source chain visible
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` with the error defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Conversion into [`Error`] — implemented for std errors and for
/// [`Error`] itself so `.context(..)` works on both kinds of `Result`.
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Attach context to errors, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoError> Context<T> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::Error::msg(::std::format!($($arg)*)))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::format!(
                "condition failed: {}",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::format!($($arg)+)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Err::<(), _>(io_err()).context("loading manifest").unwrap_err();
        assert_eq!(e.to_string(), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: file missing");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("inner").context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer"));
        assert!(d.contains("Caused by"));
        assert!(d.contains("inner"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "file missing");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(Error::msg("boom"));
        let e = r.context("stage 2").unwrap_err();
        assert_eq!(format!("{e:#}"), "stage 2: boom");
        let o: Option<u32> = None;
        assert_eq!(o.with_context(|| "empty").unwrap_err().to_string(), "empty");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Err(anyhow!("fell through with {x}"))
        }
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(1).unwrap_err().to_string(), "fell through with 1");
    }
}
