//! Offline **stub** of the `xla-rs` PJRT binding surface used by
//! `tpu_pipeline::runtime`.
//!
//! The build container carries no XLA/PJRT native libraries, so every
//! entry point that would touch the native runtime returns
//! [`Error::Unavailable`] at *runtime* (construction of [`PjRtClient`]
//! fails first).  The rest of the workspace is built to degrade cleanly:
//!
//! * `rust/tests/integration_{runtime,serving}.rs` skip when the artifact
//!   directory is absent (`make artifacts` needs the real toolchain).
//! * The multi-tenant scheduler serves real traffic through its synthetic
//!   native stage backend (`scheduler::router`), which never touches PJRT.
//!
//! Swapping this stub for the real `xla` crate (same API subset) restores
//! the hardware-backed path without any change to `tpu_pipeline`.

use std::fmt;

/// Stub error: always reports the native runtime as missing.
#[derive(Debug, Clone)]
pub enum Error {
    /// The PJRT native library is not part of this build.
    Unavailable(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT native runtime not available in this build \
                 (offline xla stub; link the real xla crate to enable it)"
            ),
        }
    }
}

impl std::error::Error for Error {}

/// Stub result alias mirroring `xla::Result`.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error::Unavailable(what.to_string()))
}

/// Element types of XLA literals (only the subset the workspace names).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    S8,
    U8,
    S32,
    F32,
}

/// Stub PJRT client — construction always fails.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real binding spawns a CPU PJRT client; the stub reports the
    /// runtime as unavailable so callers fail fast with a clear message.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("creating PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling computation")
    }
}

/// Stub HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("parsing HLO text")
    }
}

/// Stub XLA computation.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Stub loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Mirrors `execute::<Literal>(&[..])` returning per-device, per-output
    /// buffers; the stub can never be reached with a live executable, but
    /// keeps the call sites type-checking.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing segment")
    }
}

/// Stub device buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("fetching result buffer")
    }
}

/// Stub host literal.
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("building literal")
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("unpacking 1-tuple literal")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("reading literal data")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("PJRT native runtime not available"), "{msg}");
        assert!(msg.contains("creating PJRT CPU client"), "{msg}");
    }

    #[test]
    fn literal_construction_is_stubbed() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::S8, &[4], &[0; 4])
            .is_err());
    }
}
