//! Regenerate every figure/table of the paper as CSV files (for plotting)
//! plus a human-readable summary — the batch version of the `repro` CLI.
//!
//! Run: `cargo run --release --example sweep_figures [out_dir]`
//! Writes: out/fig2a_fc.csv, out/fig2a_conv.csv, ... out/table6.csv

use std::fs;
use std::path::PathBuf;

use tpu_pipeline::cli::{self, Args};
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::segment::strategy::Strategy;
use tpu_pipeline::sweep::{headline, Kind};

fn main() -> anyhow::Result<()> {
    let out_dir = std::env::args().nth(1).map(PathBuf::from).unwrap_or_else(|| "out".into());
    fs::create_dir_all(&out_dir)?;
    let cfg = SystemConfig::default();

    let csv_cmds: &[(&str, &str)] = &[
        ("fig2a_fc", "fig2a --kind fc --csv"),
        ("fig2a_conv", "fig2a --kind conv --csv"),
        ("fig2b_fc", "fig2b --kind fc --csv"),
        ("fig2b_conv", "fig2b --kind conv --csv"),
        ("fig2c_fc", "fig2c --kind fc --csv"),
        ("fig2c_conv", "fig2c --kind conv --csv"),
        ("table1", "table1 --csv"),
        ("table2", "table2 --csv"),
        ("fig4_fc", "fig4 --kind fc --csv"),
        ("fig4_conv", "fig4 --kind conv --csv"),
        ("figbatch_fc", "fig-batch --kind fc --csv"),
        ("figbatch_conv", "fig-batch --kind conv --csv"),
        ("table3", "table3 --csv"),
        ("table3b", "table3b --csv"),
        ("table4", "table4 --csv"),
        ("table5", "table5 --csv"),
        ("table6", "table6 --csv"),
        ("fig5_fc", "fig5 --kind fc --csv"),
        ("fig5_conv", "fig5 --kind conv --csv"),
        ("fig6_fc", "fig6 --kind fc --csv"),
        ("fig6_conv", "fig6 --kind conv --csv"),
    ];
    for (name, cmd) in csv_cmds {
        let argv: Vec<String> = cmd.split_whitespace().map(String::from).collect();
        let out = cli::run(&Args::parse(&argv)?)?;
        let path = out_dir.join(format!("{name}.csv"));
        fs::write(&path, &out)?;
        println!("wrote {} ({} rows)", path.display(), out.lines().count() - 1);
    }

    println!("\nheadline speedups vs 1 TPU (batch 50):");
    for kind in [Kind::Fc, Kind::Conv] {
        for (name, strat) in [
            ("default ", Strategy::Uniform),
            ("profiled", Strategy::ProfiledExhaustive { batch: 50 }),
        ] {
            let h = headline(kind, &cfg, strat, 50);
            println!(
                "  {:4} {name}: {:5.1}x (at x={}, {} TPUs)  [paper: {}]",
                kind.label(),
                h.best_speedup,
                h.at_x,
                h.n_tpus,
                match (kind, name.trim()) {
                    (Kind::Fc, "default") => "36x",
                    (Kind::Fc, "profiled") => "46x",
                    (Kind::Conv, "profiled") => "6x",
                    _ => "n/a",
                }
            );
        }
    }
    Ok(())
}
