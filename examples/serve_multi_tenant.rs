//! END-TO-END DRIVER for the multi-tenant TPU-pool scheduler: register
//! several models with different memory footprints and weights, let the
//! allocator pick per-model `(tpu_count, strategy)` under memory-aware
//! admission, deploy one pipeline (or replica set) per admitted model,
//! and serve **interleaved traffic for all tenants concurrently** through
//! the per-model router.
//!
//! Stages run on the deterministic native backend (no artifacts / PJRT
//! needed); every response is verified bit-for-bit against the tenant's
//! serial reference, so routing, ordering, or cross-tenant isolation bugs
//! fail loudly.
//!
//! Three scenarios:
//!  * a mixed pool where `fc_big` (spills a single TPU) must take two
//!    TPUs while both conv tenants fit one each — exactly a 4-TPU pool;
//!  * a weighted, oversubscribed pool where admission control queues the
//!    lightest tenant;
//!  * a single small tenant on a 3-TPU pool, where leftover TPUs become
//!    data-parallel replicas behind a `ReplicaRouter`.
//!
//! Run: `cargo run --release --example serve_multi_tenant`

use anyhow::Result;
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::scheduler::{
    allocate, plan_table, AllocatorConfig, BackendKind, DeployOptions, ModelRegistry, PoolRouter,
    Tenant,
};
use tpu_pipeline::serving;
use tpu_pipeline::util::fmt_seconds;

fn main() -> Result<()> {
    let cfg = SystemConfig::default();

    println!("=== scenario 1: mixed pool, 3 tenants on 4 TPUs ===");
    let mut registry = ModelRegistry::new();
    registry.register_named("fc_big")?; // spills 1 TPU -> needs 2
    registry.register_named("conv_a")?; // fits 1 TPU
    registry.register_named("conv_b")?; // fits 1 TPU
    run_pool(&registry, &cfg, AllocatorConfig { total_tpus: 4, ..Default::default() }, 40)?;

    println!("\n=== scenario 2: oversubscribed weighted pool (admission queues one) ===");
    let mut registry = ModelRegistry::new();
    registry.register(
        Tenant::new("fc_huge", tpu_pipeline::scheduler::resolve_model("fc_huge")?)
            .with_weight(5.0)
            .with_slo_p99_s(0.1),
    )?;
    registry.register_named("conv_big")?; // needs 4 TPUs -> loses the auction
    registry.register_named("fc_small")?;
    run_pool(&registry, &cfg, AllocatorConfig { total_tpus: 4, ..Default::default() }, 40)?;

    println!("\n=== scenario 3: leftover TPUs become replicas (ReplicaRouter) ===");
    let mut registry = ModelRegistry::new();
    registry.register_named("fc_small")?;
    run_pool(&registry, &cfg, AllocatorConfig { total_tpus: 3, ..Default::default() }, 60)?;

    Ok(())
}

fn run_pool(
    registry: &ModelRegistry,
    cfg: &SystemConfig,
    alloc: AllocatorConfig,
    batch: usize,
) -> Result<()> {
    let plan = allocate(registry, cfg, &alloc)?;
    print!("{}", plan_table(&plan).render());
    assert!(!plan.assignments.is_empty(), "nothing admitted");

    let router = PoolRouter::deploy(
        &plan,
        registry,
        cfg,
        &BackendKind::Synthetic,
        DeployOptions::new().with_queue_capacity(64),
    )?;
    let reports = serving::serve_pool(&router, batch, 0xFEED, true)?;

    println!("served {} tenant(s) x {batch} interleaved requests:", reports.len());
    for r in &reports {
        assert!(r.verified, "{}: responses must be verified", r.name);
        println!(
            "  {:10} {} TPU(s) x{} [{}]: wall {} | {:>7.0} inf/s | sim p99 {} (predicted {})",
            r.name,
            r.tpu_count,
            r.replicas,
            r.partition_label,
            fmt_seconds(r.wall_s),
            r.real_throughput,
            fmt_seconds(r.sim_p99_s),
            fmt_seconds(r.predicted_p99_s),
        );
    }
    for t in router.tenants() {
        let s = t.metrics.snapshot();
        assert_eq!(s.completed, batch as u64, "{}: all requests must complete", t.name);
        assert_eq!(s.errors, 0, "{}: no errors expected", t.name);
        println!(
            "  {:10} per-tenant metrics: submitted {} completed {} | real p50 {} p99 {}",
            t.name,
            s.submitted,
            s.completed,
            fmt_seconds(s.real_p50_s),
            fmt_seconds(s.real_p99_s),
        );
    }
    let s = router.metrics.snapshot();
    println!(
        "  scheduler counters: admitted {} queued {} rejected {} | routed {} requests",
        s.admitted, s.queued, s.rejected, s.routed_requests
    );
    router.shutdown();
    Ok(())
}
