//! Profiled segmentation under the microscope (paper §V-C): exhaustively
//! profile every contiguous partition of one model, print the full ranking
//! with per-stage times and memory placement, and draw the pipeline
//! schedule of the default vs the winning split.
//!
//! Run: `cargo run --release --example profile_partitions [fc_n|conv_f] [x] [tpus]`
//! e.g.: `cargo run --release --example profile_partitions conv_f 652 4`

use tpu_pipeline::compiler::place;
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::model::synthetic::{conv_model, fc_model};
use tpu_pipeline::pipeline::{simulate_partition, SimOptions};
use tpu_pipeline::profiler::{exhaustive_search, profile_partition, SegmentCostTable};
use tpu_pipeline::report::Table;
use tpu_pipeline::segment::uniform_cuts;
use tpu_pipeline::trace::gantt_ascii;
use tpu_pipeline::util::fmt_seconds;

fn main() {
    let mut args = std::env::args().skip(1);
    let family = args.next().unwrap_or_else(|| "fc_n".into());
    let x: u64 = args.next().and_then(|v| v.parse().ok()).unwrap_or(2100);
    let tpus: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(3);
    let batch = 50;

    let model = match family.as_str() {
        "conv_f" => conv_model(x),
        _ => fc_model(x),
    };
    let cfg = SystemConfig::default();
    println!(
        "profiling all partitions of {} ({} layers) into {} segments, batch {}\n",
        model.name,
        model.len(),
        tpus,
        batch
    );

    let profiles = exhaustive_search(&model, &cfg, tpus, batch);
    let mut t = Table::new(
        "partition ranking (best first)",
        &["split", "per-inf", "single-input", "stage-times", "host?", "delta"],
    );
    for p in &profiles {
        t.row(vec![
            p.partition.label(),
            fmt_seconds(p.per_item_s),
            fmt_seconds(p.single_latency_s),
            p.stage_exec_s.iter().map(|&e| fmt_seconds(e)).collect::<Vec<_>>().join(" "),
            if p.uses_host { "HOST".into() } else { "-".into() },
            fmt_seconds(p.stage_delta_s()),
        ]);
    }
    print!("{}", t.render());

    // memory placement of default vs best
    let table = SegmentCostTable::build(&model, &cfg);
    let default = uniform_cuts(model.len(), tpus);
    let default_prof = profile_partition(&model, &table, &default, &cfg, batch);
    let best = &profiles[0];
    for (name, p) in [("default", &default_prof), ("best", best)] {
        println!("\n{name} split {}:", p.partition.label());
        for (i, (a, b)) in p.partition.bounds().iter().enumerate() {
            let placement = place(&model.layers[*a..*b], &cfg.device);
            println!(
                "  TPU{i} layers [{a},{b}): device {:.2} MiB, host {:.2} MiB",
                placement.device_mib(),
                placement.host_mib()
            );
        }
    }

    // schedules
    for (name, part) in [("default", &default), ("best", &best.partition)] {
        let r = simulate_partition(
            &model,
            part,
            &cfg,
            &SimOptions { batch: 8, queue_capacity: None, record_gantt: true },
        );
        println!("\n{name} split {} pipeline schedule (batch 8):", part.label());
        print!("{}", gantt_ascii(&r, 100));
    }
}
