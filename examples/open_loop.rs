//! END-TO-END DRIVER for open-loop multi-tenant serving: seeded arrival
//! processes feed per-tenant ingress queues and dynamic batchers, while a
//! third tenant registers on the **live** pool mid-run — an online
//! re-plan that drains only affected deployments and never drops an
//! accepted request.
//!
//! Every response is verified bit-for-bit against the serial synthetic
//! reference; the per-layer keyed transforms make that reference
//! partition-invariant, so verification stays valid across re-plans.
//!
//! Run: `cargo run --release --example open_loop`

use anyhow::Result;
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::scheduler::{
    resolve_model, AllocatorConfig, BackendKind, DeployOptions, ModelRegistry, ServingPool,
    Tenant,
};
use tpu_pipeline::serving;
use tpu_pipeline::workload::{Arrivals, TenantLoad};

fn main() -> Result<()> {
    let mut registry = ModelRegistry::new();
    registry.register_named("fc_small")?;
    registry.register_named("conv_a")?;
    let pool = ServingPool::deploy(
        registry,
        SystemConfig::default(),
        AllocatorConfig { total_tpus: 4, replicate_leftover: false, ..Default::default() },
        BackendKind::Synthetic,
        DeployOptions::default(),
    )?;
    println!("deployed open-loop pool: {:?}", pool.names());

    let loads = vec![
        TenantLoad {
            model: "fc_small".into(),
            arrivals: Arrivals::Poisson { rate_hz: 1500.0 },
            requests: 300,
        },
        TenantLoad {
            model: "conv_a".into(),
            arrivals: Arrivals::Bursty { rate_hz: 2000.0, on_s: 0.02, off_s: 0.02 },
            requests: 300,
        },
    ];

    let mut reports = Vec::new();
    std::thread::scope(|scope| -> Result<()> {
        let driver = {
            let pool = &pool;
            let loads = &loads;
            scope.spawn(move || serving::serve_open_loop(pool, loads, 7, true))
        };
        let churn = {
            let pool = &pool;
            scope.spawn(move || -> Result<()> {
                // register a third tenant while traffic is flowing
                std::thread::sleep(std::time::Duration::from_millis(40));
                let report = pool.register(Tenant::new("conv_b", resolve_model("conv_b")?))?;
                println!(
                    "mid-run register conv_b: re-plan drained {} deployment(s), admitted {:?}",
                    report.drained, report.admitted
                );
                // the newcomer serves (and verifies) immediately
                let client = pool.client("conv_b")?;
                let reqs = client.synth_requests(20, 99);
                let expected: Vec<Vec<i8>> =
                    reqs.iter().map(|r| client.reference(&r.data)).collect();
                for r in reqs {
                    pool.submit("conv_b", r)?;
                }
                for _ in 0..20 {
                    let r = client.done.recv().expect("conv_b stream closed early");
                    assert_eq!(r.data, expected[r.id as usize], "conv_b digest mismatch");
                }
                println!("conv_b served 20 verified requests on the re-planned pool");
                Ok(())
            })
        };
        reports = driver.join().expect("open-loop driver panicked")?;
        churn.join().expect("churn thread panicked")?;
        Ok(())
    })?;

    for r in &reports {
        assert_eq!(r.submitted, r.completed, "{}: in-flight loss", r.name);
        assert!(r.verified, "{}: responses must be verified", r.name);
        println!(
            "  {:10} {:24} {}/{} verified responses in {:.3}s",
            r.name, r.arrivals, r.completed, r.submitted, r.wall_s
        );
    }
    for name in pool.names() {
        if let Some(m) = pool.tenant_metrics(&name) {
            let s = m.snapshot();
            println!(
                "  {:10} batches {} (size {} / deadline {} / closed {}) max queue depth {}",
                name, s.batches, s.flush_size, s.flush_deadline, s.flush_closed,
                s.max_queue_depth
            );
        }
    }
    let s = pool.metrics.snapshot();
    assert!(s.replans >= 1, "expected at least one online re-plan");
    println!(
        "scheduler: re-plans {} (drained {} deployments) | routed {} requests",
        s.replans, s.drained_deployments, s.routed_requests
    );
    pool.shutdown();
    Ok(())
}
