//! END-TO-END DRIVER: load a real (small) quantized model from AOT
//! artifacts and serve batched requests through the full three-layer
//! stack, proving all layers compose:
//!
//!   L1 Pallas kernels -> L2 jax segment graphs -> HLO text artifacts ->
//!   L3 Rust coordinator: PJRT stage workers + host queues + batcher.
//!
//! Reports REAL latency/throughput (PJRT CPU wall clock) alongside the
//! calibrated simulated-Edge-TPU clock, and verifies the pipelined
//! numerics equal the single-TPU reference bit-for-bit.
//!
//! Two scenarios:
//!  * `fc_n512` on the paper's 8 MiB device — fits on one TPU, so
//!    segmentation should NOT help (the paper's "use the minimum number
//!    of TPUs" rule).
//!  * `fc_n512` on a scaled-down 0.29 MiB device — the single TPU spills
//!    3 of 5 layers to host memory and pipelined segmentation wins big
//!    (the paper's headline effect, at artifact-friendly scale).
//!
//! Run: `make artifacts && cargo run --release --example serve_pipeline`
//! Recorded in EXPERIMENTS.md §End-to-end.

use anyhow::{Context, Result};
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::coordinator::batcher::{BatchPolicy, Batcher};
use tpu_pipeline::coordinator::queue::bounded;
use tpu_pipeline::runtime::{run_chain, TpuRuntime};
use tpu_pipeline::segment::strategy::Strategy;
use tpu_pipeline::serving;
use tpu_pipeline::util::fmt_seconds;

fn main() -> Result<()> {
    let dir = serving::default_artifact_dir();
    let manifest = serving::load_manifest(&dir)
        .context("run `make artifacts` first")?;
    let entry = manifest.model("fc_n512")?;
    let batch = 50;

    println!("=== scenario 1: paper-scale device (8 MiB) — model fits ===");
    run_scenario(&dir, entry, SystemConfig::default(), batch, 1)?;
    run_scenario(&dir, entry, SystemConfig::default(), batch, 3)?;

    println!("\n=== scenario 2: scaled device (0.29 MiB) — 3 of 5 layers spill ===");
    let mut small = SystemConfig::default();
    small.device.usable_mem_bytes = 300_000;
    small.device.per_layer_fixed_bytes = 1024;
    run_scenario(&dir, entry, small.clone(), batch, 1)?;
    run_scenario(&dir, entry, small.clone(), batch, 2)?;
    run_scenario(&dir, entry, small, batch, 4)?;

    println!("\n=== numeric equivalence: pipeline vs single-TPU reference ===");
    verify_numerics(&dir, entry, batch)?;

    println!("\n=== dynamic batcher demo (open arrival stream) ===");
    batcher_demo()?;
    Ok(())
}

fn run_scenario(
    dir: &std::path::Path,
    entry: &tpu_pipeline::runtime::ModelEntry,
    cfg: SystemConfig,
    batch: usize,
    n_tpus: usize,
) -> Result<()> {
    let strategy = Strategy::ProfiledExhaustive { batch };
    let plan = serving::plan(entry, n_tpus, strategy, &cfg)?;
    let pipeline = serving::spawn_pipeline(dir, entry, &plan, 64)?;
    let report = serving::serve_batch(&pipeline, &plan, serving::synth_requests(&plan, batch, 7))?;
    println!(
        "  {} TPU(s) split {:7}: real {:>9}/batch ({:>5.0} inf/s) | sim/inf {:>9} | sim speedup vs 1 TPU {:>5.1}x",
        n_tpus,
        report.partition_label,
        fmt_seconds(report.wall_s),
        report.real_throughput,
        fmt_seconds(report.sim_per_item_s),
        report.sim_speedup_vs_one_tpu,
    );
    pipeline.shutdown();
    Ok(())
}

fn verify_numerics(
    dir: &std::path::Path,
    entry: &tpu_pipeline::runtime::ModelEntry,
    batch: usize,
) -> Result<()> {
    let cfg = SystemConfig::default();
    let plan = serving::plan(entry, 4, Strategy::Uniform, &cfg)?;
    let pipeline = serving::spawn_pipeline(dir, entry, &plan, 16)?;
    let requests = serving::synth_requests(&plan, batch, 99);

    let rt = TpuRuntime::new(dir)?;
    let whole = rt.load_segment(entry.segment(0, entry.layers.len()).unwrap())?;
    let expected: Vec<Vec<i8>> = requests
        .iter()
        .map(|r| run_chain(std::slice::from_ref(&whole), &r.data))
        .collect::<Result<_>>()?;

    let responses = pipeline.serve_batch(requests)?;
    let mut ok = 0;
    for (r, e) in responses.iter().zip(&expected) {
        assert_eq!(r.data, *e, "pipelined numerics drifted on request {}", r.id);
        ok += 1;
    }
    println!("  {ok}/{batch} pipelined outputs == single-TPU reference (int8-exact)");
    // and the golden vector from the Python oracle
    let out = whole.run(&entry.golden.input)?;
    assert_eq!(out, entry.golden.output);
    println!("  golden vector from the Python oracle reproduced exactly");
    pipeline.shutdown();
    Ok(())
}

fn batcher_demo() -> Result<()> {
    use tpu_pipeline::coordinator::Request;
    let (tx, rx) = bounded::<Request>(256);
    let producer = std::thread::spawn(move || {
        for i in 0..120u64 {
            tx.send(Request { id: i, data: vec![0; 8] }).unwrap();
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        tx.close();
    });
    let batcher = Batcher::new(
        rx,
        BatchPolicy { max_batch: 50, max_wait: std::time::Duration::from_millis(4) },
    );
    let mut batches = Vec::new();
    while let Some(b) = batcher.next_batch() {
        batches.push(b.len());
    }
    producer.join().unwrap();
    println!(
        "  120 requests @5k/s -> {} batches (sizes {:?}) under a 50-max/4ms policy",
        batches.len(),
        batches
    );
    Ok(())
}
