//! Quickstart: the core API in one tour.
//!
//! 1. Build a synthetic model (the paper's generators).
//! 2. Place it on a simulated Edge TPU and read the compile report.
//! 3. See the host-memory cliff.
//! 4. Segment it across 4 TPUs with the profiled partitioner and compare.
//!
//! Run: `cargo run --release --example quickstart`

use tpu_pipeline::compiler::place;
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::device::CostModel;
use tpu_pipeline::model::synthetic::fc_model;
use tpu_pipeline::pipeline::{simulate_partition, single_tpu_latency_s, SimOptions};
use tpu_pipeline::profiler::best_partition;
use tpu_pipeline::segment::uniform_cuts;
use tpu_pipeline::util::fmt_seconds;

fn main() {
    let cfg = SystemConfig::default();
    let cm = CostModel::new(cfg.clone());

    // --- 1. a model that no longer fits in the Edge TPU's 8 MiB ---
    let model = fc_model(2100);
    println!("model {}: {} layers, {} MACs, {:.2} MiB of int8 weights",
        model.name, model.len(), model.macs(),
        model.weight_bytes() as f64 / (1024.0 * 1024.0));

    // --- 2. the edgetpu-compiler placement model ---
    let placement = place(&model.layers, &cfg.device);
    println!("\nsingle-TPU compile report:");
    println!("  device memory: {:.2} MiB", placement.device_mib());
    println!("  host   memory: {:.2} MiB  <-- streamed over PCIe every inference!",
        placement.host_mib());

    // --- 3. the cliff ---
    let cost = cm.stage_cost(&placement);
    println!("\nsingle-TPU inference: {}", fmt_seconds(cost.exec_s()));
    println!("  of which host-weight streaming: {}", fmt_seconds(cost.host_stream_s));

    // --- 4. segmentation across up to 4 TPUs ---
    let batch = 50;
    println!("\npipelined over multiple TPUs ({batch}-input batch):");
    let t1 = single_tpu_latency_s(&model, &cfg);
    for s in 2..=4 {
        let uniform = uniform_cuts(model.len(), s);
        let uni = simulate_partition(&model, &uniform, &cfg,
            &SimOptions { batch, ..Default::default() }).per_item_s(batch);
        let prof = best_partition(&model, &cfg, s, batch);
        let best = simulate_partition(&model, &prof.partition, &cfg,
            &SimOptions { batch, ..Default::default() }).per_item_s(batch);
        println!(
            "  {s} TPUs: default split {:5} -> {}/inf ({:4.1}x), profiled {:5} -> {}/inf ({:4.1}x)",
            uniform.label(), fmt_seconds(uni), t1 / uni,
            prof.partition.label(), fmt_seconds(best), t1 / best,
        );
    }
    println!("\n(the profiled 3-TPU split avoids host memory entirely — the paper's §V-C)");
}
