# Build glue for the repro harness (DESIGN.md §5, ROADMAP "vendor/xla").
#
# `make artifacts` runs the AOT driver: every contiguous segment of every
# manifest model is lowered to an HLO-text artifact + manifest.json under
# $(ARTIFACTS), which is what `repro serve`/`serve-pool` with the PJRT
# backend (and the real xla crate swapped in for the vendor/xla stub)
# consume.  Needs a Python with jax/numpy; the Rust side builds offline.

PYTHON    ?= python3
ARTIFACTS ?= artifacts
CARGO     ?= cargo

.PHONY: all build test check artifacts python-test clean

all: build

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

check:
	$(CARGO) fmt --check
	$(CARGO) clippy --all-targets -- -D warnings

# AOT-compile every manifest model's segments (python/compile/aot.py).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../$(ARTIFACTS)

python-test:
	cd python && $(PYTHON) -m pytest tests -q

clean:
	rm -rf $(ARTIFACTS)
	$(CARGO) clean
