# Build glue for the repro harness (DESIGN.md §5/§11, ROADMAP "vendor/xla").
#
# `make artifacts` runs the AOT driver: every contiguous segment of every
# manifest model is lowered to an HLO-text artifact + manifest.json under
# $(ARTIFACTS), which is what `repro serve`/`serve-pool` with the PJRT
# backend (and the real xla crate swapped in for the vendor/xla stub)
# consume.  Needs a Python with jax/numpy; the Rust side builds offline.
#
# The `smoke-*` targets are the exact commands the CI workflow runs, so a
# local `make smoke` reproduces CI byte-for-byte.  The `bench-*` targets
# drive the CI bench job: quick-mode `cargo bench` runs that emit
# BENCH_<name>.json (schema: DESIGN.md §11) and a >25% regression gate
# against the checked-in baselines under benches/baseline/.

PYTHON    ?= python3
ARTIFACTS ?= artifacts
CARGO     ?= cargo
BENCH_OUT ?= bench-out
SMOKE_OUT ?= smoke-out

.PHONY: all build test check artifacts python-test clean \
        smoke smoke-scheduler smoke-loadgen smoke-sharing smoke-dataplane \
        smoke-trace smoke-chaos smoke-cache smoke-calibrate smoke-recover \
        bench-quick bench-check bench-baseline

all: build

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

check:
	$(CARGO) fmt --check
	$(CARGO) clippy --all-targets -- -D warnings

# AOT-compile every manifest model's segments (python/compile/aot.py).
# Skips with an install hint instead of a confusing ModuleNotFoundError
# when no jax-equipped Python is around (the common offline case).
artifacts:
	@if ! command -v $(PYTHON) >/dev/null 2>&1; then \
		echo "make artifacts: skipping — $(PYTHON) not found on PATH."; \
		echo "  install python3 + deps: pip install jax jaxlib numpy"; \
	elif ! $(PYTHON) -c "import jax, numpy" >/dev/null 2>&1; then \
		echo "make artifacts: skipping — $(PYTHON) lacks jax/numpy (the AOT driver needs them)."; \
		echo "  install with: pip install jax jaxlib numpy   # then re-run: make artifacts"; \
	else \
		cd python && $(PYTHON) -m compile.aot --out ../$(ARTIFACTS); \
	fi

python-test:
	cd python && $(PYTHON) -m pytest tests -q

# ---- CI smoke (identical commands locally and in .github/workflows/ci.yml)

smoke: smoke-scheduler smoke-loadgen smoke-sharing smoke-dataplane smoke-trace smoke-chaos smoke-cache smoke-calibrate smoke-recover

smoke-scheduler:
	$(CARGO) run --release --bin repro -- schedule --models fc_big,conv_a,conv_b --tpus 4
	$(CARGO) run --release --example serve_multi_tenant

smoke-loadgen:
	mkdir -p $(SMOKE_OUT)
	$(CARGO) run --release --bin repro -- loadgen --seed 7 --models fc_small,conv_a \
		--tpus 4 --requests 120 --arrivals poisson:700 --csv > $(SMOKE_OUT)/loadgen_a.csv
	$(CARGO) run --release --bin repro -- loadgen --seed 7 --models fc_small,conv_a \
		--tpus 4 --requests 120 --arrivals poisson:700 --csv > $(SMOKE_OUT)/loadgen_b.csv
	diff $(SMOKE_OUT)/loadgen_a.csv $(SMOKE_OUT)/loadgen_b.csv
	$(CARGO) run --release --example open_loop

smoke-sharing:
	mkdir -p $(SMOKE_OUT)
	# oversubscribed pool: the whole-TPU auction queues one tenant...
	$(CARGO) run --release --bin repro -- schedule \
		--models fc_huge,fc_n2580,conv_a --tpus 4 | grep -q "queued:"
	# ...which --allow-sharing admits onto time-sliced devices,
	# deterministically across invocations
	$(CARGO) run --release --bin repro -- schedule \
		--models fc_huge,fc_n2580,conv_a --tpus 4 --allow-sharing > $(SMOKE_OUT)/shared_a.txt
	$(CARGO) run --release --bin repro -- schedule \
		--models fc_huge,fc_n2580,conv_a --tpus 4 --allow-sharing > $(SMOKE_OUT)/shared_b.txt
	diff $(SMOKE_OUT)/shared_a.txt $(SMOKE_OUT)/shared_b.txt
	grep -q "shared 1/2" $(SMOKE_OUT)/shared_a.txt
	! grep -q "queued:" $(SMOKE_OUT)/shared_a.txt
	# a shared deployment's loadgen table is byte-identical per seed
	$(CARGO) run --release --bin repro -- loadgen --seed 7 \
		--models fc_small,fc_n512 --tpus 1 --allow-sharing \
		--requests 120 --arrivals poisson:700 --csv > $(SMOKE_OUT)/shared_lg_a.csv
	$(CARGO) run --release --bin repro -- loadgen --seed 7 \
		--models fc_small,fc_n512 --tpus 1 --allow-sharing \
		--requests 120 --arrivals poisson:700 --csv > $(SMOKE_OUT)/shared_lg_b.csv
	diff $(SMOKE_OUT)/shared_lg_a.csv $(SMOKE_OUT)/shared_lg_b.csv
	# the quantum knob stays seed-deterministic too
	$(CARGO) run --release --bin repro -- loadgen --seed 7 \
		--models fc_small,fc_n512 --tpus 1 --allow-sharing --quantum-us 500 \
		--requests 120 --arrivals poisson:700 --csv > $(SMOKE_OUT)/shared_q_a.csv
	$(CARGO) run --release --bin repro -- loadgen --seed 7 \
		--models fc_small,fc_n512 --tpus 1 --allow-sharing --quantum-us 500 \
		--requests 120 --arrivals poisson:700 --csv > $(SMOKE_OUT)/shared_q_b.csv
	diff $(SMOKE_OUT)/shared_q_a.csv $(SMOKE_OUT)/shared_q_b.csv

# Telemetry determinism gate (DESIGN.md §13): the Perfetto trace and the
# metrics JSONL exported by a seeded loadgen run come from the sim clock,
# so two same-seed runs must be byte-identical; `repro trace` then proves
# the exported file round-trips through the parser/renderer.
smoke-trace:
	mkdir -p $(SMOKE_OUT)
	$(CARGO) run --release --bin repro -- loadgen --seed 7 --models fc_small,conv_a \
		--tpus 4 --requests 120 --arrivals poisson:700 --csv \
		--trace-out $(SMOKE_OUT)/trace_a.json --metrics-out $(SMOKE_OUT)/metrics_a.jsonl \
		> /dev/null
	$(CARGO) run --release --bin repro -- loadgen --seed 7 --models fc_small,conv_a \
		--tpus 4 --requests 120 --arrivals poisson:700 --csv \
		--trace-out $(SMOKE_OUT)/trace_b.json --metrics-out $(SMOKE_OUT)/metrics_b.jsonl \
		> /dev/null
	diff $(SMOKE_OUT)/trace_a.json $(SMOKE_OUT)/trace_b.json
	diff $(SMOKE_OUT)/metrics_a.jsonl $(SMOKE_OUT)/metrics_b.jsonl
	$(CARGO) run --release --bin repro -- trace --in $(SMOKE_OUT)/trace_a.json \
		| grep -q "fc_small/requests"

# Live data-plane gate (DESIGN.md §12): steady-state arena allocations
# per request must be ZERO across exclusive, shared and replica grants —
# the paper's "data movement dominates" argument, enforced host-side.
smoke-dataplane:
	$(CARGO) run --release --bin repro -- dataplane \
		--models fc_small,conv_a --tpus 2 --alloc-budget 0
	$(CARGO) run --release --bin repro -- dataplane \
		--models fc_small,fc_n512 --tpus 1 --allow-sharing --alloc-budget 0
	$(CARGO) run --release --bin repro -- dataplane \
		--models fc_small --tpus 3 --alloc-budget 0

# Fault-injection gate (DESIGN.md §14): the seeded chaos sim is a pure
# function of its flags — two same-seed CSV runs must be byte-identical —
# and the live drills must survive every fault kind: injected straggler
# -> hedges fire, tiered overload burst -> exact shed accounting, mid-run
# device kill -> drain/replay with every response verified bit-exact.
smoke-chaos:
	mkdir -p $(SMOKE_OUT)
	$(CARGO) run --release --bin repro -- chaos --seed 7 --models fc_small,conv_a \
		--tpus 4 --requests 120 --arrivals poisson:900 \
		--kills 1 --stragglers 1 --overloads 1 --csv > $(SMOKE_OUT)/chaos_a.csv
	$(CARGO) run --release --bin repro -- chaos --seed 7 --models fc_small,conv_a \
		--tpus 4 --requests 120 --arrivals poisson:900 \
		--kills 1 --stragglers 1 --overloads 1 --csv > $(SMOKE_OUT)/chaos_b.csv
	diff $(SMOKE_OUT)/chaos_a.csv $(SMOKE_OUT)/chaos_b.csv
	# replicated single-model pool so the straggler/hedge drill engages
	$(CARGO) run --release --bin repro -- chaos --seed 7 --models fc_small \
		--tpus 3 --max-tpus-per-model 1 --live

# Segment-parameter cache gate (DESIGN.md §15): a cache-on shared loadgen
# run is byte-identical per seed (warm/cold classification rides the sim
# clock), and --cache-budget-bytes 0 reproduces the cache-off table
# byte-for-byte — the new columns only appear with a non-zero budget.
smoke-cache:
	mkdir -p $(SMOKE_OUT)
	$(CARGO) run --release --bin repro -- loadgen --seed 7 \
		--models fc_small,fc_n512 --tpus 1 --allow-sharing --quantum-us 500 \
		--cache-budget-bytes 1073741824 --prefetch \
		--requests 120 --arrivals poisson:700 --csv > $(SMOKE_OUT)/cache_a.csv
	$(CARGO) run --release --bin repro -- loadgen --seed 7 \
		--models fc_small,fc_n512 --tpus 1 --allow-sharing --quantum-us 500 \
		--cache-budget-bytes 1073741824 --prefetch \
		--requests 120 --arrivals poisson:700 --csv > $(SMOKE_OUT)/cache_b.csv
	diff $(SMOKE_OUT)/cache_a.csv $(SMOKE_OUT)/cache_b.csv
	grep -q "cache_hits" $(SMOKE_OUT)/cache_a.csv
	# budget 0 must fall back to the flat model byte-for-byte
	$(CARGO) run --release --bin repro -- loadgen --seed 7 \
		--models fc_small,fc_n512 --tpus 1 --allow-sharing --quantum-us 500 \
		--requests 120 --arrivals poisson:700 --csv > $(SMOKE_OUT)/cache_off.csv
	$(CARGO) run --release --bin repro -- loadgen --seed 7 \
		--models fc_small,fc_n512 --tpus 1 --allow-sharing --quantum-us 500 \
		--cache-budget-bytes 0 \
		--requests 120 --arrivals poisson:700 --csv > $(SMOKE_OUT)/cache_zero.csv
	diff $(SMOKE_OUT)/cache_off.csv $(SMOKE_OUT)/cache_zero.csv
	! grep -q "cache_hits" $(SMOKE_OUT)/cache_zero.csv

# Online-calibration gate (DESIGN.md §16): the seeded drift scenario is
# byte-identical per seed and converges — the drifted tenant recalibrates
# (the ledger is non-empty) and the detector then quiesces; a no-drift
# run of the same seed keeps an empty ledger; and loadgen without
# --calibrate stays byte-identical to a pre-calibration run.
smoke-calibrate:
	mkdir -p $(SMOKE_OUT)
	$(CARGO) run --release --bin repro -- calibrate --seed 11 \
		--models fc_small,conv_a --tpus 2 --drift fc_small \
		--csv > $(SMOKE_OUT)/calibrate_a.csv
	$(CARGO) run --release --bin repro -- calibrate --seed 11 \
		--models fc_small,conv_a --tpus 2 --drift fc_small \
		--csv > $(SMOKE_OUT)/calibrate_b.csv
	diff $(SMOKE_OUT)/calibrate_a.csv $(SMOKE_OUT)/calibrate_b.csv
	grep -q "recalibrate" $(SMOKE_OUT)/calibrate_a.csv
	# the same seed without injected drift must keep an empty ledger
	$(CARGO) run --release --bin repro -- calibrate --seed 11 \
		--models fc_small,conv_a --tpus 2 \
		--csv > $(SMOKE_OUT)/calibrate_quiet.csv
	! grep -q "recalibrate" $(SMOKE_OUT)/calibrate_quiet.csv
	# loadgen --calibrate appends after byte-identical normal output
	$(CARGO) run --release --bin repro -- loadgen --seed 9 \
		--models fc_small --tpus 1 --requests 120 \
		--csv > $(SMOKE_OUT)/calibrate_lg_off.csv
	$(CARGO) run --release --bin repro -- loadgen --seed 9 \
		--models fc_small --tpus 1 --requests 120 \
		--csv --calibrate > $(SMOKE_OUT)/calibrate_lg_on.csv
	head -n $$(wc -l < $(SMOKE_OUT)/calibrate_lg_off.csv) \
		$(SMOKE_OUT)/calibrate_lg_on.csv \
		| diff $(SMOKE_OUT)/calibrate_lg_off.csv -
	grep -q "observed_p99_ms" $(SMOKE_OUT)/calibrate_lg_on.csv

# Crash-recovery gate (DESIGN.md §17): write a recovery journal, "crash"
# (exit without deregistering), warm-restart via `repro recover` — the
# recovered pool's deterministic loadgen CSV must be byte-identical to an
# uninterrupted same-seed `repro loadgen` run, and the live warm-restart
# (plan-fingerprint check + bit-exact verification wave) runs inside the
# recover invocation itself.
smoke-recover:
	mkdir -p $(SMOKE_OUT)
	$(CARGO) run --release --bin repro -- loadgen --seed 7 --models fc_small,conv_a \
		--tpus 4 --requests 120 --arrivals poisson:700 --csv > $(SMOKE_OUT)/recover_base.csv
	$(CARGO) run --release --bin repro -- recover --journal $(SMOKE_OUT)/recover.journal \
		--write --seed 7 --models fc_small,conv_a \
		--tpus 4 --requests 120 --arrivals poisson:700
	$(CARGO) run --release --bin repro -- recover --journal $(SMOKE_OUT)/recover.journal \
		--seed 7 --models fc_small,conv_a \
		--tpus 4 --requests 120 --arrivals poisson:700 --csv > $(SMOKE_OUT)/recover_after.csv
	diff $(SMOKE_OUT)/recover_base.csv $(SMOKE_OUT)/recover_after.csv
	# the reliability chaos columns stay seed-deterministic too
	$(CARGO) run --release --bin repro -- chaos --seed 7 --models fc_small \
		--tpus 3 --max-tpus-per-model 1 --requests 120 --arrivals poisson:900 \
		--crashes 1 --deadline-ms 50 --csv > $(SMOKE_OUT)/chaos_rel_a.csv
	$(CARGO) run --release --bin repro -- chaos --seed 7 --models fc_small \
		--tpus 3 --max-tpus-per-model 1 --requests 120 --arrivals poisson:900 \
		--crashes 1 --deadline-ms 50 --csv > $(SMOKE_OUT)/chaos_rel_b.csv
	diff $(SMOKE_OUT)/chaos_rel_a.csv $(SMOKE_OUT)/chaos_rel_b.csv
	grep -q "expired,recoveries" $(SMOKE_OUT)/chaos_rel_a.csv

# ---- CI bench pipeline (DESIGN.md §11)

bench-quick:
	mkdir -p $(BENCH_OUT)
	BENCH_QUICK=1 BENCH_JSON_DIR=$(BENCH_OUT) $(CARGO) bench --bench scheduler
	BENCH_QUICK=1 BENCH_JSON_DIR=$(BENCH_OUT) $(CARGO) bench --bench loadgen
	BENCH_QUICK=1 BENCH_JSON_DIR=$(BENCH_OUT) $(CARGO) bench --bench dataplane

# Gate against the checked-in baseline; when that baseline is still the
# empty bootstrap, fall back to the previous CI run's results restored
# under $(BENCH_PREV) (the rolling baseline cached by the CI bench job).
BENCH_PREV ?= bench-prev
bench-check:
	$(PYTHON) scripts/bench_check.py $(BENCH_OUT)/BENCH_scheduler.json benches/baseline/BENCH_scheduler.json --fallback $(BENCH_PREV)/BENCH_scheduler.json
	$(PYTHON) scripts/bench_check.py $(BENCH_OUT)/BENCH_loadgen.json benches/baseline/BENCH_loadgen.json --fallback $(BENCH_PREV)/BENCH_loadgen.json
	$(PYTHON) scripts/bench_check.py $(BENCH_OUT)/BENCH_dataplane.json benches/baseline/BENCH_dataplane.json --fallback $(BENCH_PREV)/BENCH_dataplane.json

# Re-measure on the reference runner and commit the result to activate
# the checked-in regression gate (takes precedence over the rolling one).
# Until someone does, benches/baseline/*.json hold empty bootstrap files
# and bench-check gates against the rolling CI cache only — run this ON
# THE REFERENCE RUNNER (not a laptop), review the copied JSON, and commit
# it to arm the absolute pin.
bench-baseline: bench-quick
	cp $(BENCH_OUT)/BENCH_scheduler.json $(BENCH_OUT)/BENCH_loadgen.json \
	   $(BENCH_OUT)/BENCH_dataplane.json benches/baseline/
	@echo "bench-baseline: copied quick-mode results into benches/baseline/."
	@echo "  Review and commit them to arm the absolute regression pin"
	@echo "  (scripts/bench_check.py prefers a non-empty checked-in baseline"
	@echo "  over the rolling CI cache; see DESIGN.md §11)."

clean:
	rm -rf $(ARTIFACTS) $(BENCH_OUT) $(SMOKE_OUT)
	$(CARGO) clean
