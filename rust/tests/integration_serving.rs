//! Integration: the full serving stack — manifest -> strategy -> PJRT
//! stage workers -> pipelined responses — must reproduce single-TPU
//! numerics exactly and keep its metrics/ordering invariants.
//!
//! Requires `make artifacts` (skips loudly otherwise).

use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::coordinator::Request;
use tpu_pipeline::runtime::run_chain;
use tpu_pipeline::runtime::TpuRuntime;
use tpu_pipeline::segment::strategy::Strategy;
use tpu_pipeline::serving::{self, default_artifact_dir};

fn artifacts_or_skip() -> Option<std::path::PathBuf> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        if std::env::var("TPU_PIPELINE_REQUIRE_ARTIFACTS").as_deref() == Ok("1") {
            panic!("artifacts missing at {dir:?}: run `make artifacts`");
        }
        eprintln!("SKIP: artifacts missing at {dir:?}; run `make artifacts`");
        return None;
    }
    Some(dir)
}

#[test]
fn pipelined_serving_matches_single_tpu_numerics() {
    let Some(dir) = artifacts_or_skip() else { return };
    let cfg = SystemConfig::default();
    let manifest = serving::load_manifest(&dir).unwrap();
    let entry = manifest.model("fc_n256").unwrap();

    // reference: single-threaded chain over the whole-model artifact
    let rt = TpuRuntime::new(&dir).unwrap();
    let whole = rt.load_segment(entry.segment(0, 5).unwrap()).unwrap();

    for (n_tpus, strategy) in [
        (2, Strategy::Uniform),
        (3, Strategy::Uniform),
        (4, Strategy::Uniform),
        (3, Strategy::ProfiledExhaustive { batch: 20 }),
    ] {
        let plan = serving::plan(entry, n_tpus, strategy, &cfg).unwrap();
        let pipeline = serving::spawn_pipeline(&dir, entry, &plan, 16).unwrap();
        let requests = serving::synth_requests(&plan, 20, 7);
        let expected: Vec<Vec<i8>> = requests
            .iter()
            .map(|r| run_chain(std::slice::from_ref(&whole), &r.data).unwrap())
            .collect();
        let responses = pipeline.serve_batch(requests).unwrap();
        assert_eq!(responses.len(), 20);
        for (i, resp) in responses.iter().enumerate() {
            assert_eq!(resp.id, i as u64, "order preserved");
            assert_eq!(
                resp.data, expected[i],
                "{n_tpus} TPUs ({}): item {i} numerics drifted",
                strategy.name()
            );
        }
        // every stage saw every item exactly once
        for sm in &pipeline.stage_metrics {
            assert_eq!(sm.snapshot().items, 20);
        }
        assert_eq!(pipeline.serve_metrics.snapshot().completed, 20);
        pipeline.shutdown();
    }
}

#[test]
fn conv_model_serves_correctly() {
    let Some(dir) = artifacts_or_skip() else { return };
    let cfg = SystemConfig::default();
    let manifest = serving::load_manifest(&dir).unwrap();
    let entry = manifest.model("conv_f16").unwrap();
    let plan = serving::plan(entry, 4, Strategy::Uniform, &cfg).unwrap();
    let pipeline = serving::spawn_pipeline(&dir, entry, &plan, 8).unwrap();
    // golden input through the pipeline equals the golden output
    let req = vec![Request::new(0, entry.golden.input.clone())];
    let resp = pipeline.serve_batch(req).unwrap();
    assert_eq!(resp[0].data, entry.golden.output);
    pipeline.shutdown();
}

#[test]
fn serve_report_has_consistent_speedups() {
    let Some(dir) = artifacts_or_skip() else { return };
    let cfg = SystemConfig::default();
    let manifest = serving::load_manifest(&dir).unwrap();
    let entry = manifest.model("fc_n512").unwrap();
    let plan = serving::plan(entry, 2, Strategy::Uniform, &cfg).unwrap();
    let pipeline = serving::spawn_pipeline(&dir, entry, &plan, 16).unwrap();
    let report =
        serving::serve_batch(&pipeline, &plan, serving::synth_requests(&plan, 10, 1)).unwrap();
    assert_eq!(report.batch, 10);
    assert!(report.wall_s > 0.0 && report.real_throughput > 0.0);
    assert!(report.sim_makespan_s > 0.0);
    assert!(
        (report.sim_per_item_s - report.sim_makespan_s / 10.0).abs() < 1e-12,
        "{report:?}"
    );
    // fc_n512 fits on one simulated TPU, so segmentation must NOT help
    // (paper: "the ideal is to use the minimum number of segments")
    assert!(report.sim_speedup_vs_one_tpu < 1.0, "{report:?}");
    pipeline.shutdown();
}

/// The paper's host-memory cliff, demonstrated with REAL execution: on a
/// scaled-down device (256 KiB usable) fc_n512 spills 3 layers on one TPU
/// but fits across 4 — the serving stack must report the corresponding
/// simulated speedup while producing identical numerics.
#[test]
fn scaled_device_shows_segmentation_win_with_real_numerics() {
    let Some(dir) = artifacts_or_skip() else { return };
    let mut cfg = SystemConfig::default();
    cfg.device.usable_mem_bytes = 300_000; // ~0.29 MiB toy Edge TPU
    cfg.device.per_layer_fixed_bytes = 1024;
    let manifest = serving::load_manifest(&dir).unwrap();
    let entry = manifest.model("fc_n512").unwrap();

    let plan1 = serving::plan(entry, 1, Strategy::Uniform, &cfg).unwrap();
    let plan4 =
        serving::plan(entry, 4, Strategy::ProfiledExhaustive { batch: 30 }, &cfg).unwrap();
    let p1 = serving::spawn_pipeline(&dir, entry, &plan1, 16).unwrap();
    let p4 = serving::spawn_pipeline(&dir, entry, &plan4, 16).unwrap();
    let reqs = serving::synth_requests(&plan1, 30, 99);
    let r1 = p1.serve_batch(reqs.clone()).unwrap();
    let r4 = p4.serve_batch(reqs).unwrap();
    for (a, b) in r1.iter().zip(&r4) {
        assert_eq!(a.data, b.data, "numerics must not depend on partitioning");
    }
    // the simulated clock is cumulative per pipeline: measure the report
    // on a freshly spawned pipeline
    let p4b = serving::spawn_pipeline(&dir, entry, &plan4, 16).unwrap();
    let rep4 =
        serving::serve_batch(&p4b, &plan4, serving::synth_requests(&plan4, 30, 100)).unwrap();
    assert!(
        rep4.sim_speedup_vs_one_tpu > 1.5,
        "expected a segmentation win on the scaled device: {rep4:?}"
    );
    p1.shutdown();
    p4.shutdown();
    p4b.shutdown();
}
