//! Integration: open-loop load generation end-to-end — deterministic
//! loadgen tables (the ISSUE's reproducibility acceptance), live
//! open-loop serving with bit-exact verification, and online re-planning
//! (mid-run register/deregister) without losing in-flight requests.

use tpu_pipeline::cli::{self, Args};
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::scheduler::{
    resolve_model, AllocatorConfig, BackendKind, DeployOptions, ModelRegistry, ServingPool,
    Tenant,
};
use tpu_pipeline::serving;
use tpu_pipeline::workload::{Arrivals, TenantLoad};

fn run(cmd: &str) -> String {
    let argv: Vec<String> = cmd.split_whitespace().map(String::from).collect();
    cli::run(&Args::parse(&argv).unwrap()).unwrap()
}

/// ISSUE acceptance: `repro loadgen --seed 7 ... --csv` twice produces
/// identical per-tenant p50/p99/throughput CSVs.
#[test]
fn loadgen_csv_reproducible_across_invocations() {
    let cmd = "loadgen --models fc_small,conv_a --tpus 4 --seed 7 --requests 120 \
               --arrivals poisson:700,bursty:900:0.03:0.03 --csv";
    let a = run(cmd);
    let b = run(cmd);
    assert_eq!(a, b, "same seed must render the identical CSV");
    let header = a.lines().next().unwrap();
    for col in ["p50_ms", "p99_ms", "throughput_hz", "flush_size", "flush_deadline"] {
        assert!(header.contains(col), "{header}");
    }
    assert_eq!(a.lines().count(), 3, "header + one row per tenant:\n{a}");
    // the seed is load-bearing
    let c = run("loadgen --models fc_small,conv_a --tpus 4 --seed 8 --requests 120 \
                 --arrivals poisson:700,bursty:900:0.03:0.03 --csv");
    assert_ne!(a, c, "a different seed must change the table");
}

/// All three arrival processes flow through the deterministic table.
#[test]
fn loadgen_covers_all_arrival_processes() {
    let out = run("loadgen --models fc_small,conv_a,conv_b --tpus 4 --seed 3 \
                   --requests 80 --arrivals poisson:500,bursty:800:0.02:0.05,closed:4:0.0005");
    for needle in ["poisson:500", "bursty:800", "closed:4", "admitted"] {
        assert!(out.contains(needle), "missing {needle:?} in:\n{out}");
    }
}

/// ISSUE acceptance: open-loop arrivals with a mid-run register *and*
/// deregister — responses still verify bit-for-bit and every accepted
/// request completes.
#[test]
fn open_loop_with_mid_run_churn_loses_nothing() {
    let mut registry = ModelRegistry::new();
    registry.register_named("fc_small").unwrap();
    registry.register_named("conv_a").unwrap();
    let pool = ServingPool::deploy(
        registry,
        SystemConfig::default(),
        AllocatorConfig { total_tpus: 4, ..Default::default() },
        BackendKind::Synthetic,
        DeployOptions::default(),
    )
    .unwrap();

    let loads = vec![
        TenantLoad {
            model: "fc_small".into(),
            arrivals: Arrivals::Poisson { rate_hz: 2500.0 },
            requests: 200,
        },
        TenantLoad {
            model: "conv_a".into(),
            arrivals: Arrivals::Closed { concurrency: 4, think_s: 0.0 },
            requests: 200,
        },
    ];
    let mut reports = Vec::new();
    std::thread::scope(|scope| {
        let driver = {
            let pool = &pool;
            let loads = &loads;
            scope.spawn(move || serving::serve_open_loop(pool, loads, 11, true))
        };
        let churn = {
            let pool = &pool;
            scope.spawn(move || {
                // register fc_big (needs 2 TPUs) mid-run: the 4-TPU pool
                // goes to 1+1+2, shrinking any replica grants -> drain
                std::thread::sleep(std::time::Duration::from_millis(20));
                let r = pool
                    .register(Tenant::new("fc_big", resolve_model("fc_big").unwrap()))
                    .unwrap();
                assert!(r.admitted.contains(&"fc_big".to_string()), "{r:?}");
                // then deregister it again: freed TPUs re-auction
                std::thread::sleep(std::time::Duration::from_millis(40));
                let r = pool.deregister("fc_big").unwrap();
                assert!(!r.admitted.contains(&"fc_big".to_string()), "{r:?}");
            })
        };
        reports = driver.join().unwrap().unwrap();
        churn.join().unwrap();
    });

    for r in &reports {
        assert_eq!(r.submitted, 200, "{}", r.name);
        assert_eq!(r.completed, 200, "{}: in-flight request lost", r.name);
        assert!(r.verified, "{}", r.name);
    }
    for name in ["fc_small", "conv_a"] {
        let s = pool.tenant_metrics(name).unwrap().snapshot();
        assert_eq!(s.completed, 200, "{name}");
        assert_eq!(s.errors, 0, "{name}");
    }
    let s = pool.metrics.snapshot();
    assert_eq!(s.replans, 2, "one register + one deregister");
    pool.shutdown();
}

/// ISSUE 3 acceptance: a shared deployment's loadgen table is
/// byte-identical across runs of one seed, and the live co-resident
/// pipelines serve the same seeds with bit-exact verification while
/// counting their context switches.
#[test]
fn loadgen_shared_deployment_reproducible_and_serves_live() {
    let cmd = "loadgen --models fc_small,fc_n512 --tpus 1 --allow-sharing --seed 11 \
               --requests 80 --arrivals poisson:600 --csv";
    let a = run(cmd);
    assert_eq!(a, run(cmd), "same seed must render the identical shared CSV");
    let header = a.lines().next().unwrap();
    for col in ["grant", "swaps", "swap_over_ms", "replicas"] {
        assert!(header.contains(col), "{header}");
    }
    assert!(a.contains("shared"), "{a}");

    // the same spec drives a live pool of co-resident pipelines
    let argv: Vec<String> = cmd.split_whitespace().map(String::from).collect();
    let args = Args::parse(&argv).unwrap();
    let (registry, alloc, spec) = cli::loadgen_spec(&args).unwrap();
    assert!(alloc.allow_sharing);
    let pool = ServingPool::deploy(
        registry,
        SystemConfig::default(),
        alloc,
        BackendKind::Synthetic,
        DeployOptions { policy: spec.policy, queue_capacity: 32, ..Default::default() },
    )
    .unwrap();
    let reports = serving::serve_open_loop(&pool, &spec.loads, spec.seed, true).unwrap();
    assert_eq!(reports.len(), 2);
    for r in &reports {
        assert_eq!(r.completed, 80, "{}", r.name);
        assert!(r.verified, "{}", r.name);
        let s = pool.tenant_metrics(&r.name).unwrap().snapshot();
        assert!(s.swaps >= 1, "{}: co-resident must swap: {s:?}", r.name);
        assert!(s.swap_overhead_s > 0.0, "{}: {s:?}", r.name);
    }
    let s = pool.metrics.snapshot();
    assert_eq!(s.shared, 2);
    pool.shutdown();
}

/// PR 8 acceptance: a cache budget large enough to hold both co-residents
/// leaves only the compulsory first miss (strictly fewer cold swaps than a
/// budget that pins nothing) without losing simulated throughput, budget 0
/// reproduces the flat table byte-for-byte, and `hits + misses == swaps`
/// holds on every admitted row.
#[test]
fn loadgen_cache_budget_monotone_and_zero_is_byte_identical() {
    let base = "loadgen --models fc_small,fc_n512 --tpus 1 --allow-sharing --seed 11 \
                --requests 80 --arrivals poisson:600 --csv";
    let flat = run(base);
    assert!(
        !flat.lines().next().unwrap().contains("cache_misses"),
        "cache columns must stay hidden without a budget:\n{flat}"
    );
    assert_eq!(
        run(&format!("{base} --cache-budget-bytes 0")),
        flat,
        "budget 0 must disable the cache model byte-for-byte"
    );

    // (swaps, cache_hits, cache_misses, throughput_hz) per admitted row
    let parse = |out: &str| -> Vec<(u64, u64, u64, f64)> {
        let header: Vec<&str> = out.lines().next().unwrap().split(',').collect();
        let col = |name: &str| {
            header
                .iter()
                .position(|h| *h == name)
                .unwrap_or_else(|| panic!("no {name} column in {header:?}"))
        };
        let (sw, hit, miss, thr) =
            (col("swaps"), col("cache_hits"), col("cache_misses"), col("throughput_hz"));
        out.lines()
            .skip(1)
            .map(|l| {
                let f: Vec<&str> = l.split(',').collect();
                (
                    f[sw].parse().unwrap(),
                    f[hit].parse().unwrap(),
                    f[miss].parse().unwrap(),
                    f[thr].parse().unwrap(),
                )
            })
            .collect()
    };
    let tiny = parse(&run(&format!("{base} --cache-budget-bytes 1")));
    let big = parse(&run(&format!("{base} --cache-budget-bytes 1073741824")));
    assert_eq!(tiny.len(), 2, "both tenants admitted");
    assert_eq!(big.len(), 2);
    for (t, b) in tiny.iter().zip(&big) {
        // every quantum-gated swap is classified exactly once
        assert_eq!(t.1 + t.2, t.0, "tiny budget: hits + misses == swaps");
        assert_eq!(b.1 + b.2, b.0, "big budget: hits + misses == swaps");
        // a 1-byte budget pins nothing (every swap stays cold); a budget
        // fitting both co-residents leaves only the compulsory first miss
        assert_eq!(t.2, t.0, "1-byte budget must keep every swap cold");
        assert_eq!(b.2, 1, "fitting budget leaves only the compulsory miss");
        assert!(t.2 > b.2, "larger budget must cut cold swaps: {} -> {}", t.2, b.2);
        assert!(
            b.3 >= t.3 - 1e-9,
            "warm swaps must not lose throughput: {} -> {}",
            t.3,
            b.3
        );
    }
}

/// Replica fan-out end-to-end: the table models the round-robin shards
/// deterministically and the live replicated pipelines verify bit-exact.
#[test]
fn loadgen_replicated_deployment_reproducible_and_serves_live() {
    let cmd = "loadgen --models fc_small --tpus 2 --max-tpus-per-model 1 --seed 4 \
               --requests 60 --arrivals poisson:1500 --csv";
    let a = run(cmd);
    assert_eq!(a, run(cmd), "replicated CSV must be seed-stable");

    let argv: Vec<String> = cmd.split_whitespace().map(String::from).collect();
    let args = Args::parse(&argv).unwrap();
    let (registry, alloc, spec) = cli::loadgen_spec(&args).unwrap();
    let pool = ServingPool::deploy(
        registry,
        SystemConfig::default(),
        alloc,
        BackendKind::Synthetic,
        DeployOptions { policy: spec.policy, queue_capacity: 32, ..Default::default() },
    )
    .unwrap();
    assert_eq!(pool.plan().assignment("fc_small").unwrap().replicas, 2);
    let reports = serving::serve_open_loop(&pool, &spec.loads, spec.seed, true).unwrap();
    assert_eq!(reports[0].completed, 60);
    assert!(reports[0].verified);
    pool.shutdown();
}

/// The live open-loop path and the deterministic table agree on the
/// basics: same request counts, and the live responses verify.
#[test]
fn loadgen_cli_live_smoke() {
    // non-CSV loadgen through the library path: table renders and the
    // spec round-trips
    let argv: Vec<String> = "loadgen --models fc_small --tpus 1 --seed 5 --requests 40 \
                             --arrivals closed:2:0.0"
        .split_whitespace()
        .map(String::from)
        .collect();
    let args = Args::parse(&argv).unwrap();
    let out = cli::run(&args).unwrap();
    assert!(out.contains("fc_small"), "{out}");
    assert!(out.contains("closed:2:0"), "{out}");

    // the same spec drives a live pool
    let cfg = SystemConfig::default();
    let (registry, alloc, spec) = cli::loadgen_spec(&args).unwrap();
    let pool = ServingPool::deploy(
        registry,
        cfg,
        alloc,
        BackendKind::Synthetic,
        DeployOptions { policy: spec.policy, queue_capacity: 16, ..Default::default() },
    )
    .unwrap();
    let reports = serving::serve_open_loop(&pool, &spec.loads, spec.seed, true).unwrap();
    assert_eq!(reports.len(), 1);
    assert_eq!(reports[0].completed, 40);
    pool.shutdown();
}
