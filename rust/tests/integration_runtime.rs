//! Integration: AOT artifacts -> PJRT -> numerics.
//!
//! The strong correctness signal of the whole stack: HLO text produced by
//! `aot.py` (L2 jax graphs calling L1 Pallas kernels) must execute under
//! the Rust PJRT runtime and reproduce the Python oracle's golden vectors
//! bit-exactly, both whole-model and as chained segments.
//!
//! Requires `make artifacts`.  Tests skip (with a loud message) when the
//! artifact directory is missing, unless TPU_PIPELINE_REQUIRE_ARTIFACTS=1.

use tpu_pipeline::runtime::{run_chain, TpuRuntime};
use tpu_pipeline::serving::default_artifact_dir;

fn runtime_or_skip() -> Option<TpuRuntime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        if std::env::var("TPU_PIPELINE_REQUIRE_ARTIFACTS").as_deref() == Ok("1") {
            panic!("artifacts missing at {dir:?}: run `make artifacts`");
        }
        eprintln!("SKIP: artifacts missing at {dir:?}; run `make artifacts`");
        return None;
    }
    Some(TpuRuntime::new(dir).expect("PJRT CPU client"))
}

#[test]
fn whole_model_matches_golden() {
    let Some(rt) = runtime_or_skip() else { return };
    let manifest = rt.manifest().unwrap();
    for (name, entry) in &manifest.models {
        let whole = entry.segment(0, entry.layers.len()).expect("whole artifact");
        let seg = rt.load_segment(whole).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let out = seg.run(&entry.golden.input).unwrap();
        assert_eq!(out, entry.golden.output, "{name}: PJRT output != python oracle");
    }
}

#[test]
fn segment_chains_match_whole_model() {
    let Some(rt) = runtime_or_skip() else { return };
    let manifest = rt.manifest().unwrap();
    // every contiguous partition of the 5-layer models must chain to the
    // same output (int8-exact) — the invariant pipelining relies on
    let cut_sets: [&[usize]; 5] = [&[], &[2], &[1, 3], &[1, 2, 3], &[1, 2, 3, 4]];
    for name in ["fc_n256", "conv_f16"] {
        let entry = manifest.model(name).unwrap();
        for cuts in cut_sets {
            let segs = entry.segments_for_cuts(cuts).unwrap();
            let loaded: Vec<_> =
                segs.iter().map(|s| rt.load_segment(s).unwrap()).collect();
            let out = run_chain(&loaded, &entry.golden.input).unwrap();
            assert_eq!(
                out, entry.golden.output,
                "{name} cuts {cuts:?}: chained output != golden"
            );
        }
    }
}

#[test]
fn boundary_shapes_are_consistent() {
    let Some(rt) = runtime_or_skip() else { return };
    let manifest = rt.manifest().unwrap();
    for entry in manifest.models.values() {
        for s in &entry.segments {
            for t in &entry.segments {
                if t.start == s.end {
                    assert_eq!(
                        s.output_shape, t.input_shape,
                        "{}: [{},{}) -> [{},{})",
                        entry.name, s.start, s.end, t.start, t.end
                    );
                    assert_eq!(s.out_q, t.in_q, "{}", entry.name);
                }
            }
        }
    }
}

#[test]
fn wrong_input_size_is_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    let manifest = rt.manifest().unwrap();
    let entry = manifest.model("fc_n256").unwrap();
    let seg = rt.load_segment(entry.segment(0, 5).unwrap()).unwrap();
    let err = seg.run(&[0i8; 3]).unwrap_err();
    assert!(err.to_string().contains("expects"), "{err}");
}

#[test]
fn repeated_execution_is_deterministic() {
    let Some(rt) = runtime_or_skip() else { return };
    let manifest = rt.manifest().unwrap();
    let entry = manifest.model("conv_f32").unwrap();
    let seg = rt.load_segment(entry.segment(0, 5).unwrap()).unwrap();
    let a = seg.run(&entry.golden.input).unwrap();
    for _ in 0..3 {
        assert_eq!(seg.run(&entry.golden.input).unwrap(), a);
    }
}
