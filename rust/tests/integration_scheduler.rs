//! Integration: the multi-tenant TPU-pool scheduler end-to-end —
//! registry -> memory-aware admission -> cost-model placement -> live
//! per-model routing — without any compiled artifacts (synthetic
//! backend), so it runs in the offline build.

use tpu_pipeline::cli::{self, Args};
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::scheduler::{
    allocate, AllocatorConfig, BackendKind, ModelRegistry, PoolRouter,
};
use tpu_pipeline::serving;

fn run(cmd: &str) -> String {
    let argv: Vec<String> = cmd.split_whitespace().map(String::from).collect();
    cli::run(&Args::parse(&argv).unwrap()).unwrap()
}

/// The ISSUE acceptance criterion: `repro schedule --models
/// fc_big,conv_a,conv_b --tpus 4` admits all three within the pool's
/// on-chip memory budget and prints per-model (tpus, strategy, p99).
#[test]
fn schedule_cli_acceptance() {
    let out = run("schedule --models fc_big,conv_a,conv_b --tpus 4");
    assert!(out.contains("admitted 3 queued 0 rejected 0"), "{out}");
    assert!(out.contains("4/4 TPUs used"), "{out}");
    // per-model rows carry tpu count, strategy name and a p99 column
    for model in ["fc_big", "conv_a", "conv_b"] {
        assert!(out.contains(model), "{out}");
    }
    assert!(out.contains("p99_ms"), "{out}");
    // fc_big cannot run on one TPU without host spill -> 2-TPU split
    let fc_line = out.lines().find(|l| l.starts_with("fc_big")).unwrap();
    assert!(fc_line.contains(" 2 "), "fc_big should take 2 TPUs: {fc_line}");
}

/// Full path: allocate -> deploy -> serve two tenants concurrently ->
/// verify bit-exact responses and per-tenant metrics.
#[test]
fn pool_serves_two_tenants_end_to_end() {
    let mut registry = ModelRegistry::new();
    registry.register_named("fc_big").unwrap();
    registry.register_named("fc_small").unwrap();
    let cfg = SystemConfig::default();
    let alloc = AllocatorConfig { total_tpus: 4, ..Default::default() };
    let plan = allocate(&registry, &cfg, &alloc).unwrap();
    assert_eq!(plan.assignments.len(), 2, "queued={:?}", plan.queued);

    let router =
        PoolRouter::deploy(&plan, &registry, &cfg, &BackendKind::Synthetic, 32).unwrap();
    let reports = serving::serve_pool(&router, 25, 0xBEEF, true).unwrap();
    assert_eq!(reports.len(), 2);
    for r in &reports {
        assert!(r.verified);
        assert_eq!(r.batch, 25);
        let t = router.tenant(&r.name).unwrap();
        let snap = t.metrics.snapshot();
        assert_eq!(snap.submitted, 25, "{}", r.name);
        assert_eq!(snap.completed, 25, "{}", r.name);
        assert_eq!(snap.errors, 0, "{}", r.name);
    }
    let s = router.metrics.snapshot();
    assert_eq!(s.admitted, 2);
    assert_eq!(s.routed_requests, 50);
    router.shutdown();
}

/// The ISSUE 3 acceptance criterion: on an oversubscribed pool,
/// `--allow-sharing` admits a tenant the whole-TPU allocator queued, its
/// p99 includes nonzero swap overhead, and the plan renders
/// deterministically; with sharing off the plan is the whole-TPU one.
#[test]
fn schedule_cli_sharing_acceptance() {
    let base = "schedule --models fc_huge,fc_n2580,conv_a --tpus 4";
    let off = run(base);
    assert!(off.contains("queued:"), "{off}");
    assert!(!off.contains("shared"), "whole-TPU plans must not change: {off}");

    let cmd = format!("{base} --allow-sharing");
    let on = run(&cmd);
    assert!(!on.contains("queued:"), "sharing must admit the queued tenant: {on}");
    assert!(on.contains("shared 1/2"), "{on}");
    assert!(on.contains("swap_over_ms"), "{on}");
    assert_eq!(on, run(&cmd), "shared plans must render deterministically");
}

/// Full shared-grant path: allocate with sharing -> deploy co-resident
/// pipelines -> serve both tenants concurrently -> bit-exact responses
/// and per-tenant swap accounting.
#[test]
fn co_resident_tenants_serve_end_to_end() {
    let mut registry = ModelRegistry::new();
    registry.register_named("fc_small").unwrap();
    registry.register_named("fc_n512").unwrap();
    let cfg = SystemConfig::default();
    let alloc =
        AllocatorConfig { total_tpus: 1, allow_sharing: true, ..Default::default() };
    let plan = allocate(&registry, &cfg, &alloc).unwrap();
    assert_eq!(plan.assignments.len(), 2, "queued={:?}", plan.queued);
    assert_eq!(plan.tpus_used(), 1, "both tenants ride one TPU");
    assert_eq!(plan.shared_count(), 2);
    for a in &plan.assignments {
        assert!(a.effective_p99_s > a.candidate.p99_s, "swap overhead missing: {a:?}");
    }

    let router =
        PoolRouter::deploy(&plan, &registry, &cfg, &BackendKind::Synthetic, 16).unwrap();
    let reports = serving::serve_pool(&router, 20, 0xFEED, true).unwrap();
    assert_eq!(reports.len(), 2);
    for r in &reports {
        assert!(r.verified, "{}", r.name);
        assert!(r.grant_label.starts_with("shared"), "{r:?}");
        let snap = router.tenant(&r.name).unwrap().metrics.snapshot();
        assert_eq!(snap.completed, 20, "{}", r.name);
        assert!(snap.swaps >= 1, "{}: {snap:?}", r.name);
        assert!(snap.swap_overhead_s > 0.0, "{}: {snap:?}", r.name);
    }
    let s = router.metrics.snapshot();
    assert_eq!(s.admitted, 2);
    assert_eq!(s.shared, 2);
    router.shutdown();
}

/// Leftover TPUs turn into data-parallel replicas served through the
/// (previously dead) coordinator::ReplicaRouter.
#[test]
fn replicated_tenant_round_trips() {
    let mut registry = ModelRegistry::new();
    registry.register_named("fc_small").unwrap();
    let cfg = SystemConfig::default();
    let alloc = AllocatorConfig { total_tpus: 3, ..Default::default() };
    let plan = allocate(&registry, &cfg, &alloc).unwrap();
    assert_eq!(plan.tpus_used(), 3);

    let router =
        PoolRouter::deploy(&plan, &registry, &cfg, &BackendKind::Synthetic, 16).unwrap();
    let reports = serving::serve_pool(&router, 30, 1, true).unwrap();
    assert_eq!(reports.len(), 1);
    assert_eq!(reports[0].tpu_count * reports[0].replicas, 3);
    router.shutdown();
}
