//! Integration: the multi-tenant TPU-pool scheduler end-to-end —
//! registry -> memory-aware admission -> cost-model placement -> live
//! per-model routing — without any compiled artifacts (synthetic
//! backend), so it runs in the offline build.

use tpu_pipeline::cli::{self, Args};
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::scheduler::{
    allocate, AllocatorConfig, BackendKind, DeployOptions, ModelRegistry, PoolRouter,
    ServingPool,
};
use tpu_pipeline::serving;

fn run(cmd: &str) -> String {
    let argv: Vec<String> = cmd.split_whitespace().map(String::from).collect();
    cli::run(&Args::parse(&argv).unwrap()).unwrap()
}

/// The ISSUE acceptance criterion: `repro schedule --models
/// fc_big,conv_a,conv_b --tpus 4` admits all three within the pool's
/// on-chip memory budget and prints per-model (tpus, strategy, p99).
#[test]
fn schedule_cli_acceptance() {
    let out = run("schedule --models fc_big,conv_a,conv_b --tpus 4");
    assert!(out.contains("admitted 3 queued 0 rejected 0"), "{out}");
    assert!(out.contains("4/4 TPUs used"), "{out}");
    // per-model rows carry tpu count, strategy name and a p99 column
    for model in ["fc_big", "conv_a", "conv_b"] {
        assert!(out.contains(model), "{out}");
    }
    assert!(out.contains("p99_ms"), "{out}");
    // fc_big cannot run on one TPU without host spill -> 2-TPU split
    let fc_line = out.lines().find(|l| l.starts_with("fc_big")).unwrap();
    assert!(fc_line.contains(" 2 "), "fc_big should take 2 TPUs: {fc_line}");
}

/// Full path: allocate -> deploy -> serve two tenants concurrently ->
/// verify bit-exact responses and per-tenant metrics.
#[test]
fn pool_serves_two_tenants_end_to_end() {
    let mut registry = ModelRegistry::new();
    registry.register_named("fc_big").unwrap();
    registry.register_named("fc_small").unwrap();
    let cfg = SystemConfig::default();
    let alloc = AllocatorConfig { total_tpus: 4, ..Default::default() };
    let plan = allocate(&registry, &cfg, &alloc).unwrap();
    assert_eq!(plan.assignments.len(), 2, "queued={:?}", plan.queued);

    let router =
        PoolRouter::deploy(
            &plan,
            &registry,
            &cfg,
            &BackendKind::Synthetic,
            DeployOptions::new().with_queue_capacity(32),
        )
        .unwrap();
    let reports = serving::serve_pool(&router, 25, 0xBEEF, true).unwrap();
    assert_eq!(reports.len(), 2);
    for r in &reports {
        assert!(r.verified);
        assert_eq!(r.batch, 25);
        let t = router.tenant(&r.name).unwrap();
        let snap = t.metrics.snapshot();
        assert_eq!(snap.submitted, 25, "{}", r.name);
        assert_eq!(snap.completed, 25, "{}", r.name);
        assert_eq!(snap.errors, 0, "{}", r.name);
    }
    let s = router.metrics.snapshot();
    assert_eq!(s.admitted, 2);
    assert_eq!(s.routed_requests, 50);
    router.shutdown();
}

/// The ISSUE 3 acceptance criterion: on an oversubscribed pool,
/// `--allow-sharing` admits a tenant the whole-TPU allocator queued, its
/// p99 includes nonzero swap overhead, and the plan renders
/// deterministically; with sharing off the plan is the whole-TPU one.
#[test]
fn schedule_cli_sharing_acceptance() {
    let base = "schedule --models fc_huge,fc_n2580,conv_a --tpus 4";
    let off = run(base);
    assert!(off.contains("queued:"), "{off}");
    assert!(!off.contains("shared"), "whole-TPU plans must not change: {off}");

    let cmd = format!("{base} --allow-sharing");
    let on = run(&cmd);
    assert!(!on.contains("queued:"), "sharing must admit the queued tenant: {on}");
    assert!(on.contains("shared 1/2"), "{on}");
    assert!(on.contains("swap_over_ms"), "{on}");
    assert_eq!(on, run(&cmd), "shared plans must render deterministically");
}

/// Full shared-grant path: allocate with sharing -> deploy co-resident
/// pipelines -> serve both tenants concurrently -> bit-exact responses
/// and per-tenant swap accounting.
#[test]
fn co_resident_tenants_serve_end_to_end() {
    let mut registry = ModelRegistry::new();
    registry.register_named("fc_small").unwrap();
    registry.register_named("fc_n512").unwrap();
    let cfg = SystemConfig::default();
    let alloc =
        AllocatorConfig { total_tpus: 1, allow_sharing: true, ..Default::default() };
    let plan = allocate(&registry, &cfg, &alloc).unwrap();
    assert_eq!(plan.assignments.len(), 2, "queued={:?}", plan.queued);
    assert_eq!(plan.tpus_used(), 1, "both tenants ride one TPU");
    assert_eq!(plan.shared_count(), 2);
    for a in &plan.assignments {
        assert!(a.effective_p99_s > a.candidate.p99_s, "swap overhead missing: {a:?}");
    }

    let router =
        PoolRouter::deploy(
            &plan,
            &registry,
            &cfg,
            &BackendKind::Synthetic,
            DeployOptions::new().with_queue_capacity(16),
        )
        .unwrap();
    let reports = serving::serve_pool(&router, 20, 0xFEED, true).unwrap();
    assert_eq!(reports.len(), 2);
    for r in &reports {
        assert!(r.verified, "{}", r.name);
        assert!(r.grant_label.starts_with("shared"), "{r:?}");
        let snap = router.tenant(&r.name).unwrap().metrics.snapshot();
        assert_eq!(snap.completed, 20, "{}", r.name);
        assert!(snap.swaps >= 1, "{}: {snap:?}", r.name);
        assert!(snap.swap_overhead_s > 0.0, "{}: {snap:?}", r.name);
    }
    let s = router.metrics.snapshot();
    assert_eq!(s.admitted, 2);
    assert_eq!(s.shared, 2);
    router.shutdown();
}

/// Leftover TPUs turn into data-parallel replicas served through the
/// (previously dead) coordinator::ReplicaRouter.
#[test]
fn replicated_tenant_round_trips() {
    let mut registry = ModelRegistry::new();
    registry.register_named("fc_small").unwrap();
    let cfg = SystemConfig::default();
    let alloc = AllocatorConfig { total_tpus: 3, ..Default::default() };
    let plan = allocate(&registry, &cfg, &alloc).unwrap();
    assert_eq!(plan.tpus_used(), 3);

    let router =
        PoolRouter::deploy(
            &plan,
            &registry,
            &cfg,
            &BackendKind::Synthetic,
            DeployOptions::new().with_queue_capacity(16),
        )
        .unwrap();
    let reports = serving::serve_pool(&router, 30, 1, true).unwrap();
    assert_eq!(reports.len(), 1);
    assert_eq!(reports[0].tpu_count * reports[0].replicas, 3);
    router.shutdown();
}

fn open_pool(models: &[&str], tpus: usize) -> ServingPool {
    let mut registry = ModelRegistry::new();
    for m in models {
        registry.register_named(m).unwrap();
    }
    ServingPool::deploy(
        registry,
        SystemConfig::default(),
        AllocatorConfig { total_tpus: tpus, ..Default::default() },
        BackendKind::Synthetic,
        DeployOptions::default(),
    )
    .unwrap()
}

/// Re-plan race: a fault-triggered `kill_device` drain racing a
/// `deregister` of another tenant.  Whichever order the state lock
/// serializes them in, every in-flight request of *both* tenants must
/// complete bit-exact — the deregistered tenant drains through its old
/// deployment before its stream closes, the survivor's drained work
/// replays on the re-planned deployment — and the pool keeps serving.
#[test]
fn kill_device_races_deregister_without_losing_in_flight() {
    let pool = open_pool(&["fc_small", "conv_a"], 4);
    let n = 30usize;
    let mut clients = Vec::new();
    for name in ["fc_small", "conv_a"] {
        let client = pool.client(name).unwrap();
        let reqs = client.synth_requests(n, 0xACE);
        let expected: Vec<Vec<i8>> =
            reqs.iter().map(|r| client.reference(&r.data)).collect();
        for r in reqs {
            pool.submit(name, r).unwrap();
        }
        clients.push((name, client, expected));
    }

    std::thread::scope(|s| {
        let killer = s.spawn(|| pool.kill_device(0).unwrap());
        let remover = s.spawn(|| pool.deregister("conv_a").unwrap());
        killer.join().unwrap();
        remover.join().unwrap();
    });

    for (name, client, expected) in &clients {
        let mut got = 0;
        while got < n {
            let r = client
                .done
                .recv()
                .unwrap_or_else(|| panic!("{name}: stream closed with in-flight work"));
            assert_eq!(r.data, expected[r.id as usize], "{name}: byte drift on {}", r.id);
            got += 1;
        }
    }
    // the deregistered tenant's stream closes only after its drain
    let (_, conv_client, _) = &clients[1];
    assert!(conv_client.done.recv().is_none(), "deregistered stream must close");

    // quarantine + re-plan state is consistent and the survivor serves on
    assert_eq!(pool.dead_devices(), vec![0]);
    let plan = pool.plan();
    assert_eq!(plan.assignments.len(), 1, "only fc_small remains");
    assert!(
        plan.assignments[0].devices.iter().all(|&d| d != 0),
        "dead device must leave the plan: {:?}",
        plan.assignments[0].devices
    );
    let snap = pool.metrics.snapshot();
    assert_eq!(snap.device_kills, 1);
    assert!(snap.replans >= 2, "kill + deregister each re-plan: {snap:?}");

    let (_, fc_client, _) = &clients[0];
    let reqs = fc_client.synth_requests(10, 0xF00D);
    let expected: Vec<Vec<i8>> =
        reqs.iter().map(|r| fc_client.reference(&r.data)).collect();
    for r in reqs {
        pool.submit("fc_small", r).unwrap();
    }
    for _ in 0..10 {
        let r = fc_client.done.recv().expect("survivor must keep serving");
        assert_eq!(r.data, expected[r.id as usize]);
    }
    pool.shutdown();
}

/// Two concurrent device kills against one replicated tenant: the state
/// lock serializes the re-plans, no deployment is ever doubled (exactly
/// one response per request, no stragglers on the stream), and the
/// shrunken deployment still answers bit-exact.
#[test]
fn concurrent_kills_never_double_deploy() {
    let pool = open_pool(&["fc_small"], 3);
    assert_eq!(pool.plan().assignments[0].replicas, 3);
    let client = pool.client("fc_small").unwrap();
    let n = 30usize;
    let reqs = client.synth_requests(n, 0xCAFE);
    let expected: Vec<Vec<i8>> = reqs.iter().map(|r| client.reference(&r.data)).collect();
    for r in reqs {
        pool.submit("fc_small", r).unwrap();
    }

    std::thread::scope(|s| {
        let a = s.spawn(|| pool.kill_device(0).unwrap());
        let b = s.spawn(|| pool.kill_device(1).unwrap());
        a.join().unwrap();
        b.join().unwrap();
    });

    let mut seen = vec![false; n];
    for _ in 0..n {
        let r = client.done.recv().expect("stream closed with in-flight work");
        assert!(!seen[r.id as usize], "request {} answered twice", r.id);
        seen[r.id as usize] = true;
        assert_eq!(r.data, expected[r.id as usize], "byte drift on {}", r.id);
    }
    assert!(seen.iter().all(|&s| s), "every in-flight request must complete");

    assert_eq!(pool.dead_devices(), vec![0, 1]);
    let plan = pool.plan();
    assert_eq!(plan.assignments[0].replicas, 1, "two kills shrink 3 replicas to 1");
    assert_eq!(plan.assignments[0].devices, vec![2]);
    assert_eq!(pool.metrics.snapshot().device_kills, 2);

    // a doubled deployment would leak duplicate responses: after a fresh
    // verified wave the stream must be exactly empty
    let reqs = client.synth_requests(20, 0xD00D);
    let expected: Vec<Vec<i8>> = reqs.iter().map(|r| client.reference(&r.data)).collect();
    for r in reqs {
        pool.submit("fc_small", r).unwrap();
    }
    for _ in 0..20 {
        let r = client.done.recv().expect("shrunken deployment must serve");
        assert_eq!(r.data, expected[r.id as usize]);
    }
    std::thread::sleep(std::time::Duration::from_millis(50));
    assert!(client.done.try_recv().is_none(), "no duplicate responses may trail");
    pool.shutdown();
}
