//! Integration: the zero-copy batched data plane must be observationally
//! identical to the pre-arena per-request path — synthetic reference
//! bytes pinned against golden literals (computed independently from the
//! published transform definition), live responses bit-exact across
//! exclusive, shared, and replica grants, loadgen CSV tables byte-stable,
//! and the steady-state allocation counter flat on a live pool.

use tpu_pipeline::cli::{self, Args};
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::coordinator::batcher::BatchPolicy;
use tpu_pipeline::scheduler::{
    allocate, synthetic_reference, tenant_salt, AllocatorConfig, BackendKind, ModelRegistry,
    DeployOptions, PoolRouter, ServingPool, TenantShape,
};
use tpu_pipeline::util::rng::Rng;

/// The synthetic data plane's byte contract, pinned to golden literals:
/// any refactor of the transform, the batch packing, or the slab layout
/// that changes a single output byte fails here — this is the "identical
/// to the pre-arena path" guarantee, since these literals were produced
/// by the pre-batching definition of the transform.
#[test]
fn synthetic_reference_matches_golden_bytes() {
    let salt = tenant_salt("fc_small");
    assert_eq!(salt, 0x60993f99409f7002, "FNV-1a tenant key changed");

    // fc_small = fc_model(512): 64 -> 512 x4 -> 10
    let layer_out_elems = [512usize, 512, 512, 512, 10];
    let input = Rng::new(0xD47A ^ salt).i8_vec(64);
    assert_eq!(
        &input[..8],
        &[-81, 92, -121, -28, -28, 78, 4, -56],
        "seeded request payloads changed"
    );
    let out = synthetic_reference(salt, &layer_out_elems, &input);
    assert_eq!(
        out,
        vec![-27, 17, 36, 15, 14, -20, -74, -75, -108, 11],
        "synthetic reference bytes drifted from the pre-arena path"
    );
}

#[test]
fn synthetic_transform_matches_golden_bytes() {
    use tpu_pipeline::scheduler::synthetic_transform;
    assert_eq!(
        synthetic_transform(7, &[1, 2, 3], 8),
        vec![95, -100, 118, 10, 5, -94, 111, 111],
        "keyed transform bytes drifted"
    );
}

/// Serve every grant shape live and verify byte-identity to the serial
/// reference (which the golden test above pins), through the closed-batch
/// router: exclusive, time-shared, and replica deployments.
#[test]
fn closed_batches_are_byte_identical_across_grant_shapes() {
    let cfg = SystemConfig::default();
    let cases: [(&str, Vec<&str>, AllocatorConfig); 3] = [
        (
            "exclusive",
            vec!["fc_small", "conv_a"],
            AllocatorConfig { total_tpus: 2, ..Default::default() },
        ),
        (
            "shared",
            vec!["fc_small", "fc_n512"],
            AllocatorConfig { total_tpus: 1, allow_sharing: true, ..Default::default() },
        ),
        (
            "replica",
            vec!["fc_small"],
            AllocatorConfig { total_tpus: 3, ..Default::default() },
        ),
    ];
    for (label, names, alloc) in cases {
        let mut reg = ModelRegistry::new();
        for n in &names {
            reg.register_named(n).unwrap();
        }
        let plan = allocate(&reg, &cfg, &alloc).unwrap();
        assert_eq!(plan.assignments.len(), names.len(), "{label}: {:?}", plan.queued);
        match label {
            "shared" => assert!(plan.assignments.iter().all(|a| a.grant.is_shared())),
            "replica" => assert!(plan.assignments[0].replicas > 1),
            _ => assert!(plan.assignments.iter().all(|a| !a.grant.is_shared())),
        }
        let router =
            PoolRouter::deploy(
                &plan,
                &reg,
                &cfg,
                &BackendKind::Synthetic,
                DeployOptions::new().with_queue_capacity(16),
            )
            .unwrap();
        router.wait_ready().unwrap();
        for name in &names {
            let t = router.tenant(name).unwrap();
            let reqs = t.synth_requests(25, 0xD47A);
            let expected: Vec<Vec<i8>> =
                reqs.iter().map(|r| t.reference(&r.data)).collect();
            let out = router.serve(name, reqs).unwrap();
            assert_eq!(out.len(), 25, "{label}/{name}");
            for (i, r) in out.iter().enumerate() {
                assert_eq!(r.id, i as u64, "{label}/{name}: order");
                assert_eq!(r.data, expected[i], "{label}/{name}: byte drift");
            }
        }
        router.shutdown();
    }
}

/// The open-loop pool path (batcher -> slab -> send_many completion)
/// must deliver the same bytes, including under a shared grant.
#[test]
fn open_loop_responses_are_byte_identical_under_sharing() {
    let mut reg = ModelRegistry::new();
    reg.register_named("fc_small").unwrap();
    reg.register_named("fc_n512").unwrap();
    let pool = ServingPool::deploy(
        reg,
        SystemConfig::default(),
        AllocatorConfig { total_tpus: 1, allow_sharing: true, ..Default::default() },
        BackendKind::Synthetic,
        DeployOptions {
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(1),
            },
            queue_capacity: 32,
            ..Default::default()
        },
    )
    .unwrap();
    for name in ["fc_small", "fc_n512"] {
        let client = pool.client(name).unwrap();
        let reqs = client.synth_requests(40, 7);
        let expected: Vec<Vec<i8>> =
            reqs.iter().map(|r| client.reference(&r.data)).collect();
        for r in reqs {
            pool.submit(name, r).unwrap();
        }
        let mut got = 0;
        while got < 40 {
            let r = client.done.recv().expect("stream closed early");
            assert_eq!(r.data, expected[r.id as usize], "{name}: byte drift");
            got += 1;
        }
    }
    // the pool-wide arena recycled across both tenants
    let dp = pool.data_plane().snapshot();
    assert!(dp.slab_reuses > 0, "shared arena must have recycled: {dp:?}");
    pool.shutdown();
}

/// `repro loadgen --csv` tables are a pure function of the seed across
/// every grant shape (the CSV comes from the deterministic queueing
/// simulation, which the data-plane rework must not touch).
#[test]
fn loadgen_csv_is_byte_stable_across_grant_shapes() {
    let cases = [
        // exclusive grants
        "loadgen --models fc_small,conv_a --tpus 2 --seed 7 --requests 80 \
         --arrivals poisson:600 --csv",
        // time-shared grants (+ quantum)
        "loadgen --models fc_small,fc_n512 --tpus 1 --allow-sharing --quantum-us 500 \
         --seed 7 --requests 80 --arrivals poisson:600 --csv",
        // replica fan-out
        "loadgen --models fc_small --tpus 2 --max-tpus-per-model 1 --seed 7 \
         --requests 80 --arrivals poisson:600 --csv",
    ];
    for cmd in cases {
        let argv: Vec<String> = cmd.split_whitespace().map(String::from).collect();
        let args = Args::parse(&argv).unwrap();
        let first = cli::run(&args).unwrap();
        let second = cli::run(&args).unwrap();
        assert_eq!(first, second, "CSV must be byte-identical: {cmd}");
        assert!(first.contains("admitted"), "{cmd}: {first}");
    }
}

/// Steady-state zero-allocation on a live pool, exactly as the
/// `make smoke-dataplane` gate runs it (via the `repro dataplane`
/// command with a zero budget).
#[test]
fn dataplane_smoke_command_passes_with_zero_budget() {
    let argv: Vec<String> =
        "dataplane --models fc_small --tpus 1 --alloc-budget 0 --batch 20 \
         --warmup 2 --iters 3 --open-warmup 15 --open-requests 25"
            .split_whitespace()
            .map(String::from)
            .collect();
    let args = Args::parse(&argv).unwrap();
    let out = cli::run(&args).unwrap();
    assert!(out.contains("PASS"), "{out}");
    assert!(!out.contains("FAIL"), "{out}");
    assert!(out.contains("within the allocation budget"), "{out}");
}

/// TenantShape is the shared (Arc'd) shape record: its request/reference
/// helpers must agree with the golden pins.
#[test]
fn tenant_shape_agrees_with_reference() {
    let model = tpu_pipeline::scheduler::resolve_model("fc_small").unwrap();
    let shape = TenantShape::of("fc_small", &model);
    assert_eq!(shape.in_elems, 64);
    assert_eq!(shape.out_elems, 10);
    assert_eq!(shape.layer_out_elems, vec![512, 512, 512, 512, 10]);
    let reqs = shape.synth_requests(1, 0xD47A);
    assert_eq!(
        shape.reference(&reqs[0].data),
        vec![-27, 17, 36, 15, 14, -20, -74, -75, -108, 11],
        "shape-derived reference drifted from the golden bytes"
    );
}
