//! Integration: the paper-reproduction harness end-to-end — every CLI
//! command renders, CSV output is well-formed, and the qualitative claims
//! of the evaluation hold in the generated tables themselves.

use tpu_pipeline::cli::{self, Args};
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::segment::strategy::Strategy;
use tpu_pipeline::sweep::{batch_sweep, headline, Kind};

fn run(cmd: &str) -> String {
    let argv: Vec<String> = cmd.split_whitespace().map(String::from).collect();
    cli::run(&Args::parse(&argv).unwrap()).unwrap()
}

#[test]
fn all_commands_render() {
    let out = run("all");
    for needle in [
        "Fig 2a (FC)", "Fig 2a (CONV)", "Fig 2b", "Fig 2c", "Fig 4", "§V-B", "Fig 5",
        "Fig 6", "Table I", "Table II", "Table III", "Table IV", "Table V", "Table VI",
    ] {
        assert!(out.contains(needle), "missing {needle}");
    }
}

#[test]
fn csv_outputs_have_uniform_arity() {
    for cmd in ["fig2a --csv", "fig2b --csv --kind conv", "fig6 --csv", "table3 --csv"] {
        let out = run(cmd);
        let mut lines = out.lines();
        let cols = lines.next().unwrap().split(',').count();
        for (i, line) in lines.enumerate() {
            assert_eq!(line.split(',').count(), cols, "{cmd}: row {i}");
        }
    }
}

#[test]
fn table5_profiled_fc_has_no_host_usage_where_table3b_does() {
    // §V-C: profiling eliminates the host spill the default split causes
    let t3b = run("table3b --csv");
    let t5 = run("table5 --csv");
    let host_cols = |csv: &str| -> Vec<f64> {
        csv.lines()
            .skip(1)
            .flat_map(|l| {
                l.split(',')
                    .skip(3 + 3) // x, macs, split, dev1..dev3
                    .map(|v| v.parse::<f64>().unwrap())
                    .collect::<Vec<_>>()
            })
            .collect()
    };
    let default_host: f64 = host_cols(&t3b).iter().sum();
    let profiled_host: f64 = host_cols(&t5).iter().sum();
    assert!(default_host > 5.0, "default split should spill (got {default_host})");
    assert!(profiled_host == 0.0, "profiled split must not spill (got {profiled_host})");
}

#[test]
fn fig6_headline_magnitudes() {
    let cfg = SystemConfig::default();
    let fc = headline(Kind::Fc, &cfg, Strategy::ProfiledExhaustive { batch: 50 }, 50);
    let conv = headline(Kind::Conv, &cfg, Strategy::ProfiledExhaustive { batch: 50 }, 50);
    // paper abstract: 46x FC, 6x CONV
    assert!((fc.best_speedup - 46.0).abs() < 10.0, "FC {fc:?}");
    assert!((conv.best_speedup - 6.0).abs() < 3.0, "CONV {conv:?}");
    assert!(fc.best_speedup > conv.best_speedup * 4.0);
}

#[test]
fn conv_segmentation_hurts_pre_spill_batched() {
    // §V-B: "in many models it is still slower than 1 TPU"
    let cfg = SystemConfig::default();
    let pts = batch_sweep(Kind::Conv, &cfg, Strategy::Uniform, 50);
    // small models: communication dominates -> outright loss
    let small: Vec<_> = pts.iter().filter(|p| p.x <= 180).collect();
    let losing = small.iter().filter(|p| p.speedup_vs_one_tpu[3] < 1.0).count();
    assert!(
        losing * 2 >= small.len(),
        "most small CONV points should lose with 4-way segmentation"
    );
    // the whole pre-spill band: "very poor" at best (<1.5x)
    for p in pts.iter().filter(|p| p.x <= 350) {
        assert!(
            p.speedup_vs_one_tpu[3] < 1.5,
            "x={}: {:?}",
            p.x,
            p.speedup_vs_one_tpu
        );
    }
}

#[test]
fn optimum_is_minimum_tpus_that_avoid_host() {
    // §V-C: "the optimum is to use the minimum number of TPUs that avoids
    // using host memory" — for FC models with one spilled layer, 2 TPUs
    // beat 3 and 4 (extra hops cost, no extra memory benefit needed)
    let cfg = SystemConfig::default();
    let pts = batch_sweep(Kind::Fc, &cfg, Strategy::ProfiledExhaustive { batch: 50 }, 50);
    // one-spilled-layer band: n in (1620 .. 1980)
    let p = pts.iter().find(|p| p.x == 1740).unwrap();
    let s2 = p.speedup_vs_one_tpu[1];
    let s3 = p.speedup_vs_one_tpu[2];
    let s4 = p.speedup_vs_one_tpu[3];
    assert!(s2 >= s3 && s2 >= s4, "n=1740: s2={s2:.1} s3={s3:.1} s4={s4:.1}");
}
