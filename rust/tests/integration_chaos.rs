//! Integration: the deterministic fault-injection suite (DESIGN.md §14)
//! end-to-end — the `repro chaos` CSV as a per-seed golden artifact, and
//! the live pool's reactions to each fault kind: device kill (re-plan +
//! drain replay, bit-exact), injected straggler (hedged dispatch), and
//! overload (priority-tiered shedding that turns low tiers away *before*
//! the backlog can breach anyone's SLO, with exact accounting — shed is
//! never silent, admitted work is never lost).

use std::collections::BTreeSet;
use std::time::Duration;

use tpu_pipeline::cli::{self, Args};
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::coordinator::HedgeConfig;
use tpu_pipeline::scheduler::{
    Admission, AllocatorConfig, BackendKind, DeployOptions, ModelRegistry, ServingPool,
    TenantClient,
};

fn run(cmd: &str) -> String {
    let argv: Vec<String> = cmd.split_whitespace().map(String::from).collect();
    cli::run(&Args::parse(&argv).unwrap()).unwrap()
}

fn pool(models: &[&str], tpus: usize, opts: DeployOptions) -> ServingPool {
    let mut registry = ModelRegistry::new();
    for m in models {
        registry.register_named(m).unwrap();
    }
    ServingPool::deploy(
        registry,
        SystemConfig::default(),
        AllocatorConfig { total_tpus: tpus, ..Default::default() },
        BackendKind::Synthetic,
        opts,
    )
    .unwrap()
}

/// Submit a seeded wave and verify every response byte against the serial
/// reference.
fn wave(pool: &ServingPool, client: &TenantClient, name: &str, n: usize, seed: u64) {
    let reqs = client.synth_requests(n, seed);
    let expected: Vec<Vec<i8>> = reqs.iter().map(|r| client.reference(&r.data)).collect();
    for r in reqs {
        pool.submit(name, r).unwrap();
    }
    for _ in 0..n {
        let r = client.done.recv().expect("completion stream closed early");
        assert_eq!(r.data, expected[r.id as usize], "{name}: byte drift on {}", r.id);
    }
}

/// `repro chaos --csv` is a golden artifact: a pure function of its flags,
/// byte-identical across runs of one seed, sensitive to the seed, and
/// scheduling every requested fault kind on a replicated deployment.
#[test]
fn chaos_csv_is_a_per_seed_golden_artifact() {
    let cmd = "chaos --models fc_small --tpus 3 --max-tpus-per-model 1 --seed 7 \
               --requests 120 --arrivals poisson:900 --kills 1 --stragglers 1 \
               --overloads 1 --csv";
    let first = run(cmd);
    let second = run(cmd);
    assert_eq!(first, second, "same seed must render the identical chaos CSV");
    assert!(first.starts_with("model,arrivals,replicas,events"), "{first}");

    let header: Vec<&str> = first.lines().next().unwrap().split(',').collect();
    let row: Vec<&str> = first.lines().nth(1).unwrap().split(',').collect();
    let col = |name: &str| {
        row[header.iter().position(|c| *c == name).unwrap_or_else(|| panic!("{name}"))]
    };
    assert_eq!(col("replicas"), "3", "{first}");
    // one of each fault kind actually landed in the schedule
    assert_eq!(col("events"), "k1/s1/o1", "{first}");
    // accounting invariants hold in the rendered artifact itself
    let n = |name: &str| col(name).parse::<u64>().unwrap();
    assert_eq!(n("submitted"), n("admitted") + n("shed"), "{first}");
    assert_eq!(n("completed"), n("admitted"), "{first}");

    let other = run(&cmd.replace("--seed 7", "--seed 8"));
    assert_ne!(first, other, "the seed must drive the fault schedule");
}

/// A device dies mid-run with work in flight: the pool re-plans around
/// it, the drained requests replay on the survivors, and every admitted
/// request — drained or fresh — verifies bit-exact.  Nothing is lost.
#[test]
fn device_kill_mid_run_recovers_bit_exact() {
    let p = pool(&["fc_small", "conv_a"], 4, DeployOptions::default());
    let names = p.names();
    let n = 40usize;
    let mut pending = Vec::new();
    for name in &names {
        let client = p.client(name).unwrap();
        let reqs = client.synth_requests(n, 0xC0FFEE);
        let expected: Vec<Vec<i8>> =
            reqs.iter().map(|r| client.reference(&r.data)).collect();
        for r in reqs {
            p.submit(name, r).unwrap();
        }
        pending.push((name.clone(), client, expected));
    }

    let victim = p.plan().assignments[0].devices[0];
    let report = p.kill_device(victim).unwrap();
    assert!(report.drained >= 1, "an assigned device must drain its deployment");

    for (name, client, expected) in &pending {
        for _ in 0..n {
            let r = client.done.recv().expect("drain must replay, not drop");
            assert_eq!(r.data, expected[r.id as usize], "{name}: drift on {}", r.id);
        }
    }
    assert!(p.dead_devices().contains(&victim));
    assert_eq!(p.metrics.snapshot().device_kills, 1);
    for a in p.plan().assignments.iter() {
        assert!(
            a.devices.iter().all(|d| d != &victim),
            "{}: dead device must leave the plan",
            a.name
        );
    }
    // survivors keep serving bit-exact after the re-plan
    for name in &report.admitted {
        let client = p.client(name).unwrap();
        wave(&p, &client, name, 20, 0xAF7E);
    }
    p.shutdown();
}

/// An injected replica straggler must trigger hedged dispatch — and the
/// hedge's first-response-wins merge must never corrupt or duplicate a
/// response (every wave verifies bit-exact).
#[test]
fn hedge_fires_on_injected_straggler() {
    let p = pool(
        &["fc_small"],
        3,
        DeployOptions {
            hedge: Some(HedgeConfig { p99_factor: 2.0, min_samples: 4 }),
            ..Default::default()
        },
    );
    assert_eq!(p.plan().assignment("fc_small").unwrap().replicas, 3);
    let client = p.client("fc_small").unwrap();
    // warm every replica's latency record, then slow replica 0 down
    wave(&p, &client, "fc_small", 30, 51);
    p.inject_straggler("fc_small", 0, Duration::from_millis(15)).unwrap();
    wave(&p, &client, "fc_small", 30, 52);
    wave(&p, &client, "fc_small", 30, 53);
    // responses ship before the worker books the hedge delta — settle
    std::thread::sleep(Duration::from_millis(50));
    let snap = p.tenant_metrics("fc_small").unwrap().snapshot();
    assert!(snap.hedges >= 1, "straggling replica must trigger hedges: {snap:?}");
    assert_eq!(snap.completed, 90, "hedging must not duplicate completions");
    p.shutdown();
}

/// Tiered shedding under a backlog: tier 0 is never turned away, lower
/// tiers shed once the queue crosses their (lower) thresholds — before
/// the backlog can grow into an SLO breach — and the accounting is exact:
/// submitted == completed for accepted work, shed requests get a verdict
/// at admission time and never a response.
#[test]
fn shedding_turns_low_tiers_away_before_the_backlog_breaches() {
    let p = pool(
        &["fc_small"],
        3,
        DeployOptions { queue_capacity: 4, ..Default::default() },
    );
    let replicas = p.plan().assignment("fc_small").unwrap().replicas;
    assert_eq!(replicas, 3);
    // slow every replica so the burst actually backs the ingress queue up
    for r in 0..replicas {
        p.inject_straggler("fc_small", r, Duration::from_millis(20)).unwrap();
    }
    let client = p.client("fc_small").unwrap();
    let reqs = client.synth_requests(60, 0x5105);
    let expected: Vec<Vec<i8>> = reqs.iter().map(|r| client.reference(&r.data)).collect();

    let mut accepted: BTreeSet<u64> = BTreeSet::new();
    let mut shed_by_tier = [0u64; 3];
    for (i, r) in reqs.into_iter().enumerate() {
        // tier pattern 0,2,1,0,2,1,...: blocking tier-0 keeps the queue
        // near-full while the low-tier attempts probe admission
        let tier = [0u8, 2, 1][i % 3];
        match p.submit_with_priority("fc_small", r, tier).unwrap() {
            Admission::Accepted => {
                accepted.insert(i as u64);
            }
            Admission::Shed => {
                assert_ne!(tier, 0, "tier 0 must never be shed");
                shed_by_tier[tier as usize] += 1;
            }
            Admission::Expired => unreachable!("no deadlines in this test"),
        }
    }
    let shed: u64 = shed_by_tier.iter().sum();
    assert!(shed >= 1, "a 4-deep queue behind 20 ms replicas must shed");
    assert_eq!(shed_by_tier[0], 0);
    assert_eq!(accepted.len() as u64 + shed, 60, "every request got a verdict");

    // every accepted request completes bit-exact; shed ones never appear
    for _ in 0..accepted.len() {
        let r = client.done.recv().expect("stream closed with accepted work pending");
        assert!(accepted.contains(&r.id), "shed request {} must not complete", r.id);
        assert_eq!(r.data, expected[r.id as usize], "byte drift on {}", r.id);
    }
    std::thread::sleep(Duration::from_millis(50));
    assert!(client.done.try_recv().is_none(), "no response may trail the accounting");
    let snap = p.tenant_metrics("fc_small").unwrap().snapshot();
    assert_eq!(snap.shed, shed, "shed must be metered, not silent");
    assert_eq!(snap.submitted, accepted.len() as u64);
    assert_eq!(snap.completed, accepted.len() as u64);
    p.shutdown();
}
