//! Lock-free span tracer for the live serving path, plus a deterministic
//! sim-clock span recorder for the workload simulator.
//!
//! Live side: each worker thread asks the shared [`Tracer`] for a
//! [`SpanSink`] — a private fixed-capacity ring of atomic slots.  Recording
//! a span is four relaxed word stores plus one release store (the slot's
//! validity word), no locks and no allocation, so the hot path stays
//! inside the data plane's zero-alloc budget; when a ring fills, further
//! spans are counted as dropped instead of blocking.  [`Tracer::drain`]
//! merges every ring into one deterministic ordering — call it at
//! quiescence (workers joined / pool shut down).
//!
//! Sim side: [`SimTrace`] records the same [`SpanEvent`]s but stamped from
//! the simulator's virtual clock (seconds since epoch zero), so two runs
//! with the same seed yield byte-identical traces (DESIGN.md §13).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// What part of the request lifecycle a span covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// A request entered an ingress queue (instant, duration 0).
    Enqueue,
    /// Time a request sat queued between arrival and its batch flush.
    Wait,
    /// A dynamic batch flushed into the pipeline (instant, duration 0).
    Flush,
    /// One stage backend executing one batch (`run_batch`).
    Stage,
    /// A time-shared tenant re-loading parameters after a quantum switch.
    Swap,
    /// End-to-end request residency: arrival to response.
    Response,
    /// An injected fault and the pool's recovery from it (device kill →
    /// re-plan complete), recorded on the chaos track.
    Fault,
    /// A parameter-cache prefetch overlapping the tail of the previous
    /// quantum (recorded on the tenant's [`CACHE_TRACK`]).
    Prefetch,
    /// A drift-triggered online recalibration: cost-model write-back plus
    /// the re-plan that followed, recorded on the chaos/control track.
    Recalibrate,
    /// A request shed because its deadline expired before it could be
    /// dispatched (instant, recorded on the tenant's request track).
    /// Expired requests never start a [`SpanKind::Stage`] span.
    Deadline,
    /// A replica circuit breaker tripping open (consecutive watchdog
    /// breaches), recorded on the chaos/control track.
    Trip,
    /// The control plane warm-restarting from its recovery journal
    /// (journal replay to pool ready), recorded on the chaos track.
    Recover,
}

impl SpanKind {
    /// Stable name used in trace files (`ph:"X"` event names).
    pub fn label(self) -> &'static str {
        match self {
            SpanKind::Enqueue => "enqueue",
            SpanKind::Wait => "wait",
            SpanKind::Flush => "flush",
            SpanKind::Stage => "stage",
            SpanKind::Swap => "swap",
            SpanKind::Response => "response",
            SpanKind::Fault => "fault",
            SpanKind::Prefetch => "prefetch",
            SpanKind::Recalibrate => "recalibrate",
            SpanKind::Deadline => "deadline",
            SpanKind::Trip => "trip",
            SpanKind::Recover => "recover",
        }
    }

    /// Inverse of [`SpanKind::label`] (for loading saved traces).
    pub fn from_label(s: &str) -> Option<SpanKind> {
        Some(match s {
            "enqueue" => SpanKind::Enqueue,
            "wait" => SpanKind::Wait,
            "flush" => SpanKind::Flush,
            "stage" => SpanKind::Stage,
            "swap" => SpanKind::Swap,
            "response" => SpanKind::Response,
            "fault" => SpanKind::Fault,
            "prefetch" => SpanKind::Prefetch,
            "recalibrate" => SpanKind::Recalibrate,
            "deadline" => SpanKind::Deadline,
            "trip" => SpanKind::Trip,
            "recover" => SpanKind::Recover,
            _ => return None,
        })
    }

    fn code(self) -> u64 {
        match self {
            SpanKind::Enqueue => 0,
            SpanKind::Wait => 1,
            SpanKind::Flush => 2,
            SpanKind::Stage => 3,
            SpanKind::Swap => 4,
            SpanKind::Response => 5,
            SpanKind::Fault => 6,
            SpanKind::Prefetch => 7,
            SpanKind::Recalibrate => 8,
            SpanKind::Deadline => 9,
            SpanKind::Trip => 10,
            SpanKind::Recover => 11,
        }
    }

    fn from_code(c: u64) -> SpanKind {
        match c {
            0 => SpanKind::Enqueue,
            1 => SpanKind::Wait,
            2 => SpanKind::Flush,
            3 => SpanKind::Stage,
            4 => SpanKind::Swap,
            6 => SpanKind::Fault,
            7 => SpanKind::Prefetch,
            8 => SpanKind::Recalibrate,
            9 => SpanKind::Deadline,
            10 => SpanKind::Trip,
            11 => SpanKind::Recover,
            _ => SpanKind::Response,
        }
    }
}

/// One completed span: microsecond timestamps on either the monotonic
/// process clock (live serving, relative to the tracer's epoch) or the
/// simulator's virtual clock (deterministic loadgen traces).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    pub kind: SpanKind,
    /// Render track (Perfetto thread id): see [`track_base`].
    pub track: u32,
    /// Scope id: request id for lifecycle spans, batch ordinal for
    /// flush/stage/swap spans.
    pub id: u64,
    pub start_us: u64,
    pub dur_us: u64,
}

impl SpanEvent {
    /// Deterministic ordering key (start, track, id, kind).
    fn key(&self) -> (u64, u32, u64, u64) {
        (self.start_us, self.track, self.id, self.kind.code())
    }
}

/// Track-id convention shared by the sim and live paths: each tenant owns
/// a block of [`TRACKS_PER_TENANT`] consecutive tracks (0 = request
/// lifecycle, 1 = batcher, 2.. = stage workers per replica), so traces
/// from either clock domain render identically.
pub const TRACKS_PER_TENANT: u32 = 64;

/// First track of tenant `idx` (tenants in admission order).
pub fn track_base(idx: usize) -> u32 {
    idx as u32 * TRACKS_PER_TENANT
}

/// Tenant-local track (offset from [`track_base`]) carrying parameter-cache
/// spans: the last track of the tenant's block, far above any stage worker.
pub const CACHE_TRACK: u32 = TRACKS_PER_TENANT - 1;

const SLOT_WORDS: usize = 4;
const VALID_BIT: u64 = 1 << 63;

/// Fixed-capacity span ring: slots of four atomic words
/// `[start_us, dur_us, id, valid|kind<<32|track]`.  Single producer per
/// ring (each worker gets its own via [`Tracer::handle`]); the claim
/// counter keeps growing past capacity so the overflow is observable.
struct Ring {
    slots: Vec<[AtomicU64; SLOT_WORDS]>,
    head: AtomicU64,
}

impl Ring {
    fn new(capacity: usize) -> Ring {
        let slots = (0..capacity.max(1))
            .map(|_| std::array::from_fn(|_| AtomicU64::new(0)))
            .collect();
        Ring { slots, head: AtomicU64::new(0) }
    }

    fn record(&self, e: SpanEvent) {
        let claim = self.head.fetch_add(1, Ordering::Relaxed);
        if claim >= self.slots.len() as u64 {
            return; // full: count as dropped (head - capacity), never block
        }
        let slot = &self.slots[claim as usize];
        slot[0].store(e.start_us, Ordering::Relaxed);
        slot[1].store(e.dur_us, Ordering::Relaxed);
        slot[2].store(e.id, Ordering::Relaxed);
        // the validity word is published last, so a drain racing a
        // half-written slot skips it instead of reading torn fields
        let word = VALID_BIT | (e.kind.code() << 32) | e.track as u64;
        slot[3].store(word, Ordering::Release);
    }

    fn drain_into(&self, out: &mut Vec<SpanEvent>) -> u64 {
        let head = self.head.load(Ordering::Relaxed);
        let filled = (head as usize).min(self.slots.len());
        for slot in &self.slots[..filled] {
            let word = slot[3].load(Ordering::Acquire);
            if word & VALID_BIT == 0 {
                continue;
            }
            out.push(SpanEvent {
                kind: SpanKind::from_code((word >> 32) & 0x7FFF_FFFF),
                track: word as u32,
                id: slot[2].load(Ordering::Relaxed),
                start_us: slot[0].load(Ordering::Relaxed),
                dur_us: slot[1].load(Ordering::Relaxed),
            });
        }
        head.saturating_sub(self.slots.len() as u64)
    }
}

/// Spans per [`SpanSink`] ring (per worker thread).
pub const DEFAULT_RING_CAPACITY: usize = 64 * 1024;

/// Process-wide span collector for the live serving path.  Workers record
/// through per-thread [`SpanSink`]s; the registry and track names sit
/// behind a mutex touched only at setup/drain time, never per span.
pub struct Tracer {
    epoch: Instant,
    rings: Mutex<Vec<Arc<Ring>>>,
    track_names: Mutex<std::collections::BTreeMap<u32, String>>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer {
            epoch: Instant::now(),
            rings: Mutex::new(Vec::new()),
            track_names: Mutex::new(std::collections::BTreeMap::new()),
        }
    }

    /// Microseconds since this tracer was created (the live clock domain
    /// of every span it collects).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Register a new per-thread sink with the default ring capacity.
    pub fn handle(self: &Arc<Self>) -> SpanSink {
        self.handle_with_capacity(DEFAULT_RING_CAPACITY)
    }

    /// Register a new per-thread sink holding up to `capacity` spans.
    pub fn handle_with_capacity(self: &Arc<Self>, capacity: usize) -> SpanSink {
        let ring = Arc::new(Ring::new(capacity));
        self.rings.lock().unwrap().push(ring.clone());
        SpanSink { tracer: self.clone(), ring }
    }

    /// Attach a human-readable name to a render track (setup-time only).
    pub fn name_track(&self, track: u32, name: impl Into<String>) {
        self.track_names.lock().unwrap().insert(track, name.into());
    }

    /// Snapshot of the named tracks.
    pub fn track_names(&self) -> std::collections::BTreeMap<u32, String> {
        self.track_names.lock().unwrap().clone()
    }

    /// Merge every ring into one deterministically ordered event list,
    /// returning `(events, dropped)`.  Call at quiescence (all recording
    /// threads joined); a drain racing an in-flight record skips the
    /// half-written slot.
    pub fn drain(&self) -> (Vec<SpanEvent>, u64) {
        let rings = self.rings.lock().unwrap();
        let mut events = Vec::new();
        let mut dropped = 0;
        for ring in rings.iter() {
            dropped += ring.drain_into(&mut events);
        }
        events.sort_by_key(|e| e.key());
        (events, dropped)
    }
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let rings = self.rings.lock().unwrap().len();
        write!(f, "Tracer {{ rings: {rings} }}")
    }
}

/// Per-thread recording handle (one private ring).  Cheap to clone the
/// `Arc`s inside, but each clone still writes the same ring — ask the
/// tracer for a fresh handle per producer thread instead.
#[derive(Clone)]
pub struct SpanSink {
    tracer: Arc<Tracer>,
    ring: Arc<Ring>,
}

impl SpanSink {
    /// Microseconds since the owning tracer's epoch.
    pub fn now_us(&self) -> u64 {
        self.tracer.now_us()
    }

    /// Record one completed span (lock-free, allocation-free).
    pub fn record(&self, kind: SpanKind, track: u32, id: u64, start_us: u64, dur_us: u64) {
        self.ring.record(SpanEvent { kind, track, id, start_us, dur_us });
    }
}

impl std::fmt::Debug for SpanSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SpanSink")
    }
}

/// Deterministic span recorder for the workload simulator: timestamps are
/// the sim's virtual clock in seconds, converted to whole microseconds, so
/// trace files are byte-identical per seed.
#[derive(Debug, Clone, Default)]
pub struct SimTrace {
    events: Vec<SpanEvent>,
}

impl SimTrace {
    pub fn new() -> SimTrace {
        SimTrace::default()
    }

    /// Record a span from sim-clock seconds (`end_s >= start_s`; negative
    /// times clamp to zero — the sim epoch).
    pub fn record_s(&mut self, kind: SpanKind, track: u32, id: u64, start_s: f64, end_s: f64) {
        let start_us = (start_s.max(0.0) * 1e6).round() as u64;
        let end_us = (end_s.max(0.0) * 1e6).round() as u64;
        self.events.push(SpanEvent {
            kind,
            track,
            id,
            start_us,
            dur_us: end_us.saturating_sub(start_us),
        });
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Events in the same deterministic order [`Tracer::drain`] uses.
    pub fn into_events(mut self) -> Vec<SpanEvent> {
        self.events.sort_by_key(|e| e.key());
        self.events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_records_and_drains_in_order() {
        let t = Arc::new(Tracer::new());
        let sink = t.handle_with_capacity(16);
        sink.record(SpanKind::Stage, 2, 7, 100, 50);
        sink.record(SpanKind::Flush, 1, 0, 40, 0);
        let (events, dropped) = t.drain();
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, SpanKind::Flush);
        assert_eq!(events[0].start_us, 40);
        assert_eq!(events[1].kind, SpanKind::Stage);
        assert_eq!(events[1].id, 7);
        assert_eq!(events[1].dur_us, 50);
    }

    #[test]
    fn full_ring_counts_drops_instead_of_blocking() {
        let t = Arc::new(Tracer::new());
        let sink = t.handle_with_capacity(4);
        for i in 0..10 {
            sink.record(SpanKind::Enqueue, 0, i, i, 0);
        }
        let (events, dropped) = t.drain();
        assert_eq!(events.len(), 4);
        assert_eq!(dropped, 6);
    }

    #[test]
    fn concurrent_sinks_merge_deterministically() {
        let t = Arc::new(Tracer::new());
        let handles: Vec<_> = (0..4u32)
            .map(|track| {
                let sink = t.handle_with_capacity(1024);
                std::thread::spawn(move || {
                    for i in 0..100u64 {
                        sink.record(SpanKind::Stage, track, i, i * 10, 5);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let (events, dropped) = t.drain();
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 400);
        // drain order is a deterministic total order regardless of thread
        // interleaving: sorted by (start, track, id, kind)
        let keys: Vec<_> = events.iter().map(|e| (e.start_us, e.track)).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn kind_labels_roundtrip() {
        for k in [
            SpanKind::Enqueue,
            SpanKind::Wait,
            SpanKind::Flush,
            SpanKind::Stage,
            SpanKind::Swap,
            SpanKind::Response,
            SpanKind::Fault,
            SpanKind::Prefetch,
            SpanKind::Recalibrate,
            SpanKind::Deadline,
            SpanKind::Trip,
            SpanKind::Recover,
        ] {
            assert_eq!(SpanKind::from_label(k.label()), Some(k));
            assert_eq!(SpanKind::from_code(k.code()), k);
        }
        assert_eq!(SpanKind::from_label("nope"), None);
    }

    #[test]
    fn sim_trace_stamps_whole_microseconds() {
        let mut s = SimTrace::new();
        s.record_s(SpanKind::Response, 0, 3, 1.25e-3, 2.5e-3);
        s.record_s(SpanKind::Flush, 1, 0, -1.0, 0.0); // clamps to epoch
        let events = s.into_events();
        assert_eq!(events[0].start_us, 0);
        assert_eq!(events[1].start_us, 1250);
        assert_eq!(events[1].dur_us, 1250);
    }
}
