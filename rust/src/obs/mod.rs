//! Unified telemetry layer (DESIGN.md §13): request-lifecycle span
//! tracing, streaming metric snapshots, and deterministic trace export.
//!
//! Three pieces:
//!
//! * [`span`] — a lock-free per-thread ring-buffer span tracer for the
//!   live serving path, and a sim-clock twin ([`span::SimTrace`]) whose
//!   output is byte-deterministic per seed.
//! * [`export`] — Chrome/Perfetto trace-event JSON plus JSONL metric
//!   snapshots, both built on the in-repo stable-order JSON writer.
//! * [`MetricSource`] — the uniform snapshot interface every metrics
//!   struct implements, so `--metrics-out` files and the end-of-run human
//!   tables render from the same data.

pub mod export;
pub mod span;

pub use export::{metric_line, metric_line_from, num, TraceFile};
pub use span::{SimTrace, SpanEvent, SpanKind, SpanSink, Tracer};

use crate::util::json::Json;

/// A metrics struct that can export its current counters uniformly: a
/// stable `kind` tag naming the snapshot type and the counters as one
/// JSON object (stable key order — the JSONL/`--metrics-out` contract).
pub trait MetricSource {
    /// Snapshot-type tag (`"stage"`, `"serve"`, `"tenant"`,
    /// `"data_plane"`, `"scheduler"`).
    fn metric_kind(&self) -> &'static str;

    /// Current counters as a JSON object; non-finite values (empty
    /// histograms) map to `null` via [`num`].
    fn metric_json(&self) -> Json;
}
