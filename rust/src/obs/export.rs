//! Trace and metric export: Chrome/Perfetto trace-event JSON for span
//! traces, and JSONL metric snapshots through the [`MetricSource`] trait.
//!
//! Both formats are built on [`crate::util::json::Json`] (object keys in
//! `BTreeMap` order, integers printed without exponents), so a trace of
//! the deterministic simulator serializes byte-identically per seed — the
//! same reproducibility bar as the loadgen CSVs (`make smoke-trace`).
//!
//! [`MetricSource`]: crate::obs::MetricSource

use std::collections::BTreeMap;

use anyhow::Result;

use crate::obs::span::{SpanEvent, SpanKind, Tracer};
use crate::obs::MetricSource;
use crate::util::json::Json;

/// Map a metric value to JSON, turning the NaN/infinity sentinels of
/// empty histograms into `null` (JSON has no non-finite numbers).
pub fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// A span trace plus its render-track names, loadable from / dumpable to
/// Chrome trace-event JSON (chrome://tracing, <https://ui.perfetto.dev>).
#[derive(Debug, Clone, Default)]
pub struct TraceFile {
    /// Process name shown in the trace viewer (e.g. the repro command).
    pub process: String,
    /// Track (Perfetto thread) names, keyed by track id.
    pub tracks: BTreeMap<u32, String>,
    /// Spans in deterministic order (see `SpanEvent` ordering).
    pub events: Vec<SpanEvent>,
    /// Spans lost to full rings (0 for sim traces).
    pub dropped: u64,
}

impl TraceFile {
    pub fn new(process: impl Into<String>) -> TraceFile {
        TraceFile { process: process.into(), ..TraceFile::default() }
    }

    /// Drain a live tracer into a trace file (call at quiescence).
    pub fn from_tracer(process: impl Into<String>, tracer: &Tracer) -> TraceFile {
        let (events, dropped) = tracer.drain();
        TraceFile { process: process.into(), tracks: tracer.track_names(), events, dropped }
    }

    /// Name a render track.
    pub fn name_track(&mut self, track: u32, name: impl Into<String>) {
        self.tracks.insert(track, name.into());
    }

    /// Viewer label for `track` ("track{N}" when unnamed).
    pub fn track_label(&self, track: u32) -> String {
        self.tracks.get(&track).cloned().unwrap_or_else(|| format!("track{track}"))
    }

    /// Serialize as a Chrome trace-event JSON document: one `ph:"M"`
    /// process-name record, one per named track, then every span as a
    /// `ph:"X"` complete event (`ts`/`dur` in microseconds).
    pub fn to_json(&self) -> String {
        let mut trace_events = Vec::with_capacity(self.events.len() + self.tracks.len() + 1);
        let meta = |name: &str, tid: Option<u32>, value: &str| {
            let mut o = BTreeMap::new();
            o.insert("ph".to_string(), Json::Str("M".to_string()));
            o.insert("pid".to_string(), Json::Num(1.0));
            if let Some(t) = tid {
                o.insert("tid".to_string(), Json::Num(t as f64));
            }
            o.insert("name".to_string(), Json::Str(name.to_string()));
            let mut args = BTreeMap::new();
            args.insert("name".to_string(), Json::Str(value.to_string()));
            o.insert("args".to_string(), Json::Obj(args));
            Json::Obj(o)
        };
        trace_events.push(meta("process_name", None, &self.process));
        for (&track, name) in &self.tracks {
            trace_events.push(meta("thread_name", Some(track), name));
        }
        for e in &self.events {
            let mut o = BTreeMap::new();
            o.insert("ph".to_string(), Json::Str("X".to_string()));
            o.insert("pid".to_string(), Json::Num(1.0));
            o.insert("tid".to_string(), Json::Num(e.track as f64));
            o.insert("name".to_string(), Json::Str(e.kind.label().to_string()));
            o.insert("ts".to_string(), Json::Num(e.start_us as f64));
            o.insert("dur".to_string(), Json::Num(e.dur_us as f64));
            let mut args = BTreeMap::new();
            args.insert("id".to_string(), Json::Num(e.id as f64));
            o.insert("args".to_string(), Json::Obj(args));
            trace_events.push(Json::Obj(o));
        }
        let mut top = BTreeMap::new();
        top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
        let mut other = BTreeMap::new();
        other.insert("dropped".to_string(), Json::Num(self.dropped as f64));
        top.insert("otherData".to_string(), Json::Obj(other));
        top.insert("traceEvents".to_string(), Json::Arr(trace_events));
        let mut s = Json::Obj(top).dump();
        s.push('\n');
        s
    }

    /// Load a trace previously written by [`TraceFile::to_json`].  Events
    /// with names outside the span vocabulary are skipped (foreign traces
    /// render partially instead of failing).
    pub fn parse(text: &str) -> Result<TraceFile> {
        let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("trace json: {e}"))?;
        let mut out = TraceFile::default();
        out.dropped = doc.at(&["otherData", "dropped"]).and_then(Json::as_u64).unwrap_or(0);
        let events = doc
            .get("traceEvents")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("trace json: missing traceEvents array"))?;
        for e in events {
            let ph = e.get("ph").and_then(Json::as_str).unwrap_or("");
            let name = e.get("name").and_then(Json::as_str).unwrap_or("");
            match ph {
                "M" => {
                    let value = e.at(&["args", "name"]).and_then(Json::as_str).unwrap_or("");
                    if name == "process_name" {
                        out.process = value.to_string();
                    } else if name == "thread_name" {
                        if let Some(tid) = e.get("tid").and_then(Json::as_u64) {
                            out.tracks.insert(tid as u32, value.to_string());
                        }
                    }
                }
                "X" => {
                    let Some(kind) = SpanKind::from_label(name) else { continue };
                    out.events.push(SpanEvent {
                        kind,
                        track: e.get("tid").and_then(Json::as_u64).unwrap_or(0) as u32,
                        id: e.at(&["args", "id"]).and_then(Json::as_u64).unwrap_or(0),
                        start_us: e.get("ts").and_then(Json::as_u64).unwrap_or(0),
                        dur_us: e.get("dur").and_then(Json::as_u64).unwrap_or(0),
                    });
                }
                _ => {}
            }
        }
        Ok(out)
    }
}

/// One JSONL metric-snapshot line: the source's fields plus `kind` (the
/// snapshot type tag) and `name` (the instance, e.g. a tenant).  Stable
/// key order, one `\n`-terminated object per line.
pub fn metric_line(source: &dyn MetricSource, name: &str) -> String {
    metric_line_from(source.metric_kind(), name, source.metric_json())
}

/// [`metric_line`] from an already-built snapshot object (the sim-side
/// exporters build their fields directly).
pub fn metric_line_from(kind: &str, name: &str, fields: Json) -> String {
    let mut o = match fields {
        Json::Obj(o) => o,
        other => {
            let mut o = BTreeMap::new();
            o.insert("value".to_string(), other);
            o
        }
    };
    o.insert("kind".to_string(), Json::Str(kind.to_string()));
    o.insert("name".to_string(), Json::Str(name.to_string()));
    let mut s = Json::Obj(o).dump();
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn sample() -> TraceFile {
        let mut f = TraceFile::new("unit");
        f.name_track(0, "tenant/requests");
        f.name_track(2, "tenant/stage0");
        f.events = vec![
            SpanEvent { kind: SpanKind::Flush, track: 1, id: 0, start_us: 10, dur_us: 0 },
            SpanEvent { kind: SpanKind::Stage, track: 2, id: 4, start_us: 12, dur_us: 30 },
            SpanEvent { kind: SpanKind::Response, track: 0, id: 4, start_us: 5, dur_us: 40 },
        ];
        f
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let f = sample();
        let text = f.to_json();
        let back = TraceFile::parse(&text).unwrap();
        assert_eq!(back.process, "unit");
        assert_eq!(back.tracks, f.tracks);
        assert_eq!(back.events, f.events);
        assert_eq!(back.dropped, 0);
        // a second serialization is byte-identical (stable key order)
        assert_eq!(back.to_json(), text);
    }

    #[test]
    fn json_is_chrome_trace_shaped() {
        let text = sample().to_json();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 1 process meta + 2 track metas + 3 spans
        assert_eq!(events.len(), 6);
        assert_eq!(events[0].get("ph").and_then(Json::as_str), Some("M"));
        let x = &events[3];
        assert_eq!(x.get("ph").and_then(Json::as_str), Some("X"));
        assert!(x.get("ts").is_some() && x.get("dur").is_some());
    }

    #[test]
    fn from_tracer_carries_track_names_and_drops() {
        let t = Arc::new(Tracer::new());
        t.name_track(3, "pool/stage1");
        let sink = t.handle_with_capacity(2);
        for i in 0..5 {
            sink.record(SpanKind::Stage, 3, i, i * 100, 10);
        }
        let f = TraceFile::from_tracer("live", &t);
        assert_eq!(f.events.len(), 2);
        assert_eq!(f.dropped, 3);
        assert_eq!(f.track_label(3), "pool/stage1");
        assert_eq!(f.track_label(9), "track9");
        let back = TraceFile::parse(&f.to_json()).unwrap();
        assert_eq!(back.dropped, 3);
    }

    #[test]
    fn metric_lines_are_single_json_objects() {
        let mut fields = BTreeMap::new();
        fields.insert("completed".to_string(), Json::Num(8.0));
        fields.insert("p99_s".to_string(), num(f64::NAN));
        let line = metric_line_from("tenant", "fc_small", Json::Obj(fields));
        assert!(line.ends_with('\n'));
        let doc = Json::parse(line.trim_end()).unwrap();
        assert_eq!(doc.get("kind").and_then(Json::as_str), Some("tenant"));
        assert_eq!(doc.get("name").and_then(Json::as_str), Some("fc_small"));
        assert_eq!(doc.get("completed").and_then(Json::as_u64), Some(8));
        assert_eq!(doc.get("p99_s"), Some(&Json::Null));
    }
}
