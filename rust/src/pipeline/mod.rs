//! Pipelined multi-TPU execution model (paper §V, Fig 3).
//!
//! Stages are TPUs; items flow `host -> TPU_0 -> host -> TPU_1 -> ... ->
//! host`.  Every handoff crosses PCIe twice and pays a host-thread
//! overhead (the paper implements stages as Python threads + queues).
//!
//! The simulator is the exact pipeline recurrence (equivalent to a
//! discrete-event simulation of FIFO stages with unbounded — or bounded —
//! queues), with two Edge-TPU-specific effects:
//!
//! * **DMA occupies the device**: a stage's service time includes moving
//!   its input and output activations over PCIe (no compute/transfer
//!   overlap) — this is what makes CONV segmentation a net loss for small
//!   models even under batching (§V-B).
//! * **GIL-serialized host**: the per-item stage overhead (Python worker
//!   thread + queue handoff) is executed by a single host server shared by
//!   ALL stages, so pipeline throughput can never exceed one item per
//!   `n_stages * stage_overhead` — this is why the optimum is the minimum
//!   number of TPUs that avoids host memory (§V-C).
//!
//! ```text
//! dispatch(i, k) = max(arrive(i, k), finish(i, k-1), host_free)
//! host_free      = dispatch + overhead
//! finish(i, k)   = dispatch + overhead + in_xfer_i + exec_i + out_xfer_i
//! arrive(i+1,k)  = finish(i, k) + hop_latency
//! ```
//!
//! With bounded queues, `dispatch(i-1, ·)` additionally blocks until there
//! is queue room downstream (backpressure).

use crate::compiler::{place, Placement};
use crate::config::SystemConfig;
use crate::device::CostModel;
use crate::link::Link;
use crate::model::Model;
use crate::segment::Partition;

/// Per-stage timing inputs for the simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct StageSpec {
    /// On-TPU execution time per item (incl. host weight streaming).
    pub exec_s: f64,
    /// Input tensor bytes (transfer into this stage).
    pub in_bytes: u64,
    /// Output tensor bytes (transfer out of this stage).
    pub out_bytes: u64,
}

/// Build stage specs for a partition of a model under the cost model.
pub fn build_stages(model: &Model, partition: &Partition, cfg: &SystemConfig) -> Vec<StageSpec> {
    let cm = CostModel::new(cfg.clone());
    partition
        .segments(model)
        .iter()
        .map(|seg| {
            let placement: Placement = place(seg, &cfg.device);
            let cost = cm.stage_cost(&placement);
            StageSpec {
                exec_s: cost.exec_s(),
                in_bytes: seg.first().unwrap().input_elems(),
                out_bytes: seg.last().unwrap().output_elems(),
            }
        })
        .collect()
}

/// One scheduled execution interval (for Gantt traces).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GanttEntry {
    pub stage: usize,
    pub item: usize,
    pub start_s: f64,
    pub end_s: f64,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct PipelineResult {
    /// Wall-clock to finish the whole batch (last output lands on host).
    pub makespan_s: f64,
    /// Per-item end-to-end latencies (input submitted -> output on host).
    pub latencies_s: Vec<f64>,
    /// Per-stage total busy time.
    pub stage_busy_s: Vec<f64>,
    /// Execution schedule (stage x item intervals).
    pub gantt: Vec<GanttEntry>,
}

impl PipelineResult {
    /// Batch-amortized time per inference (the paper's §V-B metric).
    pub fn per_item_s(&self, batch: usize) -> f64 {
        self.makespan_s / batch as f64
    }

    /// Stage utilization over the makespan.
    pub fn utilization(&self) -> Vec<f64> {
        self.stage_busy_s.iter().map(|b| b / self.makespan_s).collect()
    }

    /// Index of the bottleneck stage.
    pub fn bottleneck(&self) -> usize {
        self.stage_busy_s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Simulation knobs.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Items in the batch.
    pub batch: usize,
    /// Bounded inter-stage queue capacity (None = unbounded, the paper's
    /// Python `queue.Queue()` default).
    pub queue_capacity: Option<usize>,
    /// Record the Gantt schedule.
    pub record_gantt: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { batch: 1, queue_capacity: None, record_gantt: false }
    }
}

/// Simulate the pipelined execution of `batch` items through `stages`.
///
/// Event-driven: repeatedly dispatch, among all stages with a ready item,
/// the one whose dispatch time (`max(ready, stage_free, host_free)`) is
/// earliest — i.e. the shared host server is granted FCFS in *simulated*
/// time.  With `stage_overhead = 0` this reduces to the classical tandem
/// recurrence (`makespan = Σ service + (B-1)·max service`).
pub fn simulate(stages: &[StageSpec], link: &Link, opts: &SimOptions) -> PipelineResult {
    assert!(!stages.is_empty() && opts.batch > 0);
    let s = stages.len();
    let b = opts.batch;
    let overhead = link.stage_overhead_s();

    // per-stage total service time: overhead + DMA in + exec + DMA out
    let service: Vec<f64> = stages
        .iter()
        .map(|st| {
            overhead + link.xfer_s(st.in_bytes) + st.exec_s + link.xfer_s(st.out_bytes)
        })
        .collect();

    // per-stage FIFO of (item, ready_time); all items ready at stage 0 at t=0
    let mut queues: Vec<std::collections::VecDeque<(usize, f64)>> =
        (0..s).map(|_| std::collections::VecDeque::new()).collect();
    for k in 0..b {
        queues[0].push_back((k, 0.0));
    }
    let mut stage_free = vec![0.0f64; s];
    let mut host_free = 0.0f64;
    let mut latencies = vec![0.0f64; b];
    let mut busy = vec![0.0f64; s];
    let mut gantt = Vec::new();
    let mut makespan = 0.0f64;
    let mut remaining = b * s;

    while remaining > 0 {
        // candidate dispatch per stage (head of its queue, FIFO)
        let mut best: Option<(f64, usize)> = None; // (dispatch_t, stage)
        for i in 0..s {
            let Some(&(_, ready)) = queues[i].front() else { continue };
            // bounded downstream queue: block before service (the worker
            // cannot take a new item while it has nowhere to put it)
            if let Some(cap) = opts.queue_capacity {
                if i + 1 < s && queues[i + 1].len() >= cap {
                    continue;
                }
            }
            let t = ready.max(stage_free[i]).max(host_free);
            // prefer later stages on ties so downstream drains first
            let better = match best {
                None => true,
                Some((bt, bi)) => t < bt - 1e-15 || ((t - bt).abs() <= 1e-15 && i > bi),
            };
            if better {
                best = Some((t, i));
            }
        }
        let (t, i) = best.expect("pipeline stalled: no dispatchable stage");
        let (item, _) = queues[i].pop_front().unwrap();
        host_free = t + overhead;
        let finish = t + service[i];
        stage_free[i] = finish;
        busy[i] += service[i];
        if opts.record_gantt {
            gantt.push(GanttEntry { stage: i, item, start_s: t, end_s: finish });
        }
        if i + 1 < s {
            queues[i + 1].push_back((item, finish + link.hop_latency_s()));
        } else {
            latencies[item] = finish; // submitted at t=0
            makespan = makespan.max(finish);
        }
        remaining -= 1;
    }

    PipelineResult { makespan_s: makespan, latencies_s: latencies, stage_busy_s: busy, gantt }
}

/// Convenience: simulate a model/partition pair end-to-end.
pub fn simulate_partition(
    model: &Model,
    partition: &Partition,
    cfg: &SystemConfig,
    opts: &SimOptions,
) -> PipelineResult {
    let stages = build_stages(model, partition, cfg);
    simulate(&stages, &Link::new(cfg.link.clone()), opts)
}

/// Single-TPU, single-input latency (the paper's baseline): input
/// transfer + whole-model execution + output transfer, no pipeline
/// overheads.
pub fn single_tpu_latency_s(model: &Model, cfg: &SystemConfig) -> f64 {
    let cm = CostModel::new(cfg.clone());
    let link = Link::new(cfg.link.clone());
    let p = place(&model.layers, &cfg.device);
    link.xfer_s(model.layers.first().unwrap().input_elems())
        + cm.stage_cost(&p).exec_s()
        + link.xfer_s(model.layers.last().unwrap().output_elems())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::{conv_model, fc_model};
    use crate::segment::uniform_cuts;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn flat_stages(execs: &[f64]) -> Vec<StageSpec> {
        execs.iter().map(|&e| StageSpec { exec_s: e, in_bytes: 0, out_bytes: 0 }).collect()
    }

    /// Zero-byte link with no overheads isolates the pure recurrence.
    fn free_link() -> Link {
        Link::new(crate::config::LinkConfig {
            act_bw: f64::INFINITY,
            hop_latency_s: 0.0,
            stage_overhead_s: 0.0,
            ..Default::default()
        })
    }

    #[test]
    fn single_item_latency_is_sum() {
        let stages = flat_stages(&[1.0, 2.0, 3.0]);
        let r = simulate(&stages, &free_link(), &SimOptions::default());
        assert!((r.makespan_s - 6.0).abs() < 1e-12);
        assert_eq!(r.latencies_s.len(), 1);
    }

    #[test]
    fn steady_state_is_bottleneck_limited() {
        let stages = flat_stages(&[1.0, 5.0, 2.0]);
        let b = 100;
        let r = simulate(&stages, &free_link(), &SimOptions { batch: b, ..Default::default() });
        // fill (8) + (b-1) * bottleneck (5)
        let expect = 8.0 + (b as f64 - 1.0) * 5.0;
        assert!((r.makespan_s - expect).abs() < 1e-9, "makespan={}", r.makespan_s);
        assert_eq!(r.bottleneck(), 1);
    }

    #[test]
    fn utilization_bottleneck_near_one() {
        let stages = flat_stages(&[1.0, 5.0, 2.0]);
        let r = simulate(&stages, &free_link(), &SimOptions { batch: 200, ..Default::default() });
        let u = r.utilization();
        assert!(u[1] > 0.98, "u={u:?}");
        assert!(u[0] < 0.25);
    }

    #[test]
    fn bounded_queue_still_completes_and_is_slower_or_equal() {
        let stages = flat_stages(&[1.0, 5.0, 1.0]);
        let unb = simulate(&stages, &free_link(), &SimOptions { batch: 50, ..Default::default() });
        let bnd = simulate(
            &stages,
            &free_link(),
            &SimOptions { batch: 50, queue_capacity: Some(1), record_gantt: false },
        );
        assert!(bnd.makespan_s >= unb.makespan_s - 1e-12);
        assert_eq!(bnd.latencies_s.len(), 50);
    }

    #[test]
    fn gantt_entries_are_consistent() {
        let stages = flat_stages(&[1.0, 2.0]);
        let r = simulate(
            &stages,
            &free_link(),
            &SimOptions { batch: 3, queue_capacity: None, record_gantt: true },
        );
        assert_eq!(r.gantt.len(), 6);
        for e in &r.gantt {
            assert!(e.end_s > e.start_s);
        }
        // per-stage intervals do not overlap
        for stage in 0..2 {
            let mut xs: Vec<_> = r.gantt.iter().filter(|e| e.stage == stage).collect();
            xs.sort_by(|a, b| a.start_s.partial_cmp(&b.start_s).unwrap());
            for w in xs.windows(2) {
                assert!(w[1].start_s >= w[0].end_s - 1e-12);
            }
        }
    }

    /// Paper Fig 4 (FC): once the single-TPU placement spills to host,
    /// segmenting onto 2 TPUs beats 1 TPU even for a SINGLE input.
    #[test]
    fn fc_single_input_segmentation_wins_after_spill() {
        let cfg = cfg();
        let m = fc_model(2100);
        let t1 = single_tpu_latency_s(&m, &cfg);
        // 2 TPUs: one segment still spills one layer -> partial win
        let r2 = simulate_partition(&m, &uniform_cuts(5, 2), &cfg, &SimOptions::default());
        assert!(r2.makespan_s < 0.7 * t1, "t1={t1} t2={}", r2.makespan_s);
        // 4 TPUs: everything fits on-device -> order-of-magnitude win
        let r4 = simulate_partition(&m, &uniform_cuts(5, 4), &cfg, &SimOptions::default());
        assert!(r4.makespan_s < t1 / 3.0, "t1={t1} t4={}", r4.makespan_s);
    }

    /// ...but for models that fit on one TPU, segmentation only adds
    /// communication (slightly slower), §V-A.
    #[test]
    fn fc_single_input_segmentation_costs_pre_spill() {
        let cfg = cfg();
        let m = fc_model(1000);
        let t1 = single_tpu_latency_s(&m, &cfg);
        let r4 = simulate_partition(&m, &uniform_cuts(5, 4), &cfg, &SimOptions::default());
        assert!(r4.makespan_s > t1, "t1={t1} t4={}", r4.makespan_s);
        // "practically negligible compared with the difference between
        // steps" (steps are ~7-11 ms)
        assert!(r4.makespan_s - t1 < 5e-3);
    }

    /// CONV single input: intermediates are so large that segmented runs
    /// are clearly slower than single-TPU pre-spill (paper Fig 4 bottom).
    #[test]
    fn conv_single_input_segmentation_clearly_slower() {
        let cfg = cfg();
        let m = conv_model(300);
        let t1 = single_tpu_latency_s(&m, &cfg);
        let r3 = simulate_partition(&m, &uniform_cuts(5, 3), &cfg, &SimOptions::default());
        assert!(r3.makespan_s > t1 * 1.2, "t1={t1} t3={}", r3.makespan_s);
    }

    #[test]
    fn property_makespan_bounds() {
        crate::util::proptest::forall(128, |rng| {
            let s = rng.below(5) as usize + 1;
            let b = rng.below(40) as usize + 1;
            let execs: Vec<f64> = (0..s).map(|_| rng.f64_range(1e-4, 1e-2)).collect();
            let stages = flat_stages(&execs);
            let r = simulate(&stages, &free_link(), &SimOptions { batch: b, ..Default::default() });
            let sum: f64 = execs.iter().sum();
            let bneck = execs.iter().cloned().fold(0.0, f64::max);
            // lower bounds: pipeline can't beat fill + bottleneck stream
            crate::check!(r.makespan_s >= sum - 1e-12, "fill");
            crate::check!(r.makespan_s >= bneck * b as f64 - 1e-12, "bneck");
            // exact for deterministic stage times:
            let expect = sum + (b as f64 - 1.0) * bneck;
            crate::check!((r.makespan_s - expect).abs() < 1e-9, "expect={expect} got={}", r.makespan_s);
            // latency of first item == sum of stage times
            crate::check!((r.latencies_s[0] - sum).abs() < 1e-9, "lat0");
            Ok(())
        });
    }
}
