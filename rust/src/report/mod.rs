//! Aligned-text / CSV table rendering for the paper-reproduction harness
//! (every `repro <table|fig>` command prints through this).

use crate::util::json::Json;

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with per-column alignment (numbers right, text left).
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let numeric: Vec<bool> = (0..ncols)
            .map(|i| {
                self.rows.iter().all(|r| {
                    let c = r[i].trim();
                    c.is_empty() || c.parse::<f64>().is_ok() || c.ends_with('x')
                })
            })
            .collect();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String], out: &mut String| {
            let mut parts = Vec::with_capacity(ncols);
            for (i, c) in cells.iter().enumerate() {
                if numeric[i] {
                    parts.push(format!("{:>width$}", c, width = widths[i]));
                } else {
                    parts.push(format!("{:<width$}", c, width = widths[i]));
                }
            }
            out.push_str(&parts.join("  "));
            out.push('\n');
        };
        fmt_row(&self.headers, &mut out);
        out.push_str(&format!("{}\n", "-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1))));
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }

    /// CSV export (for plotting).
    pub fn csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format helpers shared by harness commands.
pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn ms(v_s: f64) -> String {
    format!("{:.2}", v_s * 1e3)
}

pub fn speedup(v: f64) -> String {
    format!("{v:.1}x")
}

/// Render `MetricSource` snapshots — `(kind, name, fields)` triples, as
/// collected by `repro serve-pool` / `repro dataplane` — as one flat
/// human table, one row per metric field.  The display twin of the
/// `--metrics-out` JSONL (`obs::metric_line_from`): both read the same
/// snapshot objects, so the table never drifts from the machine export.
pub fn metrics_table(entries: &[(String, String, Json)]) -> Table {
    let mut t = Table::new("End-of-run metrics", &["kind", "name", "metric", "value"]);
    for (kind, name, fields) in entries {
        match fields {
            Json::Obj(map) => {
                for (k, v) in map {
                    t.row(vec![kind.clone(), name.clone(), k.clone(), cell(v)]);
                }
            }
            other => t.row(vec![kind.clone(), name.clone(), "value".into(), cell(other)]),
        }
    }
    t
}

/// One metric value as a table cell ("-" for null, JSON otherwise).
fn cell(v: &Json) -> String {
    match v {
        Json::Null => "-".to_string(),
        other => other.dump(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "val"]);
        t.row(vec!["a".into(), "1.5".into()]);
        t.row(vec!["bbbb".into(), "22.25".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        // lines: [0] title, [1] headers, [2] separator, [3..] rows
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[3].len(), lines[4].len());
        // numeric column right-aligned
        assert!(lines[3].ends_with("1.5"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn metrics_table_flattens_snapshots() {
        let mut fields = std::collections::BTreeMap::new();
        fields.insert("completed".to_string(), Json::Num(40.0));
        fields.insert("p99_s".to_string(), Json::Null);
        let entries = vec![("tenant".to_string(), "fc_small".to_string(), Json::Obj(fields))];
        let s = metrics_table(&entries).render();
        assert!(s.contains("End-of-run metrics"), "{s}");
        assert!(s.contains("completed"), "{s}");
        assert!(s.contains("40"), "{s}");
        // null metrics (empty histograms) render as "-"
        let p99_row = s.lines().find(|l| l.contains("p99_s")).unwrap();
        assert!(p99_row.trim_end().ends_with('-'), "{s}");
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }
}
