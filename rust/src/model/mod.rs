//! Model IR: the layer graph the compiler places and the pipeline executes.
//!
//! Mirrors `python/compile/specs.py` (the build-time twin that materializes
//! weights): linear chains of FC or 3x3/stride-1/SAME CONV layers, with the
//! paper's MAC and weight-byte accounting as methods.

pub mod synthetic;

/// Layer kind + dimensions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layer {
    /// Dense: `(in_features,) -> (out_features,)`.
    Fc { in_features: u64, out_features: u64 },
    /// 3x3 stride-1 SAME conv: `(h, w, cin) -> (h, w, filters)`.
    Conv { height: u64, width: u64, cin: u64, filters: u64, ksize: u64 },
}

/// Layer family, used where cost constants differ (arithmetic intensity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Fc,
    Conv,
}

impl Layer {
    pub fn kind(&self) -> LayerKind {
        match self {
            Layer::Fc { .. } => LayerKind::Fc,
            Layer::Conv { .. } => LayerKind::Conv,
        }
    }

    /// MAC operations for one inference (paper §III-A).
    pub fn macs(&self) -> u64 {
        match *self {
            Layer::Fc { in_features, out_features } => in_features * out_features,
            Layer::Conv { height, width, cin, filters, ksize } => {
                height * width * cin * filters * ksize * ksize
            }
        }
    }

    /// int8 weight bytes (biases excluded, as in the paper's accounting —
    /// they grow linearly and are asymptotically negligible).
    pub fn weight_bytes(&self) -> u64 {
        match *self {
            Layer::Fc { in_features, out_features } => in_features * out_features,
            Layer::Conv { cin, filters, ksize, .. } => ksize * ksize * cin * filters,
        }
    }

    /// int8 elements of the layer's input activation tensor.
    pub fn input_elems(&self) -> u64 {
        match *self {
            Layer::Fc { in_features, .. } => in_features,
            Layer::Conv { height, width, cin, .. } => height * width * cin,
        }
    }

    /// int8 elements of the layer's output activation tensor.
    pub fn output_elems(&self) -> u64 {
        match *self {
            Layer::Fc { out_features, .. } => out_features,
            Layer::Conv { height, width, filters, .. } => height * width * filters,
        }
    }

    /// Arithmetic intensity: MACs per weight byte (FC = 1; CONV = H·W —
    /// the reuse that makes CONV ~17x faster on the device, §III-B).
    pub fn intensity(&self) -> f64 {
        self.macs() as f64 / self.weight_bytes() as f64
    }
}

/// A model: a linear chain of layers (all the paper's synthetic models and
/// its segmentation machinery operate on chains).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Model {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Model {
    pub fn new(name: impl Into<String>, layers: Vec<Layer>) -> Self {
        let m = Model { name: name.into(), layers };
        m.validate();
        m
    }

    /// Chains must be shape-consistent: each layer consumes its
    /// predecessor's output.
    pub fn validate(&self) {
        for (i, pair) in self.layers.windows(2).enumerate() {
            let (a, b) = (&pair[0], &pair[1]);
            assert_eq!(
                a.output_elems(),
                b.input_elems(),
                "{}: layer {} output {} != layer {} input {}",
                self.name,
                i,
                a.output_elems(),
                i + 1,
                b.input_elems()
            );
        }
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    pub fn macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    pub fn weight_bytes(&self) -> u64 {
        self.layers.iter().map(Layer::weight_bytes).sum()
    }

    /// Dominant layer kind (the synthetic models are homogeneous; for
    /// mixed models this picks the kind holding the most weight bytes,
    /// which is what the host-streaming constant keys off).
    pub fn dominant_kind(&self) -> LayerKind {
        let conv: u64 = self
            .layers
            .iter()
            .filter(|l| l.kind() == LayerKind::Conv)
            .map(Layer::weight_bytes)
            .sum();
        if conv * 2 >= self.weight_bytes() {
            LayerKind::Conv
        } else {
            LayerKind::Fc
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_layer_accounting() {
        let l = Layer::Fc { in_features: 64, out_features: 100 };
        assert_eq!(l.macs(), 6400);
        assert_eq!(l.weight_bytes(), 6400);
        assert_eq!(l.input_elems(), 64);
        assert_eq!(l.output_elems(), 100);
        assert_eq!(l.intensity(), 1.0);
    }

    #[test]
    fn conv_layer_accounting() {
        let l = Layer::Conv { height: 64, width: 64, cin: 3, filters: 32, ksize: 3 };
        assert_eq!(l.macs(), 64 * 64 * 3 * 32 * 9);
        assert_eq!(l.weight_bytes(), 9 * 3 * 32);
        assert_eq!(l.intensity(), (64 * 64) as f64);
    }

    #[test]
    #[should_panic(expected = "output")]
    fn inconsistent_chain_panics() {
        Model::new(
            "bad",
            vec![
                Layer::Fc { in_features: 8, out_features: 16 },
                Layer::Fc { in_features: 17, out_features: 4 },
            ],
        );
    }

    #[test]
    fn dominant_kind_mixed() {
        let m = Model::new(
            "mix",
            vec![
                Layer::Conv { height: 8, width: 8, cin: 3, filters: 4, ksize: 3 },
                // flatten boundary isn't modeled; craft matching dims
                Layer::Fc { in_features: 8 * 8 * 4, out_features: 10_000 },
            ],
        );
        assert_eq!(m.dominant_kind(), LayerKind::Fc);
    }
}
