//! The paper's synthetic model generators and sweep grids (§III-A/B).
//!
//! FC: `L_FC = 5`, `I = 64`, `O = 10`, `n` in `[100, 2640]` step 40.
//! CONV: `L_CONV = 5`, `C = 3`, `W x H = 64 x 64`, `3 x 3` filters,
//! `f` in `[32, 702]` step 10.

use super::{Layer, Model};

/// Paper FC sweep parameters.
pub const FC_LAYERS: usize = 5;
pub const FC_INPUT: u64 = 64;
pub const FC_OUTPUT: u64 = 10;
pub const FC_N_MIN: u64 = 100;
pub const FC_N_MAX: u64 = 2640;
pub const FC_N_STEP: u64 = 40;

/// Paper CONV sweep parameters.
pub const CONV_LAYERS: usize = 5;
pub const CONV_C: u64 = 3;
pub const CONV_H: u64 = 64;
pub const CONV_W: u64 = 64;
pub const CONV_K: u64 = 3;
pub const CONV_F_MIN: u64 = 32;
pub const CONV_F_MAX: u64 = 702;
pub const CONV_F_STEP: u64 = 10;

/// `I -> n -> n -> n -> n -> O` dense chain.
pub fn fc_model(n: u64) -> Model {
    fc_model_custom(n, FC_LAYERS, FC_INPUT, FC_OUTPUT)
}

pub fn fc_model_custom(n: u64, layers: usize, input: u64, output: u64) -> Model {
    assert!(layers >= 2, "need >= 2 layers");
    let mut widths = vec![input];
    widths.extend(std::iter::repeat(n).take(layers - 1));
    widths.push(output);
    let layers = widths
        .windows(2)
        .map(|w| Layer::Fc { in_features: w[0], out_features: w[1] })
        .collect();
    Model::new(format!("fc_n{n}"), layers)
}

/// `C -> f -> f -> f -> f` channel conv chain over 64x64 images.
pub fn conv_model(f: u64) -> Model {
    conv_model_custom(f, CONV_LAYERS, CONV_C, CONV_H, CONV_W)
}

pub fn conv_model_custom(f: u64, layers: usize, c: u64, h: u64, w: u64) -> Model {
    assert!(layers >= 1);
    let mut cins = vec![c];
    cins.extend(std::iter::repeat(f).take(layers - 1));
    let layers = cins
        .iter()
        .map(|&cin| Layer::Conv { height: h, width: w, cin, filters: f, ksize: CONV_K })
        .collect();
    Model::new(format!("conv_f{f}"), layers)
}

/// The FC sweep grid (Fig 2, Fig 4–6 x-axes).
pub fn fc_sweep() -> Vec<Model> {
    (FC_N_MIN..=FC_N_MAX).step_by(FC_N_STEP as usize).map(fc_model).collect()
}

/// The CONV sweep grid.
pub fn conv_sweep() -> Vec<Model> {
    (CONV_F_MIN..=CONV_F_MAX).step_by(CONV_F_STEP as usize).map(conv_model).collect()
}

/// Heterogeneous dense chain from an explicit width list (paper §VI:
/// "more complex models, possibly with heterogeneous layers both in type
/// and number of nodes").  `widths = [i, h1, h2, ..., o]` gives
/// `len(widths) - 1` layers.
pub fn hetero_fc_model(name: &str, widths: &[u64]) -> Model {
    assert!(widths.len() >= 2);
    let layers = widths
        .windows(2)
        .map(|w| Layer::Fc { in_features: w[0], out_features: w[1] })
        .collect();
    Model::new(name.to_string(), layers)
}

/// A mixed CONV->FC chain (a CNN-classifier shape): `conv_layers` 3x3
/// convs over `h x w` with `f` filters, then dense layers over the
/// flattened feature map.
pub fn conv_fc_model(f: u64, conv_layers: usize, h: u64, w: u64, fc_out: &[u64]) -> Model {
    let mut layers = Vec::new();
    let mut cin = CONV_C;
    for _ in 0..conv_layers {
        layers.push(Layer::Conv { height: h, width: w, cin, filters: f, ksize: CONV_K });
        cin = f;
    }
    let mut infeat = h * w * f; // flatten
    for &o in fc_out {
        layers.push(Layer::Fc { in_features: infeat, out_features: o });
        infeat = o;
    }
    Model::new(format!("convfc_f{f}"), layers)
}

/// Closed-form FC MAC count the paper quotes: `I·n + (L-2)·n² + n·O`.
pub fn fc_macs_closed_form(n: u64) -> u64 {
    FC_INPUT * n + (FC_LAYERS as u64 - 2) * n * n + n * FC_OUTPUT
}

/// Closed-form CONV MAC count:
/// `W·H·f·Fw·Fh·(C + f·(L-1))` (paper §III-A).
pub fn conv_macs_closed_form(f: u64) -> u64 {
    CONV_W * CONV_H * f * CONV_K * CONV_K * (CONV_C + f * (CONV_LAYERS as u64 - 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerKind;

    #[test]
    fn fc_matches_closed_form() {
        for n in [100, 1140, 1580, 2100, 2640] {
            assert_eq!(fc_model(n).macs(), fc_macs_closed_form(n), "n={n}");
        }
    }

    #[test]
    fn conv_matches_closed_form() {
        for f in [32, 292, 442, 702] {
            assert_eq!(conv_model(f).macs(), conv_macs_closed_form(f), "f={f}");
        }
    }

    #[test]
    fn paper_table_anchor_points() {
        // Table I: first FC step sits between ~0.76e7 and ~0.79e7 MACs
        assert!((fc_model(1580).macs() as f64 - 0.76e7).abs() / 0.76e7 < 0.02);
        // Table II row 1: 2.88e10 MACs at f ~ 442
        assert!((conv_model(442).macs() as f64 - 2.88e10).abs() / 2.88e10 < 0.01);
    }

    #[test]
    fn sweep_sizes() {
        assert_eq!(fc_sweep().len(), ((FC_N_MAX - FC_N_MIN) / FC_N_STEP + 1) as usize);
        assert_eq!(
            conv_sweep().len(),
            ((CONV_F_MAX - CONV_F_MIN) / CONV_F_STEP + 1) as usize
        );
        // grid 100 + 40k stays within N_max = 2640 (last point is 2620)
        assert_eq!(fc_sweep().last().unwrap().layers[1].input_elems(), 2620);
    }

    #[test]
    fn hetero_fc_chain() {
        let m = hetero_fc_model("pyramid", &[64, 2048, 512, 128, 10]);
        assert_eq!(m.len(), 4);
        assert_eq!(m.macs(), 64 * 2048 + 2048 * 512 + 512 * 128 + 128 * 10);
        m.validate();
    }

    #[test]
    fn conv_fc_chain_is_consistent() {
        let m = conv_fc_model(32, 3, 32, 32, &[256, 10]);
        assert_eq!(m.len(), 5);
        // flatten boundary: conv out elems == fc in features
        assert_eq!(m.layers[2].output_elems(), m.layers[3].input_elems());
        assert_eq!(m.layers[3].input_elems(), 32 * 32 * 32);
        // heterogeneous arithmetic intensity: conv >> fc
        assert!(m.layers[0].intensity() > 100.0 * m.layers[3].intensity());
    }

    #[test]
    fn structure() {
        let m = fc_model(100);
        assert_eq!(m.len(), 5);
        assert_eq!(m.layers[0], Layer::Fc { in_features: 64, out_features: 100 });
        assert_eq!(m.layers[4], Layer::Fc { in_features: 100, out_features: 10 });

        let c = conv_model(32);
        assert_eq!(c.len(), 5);
        assert_eq!(c.layers[0].kind(), LayerKind::Conv);
        assert_eq!(c.layers[0].weight_bytes(), 9 * 3 * 32);
        assert_eq!(c.layers[1].weight_bytes(), 9 * 32 * 32);
    }
}
