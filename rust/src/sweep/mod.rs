//! The paper's experiment harness: parameter sweeps behind every figure
//! and table of the evaluation (see DESIGN.md §4 for the index).
//!
//! Everything here runs on the calibrated cost-model simulator (the real
//! testbed is simulated per DESIGN.md §1); numeric execution of the same
//! pipelines via PJRT lives in `examples/serve_pipeline.rs`.

use crate::compiler::{place, Location, Placement};
use crate::config::SystemConfig;
use crate::device::CostModel;
use crate::hostexec::cpu_time_s;
use crate::model::synthetic::{conv_sweep, fc_sweep};
use crate::model::Model;
use crate::pipeline::{simulate_partition, single_tpu_latency_s, SimOptions};
use crate::segment::strategy::Strategy;
use crate::segment::Partition;

/// Which synthetic family (the paper's two sweeps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Fc,
    Conv,
}

impl Kind {
    pub fn models(self) -> Vec<Model> {
        match self {
            Kind::Fc => fc_sweep(),
            Kind::Conv => conv_sweep(),
        }
    }

    /// The swept parameter (n or f) of a model in this family.
    pub fn x_of(self, model: &Model) -> u64 {
        match self {
            Kind::Fc => model.layers[0].output_elems(),
            Kind::Conv => model.layers[0].output_elems() / (64 * 64),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Kind::Fc => "FC",
            Kind::Conv => "CONV",
        }
    }
}

/// One point of the single-TPU sweep (Fig 2a/2b/2c, Tables I–II).
#[derive(Debug, Clone)]
pub struct SinglePoint {
    pub x: u64,
    pub macs: u64,
    pub time_s: f64,
    pub gops: f64,
    pub device_mib: f64,
    pub host_mib: f64,
    pub host_layers: usize,
    pub cpu_time_s: f64,
}

/// Fig 2: single-TPU inference time / GOPS / memory + CPU baseline.
pub fn single_tpu_sweep(kind: Kind, cfg: &SystemConfig) -> Vec<SinglePoint> {
    let cm = CostModel::new(cfg.clone());
    kind.models()
        .iter()
        .map(|m| {
            let p: Placement = place(&m.layers, &cfg.device);
            let cost = cm.stage_cost(&p);
            let t = cost.exec_s();
            SinglePoint {
                x: kind.x_of(m),
                macs: m.macs(),
                time_s: t,
                gops: m.macs() as f64 / t / 1e9,
                device_mib: p.device_mib(),
                host_mib: p.host_mib(),
                host_layers: p.layers.iter().filter(|l| l.location == Location::Host).count(),
                cpu_time_s: cpu_time_s(m, &cfg.cpu),
            }
        })
        .collect()
}

/// Table I/II rows: the (before, after) pair around every step — i.e.
/// every time a *large* layer moves to host memory (>0.5 MiB jump; the
/// tiny 10n output layer spilling is invisible in the paper's tables).
pub fn step_rows(points: &[SinglePoint]) -> Vec<(SinglePoint, SinglePoint)> {
    let mut out = Vec::new();
    for w in points.windows(2) {
        if w[1].host_mib - w[0].host_mib > 0.5 {
            out.push((w[0].clone(), w[1].clone()));
        }
    }
    out
}

/// One point of a multi-TPU sweep: per-segment-count results.
#[derive(Debug, Clone)]
pub struct MultiPoint {
    pub x: u64,
    pub macs: u64,
    /// Indexed by segment count - 1 (s = 1..=max_tpus).
    pub per_s: Vec<f64>,
}

pub const MAX_TPUS: usize = 4;

/// Fig 4 (default splits) / Fig 5-style (profiled): single-input latency
/// across 1..=4 TPUs.
pub fn single_input_sweep(kind: Kind, cfg: &SystemConfig, strategy: Strategy) -> Vec<MultiPoint> {
    kind.models()
        .iter()
        .map(|m| {
            let per_s = (1..=MAX_TPUS)
                .map(|s| {
                    let part = partition_for(m, s, cfg, strategy);
                    simulate_partition(m, &part, cfg, &SimOptions::default()).makespan_s
                })
                .collect();
            MultiPoint { x: kind.x_of(m), macs: m.macs(), per_s }
        })
        .collect()
}

/// One point of the batched sweep (§V-B, Fig 5, Fig 6).
#[derive(Debug, Clone)]
pub struct BatchPoint {
    pub x: u64,
    pub macs: u64,
    /// Batched per-inference time, indexed by s-1.
    pub per_item_s: Vec<f64>,
    /// Speedup vs the same partition on a single input.
    pub speedup_vs_single_input: Vec<f64>,
    /// Speedup vs the single-TPU baseline.
    pub speedup_vs_one_tpu: Vec<f64>,
}

/// Batched pipelined sweep with the given strategy.
pub fn batch_sweep(
    kind: Kind,
    cfg: &SystemConfig,
    strategy: Strategy,
    batch: usize,
) -> Vec<BatchPoint> {
    kind.models()
        .iter()
        .map(|m| {
            let t1 = single_tpu_latency_s(m, cfg);
            let mut per_item = Vec::with_capacity(MAX_TPUS);
            let mut vs_single = Vec::with_capacity(MAX_TPUS);
            let mut vs_one = Vec::with_capacity(MAX_TPUS);
            for s in 1..=MAX_TPUS {
                let part = partition_for(m, s, cfg, strategy);
                let single =
                    simulate_partition(m, &part, cfg, &SimOptions::default()).makespan_s;
                let batched = simulate_partition(
                    m,
                    &part,
                    cfg,
                    &SimOptions { batch, ..Default::default() },
                )
                .per_item_s(batch);
                per_item.push(batched);
                vs_single.push(single / batched);
                vs_one.push(t1 / batched);
            }
            BatchPoint {
                x: kind.x_of(m),
                macs: m.macs(),
                per_item_s: per_item,
                speedup_vs_single_input: vs_single,
                speedup_vs_one_tpu: vs_one,
            }
        })
        .collect()
}

fn partition_for(m: &Model, s: usize, cfg: &SystemConfig, strategy: Strategy) -> Partition {
    if s == 1 {
        Partition::whole(m.len())
    } else {
        strategy.partition(m, s, cfg)
    }
}

/// Memory-usage row for Tables III–VI: per-TPU device/host MiB.
#[derive(Debug, Clone)]
pub struct MemRow {
    pub x: u64,
    pub macs: u64,
    pub dev_mib: Vec<f64>,
    pub host_mib: Vec<f64>,
    pub label: String,
}

/// Per-device memory usage for given sweep values under a strategy.
pub fn memory_rows(
    kind: Kind,
    cfg: &SystemConfig,
    n_segments: usize,
    strategy: Strategy,
    xs: &[u64],
) -> Vec<MemRow> {
    let models: Vec<Model> = match kind {
        Kind::Fc => xs.iter().map(|&n| crate::model::synthetic::fc_model(n)).collect(),
        Kind::Conv => xs.iter().map(|&f| crate::model::synthetic::conv_model(f)).collect(),
    };
    models
        .iter()
        .map(|m| {
            let part = partition_for(m, n_segments, cfg, strategy);
            let placements: Vec<Placement> =
                part.segments(m).iter().map(|seg| place(seg, &cfg.device)).collect();
            MemRow {
                x: kind.x_of(m),
                macs: m.macs(),
                dev_mib: placements.iter().map(Placement::device_mib).collect(),
                host_mib: placements.iter().map(Placement::host_mib).collect(),
                label: part.label(),
            }
        })
        .collect()
}

/// Headline numbers (paper abstract: 46x FC / 6x CONV with profiling).
#[derive(Debug, Clone, Copy)]
pub struct Headline {
    pub best_speedup: f64,
    pub at_x: u64,
    pub n_tpus: usize,
}

pub fn headline(kind: Kind, cfg: &SystemConfig, strategy: Strategy, batch: usize) -> Headline {
    let mut best = Headline { best_speedup: 0.0, at_x: 0, n_tpus: 1 };
    for p in batch_sweep(kind, cfg, strategy, batch) {
        for (i, &sp) in p.speedup_vs_one_tpu.iter().enumerate() {
            if sp > best.best_speedup {
                best = Headline { best_speedup: sp, at_x: p.x, n_tpus: i + 1 };
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn fig2_stepped_behavior() {
        let pts = single_tpu_sweep(Kind::Fc, &cfg());
        let steps = step_rows(&pts);
        // paper: three steps in the FC sweep range
        assert!((2..=4).contains(&steps.len()), "steps={}", steps.len());
        // each step is a latency cliff
        for (before, after) in &steps {
            assert!(after.time_s > before.time_s * 1.5, "{before:?} -> {after:?}");
        }
        // within a step, time grows slowly (memory-bound plateau)
        assert!(pts[0].time_s < pts[10].time_s);
    }

    #[test]
    fn fig2_conv_steps() {
        let pts = single_tpu_sweep(Kind::Conv, &cfg());
        let steps = step_rows(&pts);
        assert!((2..=4).contains(&steps.len()), "steps={}", steps.len());
        // GOPS far above FC
        let fc = single_tpu_sweep(Kind::Fc, &cfg());
        let max_fc_gops = fc.iter().map(|p| p.gops).fold(0.0, f64::max);
        let max_conv_gops = pts.iter().map(|p| p.gops).fold(0.0, f64::max);
        let ratio = max_conv_gops / max_fc_gops;
        assert!((10.0..25.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn fig2c_cpu_vs_tpu() {
        // FC: CPU competitive once host spill begins; CONV: TPU far ahead
        let fc = single_tpu_sweep(Kind::Fc, &cfg());
        let spilled = fc.iter().find(|p| p.host_layers >= 2).unwrap();
        assert!(spilled.cpu_time_s < spilled.time_s, "CPU should win for spilled FC");
        let conv = single_tpu_sweep(Kind::Conv, &cfg());
        let last = conv.last().unwrap();
        assert!(last.cpu_time_s > 3.0 * last.time_s, "TPU should win big for CONV");
    }

    #[test]
    fn uniform_2_and_3_tpu_fc_degenerate() {
        // paper §V-A: uniform FC with 2 and 3 TPUs behave the same because
        // segment 1 of the 3-way split holds only the tiny input layer:
        // identical memory behaviour => identical step onsets, and nearly
        // identical times once weights (not fixed overheads) dominate.
        let pts = single_input_sweep(Kind::Fc, &cfg(), Strategy::Uniform);
        let onset = |s: usize| {
            pts.windows(2)
                .find(|w| w[1].per_s[s - 1] > 3.0 * w[0].per_s[s - 1])
                .map(|w| w[1].x)
        };
        assert_eq!(onset(2), onset(3), "same first spill point");
        for p in pts.iter().filter(|p| p.x >= 2100) {
            let (t2, t3) = (p.per_s[1], p.per_s[2]);
            assert!((t3 - t2).abs() / t2 < 0.15, "x={} t2={t2} t3={t3}", p.x);
        }
    }

    #[test]
    fn batched_speedup_collapses_on_host_spill() {
        // §V-B: speedup vs single input drops toward ~1 when a stage
        // needs host memory
        let cfg = cfg();
        let pts = batch_sweep(Kind::Fc, &cfg, Strategy::Uniform, 50);
        // find a point where the 2-TPU split spills (large n)
        let p = pts.iter().find(|p| p.x == 2580).unwrap();
        assert!(p.speedup_vs_single_input[1] < 2.0, "{:?}", p.speedup_vs_single_input);
        // and a pre-spill point where pipelining genuinely parallelizes
        let q = pts.iter().find(|p| p.x == 1140).unwrap();
        assert!(q.speedup_vs_single_input[1] > 1.5, "{:?}", q.speedup_vs_single_input);
    }

    #[test]
    fn headline_fc_default_tens() {
        // §V-B: default segmentation reaches ~36x for the largest FC
        // models (we assert the order of magnitude, not the digit)
        let h = headline(Kind::Fc, &cfg(), Strategy::Uniform, 50);
        assert!((25.0..60.0).contains(&h.best_speedup), "{h:?}");
        assert!(h.at_x > 2000, "{h:?}");
    }

    #[test]
    fn headline_fc_profiled_46x() {
        let h = headline(Kind::Fc, &cfg(), Strategy::ProfiledExhaustive { batch: 50 }, 50);
        assert!((35.0..60.0).contains(&h.best_speedup), "{h:?}");
    }

    #[test]
    fn headline_conv_profiled_6x() {
        let h = headline(Kind::Conv, &cfg(), Strategy::ProfiledExhaustive { batch: 50 }, 50);
        assert!((3.5..10.0).contains(&h.best_speedup), "{h:?}");
        assert_eq!(h.n_tpus, 4, "{h:?}");
    }

    #[test]
    fn table3_shape() {
        // Table III x values from the paper
        let xs = [1140, 1380, 1620, 1860, 2100, 2340, 2580];
        let rows = memory_rows(Kind::Fc, &cfg(), 2, Strategy::Uniform, &xs);
        assert_eq!(rows.len(), 7);
        for r in &rows {
            assert_eq!(r.dev_mib.len(), 2);
            assert_eq!(r.label, "2+3");
        }
        // first rows fit entirely on device; later ones spill on TPU2
        assert!(rows[0].host_mib.iter().all(|&h| h == 0.0));
        assert!(rows[6].host_mib[1] > 0.0);
    }

    #[test]
    fn profiled_memory_rows_avoid_host_fc3() {
        // paper Tables V/VI: profiled split fits everything on-device
        let xs = [2100, 2340, 2580];
        let rows = memory_rows(
            Kind::Fc,
            &cfg(),
            3,
            Strategy::ProfiledExhaustive { batch: 50 },
            &xs,
        );
        for r in &rows {
            assert!(r.host_mib.iter().all(|&h| h == 0.0), "{r:?}");
        }
    }
}
