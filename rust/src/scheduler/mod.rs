//! Multi-tenant TPU-pool scheduler: memory-aware admission, cost-model
//! placement, and per-model routing (DESIGN.md §7).
//!
//! The paper's pipeline serves **one** model on a fixed TPU set.  This
//! subsystem turns that into a pool: M registered models compete for N
//! simulated Edge TPUs, and the scheduler decides
//!
//! * **whether** each model runs at all — admission is memory-aware: a
//!   model is only admitted with a segmentation whose every segment keeps
//!   its weights in on-chip memory (host streaming is the 40x cliff of
//!   Table I), otherwise it is queued (pool too small) or rejected (no
//!   partition can ever fit);
//! * **how** it runs — a per-model `(tpu_count, Strategy)` chosen by
//!   searching the profiled cost model (`pipeline::simulate` over the
//!   candidate partitions), minimizing the weighted sum of predicted p99
//!   latencies, echoing the profiled-segmentation contribution at the
//!   pool level;
//! * **where requests go** — one live [`Pipeline`](crate::coordinator) —
//!   or a [`ReplicaRouter`](crate::coordinator::ReplicaRouter) of copies
//!   when leftover TPUs were granted as replicas — per admitted model,
//!   behind a name-keyed router with per-tenant metrics.
//!
//! ```text
//! ModelRegistry --register--> PoolScheduler::plan (allocator)
//!                                   |  PoolPlan: admitted / queued / rejected
//!                                   v
//!               +------ PoolRouter::deploy  (router, closed batches)
//!               |             |  one Pipeline (xN replicas) per tenant
//!               |             v
//!               |    router.serve("model", batch) + TenantMetrics
//!               |
//!               +------ ServingPool::deploy (pool, open loop)
//!                             |  per-tenant ingress + Batcher worker
//!                             v
//!                    pool.submit("model", request) -> TenantClient::done
//!                    pool.register / pool.deregister  (online re-plan)
//!                    pool.calibrate_tick  (drift-triggered recalibration,
//!                                          calibrate module / DESIGN.md §16)
//! ```
//!
//! Entry points: `repro schedule` (plan only, prints the admission table),
//! `repro serve-pool` (plan + deploy + closed synthetic batches),
//! `repro loadgen` (seeded open-loop arrival processes + live
//! verification), `examples/serve_multi_tenant.rs` (concurrent
//! closed-batch serving) and `examples/open_loop.rs` (open arrivals with
//! mid-run registration churn).

pub mod allocator;
pub mod calibrate;
pub mod journal;
pub mod paramcache;
pub mod pool;
pub mod registry;
pub mod router;

pub use allocator::{
    allocate, candidates_for, AllocatorConfig, Assignment, Candidate, DeviceGrant, PoolPlan,
    Rejection,
};
pub use calibrate::{
    calibration_csv, simulate_calibration, CalibrateConfig, CalibrateScenario, CalibrationRun,
    Calibrator, Recalibration,
};
pub use journal::{Journal, JournalEvent, JournalLog};
pub use paramcache::{CacheEffect, ParamCache};
pub use pool::{
    plan_fingerprint, replay_journal, spawn_calibration_ticker, Admission, CalibrationTicker,
    DeadlineConfig, DeployOptions, ReplanReport, ServingPool, TenantClient,
};
#[allow(deprecated)]
pub use pool::OpenOptions;
pub use registry::{resolve_model, ModelRegistry, Tenant};
pub use router::{
    synthetic_reference, synthetic_transform, synthetic_transform_into, tenant_salt,
    BackendKind, PoolRouter, TenantHandle, TenantShape,
};

use anyhow::Result;

use crate::config::SystemConfig;
use crate::report::{ms, Table};

/// Facade: a registry plus the pool/system configuration.
pub struct PoolScheduler {
    /// The registered tenants (mutated by register/deregister).
    pub registry: ModelRegistry,
    /// Calibrated device/link constants used for cost-model placement.
    pub system: SystemConfig,
    /// Allocator knobs (pool size, profiling batch, spill policy, ...).
    pub alloc: AllocatorConfig,
}

impl PoolScheduler {
    /// An empty scheduler over the given system + allocator configuration.
    pub fn new(system: SystemConfig, alloc: AllocatorConfig) -> Self {
        PoolScheduler { registry: ModelRegistry::new(), system, alloc }
    }

    /// Register a tenant (see [`ModelRegistry::register`]).
    pub fn register(&mut self, tenant: Tenant) -> Result<()> {
        self.registry.register(tenant)
    }

    /// Remove a tenant (see [`ModelRegistry::deregister`]).  For draining
    /// removal on a live pool, use [`ServingPool::deregister`].
    pub fn deregister(&mut self, name: &str) -> Result<Tenant> {
        self.registry.deregister(name)
    }

    /// Run admission + placement over everything registered.
    pub fn plan(&self) -> Result<PoolPlan> {
        allocate(&self.registry, &self.system, &self.alloc)
    }

    /// Plan, then spawn the live closed-batch deployments.
    pub fn deploy(&self, backend: &BackendKind, opts: DeployOptions) -> Result<PoolRouter> {
        let plan = self.plan()?;
        PoolRouter::deploy(&plan, &self.registry, &self.system, backend, opts)
    }

    /// Plan, then spawn the **open-loop** serving pool: per-tenant ingress
    /// queues + dynamic batchers, with online re-planning on registration
    /// change.  The pool takes a snapshot of the current registry;
    /// subsequent membership changes go through
    /// [`ServingPool::register`] / [`ServingPool::deregister`].
    pub fn deploy_open(&self, backend: BackendKind, opts: DeployOptions) -> Result<ServingPool> {
        ServingPool::deploy(
            self.registry.clone(),
            self.system.clone(),
            self.alloc.clone(),
            backend,
            opts,
        )
    }
}

/// Render a pool plan as the `repro schedule` admission table.
///
/// Plans computed with sharing enabled grow three extra columns — the
/// grant kind (`excl` / `shared 1/N`), the concrete device ids (so
/// overlapping per-device slices are visible), and the predicted p99
/// inflation from co-residency — so whole-TPU plans render exactly as
/// before.  A non-zero `--cache-budget-bytes` adds one more: the
/// planned warm fraction of each shared grant's parameter bytes
/// (`cache_warm`), so cache-off plans also render exactly as before.
pub fn plan_table(plan: &PoolPlan) -> Table {
    let shared_cols = plan.sharing_enabled;
    let mut headers = vec![
        "model", "weight", "tpus", "replicas", "strategy", "split", "p99_ms",
        "per_item_ms", "dev_mib", "host_mib",
    ];
    if shared_cols {
        headers.push("grant");
        headers.push("devices");
        headers.push("swap_over_ms");
    }
    if plan.cache_enabled {
        headers.push("cache_warm");
    }
    headers.push("status");
    let mut t = Table::new(
        format!(
            "TPU-pool schedule — {} model(s) on {} TPUs ({} used)",
            plan.assignments.len() + plan.queued.len() + plan.rejected.len(),
            plan.total_tpus,
            plan.tpus_used(),
        ),
        &headers,
    );
    for a in &plan.assignments {
        let c = &a.candidate;
        let mut row = vec![
            a.name.clone(),
            format!("{:.1}", a.weight),
            c.tpu_count.to_string(),
            a.replicas.to_string(),
            c.strategy.name().to_string(),
            c.partition.label(),
            ms(a.effective_p99_s),
            ms(c.per_item_s),
            format!("{:.2}", c.device_mib),
            format!("{:.2}", c.host_mib),
        ];
        if shared_cols {
            row.push(a.grant.label());
            row.push(
                a.devices
                    .iter()
                    .map(ToString::to_string)
                    .collect::<Vec<_>>()
                    .join("+"),
            );
            row.push(ms(a.swap_overhead_s()));
        }
        if plan.cache_enabled {
            row.push(match a.grant.cache() {
                Some(eff) => format!("{:.0}%", eff.warm_frac * 100.0),
                None => "-".to_string(), // exclusive: nothing ever swaps
            });
        }
        row.push(if a.slo_violated() {
            "admitted (SLO at risk)".into()
        } else {
            "admitted".into()
        });
        t.row(row);
    }
    let dashes =
        (if shared_cols { 12 } else { 9 }) + usize::from(plan.cache_enabled);
    for q in &plan.queued {
        let mut row = vec![q.name.clone()];
        row.extend(vec!["-".to_string(); dashes]);
        row.push(format!("queued: {}", q.reason));
        t.row(row);
    }
    for r in &plan.rejected {
        let mut row = vec![r.name.clone()];
        row.extend(vec!["-".to_string(); dashes]);
        row.push(format!("rejected: {}", r.reason));
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn facade_plans_and_deploys() {
        let mut s =
            PoolScheduler::new(SystemConfig::default(), AllocatorConfig::default());
        s.registry.register_named("fc_big").unwrap();
        s.registry.register_named("conv_a").unwrap();
        s.registry.register_named("conv_b").unwrap();
        let plan = s.plan().unwrap();
        assert_eq!(plan.assignments.len(), 3);
        let router = s
            .deploy(&BackendKind::Synthetic, DeployOptions::new().with_queue_capacity(8))
            .unwrap();
        assert_eq!(router.len(), 3);
        router.wait_ready().unwrap();
        router.shutdown();
    }

    #[test]
    fn facade_deploys_open_loop_pool() {
        let mut s = PoolScheduler::new(
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 2, ..Default::default() },
        );
        s.registry.register_named("fc_small").unwrap();
        s.registry.register_named("conv_a").unwrap();
        let pool = s.deploy_open(BackendKind::Synthetic, DeployOptions::default()).unwrap();
        assert_eq!(pool.names(), vec!["conv_a".to_string(), "fc_small".to_string()]);
        let client = pool.client("conv_a").unwrap();
        for r in client.synth_requests(4, 1) {
            pool.submit("conv_a", r).unwrap();
        }
        for _ in 0..4 {
            let r = client.done.recv().unwrap();
            assert_eq!(r.data.len(), client.out_elems());
        }
        pool.shutdown();
    }

    #[test]
    fn plan_table_grows_device_columns_only_when_sharing() {
        let mut s = PoolScheduler::new(
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 1, allow_sharing: true, ..Default::default() },
        );
        s.registry.register_named("fc_small").unwrap();
        s.registry.register_named("fc_n512").unwrap();
        let on = plan_table(&s.plan().unwrap()).render();
        assert!(on.contains("grant"), "{on}");
        assert!(on.contains("devices"), "{on}");
        assert!(on.contains("shared 1/2"), "{on}");

        s.alloc.allow_sharing = false;
        let off = plan_table(&s.plan().unwrap()).render();
        assert!(!off.contains("grant"), "{off}");
        assert!(!off.contains("devices"), "{off}");
        assert!(!off.contains("swap_over_ms"), "{off}");
    }

    #[test]
    fn plan_table_grows_cache_column_only_with_a_budget() {
        let mut s = PoolScheduler::new(
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 1, allow_sharing: true, ..Default::default() },
        );
        s.registry.register_named("fc_small").unwrap();
        s.registry.register_named("fc_n512").unwrap();
        let off = plan_table(&s.plan().unwrap()).render();
        assert!(!off.contains("cache_warm"), "{off}");

        s.alloc.cache_budget_bytes = 1 << 30;
        let on = plan_table(&s.plan().unwrap()).render();
        assert!(on.contains("cache_warm"), "{on}");
        assert!(on.contains("100%"), "a 1 GiB budget pins both tenants: {on}");
    }

    #[test]
    fn plan_table_lists_every_tenant_once() {
        let mut s = PoolScheduler::new(
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 4, ..Default::default() },
        );
        // conv_big needs 4 TPUs and fc_huge needs 3, so one of them is
        // queued on a 4-TPU pool; fc_n3000 can never fit on-chip
        s.registry.register_named("conv_big").unwrap();
        s.registry.register_named("fc_huge").unwrap();
        s.registry.register_named("fc_n3000").unwrap();
        let plan = s.plan().unwrap();
        let rendered = plan_table(&plan).render();
        assert!(rendered.contains("conv_big"), "{rendered}");
        assert!(rendered.contains("queued"), "{rendered}");
        assert!(rendered.contains("rejected"), "{rendered}");
        assert_eq!(plan.assignments.len() + plan.queued.len() + plan.rejected.len(), 3);
    }
}
