//! Online cost-model calibration: close the profiling loop (DESIGN.md
//! §16).
//!
//! The allocator places tenants by a **profiled** cost model; the paper's
//! profiles are taken once, offline.  In a long-running pool the true
//! service time drifts away from the profile (input mix shifts, thermal
//! throttling, co-residency interference), and a plan optimized against
//! stale costs silently misallocates TPUs.  This module watches the
//! observed per-tenant latency distribution, measures **drift** against
//! an expected p99, and — when drift sustains past a threshold — rewrites
//! the tenant's profiled cost model (`Tenant::cost_scale`) and triggers a
//! re-segmentation + re-plan through the pool's existing drain/redeploy
//! path, so no in-flight request is ever lost.
//!
//! Three guards keep the loop from flapping:
//!
//! * **sustain** — drift must exceed the threshold for
//!   [`sustain_windows`](CalibrateConfig::sustain_windows) consecutive
//!   windows before anything fires (one bursty window is not drift);
//! * **hysteresis** — between `threshold - hysteresis` and `threshold`
//!   the sustain counter *holds* instead of resetting, so a p99
//!   oscillating around the trigger line cannot reset the evidence;
//! * **cooldown + budget** — after a recalibration the tenant is immune
//!   for [`cooldown_windows`](CalibrateConfig::cooldown_windows), and at
//!   most [`max_replans_per_window`](CalibrateConfig::max_replans_per_window)
//!   tenants may recalibrate in any one window (re-plans drain live
//!   deployments; a storm of them is worse than the drift).
//!
//! Drift is **self-baselined**: the first window with enough samples
//! establishes the tenant's expected p99 (the "profiling window"), and
//! drift is measured as `observed_p99 / expected_p99 - 1`.  Observed
//! open-loop latencies include queueing and batching wait that the
//! allocator's pipeline prediction deliberately excludes, so comparing
//! against the plan's `effective_p99_s` directly would read steady-state
//! queueing as permanent drift; the plan prediction is still reported in
//! the calibration table for the predicted-vs-observed gap.  On a fire,
//! the correction `scale' = scale * (1 + drift)` rebases both the cost
//! model and the expected p99 to what was actually observed, so a
//! calibrated tenant is quiescent by construction.
//!
//! The same [`Calibrator`] runs in three harnesses, in lockstep:
//!
//! * **live** — `ServingPool::calibrate_tick` diffs each tenant's
//!   lifetime sim-latency histogram ([`ingest_lifetime`]
//!   (Calibrator::ingest_lifetime)), and applies fired recalibrations
//!   through the pool's re-plan path;
//! * **sim** — [`simulate_calibration`] replays seeded windows against
//!   the deterministic workload simulation with a hidden injected drift
//!   factor ([`crate::workload::drift_factor`]), so `repro calibrate` /
//!   `repro loadgen --calibrate` are byte-identical per seed;
//! * **report** — [`calibration_csv`] renders the per-window
//!   predicted-vs-observed table and [`CalibrationRun::ledger`] the
//!   re-plan ledger.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::SystemConfig;
use crate::coordinator::batcher::BatchPolicy;
use crate::util::stats::{LatencyHistogram, WindowedHistogram};
use crate::workload::{arrival_seed, drift_factor, simulate_deployment, Arrivals};

use super::allocator::{allocate, AllocatorConfig, PoolPlan};
use super::registry::ModelRegistry;

/// Knobs of the online calibrator (all windows are calibration windows,
/// i.e. ticks of [`Calibrator::end_window`]).
#[derive(Debug, Clone)]
pub struct CalibrateConfig {
    /// Relative drift (`observed_p99 / expected_p99 - 1`) at or above
    /// which a window counts toward the sustain requirement.
    pub drift_threshold: f64,
    /// Width of the hold band below the threshold: a drift in
    /// `[threshold - hysteresis, threshold)` neither advances nor resets
    /// the sustain counter.
    pub hysteresis: f64,
    /// Consecutive over-threshold windows required before a
    /// recalibration fires.
    pub sustain_windows: u32,
    /// Windows a tenant is immune after its own recalibration.
    pub cooldown_windows: u32,
    /// Cross-tenant budget: at most this many recalibrations may fire in
    /// any single window.
    pub max_replans_per_window: u32,
    /// Minimum samples in the recent window before drift is evaluated
    /// (sparse windows are skipped, not treated as zero drift).
    pub min_samples: u64,
}

impl Default for CalibrateConfig {
    fn default() -> Self {
        CalibrateConfig {
            drift_threshold: 0.5,
            hysteresis: 0.15,
            sustain_windows: 2,
            cooldown_windows: 3,
            max_replans_per_window: 1,
            min_samples: 20,
        }
    }
}

impl CalibrateConfig {
    /// Validate the knobs (the CLI parses them from flags).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.drift_threshold.is_finite() && self.drift_threshold > 0.0,
            "drift threshold must be positive and finite (got {})",
            self.drift_threshold
        );
        anyhow::ensure!(
            self.hysteresis.is_finite() && (0.0..=self.drift_threshold).contains(&self.hysteresis),
            "hysteresis must be finite and within [0, threshold] (got {})",
            self.hysteresis
        );
        anyhow::ensure!(self.sustain_windows >= 1, "sustain windows must be at least 1");
        anyhow::ensure!(
            self.max_replans_per_window >= 1,
            "re-plan budget must allow at least one re-plan per window"
        );
        anyhow::ensure!(self.min_samples >= 1, "min samples must be at least 1");
        Ok(())
    }
}

/// One fired recalibration: the ledger entry `repro calibrate` prints
/// and the tests pin.
#[derive(Debug, Clone, PartialEq)]
pub struct Recalibration {
    /// Calibration window in which the correction fired (0-based).
    pub window: u64,
    /// The recalibrated tenant.
    pub tenant: String,
    /// Sustained relative drift that triggered it.
    pub drift: f64,
    /// The tenant's new cumulative [`cost_scale`](super::Tenant::cost_scale).
    pub scale: f64,
}

/// Per-tenant calibration state.
#[derive(Debug)]
struct TenantCal {
    /// Lifetime high-water mark of the live metrics histogram, so each
    /// [`Calibrator::ingest_lifetime`] only absorbs the new samples.
    seen: LatencyHistogram,
    /// Recent observed latencies (two-bank windowed, O(1) mergeable).
    win: WindowedHistogram,
    /// Self-baselined expected p99; `None` until the first window with
    /// enough samples (the profiling window).
    expected_p99_s: Option<f64>,
    /// Cumulative cost-model correction (starts at 1.0, uncalibrated).
    scale: f64,
    /// Consecutive over-threshold windows (the sustain counter).
    over: u32,
    /// Remaining immunity windows after this tenant's last fire.
    cooldown: u32,
    /// Drift measured in the most recent evaluated window (gauge).
    last_drift: f64,
}

impl Default for TenantCal {
    fn default() -> Self {
        TenantCal {
            seen: LatencyHistogram::new(),
            win: WindowedHistogram::new(),
            expected_p99_s: None,
            scale: 1.0,
            over: 0,
            cooldown: 0,
            last_drift: 0.0,
        }
    }
}

/// The online calibrator: per-tenant windowed observations in, a
/// deterministic re-plan ledger out.  Pure state machine — it never
/// touches the pool itself; callers apply the returned
/// [`Recalibration`]s (write `cost_scale`, re-plan).
#[derive(Debug)]
pub struct Calibrator {
    cfg: CalibrateConfig,
    tenants: BTreeMap<String, TenantCal>,
    window: u64,
}

impl Calibrator {
    /// A calibrator with no observations yet.
    pub fn new(cfg: CalibrateConfig) -> Self {
        Calibrator { cfg, tenants: BTreeMap::new(), window: 0 }
    }

    /// The configured knobs.
    pub fn config(&self) -> &CalibrateConfig {
        &self.cfg
    }

    /// Calibration windows completed so far.
    pub fn window(&self) -> u64 {
        self.window
    }

    /// Record one observed latency for `tenant` in the current window
    /// (the deterministic-sim ingestion path).
    pub fn observe(&mut self, tenant: &str, lat_s: f64) {
        self.tenants.entry(tenant.to_string()).or_default().win.record(lat_s);
    }

    /// Absorb the *new* samples of a lifetime latency histogram (the
    /// live ingestion path): diffs `hist` against the last snapshot seen
    /// for `tenant`, so the hot path needs no extra instrumentation —
    /// the tick clones the metrics histogram it already keeps.
    pub fn ingest_lifetime(&mut self, tenant: &str, hist: &LatencyHistogram) {
        let tc = self.tenants.entry(tenant.to_string()).or_default();
        let delta = hist.delta_since(&tc.seen);
        tc.win.absorb(&delta);
        tc.seen = hist.clone();
    }

    /// Drift measured for `tenant` in its most recent evaluated window
    /// (0.0 before the baseline is established).
    pub fn last_drift(&self, tenant: &str) -> f64 {
        self.tenants.get(tenant).map_or(0.0, |t| t.last_drift)
    }

    /// Cumulative cost-model correction for `tenant` (1.0 when
    /// uncalibrated or unknown).
    pub fn scale(&self, tenant: &str) -> f64 {
        self.tenants.get(tenant).map_or(1.0, |t| t.scale)
    }

    /// Close the current window: evaluate drift for every tenant (name
    /// order, so the ledger is deterministic), advance the windowed
    /// banks, and return the recalibrations that fired.
    pub fn end_window(&mut self) -> Vec<Recalibration> {
        let mut fired = Vec::new();
        for (name, tc) in &mut self.tenants {
            let mut fired_now = false;
            if tc.win.window_count() >= self.cfg.min_samples {
                let obs_p99 = tc.win.recent_percentile(99.0);
                match tc.expected_p99_s {
                    None => {
                        // profiling window: establish the baseline
                        tc.expected_p99_s = Some(obs_p99);
                        tc.last_drift = 0.0;
                    }
                    Some(expected) if expected > 0.0 => {
                        let drift = obs_p99 / expected - 1.0;
                        tc.last_drift = drift;
                        if drift >= self.cfg.drift_threshold {
                            tc.over += 1;
                        } else if drift < self.cfg.drift_threshold - self.cfg.hysteresis {
                            tc.over = 0;
                        } // else: hold inside the hysteresis band
                        if tc.over >= self.cfg.sustain_windows
                            && tc.cooldown == 0
                            && (fired.len() as u32) < self.cfg.max_replans_per_window
                        {
                            tc.scale *= 1.0 + drift;
                            // rebase: the corrected model predicts what
                            // we just observed, so a calibrated tenant
                            // reads as zero drift from here on
                            tc.expected_p99_s = Some(obs_p99);
                            tc.win = WindowedHistogram::new();
                            tc.over = 0;
                            tc.cooldown = self.cfg.cooldown_windows;
                            fired_now = true;
                            fired.push(Recalibration {
                                window: self.window,
                                tenant: name.clone(),
                                drift,
                                scale: tc.scale,
                            });
                        }
                    }
                    Some(_) => {}
                }
            }
            if !fired_now && tc.cooldown > 0 {
                tc.cooldown -= 1;
            }
            tc.win.reset_window();
        }
        self.window += 1;
        fired
    }
}

/// One seeded drift scenario for the deterministic calibration loop
/// (`repro calibrate` and `repro loadgen --calibrate`).
#[derive(Debug, Clone)]
pub struct CalibrateScenario {
    /// Run seed: arrivals, payloads and injected drift all derive from
    /// it, so the whole run is byte-identical per seed.
    pub seed: u64,
    /// Calibration windows to simulate.
    pub windows: usize,
    /// Requests offered to each tenant per window.
    pub requests_per_window: usize,
    /// Window (0-based) at which the hidden true cost of the drifted
    /// tenants jumps by their seeded [`drift_factor`]; earlier windows
    /// match the profile exactly.
    pub drift_onset_window: usize,
    /// Tenants whose true cost drifts (empty: a pure no-drift run).
    pub drifted: Vec<String>,
    /// Arrival process driven against every tenant.
    pub arrivals: Arrivals,
    /// Batching policy (per tenant it is tightened to the SLO via
    /// [`BatchPolicy::for_slo`], exactly like the live pool).
    pub policy: BatchPolicy,
    /// Calibrator knobs.
    pub calibrate: CalibrateConfig,
}

impl CalibrateScenario {
    /// A 6-window no-drift scenario at moderate Poisson load.
    pub fn new(seed: u64) -> Self {
        CalibrateScenario {
            seed,
            windows: 6,
            requests_per_window: 120,
            drift_onset_window: 2,
            drifted: Vec::new(),
            arrivals: Arrivals::Poisson { rate_hz: 400.0 },
            policy: BatchPolicy::default(),
            calibrate: CalibrateConfig::default(),
        }
    }
}

/// One tenant-window row of the calibration report.
#[derive(Debug, Clone)]
pub struct WindowRow {
    /// Calibration window (0-based).
    pub window: u64,
    /// Tenant name.
    pub model: String,
    /// Observed samples in the window.
    pub samples: u64,
    /// The plan's predicted p99 at the time of the window (reflects any
    /// cost-scale corrections already applied).
    pub predicted_p99_s: f64,
    /// Observed p99 of the window's latencies (with injected drift).
    pub observed_p99_s: f64,
    /// Drift the calibrator measured this window.
    pub drift: f64,
    /// What the calibrator did: `-`, `baseline`, or `recalibrate(xS)`.
    pub action: String,
}

/// Result of one deterministic calibration run.
#[derive(Debug)]
pub struct CalibrationRun {
    /// Per-tenant-per-window report rows, window-major then name order.
    pub rows: Vec<WindowRow>,
    /// Every recalibration that fired, in order.
    pub ledger: Vec<Recalibration>,
    /// The plan in force after the last window (carries the corrected
    /// cost model).
    pub final_plan: PoolPlan,
    /// Final per-tenant cost scales, name order.
    pub final_scales: Vec<(String, f64)>,
}

/// Salt mixing the window index into each window's arrival seed, so
/// windows draw distinct (but seed-deterministic) schedules.
const WINDOW_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// Run the closed calibration loop deterministically: plan, simulate
/// each window's open-loop serving per tenant, inject the hidden seeded
/// drift factor from the onset window on, feed observations to the
/// [`Calibrator`], and re-plan whenever it fires.  Pure function of its
/// arguments — two runs with the same scenario are byte-identical, which
/// is what `repro calibrate` and the golden-CSV tests pin.
pub fn simulate_calibration(
    registry: &ModelRegistry,
    system: &SystemConfig,
    alloc: &AllocatorConfig,
    scenario: &CalibrateScenario,
) -> Result<CalibrationRun> {
    scenario.calibrate.validate()?;
    let mut reg = registry.clone();
    let mut plan = allocate(&reg, system, alloc)?;
    let mut cal = Calibrator::new(scenario.calibrate.clone());
    let mut rows: Vec<WindowRow> = Vec::new();
    let mut ledger: Vec<Recalibration> = Vec::new();

    for w in 0..scenario.windows {
        let mut window_rows: Vec<WindowRow> = Vec::new();
        for a in &plan.assignments {
            let tenant = reg.get(&a.name)?;
            let dep = crate::serving::deployment_sim(tenant, a, system);
            let policy = scenario.policy.for_slo(a.slo_p99_s);
            let seed =
                arrival_seed(scenario.seed ^ (w as u64).wrapping_mul(WINDOW_SALT), &a.name);
            let run = simulate_deployment(
                &scenario.arrivals,
                scenario.requests_per_window,
                seed,
                &policy,
                &dep,
            );
            // hidden truth: from the onset window on, the drifted
            // tenants' real cost is `factor` times the profile — applied
            // at the latency level (a deliberate simplification: the
            // queueing structure is profiled-shaped, only the magnitude
            // drifts), which is exactly the signal the calibrator sees
            let factor = if w >= scenario.drift_onset_window
                && scenario.drifted.iter().any(|d| d == &a.name)
            {
                drift_factor(scenario.seed, &a.name)
            } else {
                1.0
            };
            let mut obs = LatencyHistogram::new();
            for &l in &run.latencies_s {
                let v = l * factor;
                obs.record(v);
                cal.observe(&a.name, v);
            }
            window_rows.push(WindowRow {
                window: w as u64,
                model: a.name.clone(),
                samples: obs.count(),
                predicted_p99_s: a.effective_p99_s,
                observed_p99_s: obs.percentile(99.0),
                drift: 0.0,           // filled after end_window
                action: String::new(), // filled after end_window
            });
        }
        let had_baseline: Vec<bool> = window_rows
            .iter()
            .map(|r| cal.tenants.get(&r.model).is_some_and(|t| t.expected_p99_s.is_some()))
            .collect();
        let fired = cal.end_window();
        for (row, had) in window_rows.iter_mut().zip(had_baseline) {
            row.drift = cal.last_drift(&row.model);
            row.action = if let Some(f) = fired.iter().find(|f| f.tenant == row.model) {
                format!("recalibrate(x{:.2})", f.scale)
            } else if !had {
                "baseline".to_string()
            } else {
                "-".to_string()
            };
        }
        rows.extend(window_rows);
        if !fired.is_empty() {
            for f in &fired {
                if let Some(t) = reg.get_mut(&f.tenant) {
                    t.cost_scale = f.scale;
                }
            }
            plan = allocate(&reg, system, alloc)?;
            ledger.extend(fired);
        }
    }

    let final_scales = reg.iter().map(|t| (t.name.clone(), t.cost_scale)).collect();
    Ok(CalibrationRun { rows, ledger, final_plan: plan, final_scales })
}

/// Render a calibration run as the golden CSV (`repro calibrate --csv`
/// and `repro loadgen --calibrate` both emit exactly this, so the
/// byte-identity tests share one renderer).
pub fn calibration_csv(run: &CalibrationRun) -> String {
    let mut out =
        String::from("window,model,samples,predicted_p99_ms,observed_p99_ms,drift_pct,action\n");
    for r in &run.rows {
        out.push_str(&format!(
            "{},{},{},{:.3},{:.3},{:+.1},{}\n",
            r.window,
            r.model,
            r.samples,
            r.predicted_p99_s * 1e3,
            r.observed_p99_s * 1e3,
            r.drift * 100.0,
            r.action,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::registry::ModelRegistry;

    fn pool(names: &[&str], tpus: usize) -> (ModelRegistry, SystemConfig, AllocatorConfig) {
        let mut reg = ModelRegistry::new();
        for n in names {
            reg.register_named(n).unwrap();
        }
        let alloc = AllocatorConfig { total_tpus: tpus, ..Default::default() };
        (reg, SystemConfig::default(), alloc)
    }

    /// The exact bucket bound a single recorded value reads back as —
    /// lets tests pick drift ratios that are quantization-proof.
    fn bucket_bound(v: f64) -> f64 {
        let mut h = LatencyHistogram::new();
        h.record(v);
        h.percentile(99.0)
    }

    #[test]
    fn no_drift_means_zero_replans() {
        let (reg, sys, alloc) = pool(&["fc_small", "conv_a"], 4);
        let scenario = CalibrateScenario::new(7);
        let run = simulate_calibration(&reg, &sys, &alloc, &scenario).unwrap();
        assert!(run.ledger.is_empty(), "no injected drift must never re-plan: {:?}", run.ledger);
        assert!(run.final_scales.iter().all(|(_, s)| *s == 1.0), "{:?}", run.final_scales);
        assert_eq!(run.rows.len(), scenario.windows * 2, "one row per tenant per window");
        assert!(
            run.rows.iter().all(|r| !r.action.starts_with("recalibrate")),
            "{:?}",
            run.rows
        );
        // window 0 is the profiling window for both tenants
        assert!(run.rows.iter().take(2).all(|r| r.action == "baseline"), "{:?}", &run.rows[..2]);
    }

    #[test]
    fn injected_drift_recalibrates_exactly_once_then_quiesces() {
        let (reg, sys, alloc) = pool(&["fc_small", "conv_a"], 4);
        let mut scenario = CalibrateScenario::new(7);
        scenario.windows = 8;
        scenario.drifted = vec!["fc_small".to_string()];
        let run = simulate_calibration(&reg, &sys, &alloc, &scenario).unwrap();
        assert_eq!(run.ledger.len(), 1, "exactly one corrective re-plan: {:?}", run.ledger);
        let fire = &run.ledger[0];
        assert_eq!(fire.tenant, "fc_small");
        assert!(fire.drift >= scenario.calibrate.drift_threshold, "{fire:?}");
        assert!(fire.scale > 1.0, "{fire:?}");
        assert!(
            fire.window >= (scenario.drift_onset_window + 1) as u64,
            "sustain requires more than one drifted window: {fire:?}"
        );
        // the undrifted tenant is untouched
        let conv = run.final_scales.iter().find(|(n, _)| n == "conv_a").unwrap();
        assert_eq!(conv.1, 1.0);
        let fc = run.final_scales.iter().find(|(n, _)| n == "fc_small").unwrap();
        assert_eq!(fc.1, fire.scale);
        // quiescence: after the fire, no further action and drift back
        // under the threshold on every evaluated fc_small window
        for r in run.rows.iter().filter(|r| r.model == "fc_small" && r.window > fire.window) {
            assert!(!r.action.starts_with("recalibrate"), "{r:?}");
            assert!(
                r.drift < scenario.calibrate.drift_threshold,
                "post-calibration drift must stay under threshold: {r:?}"
            );
        }
        // the corrected plan predicts the drifted tenant slower
        let final_p99 = run.final_plan.assignment("fc_small").unwrap().effective_p99_s;
        assert!(final_p99 > 0.0);
    }

    #[test]
    fn cooldown_blocks_immediate_refires() {
        let cfg = CalibrateConfig {
            sustain_windows: 1,
            cooldown_windows: 3,
            min_samples: 10,
            ..Default::default()
        };
        let mut cal = Calibrator::new(cfg);
        let base = bucket_bound(1e-3);
        let feed = |cal: &mut Calibrator, v: f64| {
            for _ in 0..50 {
                cal.observe("t", v);
            }
        };
        feed(&mut cal, base * 0.99); // window 0: baseline
        assert!(cal.end_window().is_empty());
        feed(&mut cal, base * 1.7); // >= two buckets up: drift 0.5625
        let first = cal.end_window();
        assert_eq!(first.len(), 1, "sustained drift past threshold must fire");
        assert_eq!(first[0].window, 1);
        // keep drifting harder: cooldown must hold windows 2, 3 and 4
        for w in 2..5u64 {
            feed(&mut cal, base * 3.0);
            assert!(cal.end_window().is_empty(), "window {w} is inside the cooldown");
        }
        feed(&mut cal, base * 3.0);
        let second = cal.end_window();
        assert_eq!(second.len(), 1, "cooldown expired: sustained drift fires again");
        assert_eq!(second[0].window, 5);
    }

    #[test]
    fn hysteresis_holds_the_sustain_counter() {
        // reset bound = threshold - hysteresis = 0.2, so a one-bucket
        // wobble (drift exactly 0.25) holds the counter instead of
        // resetting it; with sustain 3 the fire lands on window 4 only
        // if the hold worked
        let cfg = CalibrateConfig {
            drift_threshold: 0.5,
            hysteresis: 0.3,
            sustain_windows: 3,
            cooldown_windows: 0,
            min_samples: 10,
            ..Default::default()
        };
        let mut cal = Calibrator::new(cfg);
        let base = bucket_bound(1e-3);
        let feed = |cal: &mut Calibrator, v: f64| {
            for _ in 0..50 {
                cal.observe("t", v);
            }
        };
        feed(&mut cal, base * 0.99); // window 0: baseline
        assert!(cal.end_window().is_empty());
        feed(&mut cal, base * 1.7); // drift 0.5625: over = 1
        assert!(cal.end_window().is_empty());
        feed(&mut cal, base * 1.2); // drift 0.25: inside the band, holds
        assert!(cal.end_window().is_empty());
        feed(&mut cal, base * 1.7); // over = 2
        assert!(cal.end_window().is_empty());
        feed(&mut cal, base * 1.7); // over = 3: fire
        let fired = cal.end_window();
        assert_eq!(fired.len(), 1, "hysteresis hold must preserve the sustain evidence");
        assert_eq!(fired[0].window, 4);
    }

    #[test]
    fn sparse_windows_are_skipped_not_reset() {
        let cfg =
            CalibrateConfig { sustain_windows: 2, min_samples: 10, ..Default::default() };
        let mut cal = Calibrator::new(cfg);
        let base = bucket_bound(1e-3);
        for _ in 0..50 {
            cal.observe("t", base * 0.99);
        }
        assert!(cal.end_window().is_empty()); // baseline
        for _ in 0..50 {
            cal.observe("t", base * 1.7);
        }
        assert!(cal.end_window().is_empty()); // over = 1
        // a sparse window (below min_samples) neither fires nor resets
        for _ in 0..3 {
            cal.observe("t", base * 1.7);
        }
        assert!(cal.end_window().is_empty());
        for _ in 0..50 {
            cal.observe("t", base * 1.7);
        }
        let fired = cal.end_window();
        assert_eq!(fired.len(), 1, "evidence must survive a sparse window");
    }

    #[test]
    fn ledger_respects_the_per_window_budget() {
        let cfg = CalibrateConfig {
            sustain_windows: 1,
            max_replans_per_window: 1,
            min_samples: 10,
            ..Default::default()
        };
        let mut cal = Calibrator::new(cfg);
        let base = bucket_bound(1e-3);
        for t in ["a", "b"] {
            for _ in 0..50 {
                cal.observe(t, base * 0.99);
            }
        }
        assert!(cal.end_window().is_empty());
        for t in ["a", "b"] {
            for _ in 0..50 {
                cal.observe(t, base * 1.7);
            }
        }
        let w1 = cal.end_window();
        assert_eq!(w1.len(), 1, "budget caps one re-plan per window");
        assert_eq!(w1[0].tenant, "a", "name order decides who goes first");
        for t in ["a", "b"] {
            for _ in 0..50 {
                cal.observe(t, base * 1.7);
            }
        }
        let w2 = cal.end_window();
        assert_eq!(w2.len(), 1, "the deferred tenant fires next window");
        assert_eq!(w2[0].tenant, "b");
    }

    #[test]
    fn lifetime_ingestion_matches_direct_observation() {
        let mut direct = Calibrator::new(CalibrateConfig { min_samples: 5, ..Default::default() });
        let mut live = Calibrator::new(CalibrateConfig { min_samples: 5, ..Default::default() });
        let mut hist = LatencyHistogram::new();
        for w in 0..3 {
            let v = if w < 1 { 1e-3 } else { 4e-3 };
            for _ in 0..20 {
                direct.observe("t", v);
                hist.record(v);
            }
            live.ingest_lifetime("t", &hist);
            let (a, b) = (direct.end_window(), live.end_window());
            assert_eq!(a, b, "window {w}: both ingestion paths must agree");
            assert_eq!(direct.last_drift("t"), live.last_drift("t"), "window {w}");
        }
        assert_eq!(direct.scale("t"), live.scale("t"));
        assert!(direct.scale("t") > 1.0, "the drift above must have fired");
    }

    #[test]
    fn calibration_csv_is_byte_identical_per_seed() {
        let (reg, sys, alloc) = pool(&["fc_small", "conv_a"], 4);
        let mut scenario = CalibrateScenario::new(11);
        scenario.drifted = vec!["fc_small".to_string()];
        let a = calibration_csv(&simulate_calibration(&reg, &sys, &alloc, &scenario).unwrap());
        let b = calibration_csv(&simulate_calibration(&reg, &sys, &alloc, &scenario).unwrap());
        assert_eq!(a, b, "same scenario must render byte-identically");
        assert!(a.starts_with("window,model,samples,predicted_p99_ms,observed_p99_ms,"));
        scenario.seed = 12;
        let c = calibration_csv(&simulate_calibration(&reg, &sys, &alloc, &scenario).unwrap());
        assert_ne!(a, c, "the seed must matter");
    }

    #[test]
    fn config_validation_pins_error_messages() {
        let bad = CalibrateConfig { drift_threshold: f64::NAN, ..Default::default() };
        let err = format!("{:#}", bad.validate().unwrap_err());
        assert!(err.contains("finite"), "{err}");
        let bad = CalibrateConfig { hysteresis: -0.1, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = CalibrateConfig { sustain_windows: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = CalibrateConfig { max_replans_per_window: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = CalibrateConfig { min_samples: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        assert!(CalibrateConfig::default().validate().is_ok());
    }
}
