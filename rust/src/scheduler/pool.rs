//! Open-loop, re-plannable multi-tenant serving pool.
//!
//! [`PoolRouter`](super::router::PoolRouter) serves *closed* batches: the
//! caller hands over a complete request vector and blocks.  This module
//! turns the same deployments into an **open-loop** system — the shape the
//! ROADMAP's "heavy open traffic" north star asks for:
//!
//! * every admitted tenant gets its own bounded ingress queue and a
//!   [`Batcher`] worker thread that groups arrivals under a per-pool
//!   [`BatchPolicy`] (size/wait flush) and feeds its pipeline;
//! * callers [`submit`](ServingPool::submit) single requests as they
//!   arrive and collect [`Response`]s from a per-tenant completion stream
//!   ([`TenantClient::done`]) that survives re-plans;
//! * [`register`](ServingPool::register) / [`deregister`](ServingPool::deregister)
//!   on the **live** pool re-run the branch-and-bound allocator, drain
//!   only the deployments whose assignment changed, and redeploy — without
//!   dropping a single in-flight request.
//!
//! ## Data plane
//!
//! Every deployment shares the pool's buffer [`Arena`] and
//! [`DataPlaneMetrics`]: a flush is packed once into an arena slab, moves
//! batch-at-once through the pipeline, and its responses are pushed into
//! the completion stream with a single [`send_many`](crate::coordinator::queue::Sender::send_many)
//! (one lock, at most one wakeup, per batch).  Steady state allocates
//! nothing per request — `repro dataplane` asserts it on a live pool.
//!
//! Immutable plan data ([`Assignment`], the [`PoolPlan`] itself, the
//! per-tenant [`TenantShape`]) is shared by `Arc` instead of deep-cloned
//! per worker per re-plan, so an online re-plan copies each changed
//! assignment exactly once.
//!
//! ## Drain / re-plan protocol
//!
//! A re-plan holds the pool's state lock, closes the ingress queues of
//! affected tenants, and joins their batcher workers.  Queue-close
//! semantics guarantee the worker first drains everything already
//! accepted, serving it through the old deployment; responses land in the
//! tenant's *persistent* completion queue, which outlives the swap.  Only
//! then is the new deployment spawned behind a fresh ingress.
//! [`submit`](ServingPool::submit) sends *outside* the state lock (so a
//! slow tenant cannot head-of-line block the pool); a send that races the
//! swap gets its request handed back by the closing queue and retries
//! against the new ingress — accepted requests are therefore never lost,
//! and per-tenant FIFO order is preserved across the swap.
//!
//! The synthetic backend's per-layer keyed transforms make the reference
//! output partition-invariant (see [`super::router::synthetic_reference`]),
//! so responses verify bit-for-bit even when a re-plan changes a tenant's
//! segmentation mid-run.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::SystemConfig;
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::queue::{bounded, Receiver, SendError, Sender};
use crate::coordinator::{
    Arena, BreakerConfig, DelayInjector, HedgeConfig, PipelineConfig, Request, Response,
};
use crate::metrics::{DataPlaneMetrics, SchedulerMetrics, TenantMetrics};
use crate::workload::faults::shed_threshold;
use crate::obs::span::{track_base, CACHE_TRACK};
use crate::obs::{SpanKind, SpanSink, Tracer};
use crate::runtime::Manifest;

use super::allocator::{allocate, AllocatorConfig, Assignment, PoolPlan};
use super::calibrate::{CalibrateConfig, Calibrator, Recalibration};
use super::journal::{fingerprint_str, Journal, JournalEvent, JournalLog};
use super::paramcache::CacheEffect;
use super::registry::{resolve_model, ModelRegistry, Tenant};
use super::router::{build_deployment, name_tenant_tracks, BackendKind, Deployment, TenantShape};

/// Completion-queue capacity per tenant: bounds how many responses may sit
/// unconsumed before the batcher worker backpressures.  Generous, so tests
/// and drivers may submit-then-drain without interleaving.
const DONE_QUEUE_CAPACITY: usize = 4096;

/// Render track of the pool's fault spans (device kills + recovery).
/// Far above any tenant's `track_base` run, so chaos events get their own
/// named lane in Perfetto instead of overprinting a tenant's stages.
const CHAOS_TRACK: u32 = 1023 * 64;

/// Knobs of the open-loop serving path — the one options type every
/// deployment entry point consumes ([`ServingPool::deploy`] and
/// [`PoolRouter::deploy`](super::router::PoolRouter::deploy)).  Build it
/// with the field literal + `..Default::default()`, or fluently:
///
/// ```ignore
/// let opts = DeployOptions::new()
///     .with_queue_capacity(128)
///     .with_hedge(HedgeConfig { p99_factor: 2.0, min_samples: 4 })
///     .with_calibration(CalibrateConfig::default());
/// ```
#[derive(Debug, Clone)]
pub struct DeployOptions {
    /// Per-tenant dynamic batching policy (size/wait flush).
    pub policy: BatchPolicy,
    /// Capacity of each tenant's ingress queue (requests) and of the host
    /// queues between pipeline stages (batches) — the backpressure bound.
    pub queue_capacity: usize,
    /// Span tracer for `--trace-out` (DESIGN.md §13).  `None` (the
    /// default) disables tracing; workers then skip recording behind one
    /// branch, staying inside the data plane's zero-alloc budget.
    pub tracer: Option<Arc<Tracer>>,
    /// Hedged-dispatch policy for replicated deployments (DESIGN.md §14).
    /// `None` (the default) disables hedging.
    pub hedge: Option<HedgeConfig>,
    /// Online cost-model calibration (DESIGN.md §16).  `None` (the
    /// default) disables the calibrator entirely:
    /// [`ServingPool::calibrate_tick`] becomes a no-op and every output
    /// stays byte-identical to an uncalibrated pool.
    pub calibrate: Option<CalibrateConfig>,
    /// Per-replica circuit breaker + stage watchdog for replicated
    /// deployments (DESIGN.md §17).  `None` (the default) disables the
    /// breaker; sharding and hedging behave exactly as before.
    pub breaker: Option<BreakerConfig>,
    /// SLO-derived deadlines for submitted requests (DESIGN.md §17).
    /// `None` (the default) stamps nothing: only deadlines the caller
    /// set explicitly via [`Request::with_deadline`] apply.
    pub deadline: Option<DeadlineConfig>,
    /// Path of the crash-recovery journal (DESIGN.md §17).  `None` (the
    /// default) disables journaling; with a path set, every control-plane
    /// mutation is fsync-journaled before it deploys, and
    /// [`ServingPool::recover`] can warm-restart the pool from the file.
    pub journal: Option<PathBuf>,
}

impl Default for DeployOptions {
    fn default() -> Self {
        DeployOptions {
            policy: BatchPolicy::default(),
            queue_capacity: 64,
            tracer: None,
            hedge: None,
            calibrate: None,
            breaker: None,
            deadline: None,
            journal: None,
        }
    }
}

impl DeployOptions {
    /// The defaults: pool batching policy, capacity 64, no tracing, no
    /// hedging, no calibration, no breaker, no deadlines, no journal.
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the dynamic batching policy.
    pub fn with_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Set the ingress/stage queue capacity (must be at least 1).
    pub fn with_queue_capacity(mut self, capacity: usize) -> Self {
        assert!(capacity >= 1, "queue capacity must be at least 1");
        self.queue_capacity = capacity;
        self
    }

    /// Attach a span tracer (DESIGN.md §13).
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> Self {
        self.tracer = Some(tracer);
        self
    }

    /// Enable hedged dispatch for replicated deployments (DESIGN.md §14).
    pub fn with_hedge(mut self, hedge: HedgeConfig) -> Self {
        self.hedge = Some(hedge);
        self
    }

    /// Enable online cost-model calibration (DESIGN.md §16).
    pub fn with_calibration(mut self, cfg: CalibrateConfig) -> Self {
        self.calibrate = Some(cfg);
        self
    }

    /// Enable the per-replica circuit breaker + watchdog (DESIGN.md §17).
    pub fn with_breaker(mut self, cfg: BreakerConfig) -> Self {
        self.breaker = Some(cfg);
        self
    }

    /// Derive request deadlines from tenant SLOs (DESIGN.md §17).
    pub fn with_deadlines(mut self, cfg: DeadlineConfig) -> Self {
        self.deadline = Some(cfg);
        self
    }

    /// Journal every control-plane mutation to `path` (DESIGN.md §17).
    pub fn with_journal(mut self, path: impl Into<PathBuf>) -> Self {
        self.journal = Some(path.into());
        self
    }
}

/// How submitted requests get their deadline when the caller did not
/// stamp one: `deadline = submit instant + slo_factor x tenant p99 SLO`.
/// Tenants without an SLO stay deadline-free.  The factor leaves slack
/// above the SLO itself — a request is only shed once it is *hopelessly*
/// late, not merely at risk.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeadlineConfig {
    /// Multiple of the tenant's `slo_p99_s` granted before expiry
    /// (finite, at least 1).
    pub slo_factor: f64,
}

impl Default for DeadlineConfig {
    fn default() -> Self {
        DeadlineConfig { slo_factor: 4.0 }
    }
}

impl DeadlineConfig {
    /// Reject factors that would expire requests at (or before) their
    /// SLO, or never.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.slo_factor.is_finite() && self.slo_factor >= 1.0,
            "deadline slo factor must be finite and >= 1 (got {})",
            self.slo_factor
        );
        Ok(())
    }
}

/// Former name of [`DeployOptions`], kept as a migration shim.
#[deprecated(note = "renamed to DeployOptions; `deploy` entry points now share one options type")]
pub type OpenOptions = DeployOptions;

/// Outcome of a prioritized submission: either the request entered the
/// tenant's ingress queue, or admission control turned it away because the
/// queue depth crossed the caller's tier threshold.  Shed requests are
/// *returned*, never silently dropped — the caller owns the accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// The request was accepted and will be served.
    Accepted,
    /// The request was turned away by tiered load shedding.
    Shed,
    /// The request's deadline had already passed at submit time: it was
    /// never enqueued, its id was pushed onto the tenant's
    /// [`TenantClient::expired`] stream, and it counts toward the
    /// tenant's `deadline_shed` metric.
    Expired,
}

/// Outcome of one online re-plan.
#[derive(Debug, Clone)]
pub struct ReplanReport {
    /// Deployments drained (then retired or redeployed) by this re-plan.
    pub drained: u64,
    /// Model names admitted by the new plan, sorted.
    pub admitted: Vec<String>,
    /// Tenants queued (pool too small) by the new plan.
    pub queued: usize,
    /// Tenants rejected (can never fit) by the new plan.
    pub rejected: usize,
}

impl ReplanReport {
    fn of(plan: &PoolPlan, drained: u64) -> ReplanReport {
        ReplanReport {
            drained,
            admitted: plan.assignments.iter().map(|a| a.name.clone()).collect(),
            queued: plan.queued.len(),
            rejected: plan.rejected.len(),
        }
    }
}

/// One tenant's live open-loop deployment: ingress + batcher worker.
struct LiveTenant {
    ingress: Sender<Request>,
    /// Second receiver handle on the ingress queue, held only to observe
    /// its depth for tiered admission (never used to consume requests).
    depth: Receiver<Request>,
    worker: Option<JoinHandle<()>>,
    /// The assignment this deployment realizes (shared, not re-cloned:
    /// the re-plan diff reads it, clients share its grant/partition).
    assignment: Arc<Assignment>,
    /// Shape/verification info mirrored into [`TenantClient`]s.
    shape: Arc<TenantShape>,
    metrics: Arc<TenantMetrics>,
    /// Per-replica dispatch-delay hook (replicated deployments only) —
    /// the chaos suite's straggler fault injection point.
    injector: Option<DelayInjector>,
}

/// A caller's handle on one tenant's open-loop stream: shape info for
/// building requests, the completion queue, and the tenant's counters.
/// The completion queue persists across re-plans; it closes (recv returns
/// `None`) only when the tenant is deregistered or the pool shuts down.
pub struct TenantClient {
    /// Model/routing name.
    pub name: String,
    /// Tensor shapes + synthetic verification key (shared, not cloned).
    pub shape: Arc<TenantShape>,
    /// The tenant's completion stream (cloneable receiver).
    pub done: Receiver<Response>,
    /// Ids of requests whose deadline expired before they reached a TPU
    /// (DESIGN.md §17).  Expired requests are *reported* here, never
    /// silently dropped: every submitted id eventually shows up on
    /// exactly one of `done` and `expired`.  Like `done`, the stream
    /// persists across re-plans.
    pub expired: Receiver<u64>,
    /// The tenant's serving counters (persist across re-plans).
    pub metrics: Arc<TenantMetrics>,
}

impl TenantClient {
    /// Input tensor element count (what submitted requests must carry).
    pub fn in_elems(&self) -> usize {
        self.shape.in_elems
    }

    /// Output tensor element count.
    pub fn out_elems(&self) -> usize {
        self.shape.out_elems
    }

    /// Deterministic random requests shaped for this tenant, ids `0..n`.
    pub fn synth_requests(&self, n: usize, seed: u64) -> Vec<Request> {
        self.shape.synth_requests(n, seed)
    }

    /// The serial reference output for one request (synthetic backend).
    pub fn reference(&self, input: &[i8]) -> Vec<i8> {
        self.shape.reference(input)
    }
}

/// Both ends of a tenant's persistent completion queue.
type DoneChannel = (Sender<Response>, Receiver<Response>);

/// Both ends of a tenant's persistent expired-id queue.
type ExpiredChannel = (Sender<u64>, Receiver<u64>);

struct PoolState {
    registry: ModelRegistry,
    live: BTreeMap<String, LiveTenant>,
    /// name -> (producer, consumer) of the persistent completion queue.
    done: BTreeMap<String, DoneChannel>,
    /// name -> (producer, consumer) of the persistent expired-id queue:
    /// where deadline-shed request ids surface (DESIGN.md §17).
    expired: BTreeMap<String, ExpiredChannel>,
    /// Per-tenant counters, persistent across re-plans.
    tenant_metrics: BTreeMap<String, Arc<TenantMetrics>>,
    plan: Arc<PoolPlan>,
    /// Devices lost to injected (or real) faults: excluded from every
    /// subsequent allocation until the pool is rebuilt.
    dead: BTreeSet<usize>,
    /// The online calibrator (`None` unless
    /// [`DeployOptions::calibrate`] was set): windowed drift state fed by
    /// [`ServingPool::calibrate_tick`].
    calibrator: Option<Calibrator>,
}

/// The open-loop multi-tenant serving pool (see the module docs for the
/// batching and drain/re-plan protocol).
pub struct ServingPool {
    system: SystemConfig,
    alloc: AllocatorConfig,
    backend: BackendKind,
    opts: DeployOptions,
    manifest: Option<Manifest>,
    /// Pool-wide slab arena: shared by every deployment, surviving
    /// re-plans, so recycled buffers cross tenants and redeployments.
    arena: Arena,
    data_plane: Arc<DataPlaneMetrics>,
    state: Mutex<PoolState>,
    /// The open crash-recovery journal (`None` unless
    /// [`DeployOptions::journal`] was set).  Separate from the state lock
    /// so a slow fsync never blocks submits; mutations append *while
    /// holding the state lock*, so journal order always matches apply
    /// order.
    journal: Mutex<Option<Journal>>,
    /// Pool-level admission/routing/re-plan counters.
    pub metrics: Arc<SchedulerMetrics>,
}

/// Deterministic fingerprint of a plan's assignment set: FNV-1a over the
/// Debug rendering (f64 Debug is round-trip exact, the allocator is
/// deterministic — so a faithful journal replay reproduces this exactly).
pub fn plan_fingerprint(plan: &PoolPlan) -> u64 {
    fingerprint_str(&format!("{:?}", plan.assignments))
}

/// Replay a recovery journal into the registry + dead-device set it
/// describes — the pure half of [`ServingPool::recover`], shared with
/// `repro recover` (which also renders the deterministic loadgen table
/// from the recovered registry).
pub fn replay_journal(log: &JournalLog) -> Result<(ModelRegistry, BTreeSet<usize>)> {
    let mut registry = ModelRegistry::new();
    let mut dead: BTreeSet<usize> = BTreeSet::new();
    for ev in &log.events {
        match ev {
            JournalEvent::Register { name, model, weight, slo_p99_s, cost_scale } => {
                let mut t = Tenant::new(name.clone(), resolve_model(model)?)
                    .with_weight(*weight)
                    .with_cost_scale(*cost_scale);
                if let Some(s) = slo_p99_s {
                    t = t.with_slo_p99_s(*s);
                }
                registry.register(t)?;
            }
            JournalEvent::Deregister { name } => {
                registry.deregister(name)?;
            }
            JournalEvent::Kill { device } => {
                dead.insert(*device);
            }
            JournalEvent::Recalibrate { name, scale } => {
                registry
                    .get_mut(name)
                    .with_context(|| format!("journal recalibrates unknown tenant {name:?}"))?
                    .cost_scale = *scale;
            }
            JournalEvent::PlanFingerprint { .. } => {}
        }
    }
    Ok((registry, dead))
}

/// The journal record of one tenant registration.  Journaled pools
/// register tenants by model *name*, so the model must resolve at replay
/// time.
fn register_event(t: &Tenant) -> Result<JournalEvent> {
    anyhow::ensure!(
        resolve_model(&t.model.name).is_ok(),
        "journaled pools need resolvable model names (tenant {:?} has model {:?})",
        t.name,
        t.model.name
    );
    Ok(JournalEvent::Register {
        name: t.name.clone(),
        model: t.model.name.clone(),
        weight: t.weight,
        slo_p99_s: t.slo_p99_s,
        cost_scale: t.cost_scale,
    })
}

/// Per-tenant batcher worker: pull batches off the ingress queue under the
/// flush policy, serve them through the deployment, stream responses into
/// the completion queue (one `send_many` per batch).  Exits (and tears
/// the deployment down) when the ingress queue is closed and drained.
fn tenant_worker(
    deployment: Deployment,
    batcher: Batcher,
    done: Sender<Response>,
    expired_tx: Sender<u64>,
    metrics: Arc<TenantMetrics>,
    pool_metrics: Arc<SchedulerMetrics>,
    swap_s: f64,
    quantum_s: f64,
    cache: Option<CacheEffect>,
    obs: Option<(SpanSink, u32)>,
) {
    // sim latencies are recorded relative to the deployment's sim clock at
    // batch start (the clock is monotonic across batches)
    let mut sim_epoch = 0.0f64;
    // host-clock instant of the last paid parameter re-load: a batch that
    // lands inside the tenant's current scheduling quantum keeps the
    // parameters resident and skips the swap (quantum_s = 0 swaps on
    // every flush, the PR 3 behaviour).  The live run paces arrivals in
    // real time, so the host clock is the live analogue of the sim's
    // flush clock; exact swap accounting is the deterministic sim's job
    // (`workload::simulate_deployment`).
    let started = std::time::Instant::now();
    let mut last_swap_s = f64::NEG_INFINITY;
    // batch ordinal: span id of this tenant's Flush/Swap spans
    let mut batch_idx = 0u64;
    // hedged-dispatch high-water mark: the router counts cumulatively,
    // the tenant metric wants per-batch deltas
    let mut hedged_seen = 0u64;
    // breaker trip/probe high-water marks, same delta scheme
    let mut trips_seen = 0u64;
    let mut probes_seen = 0u64;
    while let Some((batch, kind)) = batcher.next_batch_with_reason() {
        metrics.record_batch(batch.len() as u64, batcher.queue_depth() as u64, kind);
        if let Some((sink, base)) = &obs {
            // flush instant on the tenant's batcher track
            sink.record(SpanKind::Flush, base + 1, batch_idx, sink.now_us(), 0);
        }
        // deadline shedding (DESIGN.md §17): drop expired requests *here*,
        // after the flush but before the swap/serve path, so they never
        // occupy a TPU quantum and never open a Stage span.  The whole
        // check is gated on any deadline being present, keeping the
        // deadline-free hot path allocation-free and byte-identical.
        let mut batch = batch;
        if batch.iter().any(|r| r.deadline.is_some()) {
            let now = Instant::now();
            let mut expired_ids: Vec<u64> = Vec::new();
            batch.retain(|r| {
                if r.expired_at(now) {
                    expired_ids.push(r.id);
                    false
                } else {
                    true
                }
            });
            if !expired_ids.is_empty() {
                metrics.record_deadline_shed(expired_ids.len() as u64);
                if let Some((sink, base)) = &obs {
                    for id in &expired_ids {
                        // expiry instant on the tenant's request track
                        sink.record(SpanKind::Deadline, *base, *id, sink.now_us(), 0);
                    }
                }
                // surface the ids — shed requests are reported, not lost
                let _ = expired_tx.send_many(expired_ids);
                if batch.is_empty() {
                    batch_idx += 1;
                    continue;
                }
            }
        }
        let batch_swap_s = if swap_s > 0.0 {
            let now_s = started.elapsed().as_secs_f64();
            if now_s >= last_swap_s + quantum_s {
                // time-shared deployment: the co-resident ran since the
                // last quantum, so this batch swaps the parameters back
                // in — at the full cold cost, unless a cache-enabled plan
                // kept part (or all) of them staged within the budget
                let first = last_swap_s == f64::NEG_INFINITY;
                last_swap_s = now_s;
                let paid = match cache {
                    Some(eff) => {
                        let class = eff.classify(swap_s, first);
                        metrics.record_cache(class.hit, class.prefetched);
                        if class.prefetched {
                            if let Some((sink, base)) = &obs {
                                // the overlapped load ends at the quantum
                                // boundary (= now): span it backwards
                                let dur_us = (eff.prefetch_s * 1e6) as u64;
                                let end_us = sink.now_us();
                                sink.record(
                                    SpanKind::Prefetch,
                                    base + CACHE_TRACK,
                                    batch_idx,
                                    end_us.saturating_sub(dur_us),
                                    dur_us,
                                );
                            }
                        }
                        swap_s * class.frac
                    }
                    None => swap_s,
                };
                metrics.record_swap(paid);
                if paid > 0.0 {
                    if let Some((sink, base)) = &obs {
                        // the paid re-load, annotated with its modelled cost
                        let dur_us = (paid * 1e6) as u64;
                        sink.record(SpanKind::Swap, base + 1, batch_idx, sink.now_us(), dur_us);
                    }
                }
                paid
            } else {
                metrics.record_swap_skipped();
                0.0
            }
        } else {
            0.0
        };
        match deployment.serve_batch(batch) {
            Ok(responses) => {
                let base = sim_epoch;
                for r in &responses {
                    // the swap's parameter re-load runs before the batch,
                    // delaying every response in it — charge it to the
                    // recorded sim latency so live p99 matches both the
                    // allocator prediction and the deterministic sim
                    metrics.record_response(
                        r.real_latency_s,
                        (r.sim_done_s - base).max(0.0) + batch_swap_s,
                    );
                    if r.sim_done_s > sim_epoch {
                        sim_epoch = r.sim_done_s;
                    }
                    if let Some((sink, track)) = &obs {
                        // request lifecycle span: ends now, spans the
                        // measured wall-clock latency backwards
                        let end_us = sink.now_us();
                        let dur_us = (r.real_latency_s * 1e6) as u64;
                        let start_us = end_us.saturating_sub(dur_us);
                        sink.record(SpanKind::Response, *track, r.id, start_us, dur_us);
                    }
                }
                // the whole batch of responses crosses the completion
                // queue under one lock/wakeup; a closed stream (pool
                // shutdown racing the drain) just drops the remainder
                let _ = done.send_many(responses);
            }
            Err(_) => metrics.record_error(),
        }
        let hedged = deployment.hedged_total();
        if hedged > hedged_seen {
            metrics.record_hedges(hedged - hedged_seen);
            hedged_seen = hedged;
        }
        // breaker activity, same cumulative->delta scheme as hedges; each
        // trip gets an instant marker on the chaos track
        let trips = deployment.breaker_trips_total();
        for t in trips_seen..trips {
            pool_metrics.record_breaker_trip();
            if let Some((sink, _base)) = &obs {
                sink.record(SpanKind::Trip, CHAOS_TRACK, t, sink.now_us(), 0);
            }
        }
        trips_seen = trips.max(trips_seen);
        let probes = deployment.breaker_probes_total();
        for _ in probes_seen..probes {
            pool_metrics.record_breaker_probe();
        }
        probes_seen = probes.max(probes_seen);
        batch_idx += 1;
    }
    deployment.shutdown();
}

impl ServingPool {
    /// Plan over `registry` and spawn one open-loop deployment per
    /// admitted tenant.  Blocks until every stage backend is constructed,
    /// so a returned pool is ready to serve.
    pub fn deploy(
        registry: ModelRegistry,
        system: SystemConfig,
        alloc: AllocatorConfig,
        backend: BackendKind,
        opts: DeployOptions,
    ) -> Result<ServingPool> {
        Self::deploy_inner(registry, system, alloc, backend, opts, BTreeSet::new(), None)
    }

    /// Warm-restart a pool from its recovery journal (DESIGN.md §17):
    /// replay the WAL into a fresh registry + fault record, re-open the
    /// journal (which bumps the generation, fencing the crashed
    /// controller for good), deploy, and verify the recovered plan's
    /// fingerprint against the journal's last snapshot — so a recovered
    /// pool provably serves the exact pre-crash plan, or refuses to
    /// serve at all.  `opts.journal` is overwritten with `journal_path`;
    /// the other options should match the crashed deployment's.
    pub fn recover(
        system: SystemConfig,
        alloc: AllocatorConfig,
        backend: BackendKind,
        opts: DeployOptions,
        journal_path: &Path,
    ) -> Result<ServingPool> {
        let log = Journal::load(journal_path)?;
        anyhow::ensure!(
            log.generation > 0,
            "no journal to recover from at {}",
            journal_path.display()
        );
        let (registry, dead) = replay_journal(&log)?;
        let mut opts = opts;
        opts.journal = Some(journal_path.to_path_buf());
        Self::deploy_inner(
            registry,
            system,
            alloc,
            backend,
            opts,
            dead,
            Some(log.last_fingerprint()),
        )
    }

    /// Shared tail of [`deploy`](ServingPool::deploy) and
    /// [`recover`](ServingPool::recover).  `recovering` is `None` for a
    /// fresh deploy (the journal, if any, is bootstrapped with the
    /// registry) and `Some(expected fingerprint)` for a recovery (the
    /// journal already holds the WAL; the recovered plan must match its
    /// last snapshot).
    fn deploy_inner(
        registry: ModelRegistry,
        system: SystemConfig,
        alloc: AllocatorConfig,
        backend: BackendKind,
        opts: DeployOptions,
        dead: BTreeSet<usize>,
        recovering: Option<Option<u64>>,
    ) -> Result<ServingPool> {
        let manifest = match &backend {
            BackendKind::Pjrt { artifact_dir } => {
                Some(Manifest::load(&artifact_dir.join("manifest.json"))?)
            }
            BackendKind::Synthetic => None,
        };
        if let Some(cfg) = &opts.calibrate {
            cfg.validate()?;
        }
        if let Some(cfg) = &opts.deadline {
            cfg.validate()?;
        }
        // opening the journal *is* becoming the controller: the
        // generation bump fences whoever held it before (crashed or not)
        let journal = match &opts.journal {
            Some(path) => {
                let mut j = Journal::open(path)?;
                if recovering.is_none() {
                    // fresh deploy: seed the WAL with the initial registry
                    for t in registry.iter() {
                        j.append(&register_event(t)?)?;
                    }
                    for d in &dead {
                        j.append(&JournalEvent::Kill { device: *d })?;
                    }
                }
                Some(j)
            }
            None => None,
        };
        let calibrator = opts.calibrate.clone().map(Calibrator::new);
        let total_tpus = alloc.total_tpus;
        let allow_sharing = alloc.allow_sharing;
        let cache_enabled = allow_sharing && alloc.cache_budget_bytes > 0;
        let data_plane = Arc::new(DataPlaneMetrics::default());
        let pool = ServingPool {
            system,
            alloc,
            backend,
            opts,
            manifest,
            arena: Arena::new(data_plane.clone()),
            data_plane,
            state: Mutex::new(PoolState {
                registry,
                live: BTreeMap::new(),
                done: BTreeMap::new(),
                expired: BTreeMap::new(),
                tenant_metrics: BTreeMap::new(),
                dead,
                calibrator,
                plan: Arc::new(PoolPlan {
                    total_tpus,
                    assignments: Vec::new(),
                    queued: Vec::new(),
                    rejected: Vec::new(),
                    objective_s: 0.0,
                    sharing_enabled: allow_sharing,
                    cache_enabled,
                }),
            }),
            journal: Mutex::new(journal),
            metrics: Arc::new(SchedulerMetrics::default()),
        };
        {
            let mut st = pool.state.lock().unwrap();
            pool.apply_plan(&mut st)?;
            if let Some(expected) = recovering {
                let got = plan_fingerprint(&st.plan);
                if let Some(expected) = expected {
                    anyhow::ensure!(
                        got == expected,
                        "recovered plan diverges from journal snapshot \
                         ({got:016x} != {expected:016x})"
                    );
                }
                pool.metrics.record_recovery();
                if let Some(t) = &pool.opts.tracer {
                    t.name_track(CHAOS_TRACK, "chaos/faults".to_string());
                    let sink = t.handle();
                    let generation =
                        pool.journal.lock().unwrap().as_ref().map_or(0, Journal::generation);
                    sink.record(SpanKind::Recover, CHAOS_TRACK, generation, sink.now_us(), 0);
                }
            }
            pool.journal_plan(&st)?;
        }
        Ok(pool)
    }

    /// Append one event to the journal, if one is open.  Called while the
    /// caller holds the state lock, so journal order matches apply order.
    fn journal_append(&self, ev: &JournalEvent) -> Result<()> {
        if let Some(j) = self.journal.lock().unwrap().as_mut() {
            j.append(ev)?;
        }
        Ok(())
    }

    /// Journal the fingerprint snapshot of the plan just applied.
    fn journal_plan(&self, st: &PoolState) -> Result<()> {
        self.journal_append(&JournalEvent::PlanFingerprint {
            fingerprint: plan_fingerprint(&st.plan),
        })
    }

    /// Re-run the allocator over the state's registry, drain deployments
    /// whose assignment vanished or changed, and spawn the missing ones.
    /// Returns how many deployments were drained.
    fn apply_plan(&self, st: &mut PoolState) -> Result<u64> {
        // an empty registry is a valid (idle) pool: deregistering the last
        // tenant must drain it, not error
        let plan = if st.registry.is_empty() {
            PoolPlan {
                total_tpus: self.alloc.total_tpus,
                assignments: Vec::new(),
                queued: Vec::new(),
                rejected: Vec::new(),
                objective_s: 0.0,
                sharing_enabled: self.alloc.allow_sharing,
                cache_enabled: self.alloc.allow_sharing
                    && self.alloc.cache_budget_bytes > 0,
            }
        } else {
            // fold the pool's fault record into the allocator's view: a
            // killed device is out of service for every future plan
            let mut alloc = self.alloc.clone();
            alloc.dead_devices = st.dead.iter().copied().collect();
            allocate(&st.registry, &self.system, &alloc)?
        };

        // drain deployments whose assignment vanished or changed; joining
        // the worker completes every request its ingress already accepted
        let names: Vec<String> = st.live.keys().cloned().collect();
        let mut drained = 0u64;
        for name in names {
            let keep = match plan.assignment(&name) {
                Some(a) => {
                    let old = &st.live[&name].assignment;
                    a.candidate.tpu_count == old.candidate.tpu_count
                        && a.replicas == old.replicas
                        && a.candidate.partition.cuts == old.candidate.partition.cuts
                        // device renumbering alone is not a change: only
                        // slice/cost/co-resident differences force a drain
                        && a.grant.same_deployment(&old.grant)
                        // ...unless the old deployment sits on a device
                        // that has since died: it must evacuate even if
                        // the new assignment looks identical
                        && !old.devices.iter().any(|d| st.dead.contains(d))
                }
                None => false,
            };
            if !keep {
                let mut lt = st.live.remove(&name).unwrap();
                lt.ingress.close();
                if let Some(h) = lt.worker.take() {
                    let _ = h.join();
                }
                drained += 1;
            }
        }

        // spawn deployments for new or changed assignments; all of them
        // share the pool's arena + data-plane counters
        let pipe = PipelineConfig {
            queue_capacity: self.opts.queue_capacity,
            arena: Some(self.arena.clone()),
            data_plane: Some(self.data_plane.clone()),
            tracer: self.opts.tracer.clone(),
            trace_track_base: 0,
        };
        for (idx, a) in plan.assignments.iter().enumerate() {
            if st.live.contains_key(&a.name) {
                continue;
            }
            // per-plan tenant track run (requests, batcher, stages); a
            // re-plan may renumber tracks, but names follow along
            let tbase = track_base(idx);
            if let Some(t) = &self.opts.tracer {
                let n_stages = a.candidate.partition.n_segments();
                name_tenant_tracks(t, &a.name, idx, a.replicas, n_stages, a.grant.cache().is_some());
            }
            let tenant_pipe = PipelineConfig { trace_track_base: tbase + 2, ..pipe.clone() };
            let built = build_deployment(
                a,
                &st.registry,
                &self.system,
                &self.backend,
                self.manifest.as_ref(),
                &tenant_pipe,
                self.opts.hedge.as_ref(),
                self.opts.breaker.as_ref(),
            )?;
            built.deployment.wait_ready()?;
            if self.opts.breaker.is_some() {
                if let Some(t) = &self.opts.tracer {
                    // breaker trips render on the chaos lane (named here,
                    // once, so the worker only needs the sink handle)
                    t.name_track(CHAOS_TRACK, "chaos/faults".to_string());
                }
            }
            let (ingress, ingress_rx) = bounded(self.opts.queue_capacity);
            let depth = ingress_rx.clone();
            let done_tx = st
                .done
                .entry(a.name.clone())
                .or_insert_with(|| bounded(DONE_QUEUE_CAPACITY))
                .0
                .clone();
            let expired_tx = st
                .expired
                .entry(a.name.clone())
                .or_insert_with(|| bounded(DONE_QUEUE_CAPACITY))
                .0
                .clone();
            let metrics = st
                .tenant_metrics
                .entry(a.name.clone())
                .or_insert_with(|| Arc::new(TenantMetrics::default()))
                .clone();
            // a tenant with a tight SLO gets a tighter flush deadline
            // than the pool-global policy (admission and batching agree
            // on the latency budget)
            let batcher =
                Batcher::new(ingress_rx, self.opts.policy.for_slo(a.slo_p99_s));
            let deployment = built.deployment;
            let worker_metrics = metrics.clone();
            let pool_metrics = self.metrics.clone();
            let swap_s = a.grant.switch_s();
            let quantum_s = a.grant.quantum_s();
            let cache = a.grant.cache();
            let obs = self.opts.tracer.as_ref().map(|t| (t.handle(), tbase));
            let worker = std::thread::spawn(move || {
                tenant_worker(
                    deployment,
                    batcher,
                    done_tx,
                    expired_tx,
                    worker_metrics,
                    pool_metrics,
                    swap_s,
                    quantum_s,
                    cache,
                    obs,
                )
            });
            st.live.insert(
                a.name.clone(),
                LiveTenant {
                    ingress,
                    depth,
                    worker: Some(worker),
                    assignment: Arc::new(a.clone()),
                    shape: built.shape,
                    metrics,
                    injector: built.injector,
                },
            );
        }

        self.metrics.record_admission(
            st.registry.len() as u64,
            plan.assignments.len() as u64,
            plan.shared_count() as u64,
            plan.queued.len() as u64,
            plan.rejected.len() as u64,
        );
        st.plan = Arc::new(plan);
        Ok(drained)
    }

    /// Submit one request to the named tenant's ingress queue.  Blocks
    /// only when that tenant's ingress queue is full (backpressure) — the
    /// state lock is released before the send, so a slow tenant never
    /// head-of-line blocks other tenants' submissions or a concurrent
    /// re-plan.  If a re-plan closes the ingress mid-send, the bounded
    /// queue hands the request back intact and the send retries against
    /// the tenant's new deployment: an accepted request is always served.
    pub fn submit(&self, model: &str, request: Request) -> Result<()> {
        // tier 0 is never shed, so this is plain (blocking) admission
        self.submit_with_priority(model, request, 0).map(|_| ())
    }

    /// [`submit`](ServingPool::submit) with priority-tiered load shedding
    /// (DESIGN.md §14): before enqueueing, the request's priority tier is
    /// checked against the tenant's current ingress depth —
    /// [`shed_threshold`] — and a request over its tier's threshold is
    /// turned away with [`Admission::Shed`] instead of blocking on a
    /// congested queue.  Tier 0 (the highest priority) is never shed;
    /// lower tiers give up progressively earlier, preserving headroom for
    /// the traffic that must meet its SLO.  A shed request is counted in
    /// the tenant's `shed` metric and *returned to the caller*, never
    /// silently dropped.
    pub fn submit_with_priority(
        &self,
        model: &str,
        request: Request,
        tier: u8,
    ) -> Result<Admission> {
        let mut request = request;
        loop {
            let (ingress, depth, metrics, expired_tx, slo) = {
                let st = self.state.lock().unwrap();
                let lt = st.live.get(model).with_context(|| {
                    format!(
                        "model {model:?} has no live deployment (admitted: {:?})",
                        st.live.keys().collect::<Vec<_>>()
                    )
                })?;
                let expired_tx =
                    st.expired.get(model).expect("live tenant has an expired channel").0.clone();
                (
                    lt.ingress.clone(),
                    lt.depth.len(),
                    lt.metrics.clone(),
                    expired_tx,
                    lt.assignment.slo_p99_s,
                )
            };
            // stamp the SLO-derived deadline once (a caller-set deadline,
            // or one stamped before a re-plan retry, is kept)
            if request.deadline.is_none() {
                if let (Some(cfg), Some(slo)) = (&self.opts.deadline, slo) {
                    request.deadline =
                        Some(Instant::now() + Duration::from_secs_f64(cfg.slo_factor * slo));
                }
            }
            if request.expired_at(Instant::now()) {
                // already hopeless at the door: typed, accounted, and
                // reported on the expired stream — never enqueued
                metrics.record_deadline_shed(1);
                let _ = expired_tx.send(request.id);
                return Ok(Admission::Expired);
            }
            if depth >= shed_threshold(tier, self.opts.queue_capacity) {
                metrics.record_shed();
                return Ok(Admission::Shed);
            }
            match ingress.send(request) {
                Ok(()) => {
                    metrics.record_submitted(1);
                    self.metrics.record_routed(1);
                    return Ok(Admission::Accepted);
                }
                // a re-plan swapped this tenant's ingress under us; the
                // request came back intact — retry (or error out above if
                // the tenant was deregistered)
                Err(SendError(r)) => request = r,
            }
        }
    }

    /// Take a device out of service and re-plan around it, as if it had
    /// died: every deployment holding the device is drained (in-flight
    /// requests complete through the old deployment and are *replayed*
    /// onto the completion stream via the PR 2 drain protocol) and the
    /// survivors are redeployed on the remaining devices.  The device
    /// stays dead for every later re-plan.  Records a [`SpanKind::Fault`]
    /// span covering kill → recovery on the chaos track, so Perfetto
    /// shows the outage and the re-plan that healed it.
    pub fn kill_device(&self, device: usize) -> Result<ReplanReport> {
        anyhow::ensure!(
            device < self.alloc.total_tpus,
            "device {device} out of range for a {}-TPU pool",
            self.alloc.total_tpus
        );
        let mut st = self.state.lock().unwrap();
        if st.dead.contains(&device) {
            // a repeated kill is an operator error, not a no-op: surface
            // it typed and meter it, so runbooks notice the double-fire
            self.metrics.record_kill_repeat();
            anyhow::bail!("device {device} is already dead");
        }
        anyhow::ensure!(
            st.dead.len() + 1 < self.alloc.total_tpus,
            "killing device {device} would leave the pool with no live devices"
        );
        st.dead.insert(device);
        self.journal_append(&JournalEvent::Kill { device })?;
        let t0 = std::time::Instant::now();
        let obs = self.opts.tracer.as_ref().map(|t| {
            t.name_track(CHAOS_TRACK, "chaos/faults".to_string());
            t.handle()
        });
        let drained = self.apply_plan(&mut st)?;
        self.journal_plan(&st)?;
        self.metrics.record_device_kill();
        self.metrics.record_replan(drained);
        if let Some(sink) = obs {
            // span the whole outage window: kill instant -> re-plan done
            let end_us = sink.now_us();
            let dur_us = (t0.elapsed().as_secs_f64() * 1e6) as u64;
            sink.record(
                SpanKind::Fault,
                CHAOS_TRACK,
                device as u64,
                end_us.saturating_sub(dur_us),
                dur_us,
            );
        }
        Ok(ReplanReport::of(&st.plan, drained))
    }

    /// Devices currently marked dead (ascending).
    pub fn dead_devices(&self) -> Vec<usize> {
        self.state.lock().unwrap().dead.iter().copied().collect()
    }

    /// Close one calibration window (DESIGN.md §16): diff every live
    /// tenant's lifetime sim-latency histogram into the calibrator's
    /// windowed banks (no hot-path instrumentation — the worker already
    /// records the histogram), evaluate drift, publish the per-tenant
    /// `drift` gauge, and — if any recalibration fired — write the
    /// corrected [`cost_scale`](Tenant::cost_scale) back into the
    /// registry and re-plan through the drain/redeploy path, so no
    /// in-flight request is lost.  Records a [`SpanKind::Recalibrate`]
    /// span per fired tenant on the chaos/control track.
    ///
    /// A no-op returning an empty ledger when the pool was deployed
    /// without [`DeployOptions::calibrate`], keeping uncalibrated pools
    /// byte-identical to before.
    pub fn calibrate_tick(&self) -> Result<Vec<Recalibration>> {
        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        if st.calibrator.is_none() {
            return Ok(Vec::new());
        }
        let fired = {
            let cal = st.calibrator.as_mut().expect("checked above");
            for (name, lt) in &st.live {
                cal.ingest_lifetime(name, &lt.metrics.sim_latency_hist());
            }
            let fired = cal.end_window();
            for (name, m) in &st.tenant_metrics {
                m.record_drift(cal.last_drift(name));
            }
            fired
        };
        if fired.is_empty() {
            return Ok(fired);
        }
        let t0 = std::time::Instant::now();
        for f in &fired {
            if let Some(t) = st.registry.get_mut(&f.tenant) {
                t.cost_scale = f.scale;
                self.journal_append(&JournalEvent::Recalibrate {
                    name: f.tenant.clone(),
                    scale: f.scale,
                })?;
            }
        }
        let drained = self.apply_plan(st)?;
        self.journal_plan(st)?;
        self.metrics.record_replan(drained);
        self.metrics.record_replan_calibration(fired.len() as u64);
        if let Some(tracer) = self.opts.tracer.as_ref() {
            tracer.name_track(CHAOS_TRACK, "chaos/faults".to_string());
            let sink = tracer.handle();
            // span the write-back + re-plan window, one span per tenant
            let end_us = sink.now_us();
            let dur_us = (t0.elapsed().as_secs_f64() * 1e6) as u64;
            for f in &fired {
                sink.record(
                    SpanKind::Recalibrate,
                    CHAOS_TRACK,
                    f.window,
                    end_us.saturating_sub(dur_us),
                    dur_us,
                );
            }
        }
        Ok(fired)
    }

    /// Operator path of the calibration loop: write `scale` into the
    /// named tenant's profiled cost model directly and re-plan through
    /// the same drain/redeploy path the drift detector uses.  `scale` is
    /// the observed/predicted service-time ratio (must be positive and
    /// finite); `1.0` restores the un-drifted profile.
    pub fn recalibrate_tenant(&self, name: &str, scale: f64) -> Result<ReplanReport> {
        anyhow::ensure!(
            scale.is_finite() && scale > 0.0,
            "cost scale must be positive and finite (got {scale})"
        );
        let mut st = self.state.lock().unwrap();
        st.registry
            .get_mut(name)
            .with_context(|| format!("model {name:?} not registered"))?
            .cost_scale = scale;
        self.journal_append(&JournalEvent::Recalibrate { name: name.to_string(), scale })?;
        let drained = self.apply_plan(&mut st)?;
        self.journal_plan(&st)?;
        self.metrics.record_replan(drained);
        self.metrics.record_replan_calibration(1);
        Ok(ReplanReport::of(&st.plan, drained))
    }

    /// Inject an artificial dispatch delay on one replica of `model`'s
    /// deployment — the chaos suite's straggler fault.  Every batch shard
    /// routed to that replica is delayed by `delay` until
    /// [`clear_straggler`](ServingPool::clear_straggler) removes it,
    /// inflating its recorded latency exactly as a contended device
    /// would (and, with [`DeployOptions::hedge`] set, eventually tripping
    /// hedged dispatch).  Errors if the tenant is not replicated: a
    /// single-pipeline deployment has no alternate replica to observe the
    /// straggle from.
    pub fn inject_straggler(&self, model: &str, replica: usize, delay: Duration) -> Result<()> {
        let st = self.state.lock().unwrap();
        let lt = st
            .live
            .get(model)
            .with_context(|| format!("model {model:?} has no live deployment"))?;
        let inj = lt.injector.as_ref().with_context(|| {
            format!("model {model:?} is not replicated: no straggler to inject")
        })?;
        inj.set(replica, delay);
        Ok(())
    }

    /// Remove an injected straggler delay (no-op if none is set).
    pub fn clear_straggler(&self, model: &str, replica: usize) -> Result<()> {
        let st = self.state.lock().unwrap();
        let lt = st
            .live
            .get(model)
            .with_context(|| format!("model {model:?} has no live deployment"))?;
        if let Some(inj) = lt.injector.as_ref() {
            inj.clear(replica);
        }
        Ok(())
    }

    /// A caller handle on one live tenant: shape info, completion stream
    /// and counters.  Cheap to call (all shared data is `Arc`-cloned);
    /// the stream survives re-plans.
    pub fn client(&self, model: &str) -> Result<TenantClient> {
        let st = self.state.lock().unwrap();
        let lt = st
            .live
            .get(model)
            .with_context(|| format!("model {model:?} has no live deployment"))?;
        let done = st.done.get(model).expect("live tenant has a done channel").1.clone();
        let expired =
            st.expired.get(model).expect("live tenant has an expired channel").1.clone();
        Ok(TenantClient {
            name: model.to_string(),
            shape: lt.shape.clone(),
            done,
            expired,
            metrics: lt.metrics.clone(),
        })
    }

    /// Register a new tenant on the live pool and re-plan.  Deployments
    /// whose assignment is unchanged keep running untouched; changed ones
    /// are drained (in-flight requests complete) and redeployed.
    pub fn register(&self, tenant: Tenant) -> Result<ReplanReport> {
        let mut st = self.state.lock().unwrap();
        // only journaled pools need a resolvable model name — check (and
        // encode) before mutating, so a bad tenant changes nothing
        let ev = match self.journal.lock().unwrap().is_some() {
            true => Some(register_event(&tenant)?),
            false => None,
        };
        st.registry.register(tenant)?;
        // write-ahead: the event lands (fsynced) before the deployment
        // changes, so a crash in between recovers to the post-event plan
        if let Some(ev) = &ev {
            self.journal_append(ev)?;
        }
        let drained = self.apply_plan(&mut st)?;
        self.journal_plan(&st)?;
        self.metrics.record_replan(drained);
        Ok(ReplanReport::of(&st.plan, drained))
    }

    /// Remove a tenant from the live pool and re-plan.  The tenant's
    /// in-flight requests complete first (drain), then its completion
    /// queue closes; freed TPUs are re-auctioned to the remaining tenants.
    pub fn deregister(&self, name: &str) -> Result<ReplanReport> {
        let mut st = self.state.lock().unwrap();
        st.registry.deregister(name)?;
        self.journal_append(&JournalEvent::Deregister { name: name.to_string() })?;
        let drained = self.apply_plan(&mut st)?;
        self.journal_plan(&st)?;
        // the drain above already flushed every accepted request's
        // response into the completion queue; now end the stream
        if let Some((tx, _rx)) = st.done.remove(name) {
            tx.close();
        }
        if let Some((tx, _rx)) = st.expired.remove(name) {
            tx.close();
        }
        st.tenant_metrics.remove(name);
        self.metrics.record_replan(drained);
        Ok(ReplanReport::of(&st.plan, drained))
    }

    /// Shared snapshot of the most recent pool plan (`Arc`, not a deep
    /// clone — plans are immutable once applied).
    pub fn plan(&self) -> Arc<PoolPlan> {
        self.state.lock().unwrap().plan.clone()
    }

    /// Names of the tenants with a live deployment, sorted.
    pub fn names(&self) -> Vec<String> {
        self.state.lock().unwrap().live.keys().cloned().collect()
    }

    /// One tenant's counters (also reachable via [`TenantClient`]).
    pub fn tenant_metrics(&self, name: &str) -> Option<Arc<TenantMetrics>> {
        self.state.lock().unwrap().tenant_metrics.get(name).cloned()
    }

    /// The pool-wide data-plane counters (handoffs, slab alloc/reuse)
    /// aggregated across every tenant's deployment, surviving re-plans.
    pub fn data_plane(&self) -> Arc<DataPlaneMetrics> {
        self.data_plane.clone()
    }

    /// Drain every tenant (in-flight requests complete), join all workers
    /// and close all completion streams.
    pub fn shutdown(self) {
        let mut st = self.state.into_inner().unwrap();
        let names: Vec<String> = st.live.keys().cloned().collect();
        for name in names {
            let mut lt = st.live.remove(&name).unwrap();
            lt.ingress.close();
            if let Some(h) = lt.worker.take() {
                let _ = h.join();
            }
        }
        for (_name, (tx, _rx)) in st.done {
            tx.close();
        }
        for (_name, (tx, _rx)) in st.expired {
            tx.close();
        }
    }
}

/// Handle to a background calibration thread started by
/// [`spawn_calibration_ticker`].  Dropping it (or calling
/// [`stop`](CalibrationTicker::stop)) signals the thread and joins it, so
/// a ticker can never outlive the scope that owns it.
pub struct CalibrationTicker {
    stop: Arc<std::sync::atomic::AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl CalibrationTicker {
    /// Signal the ticker thread and wait for it to exit.
    pub fn stop(self) {
        // Drop does the work; `stop` exists so call sites read as intent.
    }
}

impl Drop for CalibrationTicker {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Drive [`ServingPool::calibrate_tick`] every `period` from a background
/// thread until the returned [`CalibrationTicker`] is stopped or dropped.
/// The live counterpart of the sim driver's per-window loop: each tick
/// closes one calibration window.  Tick errors are swallowed — a failed
/// re-plan leaves the previous plan serving, and the next window retries.
pub fn spawn_calibration_ticker(pool: Arc<ServingPool>, period: Duration) -> CalibrationTicker {
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let flag = stop.clone();
    let handle = std::thread::spawn(move || loop {
        std::thread::sleep(period);
        if flag.load(std::sync::atomic::Ordering::SeqCst) {
            return;
        }
        let _ = pool.calibrate_tick();
    });
    CalibrationTicker { stop, handle: Some(handle) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::allocator::DeviceGrant;

    fn pool(names: &[&str], tpus: usize) -> ServingPool {
        let mut reg = ModelRegistry::new();
        for n in names {
            reg.register_named(n).unwrap();
        }
        ServingPool::deploy(
            reg,
            SystemConfig::default(),
            AllocatorConfig { total_tpus: tpus, ..Default::default() },
            BackendKind::Synthetic,
            DeployOptions::default(),
        )
        .unwrap()
    }

    /// Submit n requests, collect n responses, verify each bit-for-bit.
    fn run_and_verify(p: &ServingPool, name: &str, n: usize, seed: u64) {
        let client = p.client(name).unwrap();
        let reqs = client.synth_requests(n, seed);
        let expected: Vec<Vec<i8>> = reqs.iter().map(|r| client.reference(&r.data)).collect();
        for r in reqs {
            p.submit(name, r).unwrap();
        }
        let mut got = 0usize;
        while got < n {
            let r = client.done.recv().expect("stream closed early");
            assert_eq!(r.data, expected[r.id as usize], "{name}: digest mismatch");
            assert_eq!(r.data.len(), client.out_elems());
            got += 1;
        }
    }

    #[test]
    fn open_loop_round_trip_two_tenants() {
        let p = pool(&["fc_small", "conv_a"], 2);
        run_and_verify(&p, "fc_small", 40, 11);
        run_and_verify(&p, "conv_a", 40, 22);
        for name in ["fc_small", "conv_a"] {
            let s = p.tenant_metrics(name).unwrap().snapshot();
            assert_eq!(s.submitted, 40, "{name}");
            assert_eq!(s.completed, 40, "{name}");
            assert_eq!(s.errors, 0, "{name}");
            assert!(s.batches >= 1, "{name}");
            assert_eq!(
                s.flush_size + s.flush_deadline + s.flush_closed,
                s.batches,
                "{name}: every batch has exactly one flush reason"
            );
        }
        assert_eq!(p.metrics.snapshot().routed_requests, 80);
        let dp = p.data_plane().snapshot();
        assert!(dp.handoffs >= 2, "batches must have crossed the data plane");
        assert!(dp.handoff_items >= 80);
        p.shutdown();
    }

    #[test]
    fn register_replans_without_losing_in_flight_requests() {
        // fc_small alone on 3 TPUs -> replicated; registering fc_big
        // (needs 2 TPUs) forces fc_small to shrink: its deployment is
        // drained and redeployed mid-stream
        let p = pool(&["fc_small"], 3);
        assert!(p.plan().assignment("fc_small").unwrap().replicas > 1);
        let client = p.client("fc_small").unwrap();
        let reqs = client.synth_requests(30, 5);
        let expected: Vec<Vec<i8>> = reqs.iter().map(|r| client.reference(&r.data)).collect();
        for r in reqs {
            p.submit("fc_small", r).unwrap();
        }
        // re-plan while those 30 are in flight
        let report = p
            .register(Tenant::new("fc_big", super::super::resolve_model("fc_big").unwrap()))
            .unwrap();
        assert!(report.admitted.contains(&"fc_big".to_string()), "{report:?}");
        assert!(report.drained >= 1, "fc_small must have been drained: {report:?}");
        // every pre-replan request completes, bit-exact (same reference:
        // the synthetic function is partition-invariant)
        let mut got = 0;
        while got < 30 {
            let r = client.done.recv().expect("stream closed early");
            assert_eq!(r.data, expected[r.id as usize], "in-flight request corrupted");
            got += 1;
        }
        assert_eq!(client.metrics.snapshot().completed, 30);
        // both tenants serve after the re-plan
        run_and_verify(&p, "fc_small", 10, 6);
        run_and_verify(&p, "fc_big", 10, 7);
        let s = p.metrics.snapshot();
        assert_eq!(s.replans, 1);
        assert!(s.drained_deployments >= 1);
        p.shutdown();
    }

    #[test]
    fn register_rejected_tenant_drains_nothing() {
        let p = pool(&["fc_small", "conv_a"], 2);
        run_and_verify(&p, "fc_small", 5, 1);
        // fc_n3000 can never fit on-chip -> rejected; nobody is drained
        let report = p
            .register(Tenant::new("fc_n3000", super::super::resolve_model("fc_n3000").unwrap()))
            .unwrap();
        assert_eq!(report.rejected, 1, "{report:?}");
        assert_eq!(report.drained, 0, "unchanged tenants must keep running: {report:?}");
        // the untouched deployments still serve
        run_and_verify(&p, "fc_small", 5, 2);
        run_and_verify(&p, "conv_a", 5, 3);
        p.shutdown();
    }

    #[test]
    fn replan_promotes_shared_tenant_to_exclusive_after_deregister() {
        // two 1-TPU tenants time-share the single TPU; deregistering the
        // owner promotes the rider to an exclusive grant (drain+redeploy)
        let mut reg = ModelRegistry::new();
        reg.register(
            Tenant::new("owner", super::super::resolve_model("fc_small").unwrap())
                .with_weight(2.0),
        )
        .unwrap();
        reg.register(Tenant::new("rider", super::super::resolve_model("fc_small").unwrap()))
            .unwrap();
        let p = ServingPool::deploy(
            reg,
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 1, allow_sharing: true, ..Default::default() },
            BackendKind::Synthetic,
            DeployOptions::default(),
        )
        .unwrap();
        let plan = p.plan();
        assert_eq!(plan.assignments.len(), 2, "queued={:?}", plan.queued);
        assert!(plan.assignment("rider").unwrap().grant.is_shared());
        assert!(plan.assignment("owner").unwrap().grant.is_shared());
        run_and_verify(&p, "owner", 10, 1);
        run_and_verify(&p, "rider", 10, 2);
        // the rider's worker recorded its context switches
        let before = p.tenant_metrics("rider").unwrap().snapshot();
        assert!(before.swaps >= 1, "{before:?}");
        assert!(before.swap_overhead_s > 0.0, "{before:?}");

        let report = p.deregister("owner").unwrap();
        assert!(report.drained >= 1, "grant change must drain: {report:?}");
        let plan = p.plan();
        assert_eq!(plan.assignment("rider").unwrap().grant, DeviceGrant::Exclusive);
        run_and_verify(&p, "rider", 10, 3);
        // exclusive deployments never swap: the counter froze
        let after = p.tenant_metrics("rider").unwrap().snapshot();
        assert_eq!(after.swaps, before.swaps, "{after:?}");
        p.shutdown();
    }

    #[test]
    fn kill_drill_replans_with_pinned_switch_cost_and_cache_knobs() {
        // regression (ISSUE 8 satellite): the kill-drill re-plan runs off
        // `self.alloc` with only `dead_devices` overridden, so an
        // operator-pinned `--switch-cost-us` and the cache knobs must
        // survive into the post-kill plan verbatim
        let mut reg = ModelRegistry::new();
        reg.register(
            Tenant::new("owner", super::super::resolve_model("fc_small").unwrap())
                .with_weight(2.0),
        )
        .unwrap();
        reg.register(Tenant::new("rider", super::super::resolve_model("fc_small").unwrap()))
            .unwrap();
        let p = ServingPool::deploy(
            reg,
            SystemConfig::default(),
            AllocatorConfig {
                total_tpus: 2,
                allow_sharing: true,
                switch_cost_us: Some(1500.0),
                cache_budget_bytes: 1 << 30,
                prefetch: true,
                ..Default::default()
            },
            BackendKind::Synthetic,
            DeployOptions::default(),
        )
        .unwrap();
        let report = p.kill_device(0).unwrap();
        assert_eq!(report.admitted.len(), 2, "both tenants must share the survivor: {report:?}");
        let plan = p.plan();
        assert!(plan.cache_enabled, "cache knobs lost in the kill re-plan");
        for name in ["owner", "rider"] {
            let a = plan.assignment(name).unwrap();
            assert!(a.grant.is_shared(), "{name}: {:?}", a.grant);
            assert!(
                (a.grant.switch_s() - 1.5e-3).abs() < 1e-12,
                "{name}: pinned --switch-cost-us lost in the kill re-plan: {:?}",
                a.grant
            );
            let eff = a.grant.cache().expect("cache-enabled plans fill the effect");
            assert!(
                (eff.warm_frac - 1.0).abs() < 1e-12,
                "{name}: a 1 GiB budget pins both co-residents: {eff:?}"
            );
        }
        run_and_verify(&p, "owner", 8, 51);
        run_and_verify(&p, "rider", 8, 52);
        p.shutdown();
    }

    #[test]
    fn deregister_last_tenant_leaves_an_idle_pool() {
        let p = pool(&["fc_small"], 1);
        run_and_verify(&p, "fc_small", 6, 2);
        let report = p.deregister("fc_small").unwrap();
        assert!(report.admitted.is_empty(), "{report:?}");
        assert!(p.names().is_empty());
        assert!(p.plan().assignments.is_empty());
        p.shutdown();
    }

    #[test]
    fn deregister_drains_then_closes_the_stream() {
        let p = pool(&["fc_small", "conv_a"], 2);
        let client = p.client("fc_small").unwrap();
        let reqs = client.synth_requests(12, 9);
        let expected: Vec<Vec<i8>> = reqs.iter().map(|r| client.reference(&r.data)).collect();
        for r in reqs {
            p.submit("fc_small", r).unwrap();
        }
        let report = p.deregister("fc_small").unwrap();
        assert!(!report.admitted.contains(&"fc_small".to_string()));
        // all 12 in-flight responses arrive, then the stream ends
        let mut got = 0;
        while let Some(r) = client.done.recv() {
            assert_eq!(r.data, expected[r.id as usize]);
            got += 1;
        }
        assert_eq!(got, 12, "deregister must not drop in-flight requests");
        // submitting to the gone tenant errors; the survivor still serves
        assert!(p.submit("fc_small", Request::new(0, vec![0; 4])).is_err());
        run_and_verify(&p, "conv_a", 8, 4);
        p.shutdown();
    }

    #[test]
    fn arena_survives_replans_and_keeps_recycling() {
        // warm the pool, re-plan it, and confirm the shared arena still
        // recycles: a redeploy must not reset the data plane
        let p = pool(&["fc_small"], 1);
        run_and_verify(&p, "fc_small", 20, 1);
        let warm = p.data_plane().snapshot();
        assert!(warm.slab_allocs > 0);
        // a registration change re-plans the pool (the newcomer is queued
        // on 1 TPU); fc_small must keep serving from the warm slabs
        let report = p
            .register(Tenant::new("conv_a", super::super::resolve_model("conv_a").unwrap()))
            .unwrap();
        assert!(report.queued >= 1 || report.admitted.len() > 1, "{report:?}");
        run_and_verify(&p, "fc_small", 20, 2);
        let after = p.data_plane().snapshot();
        assert!(
            after.slab_reuses > warm.slab_reuses,
            "recycling must continue after re-plan attempts: {after:?}"
        );
        p.shutdown();
    }

    #[test]
    fn kill_device_replans_and_replays_in_flight_requests() {
        // fc_small replicated over both devices; killing device 0 drains
        // the deployment (completing everything in flight through it) and
        // redeploys on the survivor
        let p = pool(&["fc_small"], 2);
        let before = p.plan();
        assert_eq!(before.assignment("fc_small").unwrap().replicas, 2);
        let client = p.client("fc_small").unwrap();
        let reqs = client.synth_requests(30, 13);
        let expected: Vec<Vec<i8>> = reqs.iter().map(|r| client.reference(&r.data)).collect();
        for r in reqs {
            p.submit("fc_small", r).unwrap();
        }
        let report = p.kill_device(0).unwrap();
        assert!(report.drained >= 1, "{report:?}");
        assert_eq!(p.dead_devices(), vec![0]);
        // every request accepted before the kill is replayed onto the
        // stream, bit-exact (the reference is partition-invariant)
        let mut got = 0;
        while got < 30 {
            let r = client.done.recv().expect("stream closed early");
            assert_eq!(r.data, expected[r.id as usize], "in-flight request corrupted");
            got += 1;
        }
        // the new plan avoids the dead device entirely
        let after = p.plan();
        let a = after.assignment("fc_small").unwrap();
        assert!(!a.devices.contains(&0), "dead device still granted: {a:?}");
        run_and_verify(&p, "fc_small", 10, 14);
        let s = p.metrics.snapshot();
        assert_eq!(s.device_kills, 1);
        assert!(s.replans >= 1);
        // out-of-range and last-device kills are rejected
        assert!(p.kill_device(9).is_err());
        assert!(p.kill_device(1).is_err(), "must refuse to kill the last live device");
        p.shutdown();
    }

    #[test]
    fn kill_device_shrinks_capacity_and_queues_the_loser() {
        // two exclusive 1-TPU tenants on 2 devices; killing one device
        // leaves room for only one tenant — the other is queued, but its
        // in-flight requests still complete first
        let p = pool(&["fc_small", "conv_a"], 2);
        let clients: Vec<TenantClient> =
            ["fc_small", "conv_a"].iter().map(|n| p.client(n).unwrap()).collect();
        let mut expected = Vec::new();
        for c in &clients {
            let reqs = c.synth_requests(8, 21);
            expected.push(
                reqs.iter().map(|r| c.reference(&r.data)).collect::<Vec<Vec<i8>>>(),
            );
            for r in reqs {
                p.submit(&c.name, r).unwrap();
            }
        }
        let report = p.kill_device(0).unwrap();
        assert_eq!(report.admitted.len() + report.queued, 2, "{report:?}");
        assert_eq!(report.queued, 1, "one tenant must be queued on 1 TPU: {report:?}");
        // both tenants' accepted requests complete bit-exact, including
        // the queued one's (drained through its old deployment)
        for (c, exp) in clients.iter().zip(&expected) {
            let mut got = 0;
            while got < 8 {
                let r = c.done.recv().expect("stream closed early");
                assert_eq!(r.data, exp[r.id as usize], "{}: corrupted", c.name);
                got += 1;
            }
        }
        // the surviving deployment serves on; the queued one rejects
        let admitted = &report.admitted[0];
        run_and_verify(&p, admitted, 6, 22);
        let queued: &str =
            if admitted == "fc_small" { "conv_a" } else { "fc_small" };
        assert!(p.submit(queued, Request::new(0, vec![0; 4])).is_err());
        p.shutdown();
    }

    #[test]
    fn tiered_shedding_sheds_low_priority_under_backlog() {
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        let p = ServingPool::deploy(
            reg,
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 3, ..Default::default() },
            BackendKind::Synthetic,
            DeployOptions { queue_capacity: 4, ..Default::default() },
        )
        .unwrap();
        assert!(p.plan().assignment("fc_small").unwrap().replicas > 1);
        // slow every replica so the tiny ingress queue stays backed up
        for rep in 0..3 {
            p.inject_straggler("fc_small", rep, std::time::Duration::from_millis(20)).unwrap();
        }
        let client = p.client("fc_small").unwrap();
        let all = client.synth_requests(60, 31);
        let expected: Vec<Vec<i8>> = all.iter().map(|r| client.reference(&r.data)).collect();
        let mut accepted: Vec<u64> = Vec::new();
        let mut shed = 0usize;
        let mut it = all.into_iter();
        // alternate a blocking tier-0 submit (which keeps the queue near
        // capacity) with a tier-2 attempt: under this backlog the low
        // tier must shed at least once, and tier 0 must never shed
        for _ in 0..20 {
            let r0 = it.next().unwrap();
            let id0 = r0.id;
            assert_eq!(
                p.submit_with_priority("fc_small", r0, 0).unwrap(),
                Admission::Accepted,
                "tier 0 must never be shed"
            );
            accepted.push(id0);
            let r2 = it.next().unwrap();
            let id2 = r2.id;
            match p.submit_with_priority("fc_small", r2, 2).unwrap() {
                Admission::Accepted => accepted.push(id2),
                Admission::Shed => shed += 1,
                Admission::Expired => unreachable!("no deadlines in this test"),
            }
        }
        assert!(shed >= 1, "tier 2 must shed under a saturated queue");
        // every *accepted* request completes bit-exact; shed ones are
        // accounted, not silently lost
        let mut got = 0;
        while got < accepted.len() {
            let r = client.done.recv().expect("stream closed early");
            assert!(accepted.contains(&r.id), "got a shed request's response");
            assert_eq!(r.data, expected[r.id as usize]);
            got += 1;
        }
        let s = client.metrics.snapshot();
        assert_eq!(s.shed as usize, shed);
        assert_eq!(s.submitted as usize, accepted.len());
        assert_eq!(s.completed as usize, accepted.len());
        p.shutdown();
    }

    #[test]
    fn pool_hedges_around_injected_straggler() {
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        let p = ServingPool::deploy(
            reg,
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 3, ..Default::default() },
            BackendKind::Synthetic,
            DeployOptions {
                hedge: Some(crate::coordinator::HedgeConfig {
                    p99_factor: 2.0,
                    min_samples: 4,
                }),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(p.plan().assignment("fc_small").unwrap().replicas, 3);
        // warm every replica's latency record, then make replica 0 straggle
        run_and_verify(&p, "fc_small", 30, 41);
        p.inject_straggler("fc_small", 0, std::time::Duration::from_millis(15)).unwrap();
        run_and_verify(&p, "fc_small", 30, 42); // replica 0's p99 inflates
        run_and_verify(&p, "fc_small", 30, 43); // ...and its shards hedge
        // responses ship before the worker books the batch's hedge delta;
        // give the counter a moment to settle
        std::thread::sleep(std::time::Duration::from_millis(50));
        let s = p.tenant_metrics("fc_small").unwrap().snapshot();
        assert!(s.hedges >= 1, "straggling replica must trigger hedged dispatch: {s:?}");
        // run_and_verify already proved every response bit-exact — the
        // hedge merge never double-delivers or cross-delivers
        assert_eq!(s.completed, 90);
        p.shutdown();
    }

    #[test]
    fn manual_recalibration_replans_and_scales_the_prediction() {
        let p = pool(&["fc_small"], 2);
        let before = p.plan().assignment("fc_small").unwrap().effective_p99_s;
        run_and_verify(&p, "fc_small", 10, 61);
        let report = p.recalibrate_tenant("fc_small", 1.7).unwrap();
        assert!(report.admitted.contains(&"fc_small".to_string()), "{report:?}");
        let after = p.plan().assignment("fc_small").unwrap().effective_p99_s;
        assert!(
            (after / before - 1.7).abs() < 1e-12,
            "re-plan must carry the written-back scale: {before} -> {after}"
        );
        // the pool keeps serving bit-exact through the recalibration re-plan
        run_and_verify(&p, "fc_small", 10, 62);
        let s = p.metrics.snapshot();
        assert_eq!(s.replans_calibration, 1);
        assert!(s.replans >= 1);
        // bad inputs are rejected with pinned messages, without re-planning
        let err = p.recalibrate_tenant("ghost", 1.2).unwrap_err().to_string();
        assert!(err.contains("not registered"), "{err}");
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let err = p.recalibrate_tenant("fc_small", bad).unwrap_err().to_string();
            assert!(err.contains("cost scale must be positive and finite"), "{err}");
        }
        assert_eq!(p.metrics.snapshot().replans_calibration, 1);
        p.shutdown();
    }

    #[test]
    fn kill_during_recalibration_keeps_every_in_flight_request() {
        // a chaos kill racing a drift recalibration must serialize on the
        // pool's state lock: both re-plans land, nothing in flight is
        // lost, and the final plan reflects both the dead device and the
        // rewritten cost model — with exactly one live deployment
        let p = pool(&["fc_small"], 3);
        assert_eq!(p.plan().assignment("fc_small").unwrap().replicas, 3);
        let client = p.client("fc_small").unwrap();
        let reqs = client.synth_requests(30, 71);
        let expected: Vec<Vec<i8>> = reqs.iter().map(|r| client.reference(&r.data)).collect();
        for r in reqs {
            p.submit("fc_small", r).unwrap();
        }
        std::thread::scope(|scope| {
            let kill = scope.spawn(|| p.kill_device(0).unwrap());
            let recal = scope.spawn(|| p.recalibrate_tenant("fc_small", 1.7).unwrap());
            kill.join().unwrap();
            recal.join().unwrap();
        });
        let mut got = 0;
        while got < 30 {
            let r = client.done.recv().expect("stream closed early");
            assert_eq!(r.data, expected[r.id as usize], "in-flight request corrupted");
            got += 1;
        }
        let plan = p.plan();
        let deployed: Vec<&Assignment> =
            plan.assignments.iter().filter(|a| a.name == "fc_small").collect();
        assert_eq!(deployed.len(), 1, "double-deploy after racing re-plans: {plan:?}");
        assert!(!deployed[0].devices.contains(&0), "dead device still granted");
        let s = p.metrics.snapshot();
        assert!(s.replans >= 2, "{s:?}");
        assert_eq!(s.replans_calibration, 1);
        assert_eq!(s.device_kills, 1);
        run_and_verify(&p, "fc_small", 10, 72);
        p.shutdown();
    }

    #[test]
    fn calibrate_tick_without_drift_never_replans() {
        // a pool deployed without calibration: the tick is a pure no-op
        let p = pool(&["fc_small"], 1);
        run_and_verify(&p, "fc_small", 10, 81);
        assert!(p.calibrate_tick().unwrap().is_empty());
        assert_eq!(p.metrics.snapshot().replans, 0);
        p.shutdown();

        // a calibrated pool under steady traffic: the first window is the
        // self-baseline, later windows match it, so drift stays inside the
        // threshold and the detector never re-plans
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        let p = ServingPool::deploy(
            reg,
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 1, ..Default::default() },
            BackendKind::Synthetic,
            DeployOptions::new()
                .with_calibration(CalibrateConfig { min_samples: 5, ..Default::default() }),
        )
        .unwrap();
        for w in 0..3u64 {
            run_and_verify(&p, "fc_small", 40, 90 + w);
            let fired = p.calibrate_tick().unwrap();
            assert!(fired.is_empty(), "steady traffic must not fire: {fired:?}");
        }
        let s = p.metrics.snapshot();
        assert_eq!(s.replans, 0, "{s:?}");
        assert_eq!(s.replans_calibration, 0);
        p.shutdown();
    }

    #[test]
    fn repeated_kill_is_a_typed_error_and_metered() {
        let p = pool(&["fc_small"], 3);
        p.kill_device(0).unwrap();
        let err = p.kill_device(0).unwrap_err().to_string();
        assert_eq!(err, "device 0 is already dead");
        assert_eq!(p.metrics.snapshot().kill_repeats, 1);
        // the repeat changed nothing: fault record intact, pool serving
        assert_eq!(p.dead_devices(), vec![0]);
        assert_eq!(p.metrics.snapshot().device_kills, 1);
        run_and_verify(&p, "fc_small", 8, 33);
        // an out-of-range kill is a different error, not a "repeat"
        assert!(p.kill_device(9).is_err());
        assert_eq!(p.metrics.snapshot().kill_repeats, 1);
        p.shutdown();
    }

    #[test]
    fn expired_at_submit_is_typed_reported_and_never_served() {
        let p = pool(&["fc_small"], 1);
        let client = p.client("fc_small").unwrap();
        let mut reqs = client.synth_requests(4, 17);
        // a deadline of "now" is already expired by the admission check
        let past = Instant::now();
        let mut expired_ids = Vec::new();
        for r in reqs.drain(..2) {
            expired_ids.push(r.id);
            let adm =
                p.submit_with_priority("fc_small", r.with_deadline(past), 0).unwrap();
            assert_eq!(adm, Admission::Expired);
        }
        // generous deadlines sail through untouched
        let future = Instant::now() + Duration::from_secs(60);
        for r in reqs {
            assert_eq!(
                p.submit_with_priority("fc_small", r.with_deadline(future), 0).unwrap(),
                Admission::Accepted
            );
        }
        for _ in 0..2 {
            let r = client.done.recv().expect("stream closed early");
            assert!(!expired_ids.contains(&r.id), "an expired request was served");
        }
        // the expired ids surfaced on the typed stream, in submit order
        for id in &expired_ids {
            assert_eq!(client.expired.recv(), Some(*id));
        }
        let s = client.metrics.snapshot();
        assert_eq!(s.deadline_shed, 2);
        assert_eq!(s.submitted, 2, "expired-at-submit is not an accepted submission");
        assert_eq!(s.completed, 2);
        p.shutdown();
    }

    #[test]
    fn queued_expiry_sheds_before_the_tpu_and_opens_no_stage_span() {
        // the batcher is told to wait 150 ms for a fuller batch while
        // every request expires at 20 ms: the whole batch must be shed at
        // the flush — before the swap/serve path — so the TPU never runs,
        // no slab is ever packed, and the trace shows Deadline markers
        // but not a single Stage/Response/Swap span
        let tracer = Arc::new(Tracer::new());
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        let p = ServingPool::deploy(
            reg,
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 1, ..Default::default() },
            BackendKind::Synthetic,
            DeployOptions::new()
                .with_policy(BatchPolicy {
                    max_batch: 1000,
                    max_wait: Duration::from_millis(150),
                })
                .with_tracer(tracer.clone()),
        )
        .unwrap();
        let client = p.client("fc_small").unwrap();
        let deadline = Instant::now() + Duration::from_millis(20);
        for r in client.synth_requests(10, 23) {
            assert_eq!(
                p.submit_with_priority("fc_small", r.with_deadline(deadline), 0).unwrap(),
                Admission::Accepted
            );
        }
        let mut ids: Vec<u64> = (0..10)
            .map(|_| client.expired.recv().expect("expired stream closed early"))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>(), "every id must be reported");
        let s = client.metrics.snapshot();
        assert_eq!(s.deadline_shed, 10);
        assert_eq!(s.submitted, 10);
        assert_eq!(s.completed, 0);
        // provably no leak: a shed batch never packs an arena slab at all
        let dp = p.data_plane().snapshot();
        assert_eq!(dp.slab_allocs, 0, "shed batches must never touch the arena: {dp:?}");
        p.shutdown();
        let (events, _dropped) = tracer.drain();
        assert!(
            events.iter().any(|e| matches!(e.kind, SpanKind::Deadline)),
            "expiries must be visible in the trace"
        );
        assert!(
            !events
                .iter()
                .any(|e| matches!(e.kind, SpanKind::Stage | SpanKind::Response | SpanKind::Swap)),
            "an expired batch must never reach a TPU stage"
        );
    }

    #[test]
    fn pool_breaker_trips_on_straggler_and_keeps_serving() {
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        let p = ServingPool::deploy(
            reg,
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 3, ..Default::default() },
            BackendKind::Synthetic,
            DeployOptions::new().with_breaker(BreakerConfig {
                watchdog: Duration::from_millis(30),
                trip_after: 1,
                cooldown: Duration::from_secs(600),
            }),
        )
        .unwrap();
        assert_eq!(p.plan().assignment("fc_small").unwrap().replicas, 3);
        p.inject_straggler("fc_small", 0, Duration::from_millis(100)).unwrap();
        run_and_verify(&p, "fc_small", 30, 44); // replica 0 breaches its watchdog
        run_and_verify(&p, "fc_small", 30, 45); // ...and later shards route around it
        // responses ship before the worker books the trip delta; let it settle
        std::thread::sleep(Duration::from_millis(50));
        let s = p.metrics.snapshot();
        assert!(s.breaker_trips >= 1, "straggling replica must trip its breaker: {s:?}");
        // run_and_verify proved every response bit-exact: quarantining a
        // replica loses nothing
        assert_eq!(p.tenant_metrics("fc_small").unwrap().snapshot().completed, 60);
        p.shutdown();
    }

    fn journal_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("repro-pool-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn recover_rebuilds_the_exact_precrash_plan_from_the_journal() {
        let path = journal_dir("recover").join("pool.journal");
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        let alloc = AllocatorConfig { total_tpus: 3, ..Default::default() };
        let p = ServingPool::deploy(
            reg,
            SystemConfig::default(),
            alloc.clone(),
            BackendKind::Synthetic,
            DeployOptions::new().with_journal(&path),
        )
        .unwrap();
        run_and_verify(&p, "fc_small", 10, 81);
        // a busy control-plane life: register, recalibrate, kill
        p.register(
            Tenant::new("conv_a", resolve_model("conv_a").unwrap())
                .with_weight(2.0)
                .with_slo_p99_s(0.05),
        )
        .unwrap();
        p.recalibrate_tenant("fc_small", 1.3).unwrap();
        p.kill_device(0).unwrap();
        let before = format!("{:?}", p.plan().assignments);
        p.shutdown(); // crash stand-in: append-only journals need no clean close
        let p2 = ServingPool::recover(
            SystemConfig::default(),
            alloc,
            BackendKind::Synthetic,
            DeployOptions::new(),
            &path,
        )
        .unwrap();
        assert_eq!(
            format!("{:?}", p2.plan().assignments),
            before,
            "recovery must restore the exact pre-crash plan"
        );
        assert_eq!(p2.dead_devices(), vec![0], "the fault record must survive the crash");
        assert_eq!(p2.metrics.snapshot().recoveries, 1);
        run_and_verify(&p2, "fc_small", 10, 82);
        run_and_verify(&p2, "conv_a", 10, 83);
        p2.shutdown();
    }

    #[test]
    fn recovery_fences_the_stale_controller() {
        let path = journal_dir("fence").join("pool.journal");
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        let alloc = AllocatorConfig { total_tpus: 2, ..Default::default() };
        let p = ServingPool::deploy(
            reg,
            SystemConfig::default(),
            alloc.clone(),
            BackendKind::Synthetic,
            DeployOptions::new().with_journal(&path),
        )
        .unwrap();
        // a successor recovers from the same journal while the original
        // controller still lives — the original is now stale
        let p2 = ServingPool::recover(
            SystemConfig::default(),
            alloc,
            BackendKind::Synthetic,
            DeployOptions::new(),
            &path,
        )
        .unwrap();
        let err = p.recalibrate_tenant("fc_small", 1.5).unwrap_err().to_string();
        assert!(err.contains("stale controller write fenced"), "{err}");
        // the successor mutates (and journals) freely: no double-deploy
        p2.recalibrate_tenant("fc_small", 1.5).unwrap();
        run_and_verify(&p2, "fc_small", 8, 84);
        p.shutdown();
        p2.shutdown();
    }

    #[test]
    fn recover_without_a_journal_is_a_typed_error() {
        let path = journal_dir("missing").join("pool.journal");
        let err = ServingPool::recover(
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 1, ..Default::default() },
            BackendKind::Synthetic,
            DeployOptions::new(),
            &path,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("no journal to recover from"), "{err}");
    }

    #[test]
    fn deadline_config_validation_pins_messages() {
        for bad in [0.0, 0.5, -1.0, f64::NAN, f64::INFINITY] {
            let err = DeadlineConfig { slo_factor: bad }.validate().unwrap_err().to_string();
            assert!(
                err.contains("deadline slo factor must be finite and >= 1"),
                "{err}"
            );
        }
        DeadlineConfig::default().validate().unwrap();
        // deploy refuses a bad factor up front
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        let err = ServingPool::deploy(
            reg,
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 1, ..Default::default() },
            BackendKind::Synthetic,
            DeployOptions::new().with_deadlines(DeadlineConfig { slo_factor: 0.0 }),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("deadline slo factor"), "{err}");
    }

    #[test]
    fn slo_derived_deadlines_stamp_only_slo_tenants() {
        // one tenant with an SLO, one without, deadlines on: only the SLO
        // tenant's requests get stamped — and a generous factor means
        // nothing expires under light traffic
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        reg.register(
            Tenant::new("slo", resolve_model("conv_a").unwrap()).with_slo_p99_s(30.0),
        )
        .unwrap();
        let p = ServingPool::deploy(
            reg,
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 2, ..Default::default() },
            BackendKind::Synthetic,
            DeployOptions::new().with_deadlines(DeadlineConfig::default()),
        )
        .unwrap();
        run_and_verify(&p, "fc_small", 10, 86);
        run_and_verify(&p, "slo", 10, 87);
        for name in ["fc_small", "slo"] {
            let s = p.tenant_metrics(name).unwrap().snapshot();
            assert_eq!(s.deadline_shed, 0, "{name}: generous deadlines must not shed");
            assert_eq!(s.completed, 10, "{name}");
        }
        p.shutdown();
    }

    #[test]
    fn calibration_ticker_starts_and_stops_cleanly() {
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        let p = Arc::new(
            ServingPool::deploy(
                reg,
                SystemConfig::default(),
                AllocatorConfig { total_tpus: 1, ..Default::default() },
                BackendKind::Synthetic,
                DeployOptions::new().with_calibration(CalibrateConfig::default()),
            )
            .unwrap(),
        );
        let ticker = spawn_calibration_ticker(p.clone(), Duration::from_millis(5));
        run_and_verify(&p, "fc_small", 20, 95);
        std::thread::sleep(Duration::from_millis(25));
        ticker.stop(); // joins the thread: no tick is mid-flight past here
        assert_eq!(p.metrics.snapshot().replans, 0, "steady traffic must not re-plan");
        if let Ok(pool) = Arc::try_unwrap(p) {
            pool.shutdown();
        }
    }
}
