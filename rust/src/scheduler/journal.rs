//! Crash-recoverable control plane: an append-only, fsync-gated recovery
//! journal (DESIGN.md §17).
//!
//! The journal is a plain-text write-ahead log of every control-plane
//! decision a [`ServingPool`](super::pool::ServingPool) makes — tenant
//! register/deregister, device kills, cost-model recalibrations — plus a
//! fingerprint snapshot of each applied plan.  Replaying the log through
//! the deterministic allocator reconstructs the exact pre-crash plan
//! without re-profiling or re-solving anything beyond one allocator run,
//! which is what lets `ServingPool::recover` warm-restart a pool whose
//! controller died mid-flight.
//!
//! ## Record format
//!
//! One record per line, space-separated, hand-rolled like every other
//! artifact in this repo (no serde).  Floats are written with Rust's
//! round-trip `{:?}` formatting, so a load parses back the exact bits:
//!
//! ```text
//! open 1
//! register 1 fc_small fc_small 2.0 0.02 1.0
//! kill 1 0
//! plan 1 a1b2c3d4e5f60789
//! open 2
//! ```
//!
//! Every record is appended with [`File::sync_data`] before the caller's
//! mutation is acknowledged — the fsync gate — so an acknowledged event
//! is never lost to a crash.
//!
//! ## Generation fencing
//!
//! Each [`Journal::open`] scans the existing log, takes the highest
//! `open` generation seen, and appends `open gen+1`: opening the journal
//! *is* taking over the pool.  A handle stamps its generation on every
//! record and, before each append, checks that the file still ends where
//! its own last write left it.  A stale controller — one whose journal
//! was re-opened by its successor — therefore fails its next append with
//! a typed error instead of corrupting the log, and can never
//! double-deploy: the recovered pool's plan fingerprint is checked
//! against the journal's last snapshot before serving resumes.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// One journaled control-plane event.
#[derive(Debug, Clone, PartialEq)]
pub enum JournalEvent {
    /// A tenant joined the pool.  `model` must resolve through
    /// [`resolve_model`](super::registry::resolve_model) at replay time
    /// (journaled pools register tenants by model name).
    Register {
        /// Registry/routing key.
        name: String,
        /// Resolvable model name (alias or parametric form).
        model: String,
        /// Scheduling weight.
        weight: f64,
        /// Optional p99 SLO in seconds.
        slo_p99_s: Option<f64>,
        /// Calibration scale on the profiled cost model.
        cost_scale: f64,
    },
    /// A tenant left the pool.
    Deregister { name: String },
    /// A device was taken out of service.
    Kill { device: usize },
    /// A tenant's cost model was recalibrated.
    Recalibrate { name: String, scale: f64 },
    /// Fingerprint snapshot of the plan applied after the events so far.
    PlanFingerprint { fingerprint: u64 },
}

/// FNV-1a over a deterministic rendering — the plan snapshot fingerprint.
/// The allocator is deterministic, so a faithful WAL replay reproduces
/// the exact assignment set and with it the exact fingerprint.
pub fn fingerprint_str(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn fmt_f64(v: f64) -> String {
    format!("{v:?}")
}

fn parse_f64(s: &str) -> Result<f64> {
    s.parse::<f64>().with_context(|| format!("bad float {s:?} in journal"))
}

/// A token must survive space-separated round-tripping.
fn check_token(kind: &str, tok: &str) -> Result<()> {
    anyhow::ensure!(
        !tok.is_empty() && !tok.contains(char::is_whitespace),
        "journal {kind} {tok:?} must be a non-empty whitespace-free token"
    );
    Ok(())
}

fn encode(generation: u64, ev: &JournalEvent) -> Result<String> {
    Ok(match ev {
        JournalEvent::Register { name, model, weight, slo_p99_s, cost_scale } => {
            check_token("tenant name", name)?;
            check_token("model name", model)?;
            let slo = match slo_p99_s {
                Some(s) => fmt_f64(*s),
                None => "-".to_string(),
            };
            format!(
                "register {generation} {name} {model} {} {slo} {}",
                fmt_f64(*weight),
                fmt_f64(*cost_scale)
            )
        }
        JournalEvent::Deregister { name } => {
            check_token("tenant name", name)?;
            format!("deregister {generation} {name}")
        }
        JournalEvent::Kill { device } => format!("kill {generation} {device}"),
        JournalEvent::Recalibrate { name, scale } => {
            check_token("tenant name", name)?;
            format!("recalibrate {generation} {name} {}", fmt_f64(*scale))
        }
        JournalEvent::PlanFingerprint { fingerprint } => {
            format!("plan {generation} {fingerprint:016x}")
        }
    })
}

/// `(generation, None)` for an `open` record, `(generation, Some(event))`
/// otherwise.
fn decode(line: &str) -> Result<(u64, Option<JournalEvent>)> {
    let fields: Vec<&str> = line.split(' ').collect();
    let bad = || anyhow::anyhow!("malformed journal record {line:?}");
    let generation: u64 = fields.get(1).ok_or_else(bad)?.parse().map_err(|_| bad())?;
    let ev = match fields[0] {
        "open" => {
            anyhow::ensure!(fields.len() == 2, bad());
            None
        }
        "register" => {
            anyhow::ensure!(fields.len() == 7, bad());
            Some(JournalEvent::Register {
                name: fields[2].to_string(),
                model: fields[3].to_string(),
                weight: parse_f64(fields[4])?,
                slo_p99_s: if fields[5] == "-" { None } else { Some(parse_f64(fields[5])?) },
                cost_scale: parse_f64(fields[6])?,
            })
        }
        "deregister" => {
            anyhow::ensure!(fields.len() == 3, bad());
            Some(JournalEvent::Deregister { name: fields[2].to_string() })
        }
        "kill" => {
            anyhow::ensure!(fields.len() == 3, bad());
            Some(JournalEvent::Kill {
                device: fields[2].parse().map_err(|_| bad())?,
            })
        }
        "recalibrate" => {
            anyhow::ensure!(fields.len() == 4, bad());
            Some(JournalEvent::Recalibrate {
                name: fields[2].to_string(),
                scale: parse_f64(fields[3])?,
            })
        }
        "plan" => {
            anyhow::ensure!(fields.len() == 3, bad());
            Some(JournalEvent::PlanFingerprint {
                fingerprint: u64::from_str_radix(fields[2], 16).map_err(|_| bad())?,
            })
        }
        _ => anyhow::bail!("unknown journal record kind in {line:?}"),
    };
    Ok((generation, ev))
}

/// The full readable state of a journal file.
#[derive(Debug, Default)]
pub struct JournalLog {
    /// Highest `open` generation recorded (0 for an empty/missing file).
    pub generation: u64,
    /// Every event, in append order, across all generations — the WAL a
    /// recovery replays.
    pub events: Vec<JournalEvent>,
}

impl JournalLog {
    /// The fingerprint of the last `plan` snapshot, if any.
    pub fn last_fingerprint(&self) -> Option<u64> {
        self.events.iter().rev().find_map(|e| match e {
            JournalEvent::PlanFingerprint { fingerprint } => Some(*fingerprint),
            _ => None,
        })
    }
}

/// An open (writing) handle on the recovery journal.  Creating one bumps
/// the generation, fencing every earlier handle (see module docs).
#[derive(Debug)]
pub struct Journal {
    path: PathBuf,
    file: File,
    generation: u64,
    /// File length after our last acknowledged write: a longer file at
    /// the next append means another controller took over.
    expected_len: u64,
}

impl Journal {
    /// Read a journal file without taking it over (missing file = empty
    /// log at generation 0).
    pub fn load(path: &Path) -> Result<JournalLog> {
        let mut log = JournalLog::default();
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(log),
            Err(e) => {
                return Err(e).with_context(|| format!("reading journal {}", path.display()))
            }
        };
        for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            let (generation, ev) = decode(line)?;
            match ev {
                None => log.generation = log.generation.max(generation),
                Some(ev) => log.events.push(ev),
            }
        }
        Ok(log)
    }

    /// Open the journal for writing, becoming the current controller:
    /// appends (fsync-gated) an `open` record one generation above the
    /// highest on disk, which fences every older handle.
    pub fn open(path: &Path) -> Result<Journal> {
        let log = Self::load(path)?;
        let generation = log.generation + 1;
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating journal dir {}", dir.display()))?;
            }
        }
        let mut file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        writeln!(file, "open {generation}")?;
        file.sync_data()?;
        let expected_len = file.metadata()?.len();
        Ok(Journal { path: path.to_path_buf(), file, generation, expected_len })
    }

    /// This handle's generation (the one stamped on its records).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Append one event, fsync-gated.  Fails with a typed error if a
    /// newer controller has opened the journal since our last write — a
    /// stale controller can never extend the log.
    pub fn append(&mut self, ev: &JournalEvent) -> Result<()> {
        let len = std::fs::metadata(&self.path)
            .with_context(|| format!("statting journal {}", self.path.display()))?
            .len();
        anyhow::ensure!(
            len == self.expected_len,
            "stale controller write fenced: journal advanced past generation {}",
            self.generation
        );
        let line = encode(self.generation, ev)?;
        writeln!(self.file, "{line}")?;
        self.file.sync_data()?;
        self.expected_len = self.file.metadata()?.len();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "repro-journal-{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("pool.journal")
    }

    fn sample_events() -> Vec<JournalEvent> {
        vec![
            JournalEvent::Register {
                name: "fc_small".into(),
                model: "fc_small".into(),
                weight: 2.0,
                slo_p99_s: Some(0.02),
                cost_scale: 1.0,
            },
            JournalEvent::Register {
                name: "conv_a".into(),
                model: "conv_a".into(),
                weight: 1.0,
                slo_p99_s: None,
                cost_scale: 1.0,
            },
            JournalEvent::Kill { device: 0 },
            JournalEvent::Recalibrate { name: "fc_small".into(), scale: 1.7 },
            JournalEvent::Deregister { name: "conv_a".into() },
            JournalEvent::PlanFingerprint { fingerprint: 0xdead_beef_0badcafe },
        ]
    }

    #[test]
    fn events_round_trip_through_the_file() {
        let path = tmp("roundtrip");
        let mut j = Journal::open(&path).unwrap();
        assert_eq!(j.generation(), 1);
        for ev in sample_events() {
            j.append(&ev).unwrap();
        }
        let log = Journal::load(&path).unwrap();
        assert_eq!(log.generation, 1);
        assert_eq!(log.events, sample_events());
        assert_eq!(log.last_fingerprint(), Some(0xdead_beef_0badcafe));
    }

    #[test]
    fn missing_file_is_an_empty_log() {
        let path = tmp("missing");
        let log = Journal::load(&path).unwrap();
        assert_eq!(log.generation, 0);
        assert!(log.events.is_empty());
        assert_eq!(log.last_fingerprint(), None);
    }

    #[test]
    fn reopen_bumps_the_generation_and_keeps_the_wal() {
        let path = tmp("reopen");
        let mut j1 = Journal::open(&path).unwrap();
        j1.append(&JournalEvent::Kill { device: 2 }).unwrap();
        drop(j1);
        let mut j2 = Journal::open(&path).unwrap();
        assert_eq!(j2.generation(), 2, "each takeover bumps the generation");
        j2.append(&JournalEvent::Kill { device: 3 }).unwrap();
        let log = Journal::load(&path).unwrap();
        assert_eq!(log.generation, 2);
        assert_eq!(
            log.events,
            vec![JournalEvent::Kill { device: 2 }, JournalEvent::Kill { device: 3 }],
            "the WAL spans generations"
        );
    }

    #[test]
    fn stale_controller_append_is_fenced() {
        let path = tmp("fence");
        let mut stale = Journal::open(&path).unwrap();
        stale.append(&JournalEvent::Kill { device: 0 }).unwrap();
        // a successor takes over the journal...
        let mut fresh = Journal::open(&path).unwrap();
        assert_eq!(fresh.generation(), 2);
        // ...so the stale handle's next write must be refused
        let err = stale.append(&JournalEvent::Kill { device: 1 }).unwrap_err();
        assert_eq!(
            err.to_string(),
            "stale controller write fenced: journal advanced past generation 1"
        );
        // the successor writes on unhindered
        fresh.append(&JournalEvent::Kill { device: 1 }).unwrap();
        let log = Journal::load(&path).unwrap();
        assert_eq!(log.events.len(), 2, "the fenced write never landed");
    }

    #[test]
    fn tokens_with_whitespace_are_rejected_at_append() {
        let path = tmp("tokens");
        let mut j = Journal::open(&path).unwrap();
        let err = j
            .append(&JournalEvent::Deregister { name: "two words".into() })
            .unwrap_err();
        assert!(err.to_string().contains("whitespace-free token"), "{err}");
    }

    #[test]
    fn floats_round_trip_bit_exact() {
        let path = tmp("floats");
        let mut j = Journal::open(&path).unwrap();
        let scale = 1.699_999_999_999_99;
        j.append(&JournalEvent::Recalibrate { name: "t".into(), scale }).unwrap();
        let log = Journal::load(&path).unwrap();
        match &log.events[0] {
            JournalEvent::Recalibrate { scale: got, .. } => {
                assert_eq!(got.to_bits(), scale.to_bits(), "round-trip must be bit-exact");
            }
            other => panic!("wrong event {other:?}"),
        }
    }

    #[test]
    fn malformed_records_error_with_context() {
        let path = tmp("malformed");
        std::fs::write(&path, "open 1\nwat 1 2\n").unwrap();
        let err = Journal::load(&path).unwrap_err();
        assert!(err.to_string().contains("unknown journal record"), "{err}");
        std::fs::write(&path, "register 1 a b notafloat - 1.0\n").unwrap();
        let err = Journal::load(&path).unwrap_err();
        assert!(err.to_string().contains("bad float"), "{err}");
    }
}
