//! Model registry: the set of tenants competing for the TPU pool.
//!
//! Each [`Tenant`] carries the layer-IR model (what the allocator places
//! and costs), a scheduling weight (the objective multiplier), and an
//! optional p99 SLO.  Tenants can be registered from artifact-manifest
//! entries (`runtime::ModelEntry`) or resolved by name from the paper's
//! synthetic families — the latter is what `repro schedule` uses, so the
//! pool allocator runs without any compiled artifacts.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::model::synthetic::{conv_model, fc_model, hetero_fc_model};
use crate::model::Model;
use crate::runtime::ModelEntry;

/// One registered model competing for the pool.
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Registry key (also the routing key for the per-model router).
    pub name: String,
    /// Layer-IR model the allocator segments and places.
    pub model: Model,
    /// Relative scheduling weight: the allocator minimizes
    /// `Σ weight · p99`, so heavier tenants get TPUs first.
    pub weight: f64,
    /// Optional p99 latency SLO in seconds (predicted violations are
    /// penalized by the allocator and flagged in reports).
    pub slo_p99_s: Option<f64>,
    /// Calibration scale on the profiled cost model: the
    /// observed/predicted service-time ratio the online calibrator
    /// (`scheduler::calibrate`) writes back when live drift sustains
    /// past its threshold.  `1.0` (the default) leaves every profiled
    /// prediction untouched, so uncalibrated plans stay bit-identical.
    pub cost_scale: f64,
}

impl Tenant {
    /// A tenant with weight 1, no SLO and an uncalibrated cost model.
    pub fn new(name: impl Into<String>, model: Model) -> Self {
        Tenant { name: name.into(), model, weight: 1.0, slo_p99_s: None, cost_scale: 1.0 }
    }

    /// Set the scheduling weight (must be positive).
    pub fn with_weight(mut self, weight: f64) -> Self {
        assert!(weight > 0.0, "weight must be positive");
        self.weight = weight;
        self
    }

    /// Declare a p99 latency SLO in seconds.
    pub fn with_slo_p99_s(mut self, slo_s: f64) -> Self {
        self.slo_p99_s = Some(slo_s);
        self
    }

    /// Scale the profiled cost model (observed/predicted ratio; must be
    /// positive and finite).  The calibrator's write-back path.
    pub fn with_cost_scale(mut self, scale: f64) -> Self {
        assert!(scale.is_finite() && scale > 0.0, "cost scale must be positive and finite");
        self.cost_scale = scale;
        self
    }
}

/// The registry: name -> tenant, deterministic iteration order.
#[derive(Debug, Clone, Default)]
pub struct ModelRegistry {
    tenants: BTreeMap<String, Tenant>,
}

impl ModelRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        ModelRegistry::default()
    }

    /// Register a tenant; duplicate names are an error (tenants are
    /// routing keys).
    pub fn register(&mut self, tenant: Tenant) -> Result<()> {
        anyhow::ensure!(
            !self.tenants.contains_key(&tenant.name),
            "model {:?} already registered",
            tenant.name
        );
        self.tenants.insert(tenant.name.clone(), tenant);
        Ok(())
    }

    /// Resolve `name` against the synthetic families and register it.
    pub fn register_named(&mut self, name: &str) -> Result<()> {
        let model = resolve_model(name)?;
        self.register(Tenant::new(name, model))
    }

    /// Register a model from an artifact-manifest entry (PJRT-backed
    /// deployments route by the manifest name).
    pub fn register_manifest_entry(&mut self, entry: &ModelEntry) -> Result<()> {
        self.register(Tenant::new(entry.name.clone(), entry.to_model()))
    }

    /// Remove a tenant, returning it; unknown names are an error.  On a
    /// live pool, go through `ServingPool::deregister` instead so the
    /// tenant's deployment is drained first.
    pub fn deregister(&mut self, name: &str) -> Result<Tenant> {
        self.tenants.remove(name).with_context(|| {
            format!("model {name:?} not registered (have: {:?})", self.names())
        })
    }

    /// Look up a registered tenant by name (error lists what exists).
    pub fn get(&self, name: &str) -> Result<&Tenant> {
        self.tenants.get(name).with_context(|| {
            format!("model {name:?} not registered (have: {:?})", self.names())
        })
    }

    /// Mutable lookup, e.g. to adjust a tenant's weight or SLO.
    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tenant> {
        self.tenants.get_mut(name)
    }

    /// Number of registered tenants.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    /// Whether no tenant is registered.
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Iterate over registered tenants in name order.
    pub fn iter(&self) -> impl Iterator<Item = &Tenant> {
        self.tenants.values()
    }

    /// Registered tenant names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.tenants.keys().cloned().collect()
    }
}

/// Resolve a model name to a layer-IR model without artifacts.
///
/// Friendly aliases (sized off the paper's Tables I–IV so they exercise
/// distinct admission regimes):
///
/// | alias      | model            | single-TPU placement            |
/// |------------|------------------|---------------------------------|
/// | `fc_small` | `fc_model(512)`  | fits on one TPU                 |
/// | `fc_big`   | `fc_model(1980)` | spills on one TPU, fits on two  |
/// | `fc_huge`  | `fc_model(2580)` | needs three TPUs (profiled)     |
/// | `conv_a`   | `conv_model(292)`| fits on one TPU                 |
/// | `conv_b`   | `conv_model(412)`| fits on one TPU (barely)        |
/// | `conv_big` | `conv_model(592)`| needs four TPUs (profiled)      |
/// | `pyramid`  | hetero FC chain  | fits on one TPU                 |
///
/// Parametric forms `fc_n<width>` and `conv_f<filters>` address the whole
/// synthetic sweep grids.
pub fn resolve_model(name: &str) -> Result<Model> {
    let model = match name {
        "fc_small" => fc_model(512),
        "fc_big" => fc_model(1980),
        "fc_huge" => fc_model(2580),
        "conv_a" => conv_model(292),
        "conv_b" => conv_model(412),
        "conv_big" => conv_model(592),
        "pyramid" => hetero_fc_model("pyramid", &[64, 2048, 1024, 256, 10]),
        other => {
            if let Some(n) = other.strip_prefix("fc_n") {
                let n: u64 = n.parse().with_context(|| format!("bad fc width in {other:?}"))?;
                fc_model(n)
            } else if let Some(f) = other.strip_prefix("conv_f") {
                let f: u64 =
                    f.parse().with_context(|| format!("bad conv filters in {other:?}"))?;
                conv_model(f)
            } else {
                anyhow::bail!(
                    "unknown model {other:?} (aliases: fc_small fc_big fc_huge conv_a \
                     conv_b conv_big pyramid; parametric: fc_n<width> conv_f<filters>)"
                );
            }
        }
    };
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::place;
    use crate::config::DeviceConfig;

    #[test]
    fn aliases_resolve_and_have_documented_placement() {
        let cfg = DeviceConfig::default();
        // one-TPU-fitting aliases
        for name in ["fc_small", "conv_a", "conv_b", "pyramid"] {
            let m = resolve_model(name).unwrap();
            assert!(!place(&m.layers, &cfg).uses_host(), "{name} should fit one TPU");
        }
        // spilling aliases
        for name in ["fc_big", "fc_huge", "conv_big"] {
            let m = resolve_model(name).unwrap();
            assert!(place(&m.layers, &cfg).uses_host(), "{name} should spill one TPU");
        }
    }

    #[test]
    fn parametric_names_resolve() {
        assert_eq!(resolve_model("fc_n256").unwrap().name, "fc_n256");
        assert_eq!(resolve_model("conv_f100").unwrap().name, "conv_f100");
        assert!(resolve_model("fc_nxyz").is_err());
        assert!(resolve_model("bogus").is_err());
    }

    #[test]
    fn registry_rejects_duplicates_and_resolves() {
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        reg.register_named("conv_a").unwrap();
        assert!(reg.register_named("fc_small").is_err(), "duplicate must fail");
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.names(), vec!["conv_a".to_string(), "fc_small".to_string()]);
        assert!(reg.get("fc_small").is_ok());
        assert!(reg.get("nope").is_err());
    }

    #[test]
    fn deregister_removes_and_errors_on_unknown() {
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        let t = reg.deregister("fc_small").unwrap();
        assert_eq!(t.name, "fc_small");
        assert!(reg.is_empty());
        assert!(reg.deregister("fc_small").is_err(), "double deregister must fail");
        // the name is free for re-registration after removal
        reg.register_named("fc_small").unwrap();
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn tenant_builder_sets_policy_fields() {
        let t = Tenant::new("t", fc_model(512)).with_weight(2.5).with_slo_p99_s(0.02);
        assert_eq!(t.weight, 2.5);
        assert_eq!(t.slo_p99_s, Some(0.02));
        assert_eq!(t.cost_scale, 1.0, "tenants start uncalibrated");
        let t = t.with_cost_scale(1.4);
        assert_eq!(t.cost_scale, 1.4);
    }
}
