//! Per-device segment-parameter cache model: warm/cold swap costs,
//! quantum-boundary prefetch, and the LRU-with-pinning staging cache
//! behind them (DESIGN.md §15).
//!
//! The cost model charges every context switch of a time-shared device
//! as a full *cold* re-load of the incoming tenant's segment parameters
//! over the off-chip host-bandwidth term — exactly the traffic the
//! paper identifies as the dominant inference cost (and arXiv
//! 2109.14320 identifies as the highest-leverage thing to remove).
//! Real deployments keep a host-side staging area warm: parameters
//! pinned there skip the re-load entirely (a *warm* swap, near-zero
//! cost), and a prefetch issued at the quantum boundary overlaps the
//! next resident's load with the tail of the current quantum, hiding up
//! to `(1 - slice) * quantum` seconds of whatever cold traffic remains.
//!
//! Two layers live here:
//!
//! * [`CacheEffect`] — the *planned* outcome of pinning + prefetch for
//!   one shared grant, attached to `DeviceGrant::Shared` by the
//!   allocator's packing pass and replayed identically by the live pool
//!   worker, the pool router and the deterministic workload sim (so
//!   `repro loadgen` stays byte-identical per seed).
//! * [`ParamCache`] — the runtime LRU-with-pinning structure keyed by
//!   `(tenant, stage)` over a per-device byte budget, which the packing
//!   pass uses to decide what stays pinned.
//!
//! With a zero budget every swap is cold and every cost, column and
//! trace byte matches the pre-cache behaviour — the whole module is
//! additive.

use std::collections::BTreeMap;

/// Planned cache outcome of one shared grant: what fraction of the
/// tenant's parameter bytes stay pinned in the per-device staging
/// budget, and how much of the residual cold traffic the
/// quantum-boundary prefetch can hide.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheEffect {
    /// Fraction of the tenant's segment-parameter bytes pinned in the
    /// host staging cache (`0.0` = fully cold, `1.0` = fully warm).
    pub warm_frac: f64,
    /// Seconds of cold re-load the quantum-boundary prefetch overlaps
    /// with the tail of the previous resident's quantum (`0.0` when
    /// prefetch is off or the quantum is zero — no window to hide in).
    pub prefetch_s: f64,
}

/// How one quantum-gated swap was classified under a [`CacheEffect`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwapClass {
    /// Fraction of the cold re-load cost actually charged.
    pub frac: f64,
    /// Warm hit: residency + prefetch hid the entire swap cost.
    pub hit: bool,
    /// A quantum-boundary prefetch was issued for the unpinned bytes.
    pub prefetched: bool,
}

impl CacheEffect {
    /// Fraction of the cold swap cost still charged after pinning and
    /// prefetch.  The *first* swap of a deployment is always a full
    /// cold load (compulsory miss: nothing is resident yet).
    pub fn residual_frac(&self, cold_s: f64, first: bool) -> f64 {
        if first {
            return 1.0;
        }
        if cold_s <= 0.0 {
            return 0.0;
        }
        ((((1.0 - self.warm_frac) * cold_s) - self.prefetch_s).max(0.0)) / cold_s
    }

    /// Steady-state per-swap cost under this effect (the quantity the
    /// allocator prices into shared candidates' p99).
    pub fn effective_switch_s(&self, cold_s: f64) -> f64 {
        cold_s * self.residual_frac(cold_s, false)
    }

    /// Classify one quantum-gated swap: the charged cost fraction, the
    /// hit/miss verdict and whether a prefetch was issued.  Shared
    /// verbatim by the live pool worker, the pool router and the
    /// deterministic workload sim so all three count identically.
    pub fn classify(&self, cold_s: f64, first: bool) -> SwapClass {
        let frac = self.residual_frac(cold_s, first);
        SwapClass {
            frac,
            hit: !first && frac <= 0.0,
            prefetched: !first
                && self.prefetch_s > 0.0
                && (1.0 - self.warm_frac) * cold_s > 0.0,
        }
    }
}

/// Plan the cache effect of one shared placement: greedily pin the
/// tenant's smallest stages (ties by stage index) into whatever budget
/// the co-residents already staged on those devices left over
/// (`pressure_bytes`), and size the prefetch window to the tail of the
/// quantum the tenant does not own.  With `pressure_bytes = 0` this is
/// the best case any placement can reach, which keeps the allocator's
/// suffix lower bound admissible.
pub fn plan_effect(
    stage_bytes: &[u64],
    budget_bytes: u64,
    pressure_bytes: u64,
    prefetch: bool,
    slice: f64,
    quantum_s: f64,
) -> CacheEffect {
    let available = budget_bytes.saturating_sub(pressure_bytes);
    let total: u64 = stage_bytes.iter().sum();
    let mut order: Vec<usize> = (0..stage_bytes.len()).collect();
    order.sort_by_key(|&i| (stage_bytes[i], i));
    let mut pinned = 0u64;
    for i in order {
        if pinned + stage_bytes[i] <= available {
            pinned += stage_bytes[i];
        } else {
            break; // smallest-first: nothing later fits either
        }
    }
    let warm_frac = if total == 0 { 1.0 } else { pinned as f64 / total as f64 };
    let prefetch_s = if prefetch { (1.0 - slice) * quantum_s } else { 0.0 };
    CacheEffect { warm_frac, prefetch_s }
}

/// One staged entry.
#[derive(Debug, Clone)]
struct Entry {
    bytes: u64,
    last_use: u64,
    pinned: bool,
}

/// LRU-with-pinning host staging cache keyed by `(tenant, stage)` over
/// a per-device byte budget.  Pinned entries are never evicted; misses
/// stage the entry after evicting least-recently-used unpinned entries
/// (ties broken by key order, so eviction is deterministic).
#[derive(Debug)]
pub struct ParamCache {
    budget: u64,
    used: u64,
    tick: u64,
    entries: BTreeMap<(String, usize), Entry>,
}

impl ParamCache {
    /// Empty cache over `budget_bytes` of host staging memory.
    pub fn new(budget_bytes: u64) -> Self {
        ParamCache { budget: budget_bytes, used: 0, tick: 0, entries: BTreeMap::new() }
    }

    /// The configured staging budget.
    pub fn budget_bytes(&self) -> u64 {
        self.budget
    }

    /// Bytes currently staged (pinned + unpinned).
    pub fn resident_bytes(&self) -> u64 {
        self.used
    }

    /// Whether `(tenant, stage)` is currently staged.
    pub fn contains(&self, tenant: &str, stage: usize) -> bool {
        self.entries.contains_key(&(tenant.to_string(), stage))
    }

    /// Touch `(tenant, stage)` on a swap: `true` = warm hit (already
    /// staged), `false` = cold miss.  A miss stages the entry, evicting
    /// LRU unpinned entries as needed; an entry that cannot fit even
    /// after evicting every unpinned entry is served cold and not
    /// staged.
    pub fn access(&mut self, tenant: &str, stage: usize, bytes: u64) -> bool {
        self.tick += 1;
        let key = (tenant.to_string(), stage);
        if let Some(e) = self.entries.get_mut(&key) {
            e.last_use = self.tick;
            return true;
        }
        if self.stage_in(bytes) {
            self.entries
                .insert(key, Entry { bytes, last_use: self.tick, pinned: false });
        }
        false
    }

    /// Pin `(tenant, stage)` so it can never be evicted, staging it
    /// first if absent.  `false` when it cannot fit alongside the other
    /// pinned entries.
    pub fn pin(&mut self, tenant: &str, stage: usize, bytes: u64) -> bool {
        self.tick += 1;
        let key = (tenant.to_string(), stage);
        if let Some(e) = self.entries.get_mut(&key) {
            e.last_use = self.tick;
            e.pinned = true;
            return true;
        }
        if !self.stage_in(bytes) {
            return false;
        }
        self.entries.insert(key, Entry { bytes, last_use: self.tick, pinned: true });
        true
    }

    /// Make room for `bytes`, evicting LRU unpinned entries; `true`
    /// when the bytes fit afterwards (`used` is charged on success).
    fn stage_in(&mut self, bytes: u64) -> bool {
        if bytes > self.budget {
            return false;
        }
        while self.used + bytes > self.budget {
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| !e.pinned)
                .min_by_key(|(k, e)| (e.last_use, (*k).clone()))
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else {
                return false; // everything left is pinned
            };
            let e = self.entries.remove(&victim).expect("victim key just observed");
            self.used -= e.bytes;
        }
        self.used += bytes;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_frac_covers_first_warm_and_partial_swaps() {
        let eff = CacheEffect { warm_frac: 0.75, prefetch_s: 0.0 };
        // compulsory miss: the first swap is always fully cold
        assert_eq!(eff.residual_frac(1.0, true), 1.0);
        // steady state: only the unpinned quarter is charged
        assert!((eff.residual_frac(1.0, false) - 0.25).abs() < 1e-12);
        assert!((eff.effective_switch_s(2.0) - 0.5).abs() < 1e-12);
        // fully warm => free swaps; zero cold cost => nothing to charge
        let warm = CacheEffect { warm_frac: 1.0, prefetch_s: 0.0 };
        assert_eq!(warm.residual_frac(1.0, false), 0.0);
        assert_eq!(eff.residual_frac(0.0, false), 0.0);
    }

    #[test]
    fn prefetch_hides_residual_cost_but_never_goes_negative() {
        let eff = CacheEffect { warm_frac: 0.5, prefetch_s: 0.2 };
        // residual = (0.5 * 1.0 - 0.2) / 1.0
        assert!((eff.residual_frac(1.0, false) - 0.3).abs() < 1e-12);
        // a prefetch window longer than the cold remainder clamps to 0
        let wide = CacheEffect { warm_frac: 0.5, prefetch_s: 10.0 };
        assert_eq!(wide.residual_frac(1.0, false), 0.0);
        assert!(wide.classify(1.0, false).hit);
    }

    #[test]
    fn classify_counts_hits_misses_and_prefetches() {
        let eff = CacheEffect { warm_frac: 0.5, prefetch_s: 0.1 };
        let first = eff.classify(1.0, true);
        assert!(!first.hit && !first.prefetched);
        assert_eq!(first.frac, 1.0);
        let steady = eff.classify(1.0, false);
        assert!(!steady.hit, "0.4 of the cold cost is still charged");
        assert!(steady.prefetched);
        // fully pinned => hit, and nothing left to prefetch
        let warm = CacheEffect { warm_frac: 1.0, prefetch_s: 0.1 };
        let hit = warm.classify(1.0, false);
        assert!(hit.hit && !hit.prefetched);
    }

    #[test]
    fn plan_effect_pins_smallest_stages_within_the_leftover_budget() {
        let stages = [30u64, 10, 20];
        // 35 bytes left: stages of 10 and 20 pin, 30 does not
        let eff = plan_effect(&stages, 35, 0, false, 0.5, 0.0);
        assert!((eff.warm_frac - 0.5).abs() < 1e-12);
        assert_eq!(eff.prefetch_s, 0.0);
        // co-residents already staged 30 of the 35 => only 5 left
        let squeezed = plan_effect(&stages, 35, 30, false, 0.5, 0.0);
        assert_eq!(squeezed.warm_frac, 0.0);
        // prefetch window = the co-residents' share of the quantum
        let pf = plan_effect(&stages, 35, 0, true, 0.25, 2.0);
        assert!((pf.prefetch_s - 1.5).abs() < 1e-12);
        // a weightless pipeline is trivially warm
        assert_eq!(plan_effect(&[], 0, 0, false, 0.5, 0.0).warm_frac, 1.0);
    }

    #[test]
    fn lru_evicts_unpinned_entries_deterministically() {
        let mut c = ParamCache::new(100);
        assert!(!c.access("a", 0, 60), "first touch is a miss");
        assert!(c.access("a", 0, 60), "second touch is warm");
        // b does not fit next to a => a (LRU, unpinned) is evicted
        assert!(!c.access("b", 0, 50));
        assert!(!c.contains("a", 0));
        assert!(c.contains("b", 0));
        assert_eq!(c.resident_bytes(), 50);
        // an entry larger than the whole budget is never staged
        assert!(!c.access("huge", 0, 1_000));
        assert!(!c.contains("huge", 0));
    }

    #[test]
    fn pinned_entries_survive_pressure() {
        let mut c = ParamCache::new(100);
        assert!(c.pin("a", 0, 60));
        // b cannot evict the pinned entry, so it is served cold forever
        assert!(!c.access("b", 0, 50));
        assert!(!c.access("b", 0, 50));
        assert!(c.contains("a", 0));
        // but a smaller rider co-resides warm next to the pin
        assert!(!c.access("c", 0, 40));
        assert!(c.access("c", 0, 40));
        // a second pin that cannot fit is refused
        assert!(!c.pin("d", 0, 50));
    }
}
