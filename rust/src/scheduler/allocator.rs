//! Pool allocator: memory-aware admission control + cost-model placement
//! search over per-model `(tpu_count, Strategy)` assignments.
//!
//! Given N TPUs and M registered models, the allocator:
//!
//! 1. builds, per tenant, the set of **admissible candidates** — every
//!    `(tpu_count, strategy)` whose chosen partition keeps all segment
//!    weights in on-chip memory (host-streaming candidates are rejected
//!    unless `allow_host_spill` is set, because host streaming is the 40x
//!    cliff the whole paper is about);
//! 2. runs an exhaustive branch-and-bound over per-tenant candidate
//!    choices subject to `Σ tpu_count ≤ N`, minimizing the weighted sum of
//!    predicted p99 latencies (simulated on the repo's pipelined batch
//!    workload), with a large penalty for queueing a tenant so admission
//!    is maximized first;
//! 3. hands leftover TPUs out as **data-parallel replicas** (served by
//!    `coordinator::ReplicaRouter`) to the admitted tenant with the
//!    largest weighted p99, greedily.
//!
//! Models that fit no admissible candidate at all are **rejected**
//! (`cannot fit`); models that fit but lost the TPU-count auction are
//! **queued** (they would be admitted on a bigger pool).
//!
//! With [`AllocatorConfig::allow_sharing`] set, a fourth outcome exists:
//! a queued tenant may be granted a **time-multiplexed slice** of a TPU
//! set already granted to an admitted tenant ([`DeviceGrant::Shared`],
//! cf. arXiv 2602.17808's collaborative co-residency).  Co-resident
//! segments do not fit on-chip together, so every scheduling quantum the
//! incoming tenant's parameters are re-loaded from host memory — the
//! context-switch cost is the same off-chip-bandwidth term the cost
//! model charges spilled layers (arXiv 2102.10423 quantifies that
//! penalty).  A shared placement is only granted when the predicted p99
//! *including* swap overhead still meets every affected tenant's SLO.

use anyhow::Result;

use crate::compiler::place;
use crate::config::SystemConfig;
use crate::link::Link;
use crate::model::Model;
use crate::pipeline::{build_stages, simulate, SimOptions};
use crate::segment::strategy::Strategy;
use crate::segment::Partition;
use crate::util::mib;
use crate::util::stats::Summary;

use super::registry::{ModelRegistry, Tenant};

/// Allocator knobs.
#[derive(Debug, Clone)]
pub struct AllocatorConfig {
    /// TPUs in the pool.
    pub total_tpus: usize,
    /// Batch size used when profiling candidates (the paper's §V-B
    /// closed-batch workload; also the router's serving batch).
    pub batch: usize,
    /// Per-model ceiling on pipeline depth (the paper's testbed tops out
    /// at 4 TPUs; deeper pipelines only add GIL-serialized overhead).
    pub max_tpus_per_model: usize,
    /// Admit candidates that stream weights from host memory.  Off by
    /// default: spilled segments are the pathology segmentation exists to
    /// remove.
    pub allow_host_spill: bool,
    /// Hand leftover TPUs to admitted tenants as pipeline replicas.
    pub replicate_leftover: bool,
    /// Grant queued tenants a time-multiplexed slice of an already
    /// granted TPU set ([`DeviceGrant::Shared`]).  Off by default: with
    /// it off, plans are identical to the whole-TPU allocator's.
    pub allow_sharing: bool,
    /// Override the per-swap context-switch cost (microseconds, whole
    /// pipeline).  `None` derives it per tenant from the cost model's
    /// host-memory bandwidth term (`serving::stage_switch_costs`).
    pub switch_cost_us: Option<f64>,
    /// Maximum co-resident tenants per TPU set (>= 2 when sharing).
    pub max_residents: usize,
}

impl Default for AllocatorConfig {
    fn default() -> Self {
        AllocatorConfig {
            total_tpus: 4,
            batch: 50,
            max_tpus_per_model: 4,
            allow_host_spill: false,
            replicate_leftover: true,
            allow_sharing: false,
            switch_cost_us: None,
            max_residents: 2,
        }
    }
}

/// How an assignment occupies its TPUs — the abstraction that replaces
/// the old implicit "whole devices only" invariant.
#[derive(Debug, Clone, PartialEq)]
pub enum DeviceGrant {
    /// The assignment owns its `tpu_count * replicas` devices outright.
    Exclusive,
    /// Time-multiplexed co-residency: the assignment runs on a TPU set
    /// owned by `group[0]`; each member receives a `slice` of device time
    /// and pays `switch_s` seconds per scheduling quantum to re-load its
    /// segment parameters from host memory.
    Shared {
        /// Fraction of device time granted (`1 / group.len()`).
        slice: f64,
        /// Per-swap parameter re-load cost, summed over pipeline stages.
        switch_s: f64,
        /// Every co-resident on this TPU set, owner first (the owner's
        /// TPUs are the ones counted against the pool).
        group: Vec<String>,
    },
}

impl DeviceGrant {
    /// Fraction of device time this grant delivers (1.0 when exclusive).
    pub fn slice(&self) -> f64 {
        match self {
            DeviceGrant::Exclusive => 1.0,
            DeviceGrant::Shared { slice, .. } => *slice,
        }
    }

    /// Per-quantum context-switch cost (0 when exclusive).
    pub fn switch_s(&self) -> f64 {
        match self {
            DeviceGrant::Exclusive => 0.0,
            DeviceGrant::Shared { switch_s, .. } => *switch_s,
        }
    }

    /// Whether the grant time-shares its TPUs.
    pub fn is_shared(&self) -> bool {
        matches!(self, DeviceGrant::Shared { .. })
    }

    /// Compact table label, e.g. `excl` or `shared 1/2`.
    pub fn label(&self) -> String {
        match self {
            DeviceGrant::Exclusive => "excl".to_string(),
            DeviceGrant::Shared { group, .. } => format!("shared 1/{}", group.len()),
        }
    }
}

/// One evaluated `(tpu_count, strategy)` option for a tenant.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Pipeline depth (TPUs) this candidate uses.
    pub tpu_count: usize,
    /// Segmentation strategy that chose the partition.
    pub strategy: Strategy,
    /// The concrete layer partition.
    pub partition: Partition,
    /// Batch-amortized per-inference seconds (simulated Edge TPU clock).
    pub per_item_s: f64,
    /// p99 of the simulated completion-time distribution for the profiling
    /// batch — the allocator's latency objective.
    pub p99_s: f64,
    /// Total on-chip weight footprint across segments.
    pub device_mib: f64,
    /// Total host-resident (streamed) weight footprint across segments.
    pub host_mib: f64,
    /// Whether any segment streams weights from the host.
    pub uses_host: bool,
    /// Whole-pipeline context-switch cost if this candidate time-shares
    /// its TPUs: re-loading every segment's on-chip weights from host
    /// memory over the off-chip bandwidth term (seconds per swap).
    pub switch_s: f64,
}

/// Why a tenant was not admitted.
#[derive(Debug, Clone)]
pub struct Rejection {
    /// The tenant's registry name.
    pub name: String,
    /// Human-readable reason it was queued/rejected.
    pub reason: String,
}

/// Final placement of one admitted tenant.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// The tenant's registry name.
    pub name: String,
    /// The tenant's scheduling weight (objective multiplier).
    pub weight: f64,
    /// The tenant's p99 SLO, if declared.
    pub slo_p99_s: Option<f64>,
    /// The winning `(tpu_count, strategy, partition)` candidate.
    pub candidate: Candidate,
    /// Data-parallel copies of the whole pipeline (>= 1).
    pub replicas: usize,
    /// How the TPUs are held: exclusive or a time-multiplexed slice.
    pub grant: DeviceGrant,
    /// Predicted p99 after replication (replicas split the batch) and,
    /// for shared grants, slice dilation + swap overhead.
    pub effective_p99_s: f64,
}

impl Assignment {
    /// TPUs this assignment charges against the pool: pipeline depth ×
    /// replicas for exclusive grants and share-group owners; 0 for a
    /// tenant riding a slice of somebody else's TPUs.
    pub fn tpus_used(&self) -> usize {
        if self.owns_tpus() {
            self.candidate.tpu_count * self.replicas
        } else {
            0
        }
    }

    /// Whether this assignment is the one whose TPUs are counted (every
    /// exclusive grant, plus the first member of each share group).
    pub fn owns_tpus(&self) -> bool {
        match &self.grant {
            DeviceGrant::Exclusive => true,
            DeviceGrant::Shared { group, .. } => group.first() == Some(&self.name),
        }
    }

    /// Predicted p99 inflation from co-residency (slice dilation + swap
    /// cost); 0 for exclusive grants.
    pub fn swap_overhead_s(&self) -> f64 {
        if self.grant.is_shared() {
            (self.effective_p99_s - self.candidate.p99_s).max(0.0)
        } else {
            0.0
        }
    }

    /// Whether the predicted p99 violates the tenant's SLO.
    pub fn slo_violated(&self) -> bool {
        matches!(self.slo_p99_s, Some(slo) if self.effective_p99_s > slo)
    }
}

/// The allocator's output: admitted placements + non-admitted tenants.
#[derive(Debug, Clone)]
pub struct PoolPlan {
    /// TPUs in the pool this plan was computed for.
    pub total_tpus: usize,
    /// Admitted tenants with their winning placements.
    pub assignments: Vec<Assignment>,
    /// Tenants that fit the device but lost the TPU auction on this pool.
    pub queued: Vec<Rejection>,
    /// Tenants no partition of which fits the pool's on-chip memory.
    pub rejected: Vec<Rejection>,
    /// Weighted effective-p99 objective over admitted tenants (after
    /// replica grants).
    pub objective_s: f64,
    /// Whether time-multiplexed sharing was enabled for this plan (drives
    /// the extended `repro schedule` columns).
    pub sharing_enabled: bool,
}

impl PoolPlan {
    /// TPUs occupied across all admitted assignments.
    pub fn tpus_used(&self) -> usize {
        self.assignments.iter().map(Assignment::tpus_used).sum()
    }

    /// The admitted assignment for `name`, if it was admitted.
    pub fn assignment(&self, name: &str) -> Option<&Assignment> {
        self.assignments.iter().find(|a| a.name == name)
    }

    /// Number of admitted tenants holding a time-multiplexed grant.
    pub fn shared_count(&self) -> usize {
        self.assignments.iter().filter(|a| a.grant.is_shared()).count()
    }
}

/// Simulated-latency penalty (seconds) for queueing one unit of tenant
/// weight: large enough that admitting everyone always beats any latency
/// trade, small enough to stay finite in the objective.
const QUEUE_PENALTY_S: f64 = 1.0e4;

/// Per-weight-unit penalty (seconds) for admitting a tenant whose
/// predicted p99 violates its SLO: steers the auction toward SLO-meeting
/// placements, while staying far below [`QUEUE_PENALTY_S`] so a violating
/// admission still beats not running at all.
const SLO_PENALTY_S: f64 = 1.0e2;

/// Evaluate one concrete partition of `model` under the profiling batch.
fn evaluate(
    model: &Model,
    tpu_count: usize,
    strategy: Strategy,
    partition: Partition,
    cfg: &SystemConfig,
    batch: usize,
) -> Candidate {
    let mut device_bytes = 0u64;
    let mut host_bytes = 0u64;
    let mut uses_host = false;
    for &(a, b) in &partition.bounds() {
        let placement = place(&model.layers[a..b], &cfg.device);
        device_bytes += placement.device_bytes();
        host_bytes += placement.host_bytes();
        uses_host |= placement.uses_host();
    }
    let stages = build_stages(model, &partition, cfg);
    let link = Link::new(cfg.link.clone());
    let result = simulate(
        &stages,
        &link,
        &SimOptions { batch, queue_capacity: None, record_gantt: false },
    );
    let mut lat = Summary::new();
    for &l in &result.latencies_s {
        lat.add(l);
    }
    let switch_s: f64 =
        crate::serving::stage_switch_costs(model, &partition, cfg).iter().sum();
    Candidate {
        tpu_count,
        strategy,
        partition,
        per_item_s: result.per_item_s(batch),
        p99_s: lat.p99(),
        device_mib: mib(device_bytes),
        host_mib: mib(host_bytes),
        uses_host,
        switch_s,
    }
}

/// All admissible candidates for one model on this pool, best-p99 first.
/// Empty iff no `(tpu_count, strategy)` keeps the model on-chip (and
/// spilling is not allowed).
pub fn candidates_for(
    model: &Model,
    cfg: &SystemConfig,
    alloc: &AllocatorConfig,
) -> Vec<Candidate> {
    let max_k = alloc.max_tpus_per_model.min(alloc.total_tpus).min(model.len());
    let mut out: Vec<Candidate> = Vec::new();
    for k in 1..=max_k {
        let strategies = if k == 1 {
            vec![Strategy::Uniform]
        } else {
            vec![
                Strategy::Uniform,
                Strategy::MemoryBalanced,
                Strategy::ProfiledExhaustive { batch: alloc.batch },
            ]
        };
        for strategy in strategies {
            let partition = if k == 1 {
                Partition::whole(model.len())
            } else {
                strategy.partition(model, k, cfg)
            };
            // dedupe: different strategies often pick the same cuts
            if out.iter().any(|c| c.tpu_count == k && c.partition == partition) {
                continue;
            }
            let cand = evaluate(model, k, strategy, partition, cfg, alloc.batch);
            if cand.uses_host && !alloc.allow_host_spill {
                continue;
            }
            out.push(cand);
        }
    }
    out.sort_by(|a, b| a.p99_s.partial_cmp(&b.p99_s).unwrap());
    out
}

/// Branch-and-bound over per-tenant candidate choices.
struct Search<'a> {
    /// (tenant index in `tenants`) -> admissible candidates.
    cands: &'a [Vec<Candidate>],
    weights: &'a [f64],
    /// Per-tenant p99 SLO, if any (violating admissions are penalized).
    slos: &'a [Option<f64>],
    total_tpus: usize,
    best_cost: f64,
    /// Best choice per tenant: `Some(candidate index)` or `None` = queued.
    best_choice: Vec<Option<usize>>,
    current: Vec<Option<usize>>,
}

impl Search<'_> {
    fn run(&mut self, idx: usize, tpus_left: usize, cost: f64) {
        if cost >= self.best_cost {
            return; // prune: objective only grows
        }
        if idx == self.cands.len() {
            self.best_cost = cost;
            self.best_choice = self.current.clone();
            return;
        }
        // copy the shared slice reference out so the loop below doesn't
        // hold a borrow of `self` across the recursive &mut calls
        let cands = self.cands;
        // try admitting with each candidate that still fits the pool
        for (ci, cand) in cands[idx].iter().enumerate() {
            if cand.tpu_count > tpus_left {
                continue;
            }
            let mut step = self.weights[idx] * cand.p99_s;
            if let Some(slo) = self.slos[idx] {
                if cand.p99_s > slo {
                    step += self.weights[idx] * SLO_PENALTY_S;
                }
            }
            self.current[idx] = Some(ci);
            self.run(idx + 1, tpus_left - cand.tpu_count, cost + step);
        }
        // or queue this tenant
        self.current[idx] = None;
        self.run(idx + 1, tpus_left, cost + self.weights[idx] * QUEUE_PENALTY_S);
        self.current[idx] = None;
    }
}

/// Run admission + placement search for every registered tenant.
pub fn allocate(
    registry: &ModelRegistry,
    cfg: &SystemConfig,
    alloc: &AllocatorConfig,
) -> Result<PoolPlan> {
    anyhow::ensure!(alloc.total_tpus >= 1, "pool needs at least one TPU");
    anyhow::ensure!(alloc.batch >= 1, "profiling batch must be at least 1");
    anyhow::ensure!(!registry.is_empty(), "no models registered");
    anyhow::ensure!(
        !alloc.allow_sharing || alloc.max_residents >= 2,
        "sharing needs max_residents >= 2"
    );
    if let Some(us) = alloc.switch_cost_us {
        anyhow::ensure!(us >= 0.0, "switch cost must be non-negative");
    }

    // deterministic order: weight desc, then name (registry order is
    // name-sorted already)
    let mut tenants: Vec<_> = registry.iter().collect();
    tenants.sort_by(|a, b| {
        b.weight.partial_cmp(&a.weight).unwrap().then_with(|| a.name.cmp(&b.name))
    });

    let mut rejected = Vec::new();
    let mut searchable = Vec::new(); // (tenant, candidates)
    for t in tenants {
        let cands = candidates_for(&t.model, cfg, alloc);
        if cands.is_empty() {
            let single = place(&t.model.layers, &cfg.device);
            rejected.push(Rejection {
                name: t.name.clone(),
                reason: format!(
                    "no (tpu_count <= {}, strategy) keeps its {:.2} MiB of weights \
                     in on-chip memory",
                    alloc.max_tpus_per_model.min(alloc.total_tpus),
                    mib(single.device_bytes() + single.host_bytes()),
                ),
            });
        } else {
            searchable.push((t, cands));
        }
    }

    let cand_sets: Vec<Vec<Candidate>> =
        searchable.iter().map(|(_, c)| c.clone()).collect();
    let weights: Vec<f64> = searchable.iter().map(|(t, _)| t.weight).collect();
    let slos: Vec<Option<f64>> = searchable.iter().map(|(t, _)| t.slo_p99_s).collect();
    let n = cand_sets.len();
    let mut search = Search {
        cands: &cand_sets,
        weights: &weights,
        slos: &slos,
        total_tpus: alloc.total_tpus,
        best_cost: f64::INFINITY,
        best_choice: vec![None; n],
        current: vec![None; n],
    };
    let total = search.total_tpus;
    search.run(0, total, 0.0);

    let mut assignments = Vec::new();
    let mut unplaced: Vec<(&Tenant, &Vec<Candidate>)> = Vec::new();
    for (i, (t, cands)) in searchable.iter().enumerate() {
        match search.best_choice[i] {
            Some(ci) => {
                let cand = cands[ci].clone();
                assignments.push(Assignment {
                    name: t.name.clone(),
                    weight: t.weight,
                    slo_p99_s: t.slo_p99_s,
                    effective_p99_s: cand.p99_s,
                    candidate: cand,
                    replicas: 1,
                    grant: DeviceGrant::Exclusive,
                });
            }
            None => unplaced.push((*t, cands)),
        }
    }

    if alloc.replicate_leftover {
        grant_replicas(registry, cfg, alloc, &mut assignments);
    }

    // auction losers get a second chance as time-sliced co-residents
    let mut queued = Vec::new();
    for (t, cands) in unplaced {
        if alloc.allow_sharing {
            match grant_shared(t, cands, alloc, &mut assignments) {
                Ok(()) => continue,
                Err(reason) => {
                    queued.push(Rejection { name: t.name.clone(), reason });
                    continue;
                }
            }
        }
        let min_k = cands.iter().map(|c| c.tpu_count).min().unwrap_or(0);
        queued.push(Rejection {
            name: t.name.clone(),
            reason: format!(
                "needs {} TPU(s) but the pool auction left none \
                 ({} total)",
                min_k, alloc.total_tpus
            ),
        });
    }

    // the reported objective reflects what will actually be deployed,
    // including the p99 improvement from replica grants and the swap
    // inflation of shared grants
    let objective_s =
        assignments.iter().map(|a| a.weight * a.effective_p99_s).sum();
    Ok(PoolPlan {
        total_tpus: alloc.total_tpus,
        assignments,
        queued,
        rejected,
        objective_s,
        sharing_enabled: alloc.allow_sharing,
    })
}

/// Predicted p99 of one co-resident under a `1/residents` time slice: the
/// device delivers only `slice` of its cycles over any window, and every
/// scheduling quantum re-loads the tenant's parameters from host memory.
fn shared_p99_s(base_p99_s: f64, residents: usize, switch_s: f64) -> f64 {
    base_p99_s * residents as f64 + switch_s
}

/// Per-swap cost of a candidate under the allocator config: the
/// cost-model-derived re-load time ([`Candidate::switch_s`], the Table-I
/// off-chip-bandwidth term) unless the operator pinned `switch_cost_us`.
fn switch_cost_s(cand: &Candidate, alloc: &AllocatorConfig) -> f64 {
    match alloc.switch_cost_us {
        Some(us) => us * 1e-6,
        None => cand.switch_s,
    }
}

/// Try to admit an auction-losing tenant as a time-sliced co-resident on
/// an already granted TPU set.  Pipelines co-reside stage-for-stage, so
/// the tenant needs a candidate whose depth equals the host group's;
/// every affected tenant's SLO must survive the slice dilation + swap
/// overhead.  On success the tenant is appended to `assignments` and the
/// whole group's grants/p99s are updated; on failure the queue reason is
/// returned.
fn grant_shared(
    tenant: &Tenant,
    cands: &[Candidate],
    alloc: &AllocatorConfig,
    assignments: &mut Vec<Assignment>,
) -> std::result::Result<(), String> {
    debug_assert!(alloc.max_residents >= 2, "sharing needs max_residents >= 2");
    let mut slo_blocked = false;
    // (owner index, candidate index, weighted-p99 increase)
    let mut best: Option<(usize, usize, f64)> = None;
    for (oi, owner) in assignments.iter().enumerate() {
        // share groups are keyed by their owner; replicated pipelines are
        // not shareable (a rider would need the whole replica set)
        if !owner.owns_tpus() || owner.replicas != 1 {
            continue;
        }
        let members = group_members(assignments, oi);
        let residents = members.len() + 2; // owner + riders + the newcomer
        if residents > alloc.max_residents {
            continue;
        }
        for (ci, cand) in cands.iter().enumerate() {
            if cand.tpu_count != owner.candidate.tpu_count {
                continue;
            }
            let rider_p99 =
                shared_p99_s(cand.p99_s, residents, switch_cost_s(cand, alloc));
            if matches!(tenant.slo_p99_s, Some(slo) if rider_p99 > slo) {
                slo_blocked = true;
                continue; // the swap overhead breaches the rider's SLO
            }
            // existing members must not end up over their own SLOs — a
            // host already flagged "SLO at risk" is not degraded further
            let mut delta = tenant.weight * rider_p99;
            let mut feasible = true;
            for mi in members.iter().copied().chain([oi]) {
                let m = &assignments[mi];
                let m_p99 = shared_p99_s(
                    m.candidate.p99_s,
                    residents,
                    switch_cost_s(&m.candidate, alloc),
                );
                if matches!(m.slo_p99_s, Some(slo) if m_p99 > slo) {
                    feasible = false;
                    slo_blocked = true;
                    break;
                }
                delta += m.weight * (m_p99 - m.effective_p99_s);
            }
            if !feasible {
                continue;
            }
            match best {
                Some((_, _, d)) if d <= delta => {}
                _ => best = Some((oi, ci, delta)),
            }
        }
    }
    let Some((oi, ci, _)) = best else {
        let min_k = cands.iter().map(|c| c.tpu_count).min().unwrap_or(0);
        return Err(if slo_blocked {
            format!(
                "needs {} TPU(s); a shared slot exists but its swap \
                 overhead breaches an SLO",
                min_k
            )
        } else {
            format!(
                "needs {} TPU(s) but the pool auction left none ({} total) \
                 and no same-depth TPU set accepts a co-resident",
                min_k, alloc.total_tpus
            )
        });
    };

    // apply: rebuild the whole group's grants at the new resident count
    let cand = cands[ci].clone();
    let mut members = vec![oi];
    members.extend(group_members(assignments, oi));
    let residents = members.len() + 1;
    let mut group: Vec<String> =
        members.iter().map(|&i| assignments[i].name.clone()).collect();
    group.push(tenant.name.clone());
    for &mi in &members {
        let m = &mut assignments[mi];
        let m_switch = switch_cost_s(&m.candidate, alloc);
        m.effective_p99_s = shared_p99_s(m.candidate.p99_s, residents, m_switch);
        m.grant = DeviceGrant::Shared {
            slice: 1.0 / residents as f64,
            switch_s: m_switch,
            group: group.clone(),
        };
    }
    let switch = switch_cost_s(&cand, alloc);
    assignments.push(Assignment {
        name: tenant.name.clone(),
        weight: tenant.weight,
        slo_p99_s: tenant.slo_p99_s,
        effective_p99_s: shared_p99_s(cand.p99_s, residents, switch),
        candidate: cand,
        replicas: 1,
        grant: DeviceGrant::Shared {
            slice: 1.0 / residents as f64,
            switch_s: switch,
            group,
        },
    });
    Ok(())
}

/// Indices of the non-owner members riding assignment `oi`'s TPU set.
fn group_members(assignments: &[Assignment], oi: usize) -> Vec<usize> {
    let owner = &assignments[oi].name;
    assignments
        .iter()
        .enumerate()
        .filter(|(i, a)| {
            *i != oi
                && matches!(&a.grant, DeviceGrant::Shared { group, .. }
                    if group.first() == Some(owner))
        })
        .map(|(i, _)| i)
        .collect()
}

/// Greedily hand leftover TPUs out as whole-pipeline replicas: each round,
/// the admitted tenant with the largest weighted effective p99 whose
/// pipeline still fits the remainder gets one more copy.  Replicas split
/// the batch, so the effective p99 is re-simulated on `ceil(batch / r)`
/// items per copy.
fn grant_replicas(
    registry: &ModelRegistry,
    cfg: &SystemConfig,
    alloc: &AllocatorConfig,
    assignments: &mut [Assignment],
) {
    let used: usize = assignments.iter().map(Assignment::tpus_used).sum();
    let mut leftover = alloc.total_tpus.saturating_sub(used);
    loop {
        let Some(best) = assignments
            .iter()
            .enumerate()
            .filter(|(_, a)| a.candidate.tpu_count <= leftover)
            .max_by(|a, b| {
                let wa = a.1.weight * a.1.effective_p99_s;
                let wb = b.1.weight * b.1.effective_p99_s;
                wa.partial_cmp(&wb).unwrap()
            })
            .map(|(i, _)| i)
        else {
            return;
        };
        let a = &mut assignments[best];
        leftover -= a.candidate.tpu_count;
        a.replicas += 1;
        // re-predict: each replica serves batch/replicas items
        let Ok(tenant) = registry.get(&a.name) else { return };
        let shard = ((alloc.batch + a.replicas - 1) / a.replicas).max(1);
        let re = evaluate(
            &tenant.model,
            a.candidate.tpu_count,
            a.candidate.strategy,
            a.candidate.partition.clone(),
            cfg,
            shard,
        );
        a.effective_p99_s = re.p99_s;
        if leftover == 0 {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::{conv_model, fc_model};
    use crate::scheduler::registry::Tenant;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn registry(names: &[&str]) -> ModelRegistry {
        let mut r = ModelRegistry::new();
        for n in names {
            r.register_named(n).unwrap();
        }
        r
    }

    #[test]
    fn candidates_respect_memory_admission() {
        let alloc = AllocatorConfig::default();
        // fc_big spills on one TPU -> no k=1 candidate, but k>=2 exists
        let cands = candidates_for(&fc_model(1980), &cfg(), &alloc);
        assert!(!cands.is_empty());
        assert!(cands.iter().all(|c| !c.uses_host));
        assert!(cands.iter().all(|c| c.tpu_count >= 2), "{cands:?}");
        // fc_small fits whole
        let cands = candidates_for(&fc_model(512), &cfg(), &alloc);
        assert!(cands.iter().any(|c| c.tpu_count == 1));
        // spill admission turns the k=1 fc_big candidate back on
        let spilling = AllocatorConfig { allow_host_spill: true, ..alloc };
        let cands = candidates_for(&fc_model(1980), &cfg(), &spilling);
        assert!(cands.iter().any(|c| c.tpu_count == 1 && c.uses_host));
    }

    #[test]
    fn acceptance_pool_admits_all_three() {
        // the ISSUE's acceptance scenario: fc_big needs 2 TPUs, each conv
        // fits on 1 -> exactly a 4-TPU pool
        let reg = registry(&["fc_big", "conv_a", "conv_b"]);
        let plan =
            allocate(&reg, &cfg(), &AllocatorConfig::default()).unwrap();
        assert_eq!(plan.assignments.len(), 3, "queued={:?}", plan.queued);
        assert!(plan.queued.is_empty());
        assert!(plan.rejected.is_empty());
        assert_eq!(plan.tpus_used(), 4);
        let fc = plan.assignment("fc_big").unwrap();
        assert_eq!(fc.candidate.tpu_count, 2);
        assert!(!fc.candidate.uses_host);
        for name in ["conv_a", "conv_b"] {
            assert_eq!(plan.assignment(name).unwrap().candidate.tpu_count, 1);
        }
    }

    #[test]
    fn oversubscribed_pool_queues_lowest_weight() {
        // fc_huge needs 3 TPUs, conv_big needs 4 -> a 4-TPU pool can only
        // hold one of them; the heavier tenant wins
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("conv_big", conv_model(592)).with_weight(5.0)).unwrap();
        reg.register(Tenant::new("fc_huge", fc_model(2580)).with_weight(1.0)).unwrap();
        let plan =
            allocate(&reg, &cfg(), &AllocatorConfig::default()).unwrap();
        assert_eq!(plan.assignments.len(), 1);
        assert_eq!(plan.assignments[0].name, "conv_big");
        assert_eq!(plan.queued.len(), 1);
        assert_eq!(plan.queued[0].name, "fc_huge");
        assert!(plan.queued[0].reason.contains("TPU"), "{}", plan.queued[0].reason);
    }

    #[test]
    fn impossible_model_is_rejected_with_reason() {
        // a single 3000-wide dense layer exceeds on-chip memory alone, so
        // NO partition can avoid host streaming
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("fc_n3000", fc_model(3000))).unwrap();
        reg.register_named("fc_small").unwrap();
        let plan =
            allocate(&reg, &cfg(), &AllocatorConfig::default()).unwrap();
        assert_eq!(plan.rejected.len(), 1);
        assert_eq!(plan.rejected[0].name, "fc_n3000");
        assert!(plan.rejected[0].reason.contains("on-chip"), "{}", plan.rejected[0].reason);
        assert_eq!(plan.assignments.len(), 1);
    }

    #[test]
    fn leftover_tpus_become_replicas() {
        let reg = registry(&["fc_small"]);
        let alloc = AllocatorConfig { total_tpus: 3, ..Default::default() };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        let a = plan.assignment("fc_small").unwrap();
        // fc_small fits one TPU; 3-TPU pool -> up to 3 replicas (the
        // allocator may also pick a deeper pipeline if it predicts faster)
        assert_eq!(plan.tpus_used(), 3, "replicas should soak the pool: {a:?}");
        assert!(a.replicas >= 1);
        assert!(a.effective_p99_s <= a.candidate.p99_s + 1e-12);
    }

    #[test]
    fn replication_disabled_leaves_tpus_idle() {
        let reg = registry(&["fc_small"]);
        let alloc = AllocatorConfig {
            total_tpus: 4,
            replicate_leftover: false,
            ..Default::default()
        };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        assert_eq!(plan.assignment("fc_small").unwrap().replicas, 1);
    }

    #[test]
    fn weighted_objective_prefers_heavy_tenant() {
        // two tenants contending for the pool: the heavier one must never
        // end up queued while the lighter is admitted
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("heavy", fc_model(2580)).with_weight(10.0)).unwrap();
        reg.register(Tenant::new("light", fc_model(2580)).with_weight(1.0)).unwrap();
        let alloc = AllocatorConfig { total_tpus: 3, ..Default::default() };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        assert_eq!(plan.assignments.len(), 1);
        assert_eq!(plan.assignments[0].name, "heavy");
        assert_eq!(plan.queued[0].name, "light");
    }

    #[test]
    fn slo_penalty_steers_admission() {
        // equal-weight tie for one 3-TPU slot: without SLOs the search
        // keeps the first solution it finds (alphabetical tenant wins);
        // an unmeetable SLO on that tenant must flip the auction
        let mk = |with_slo: bool| {
            let mut reg = ModelRegistry::new();
            let mut alpha = Tenant::new("alpha", fc_model(2580));
            if with_slo {
                alpha = alpha.with_slo_p99_s(1e-9);
            }
            reg.register(alpha).unwrap();
            reg.register(Tenant::new("beta", fc_model(2580))).unwrap();
            let alloc = AllocatorConfig { total_tpus: 3, ..Default::default() };
            allocate(&reg, &cfg(), &alloc).unwrap()
        };
        let without = mk(false);
        assert_eq!(without.assignments[0].name, "alpha", "tie-break baseline");
        let with = mk(true);
        assert_eq!(with.assignments.len(), 1);
        assert_eq!(with.assignments[0].name, "beta", "SLO-meeting tenant must win");
        assert_eq!(with.queued[0].name, "alpha");
    }

    #[test]
    fn objective_matches_deployed_effective_p99() {
        let reg = registry(&["fc_small", "conv_a"]);
        let alloc = AllocatorConfig { total_tpus: 4, ..Default::default() };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        let want: f64 =
            plan.assignments.iter().map(|a| a.weight * a.effective_p99_s).sum();
        assert!((plan.objective_s - want).abs() < 1e-12, "{} vs {want}", plan.objective_s);
    }

    #[test]
    fn slo_violation_is_flagged() {
        let mut reg = ModelRegistry::new();
        reg.register(
            Tenant::new("strict", fc_model(512)).with_slo_p99_s(1e-9),
        )
        .unwrap();
        let plan =
            allocate(&reg, &cfg(), &AllocatorConfig::default()).unwrap();
        assert!(plan.assignments[0].slo_violated());
    }

    #[test]
    fn candidates_carry_a_positive_switch_cost() {
        let cands = candidates_for(&fc_model(512), &cfg(), &AllocatorConfig::default());
        assert!(cands.iter().all(|c| c.switch_s > 0.0), "{cands:?}");
        // the re-load crosses the slow host link, so it dwarfs the
        // on-chip per-inference time (the whole point of co-residency
        // being a *cost*, not free)
        assert!(cands[0].switch_s > cands[0].per_item_s, "{cands:?}");
    }

    #[test]
    fn sharing_admits_queued_tenant_with_swap_overhead() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("heavy", fc_model(2580)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("light", fc_model(2580)).with_weight(1.0)).unwrap();
        let alloc = AllocatorConfig { total_tpus: 3, ..Default::default() };
        let without = allocate(&reg, &cfg(), &alloc).unwrap();
        assert_eq!(without.queued.len(), 1, "whole-TPU allocator must queue one");
        assert!(!without.sharing_enabled);

        let sharing = AllocatorConfig { allow_sharing: true, ..alloc };
        let plan = allocate(&reg, &cfg(), &sharing).unwrap();
        assert!(plan.sharing_enabled);
        assert!(plan.queued.is_empty(), "{:?}", plan.queued);
        assert_eq!(plan.assignments.len(), 2);
        assert_eq!(plan.tpus_used(), 3, "a rider occupies no extra TPUs");
        assert_eq!(plan.shared_count(), 2);
        let rider = plan.assignment("light").unwrap();
        assert!(rider.grant.is_shared());
        assert!(!rider.owns_tpus());
        assert!(rider.swap_overhead_s() > 0.0, "p99 must include swap overhead");
        assert!(rider.effective_p99_s > rider.candidate.p99_s);
        let host = plan.assignment("heavy").unwrap();
        assert!(host.grant.is_shared(), "the owner time-shares too");
        assert!(host.owns_tpus());
        assert!((host.grant.slice() - 0.5).abs() < 1e-12);
        assert!(host.swap_overhead_s() > 0.0);
        // objective reflects the inflated p99s
        let want: f64 =
            plan.assignments.iter().map(|a| a.weight * a.effective_p99_s).sum();
        assert!((plan.objective_s - want).abs() < 1e-12);
    }

    #[test]
    fn two_tenants_saturate_one_tpu() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("a", fc_model(512))).unwrap();
        reg.register(Tenant::new("b", fc_model(512))).unwrap();
        let alloc = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            ..Default::default()
        };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        assert_eq!(plan.assignments.len(), 2, "queued={:?}", plan.queued);
        assert_eq!(plan.tpus_used(), 1, "both must fit the single TPU");
        assert_eq!(plan.shared_count(), 2);
        for a in &plan.assignments {
            assert_eq!(a.candidate.tpu_count, 1);
            assert!((a.grant.slice() - 0.5).abs() < 1e-12);
            assert!(a.grant.switch_s() > 0.0);
        }
        // max_residents caps the group: a third tenant stays queued
        let mut reg3 = reg.clone();
        reg3.register(Tenant::new("c", fc_model(512))).unwrap();
        let plan3 = allocate(&reg3, &cfg(), &alloc).unwrap();
        assert_eq!(plan3.assignments.len(), 2);
        assert_eq!(plan3.queued.len(), 1);
        // ...unless the cap is raised
        let wide = AllocatorConfig { max_residents: 3, ..alloc };
        let plan3 = allocate(&reg3, &cfg(), &wide).unwrap();
        assert_eq!(plan3.assignments.len(), 3, "queued={:?}", plan3.queued);
        assert!(plan3
            .assignments
            .iter()
            .all(|a| (a.grant.slice() - 1.0 / 3.0).abs() < 1e-12));
    }

    #[test]
    fn shared_grant_breaching_slo_stays_queued() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("host", fc_model(512)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("strict", fc_model(512)).with_slo_p99_s(1e-9)).unwrap();
        let alloc = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            ..Default::default()
        };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        assert_eq!(plan.assignments.len(), 1);
        assert_eq!(plan.queued.len(), 1, "the SLO-breaching rider must stay queued");
        assert_eq!(plan.queued[0].name, "strict");
        assert!(plan.queued[0].reason.contains("SLO"), "{}", plan.queued[0].reason);
        assert_eq!(plan.assignment("host").unwrap().grant, DeviceGrant::Exclusive);
    }

    #[test]
    fn sharing_never_breaks_a_hosts_met_slo() {
        // learn the exclusive p99, then pin the host's SLO between the
        // exclusive and the time-shared prediction: co-residency would
        // break a met SLO, so the rider must stay queued
        let mut probe = ModelRegistry::new();
        probe.register(Tenant::new("host", fc_model(512)).with_weight(2.0)).unwrap();
        let alloc = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            ..Default::default()
        };
        let p99 = allocate(&probe, &cfg(), &alloc)
            .unwrap()
            .assignment("host")
            .unwrap()
            .candidate
            .p99_s;
        let mut reg = ModelRegistry::new();
        reg.register(
            Tenant::new("host", fc_model(512)).with_weight(2.0).with_slo_p99_s(p99 * 1.5),
        )
        .unwrap();
        reg.register(Tenant::new("rider", fc_model(512))).unwrap();
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        let host = plan.assignment("host").unwrap();
        assert_eq!(host.grant, DeviceGrant::Exclusive, "met SLO must survive");
        assert!(!host.slo_violated());
        assert_eq!(plan.queued.len(), 1);
        assert_eq!(plan.queued[0].name, "rider");
        assert!(plan.queued[0].reason.contains("SLO"), "{}", plan.queued[0].reason);
    }

    #[test]
    fn switch_cost_override_applies() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("a", fc_model(512)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("b", fc_model(512))).unwrap();
        let alloc = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            switch_cost_us: Some(1234.0),
            ..Default::default()
        };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        let rider = plan.assignment("b").unwrap();
        assert!((rider.grant.switch_s() - 1234e-6).abs() < 1e-12);
        let want = rider.candidate.p99_s * 2.0 + 1234e-6;
        assert!((rider.effective_p99_s - want).abs() < 1e-9);
        // negative override is rejected
        let bad = AllocatorConfig { switch_cost_us: Some(-1.0), ..alloc };
        assert!(allocate(&reg, &cfg(), &bad).is_err());
    }

    #[test]
    fn empty_registry_is_an_error() {
        let reg = ModelRegistry::new();
        assert!(allocate(&reg, &cfg(), &AllocatorConfig::default()).is_err());
    }

    #[test]
    fn zero_batch_is_an_error_not_a_panic() {
        let reg = registry(&["fc_small"]);
        let alloc = AllocatorConfig { batch: 0, ..Default::default() };
        let err = allocate(&reg, &cfg(), &alloc).unwrap_err();
        assert!(err.to_string().contains("batch"), "{err}");
    }
}
