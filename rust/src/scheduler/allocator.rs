//! Pool allocator: memory-aware admission control + sharing-aware
//! branch-and-bound placement over per-model `(tpu_count, strategy,
//! slice)` assignments.
//!
//! Given N TPUs and M registered models, the allocator:
//!
//! 1. builds, per tenant, the set of **admissible candidates** — every
//!    `(tpu_count, strategy)` whose chosen partition keeps all segment
//!    weights in on-chip memory (host-streaming candidates are rejected
//!    unless `allow_host_spill` is set, because host streaming is the 40x
//!    cliff the whole paper is about);
//! 2. runs a branch-and-bound over per-tenant `(candidate, slice)`
//!    choices with **per-device residual slice capacity** carried in
//!    every search node: a choice is exclusive (`slice = 1`) or a
//!    time-multiplexed fraction (`slice = 1/2 .. 1/max_residents`,
//!    [`AllocatorConfig::allow_sharing`]), and the objective — the
//!    weighted sum of predicted p99 latencies including slice dilation,
//!    context-switch (swap) overhead and the scheduling-quantum wait —
//!    is priced into the bound together with SLO penalties, with a
//!    large penalty for queueing a tenant so admission is maximized
//!    first;
//! 3. hands leftover whole TPUs out as **data-parallel replicas** (served
//!    by `coordinator::ReplicaRouter`) to the admitted exclusive tenant
//!    with the largest weighted p99, greedily.
//!
//! Models that fit no admissible candidate at all are **rejected**
//! (`cannot fit`); models that fit but lost the auction are **queued**
//! (they would be admitted on a bigger pool).
//!
//! ## Unified sharing search (vs the retired two-phase design)
//!
//! Through PR 3 sharing was a pairwise-greedy pass *after* the exclusive
//! auction: queued tenants could only ride a same-depth TPU set, leaving
//! admissible plans on the table.  The search now tracks slices **per
//! device**, so tenants of different pipeline depths co-reside on
//! overlapping device subsets (cf. arXiv 2503.01035 on jointly choosing
//! split and assignment, and arXiv 2602.17808 on collaborative
//! co-residency).  Co-resident segments do not fit on-chip together, so
//! each scheduling quantum re-loads the incoming tenant's parameters
//! from host memory — the context-switch cost is the same
//! off-chip-bandwidth term the cost model charges spilled layers (arXiv
//! 2102.10423 quantifies that penalty).  A fractional choice whose
//! predicted p99 *including* swap overhead breaches the tenant's own SLO
//! is infeasible (hard gate); a tenant's reserved slice is never diluted
//! by later arrivals, so co-residency cannot degrade an already granted
//! placement.
//!
//! With sharing **off** the search degenerates to the exclusive-only
//! auction with PR 3's exact exploration and pruning order, so whole-TPU
//! plans — and the `repro schedule` output rendered from them — are
//! unchanged.

use anyhow::Result;

use crate::compiler::place;
use crate::config::SystemConfig;
use crate::link::Link;
use crate::model::Model;
use crate::pipeline::{build_stages, simulate, SimOptions};
use crate::segment::strategy::Strategy;
use crate::segment::Partition;
use crate::util::mib;
use crate::util::stats::Summary;

use super::paramcache::{plan_effect, CacheEffect, ParamCache};
use super::registry::{ModelRegistry, Tenant};

/// Allocator knobs.
#[derive(Debug, Clone)]
pub struct AllocatorConfig {
    /// TPUs in the pool.
    pub total_tpus: usize,
    /// Batch size used when profiling candidates (the paper's §V-B
    /// closed-batch workload; also the router's serving batch).
    pub batch: usize,
    /// Per-model ceiling on pipeline depth (the paper's testbed tops out
    /// at 4 TPUs; deeper pipelines only add GIL-serialized overhead).
    pub max_tpus_per_model: usize,
    /// Admit candidates that stream weights from host memory.  Off by
    /// default: spilled segments are the pathology segmentation exists to
    /// remove.
    pub allow_host_spill: bool,
    /// Hand leftover TPUs to admitted tenants as pipeline replicas.
    pub replicate_leftover: bool,
    /// Let the search grant time-multiplexed per-device slices
    /// ([`DeviceGrant::Shared`]).  Off by default: with it off, plans are
    /// identical to the whole-TPU allocator's.
    pub allow_sharing: bool,
    /// Override the per-swap context-switch cost (microseconds, whole
    /// pipeline).  `None` derives it per tenant from the cost model's
    /// host-memory bandwidth term (`serving::stage_switch_costs`).
    pub switch_cost_us: Option<f64>,
    /// Maximum co-resident tenants per device (>= 2 when sharing); also
    /// the smallest grantable slice (`1/max_residents`).
    pub max_residents: usize,
    /// Scheduling-quantum length for time-shared devices, microseconds.
    /// `0` (the default) swaps on every batch flush, PR 3's behaviour; a
    /// longer quantum swaps less often under overload (more throughput)
    /// at the price of a `(1 - slice) * quantum` worst-case wait priced
    /// into every shared candidate's p99 (the latency↔throughput trade
    /// of arXiv 2602.17808's collaborative scheduling).
    pub quantum_us: f64,
    /// Pool device ids currently out of service (chaos device kills,
    /// real hardware loss).  A dead device holds no residual slice
    /// capacity and never counts as a replica leftover; re-planning with
    /// a freshly-dead device is how the live pool migrates its tenants
    /// off it.
    pub dead_devices: Vec<usize>,
    /// Per-device host staging budget (bytes) for the segment-parameter
    /// cache ([`super::paramcache`]).  Co-resident stages pinned within
    /// the budget swap *warm* (near-zero cost) instead of paying the
    /// cold host-bandwidth re-load.  `0` (the default) disables the
    /// cache entirely: every swap is cold and plans are byte-identical
    /// to the flat-cost allocator's.
    pub cache_budget_bytes: u64,
    /// Overlap the next resident's parameter load with the tail of the
    /// current quantum: hides up to `(1 - slice) * quantum` seconds of
    /// whatever cold traffic the cache budget could not pin.  Inert
    /// with a zero quantum (no window) or a zero cache budget.
    pub prefetch: bool,
}

impl Default for AllocatorConfig {
    fn default() -> Self {
        AllocatorConfig {
            total_tpus: 4,
            batch: 50,
            max_tpus_per_model: 4,
            allow_host_spill: false,
            replicate_leftover: true,
            allow_sharing: false,
            switch_cost_us: None,
            max_residents: 2,
            quantum_us: 0.0,
            dead_devices: Vec::new(),
            cache_budget_bytes: 0,
            prefetch: false,
        }
    }
}

impl AllocatorConfig {
    /// Typed builder over the planner knobs, starting from the defaults;
    /// [`AllocatorConfigBuilder::build`] runs [`AllocatorConfig::validate`]
    /// so an invalid knob combination never escapes construction.
    pub fn builder() -> AllocatorConfigBuilder {
        AllocatorConfigBuilder::default()
    }

    /// Validate every knob in one place.  [`allocate`] calls this on
    /// entry, so struct-literal configs keep working; programmatic
    /// callers (the calibration loop re-invokes planning) should go
    /// through [`AllocatorConfig::builder`], which validates eagerly.
    /// The error strings are stable — CLI tests and operators match on
    /// them.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.total_tpus >= 1, "pool needs at least one TPU");
        anyhow::ensure!(self.batch >= 1, "profiling batch must be at least 1");
        anyhow::ensure!(
            !self.allow_sharing || self.max_residents >= 2,
            "sharing needs max_residents >= 2"
        );
        anyhow::ensure!(
            self.quantum_us.is_finite(),
            "quantum must be a finite number of microseconds (got {})",
            self.quantum_us
        );
        anyhow::ensure!(self.quantum_us >= 0.0, "quantum must be non-negative");
        if let Some(us) = self.switch_cost_us {
            anyhow::ensure!(
                us.is_finite(),
                "switch cost must be a finite number of microseconds (got {us})"
            );
            anyhow::ensure!(us >= 0.0, "switch cost must be non-negative (got {us})");
        }
        let mut dead = self.dead_devices.clone();
        dead.sort_unstable();
        dead.dedup();
        for &d in &dead {
            anyhow::ensure!(
                d < self.total_tpus,
                "dead device {d} out of range (pool has {} TPUs)",
                self.total_tpus
            );
        }
        anyhow::ensure!(
            dead.len() < self.total_tpus,
            "every pool device is dead ({} of {})",
            dead.len(),
            self.total_tpus
        );
        Ok(())
    }
}

/// Builder for [`AllocatorConfig`]: one method per knob, validated on
/// [`build`](AllocatorConfigBuilder::build).  This is the consolidated
/// construction path the CLI flag group and the calibration loop share;
/// plain struct literals stay supported for tests and embedders that
/// already hold a known-valid config.
#[derive(Debug, Clone, Default)]
pub struct AllocatorConfigBuilder {
    cfg: AllocatorConfig,
}

impl AllocatorConfigBuilder {
    /// TPUs in the pool.
    pub fn total_tpus(mut self, n: usize) -> Self {
        self.cfg.total_tpus = n;
        self
    }

    /// Profiling (and serving) batch size.
    pub fn batch(mut self, n: usize) -> Self {
        self.cfg.batch = n;
        self
    }

    /// Per-model pipeline-depth ceiling.
    pub fn max_tpus_per_model(mut self, n: usize) -> Self {
        self.cfg.max_tpus_per_model = n;
        self
    }

    /// Admit candidates that stream weights from host memory.
    pub fn allow_host_spill(mut self, on: bool) -> Self {
        self.cfg.allow_host_spill = on;
        self
    }

    /// Hand leftover TPUs out as pipeline replicas.
    pub fn replicate_leftover(mut self, on: bool) -> Self {
        self.cfg.replicate_leftover = on;
        self
    }

    /// Let the search grant time-multiplexed per-device slices.
    pub fn allow_sharing(mut self, on: bool) -> Self {
        self.cfg.allow_sharing = on;
        self
    }

    /// Pin the per-swap context-switch cost (µs, whole pipeline).
    pub fn switch_cost_us(mut self, us: f64) -> Self {
        self.cfg.switch_cost_us = Some(us);
        self
    }

    /// Maximum co-resident tenants per device.
    pub fn max_residents(mut self, n: usize) -> Self {
        self.cfg.max_residents = n;
        self
    }

    /// Scheduling-quantum length for time-shared devices (µs).
    pub fn quantum_us(mut self, us: f64) -> Self {
        self.cfg.quantum_us = us;
        self
    }

    /// Pool device ids currently out of service.
    pub fn dead_devices(mut self, dead: Vec<usize>) -> Self {
        self.cfg.dead_devices = dead;
        self
    }

    /// Per-device host staging budget for the segment-parameter cache.
    pub fn cache_budget_bytes(mut self, bytes: u64) -> Self {
        self.cfg.cache_budget_bytes = bytes;
        self
    }

    /// Overlap residual parameter loads with the previous quantum tail.
    pub fn prefetch(mut self, on: bool) -> Self {
        self.cfg.prefetch = on;
        self
    }

    /// Validate and return the finished config.
    pub fn build(self) -> Result<AllocatorConfig> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

/// How an assignment occupies its TPUs — the abstraction that replaces
/// the old implicit "whole devices only" invariant.
#[derive(Debug, Clone, PartialEq)]
pub enum DeviceGrant {
    /// The assignment owns its `tpu_count * replicas` devices outright.
    Exclusive,
    /// Time-multiplexed co-residency: the assignment reserves `slice` of
    /// device time on each device it runs on, and pays `switch_s`
    /// seconds per scheduling quantum to re-load its segment parameters
    /// from host memory.
    Shared {
        /// Fraction of device time reserved on every device of the set.
        slice: f64,
        /// Per-swap parameter re-load cost, summed over pipeline stages.
        switch_s: f64,
        /// Scheduling-quantum length (seconds); `0` swaps every flush.
        quantum_s: f64,
        /// Per-device co-residency map: `(device id, name-sorted tenants
        /// time-sharing that device, this one included)`.  Devices of
        /// different pipeline depths may overlap partially, so the map is
        /// per device, not per TPU set.
        residents: Vec<(usize, Vec<String>)>,
        /// Planned segment-parameter cache outcome for this grant
        /// (pinned warm fraction + prefetch window), `None` when the
        /// cache is disabled — `switch_s` above always stays the *cold*
        /// cost, and consumers scale it by the effect at swap time.
        cache: Option<CacheEffect>,
    },
}

impl DeviceGrant {
    /// Fraction of device time this grant delivers (1.0 when exclusive).
    pub fn slice(&self) -> f64 {
        match self {
            DeviceGrant::Exclusive => 1.0,
            DeviceGrant::Shared { slice, .. } => *slice,
        }
    }

    /// Per-quantum context-switch cost (0 when exclusive).
    pub fn switch_s(&self) -> f64 {
        match self {
            DeviceGrant::Exclusive => 0.0,
            DeviceGrant::Shared { switch_s, .. } => *switch_s,
        }
    }

    /// Scheduling-quantum length in seconds (0 when exclusive: an owner
    /// never swaps, so the quantum is meaningless).
    pub fn quantum_s(&self) -> f64 {
        match self {
            DeviceGrant::Exclusive => 0.0,
            DeviceGrant::Shared { quantum_s, .. } => *quantum_s,
        }
    }

    /// Whether the grant time-shares its TPUs.
    pub fn is_shared(&self) -> bool {
        matches!(self, DeviceGrant::Shared { .. })
    }

    /// Planned segment-parameter cache effect (`None` when exclusive or
    /// the cache is disabled).
    pub fn cache(&self) -> Option<CacheEffect> {
        match self {
            DeviceGrant::Exclusive => None,
            DeviceGrant::Shared { cache, .. } => *cache,
        }
    }

    /// Whether two grants describe the same deployment behaviour.  The
    /// live pool's re-plan diff uses this instead of `==`: concrete
    /// device ids are bookkeeping (stage sims, slice dilation and swap
    /// costs never depend on them), so a re-plan that merely renumbers a
    /// shared group's devices — e.g. after an unrelated tenant leaves —
    /// must not drain deployments whose slice, costs and co-residents
    /// are unchanged.
    pub fn same_deployment(&self, other: &DeviceGrant) -> bool {
        match (self, other) {
            (DeviceGrant::Exclusive, DeviceGrant::Exclusive) => true,
            (
                DeviceGrant::Shared {
                    slice: s1,
                    switch_s: w1,
                    quantum_s: q1,
                    residents: r1,
                    cache: c1,
                },
                DeviceGrant::Shared {
                    slice: s2,
                    switch_s: w2,
                    quantum_s: q2,
                    residents: r2,
                    cache: c2,
                },
            ) => {
                let names = |r: &[(usize, Vec<String>)]| {
                    let mut groups: Vec<Vec<String>> =
                        r.iter().map(|(_, n)| n.clone()).collect();
                    groups.sort();
                    groups
                };
                s1 == s2 && w1 == w2 && q1 == q2 && c1 == c2 && names(r1) == names(r2)
            }
            _ => false,
        }
    }

    /// Compact table label, e.g. `excl` or `shared 1/2`.
    pub fn label(&self) -> String {
        match self {
            DeviceGrant::Exclusive => "excl".to_string(),
            DeviceGrant::Shared { slice, .. } => {
                let denom = (1.0 / slice).round();
                if denom >= 1.0 && (slice * denom - 1.0).abs() < 1e-6 {
                    format!("shared 1/{}", denom as u64)
                } else {
                    format!("shared {slice:.2}")
                }
            }
        }
    }
}

/// One evaluated `(tpu_count, strategy)` option for a tenant.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Pipeline depth (TPUs) this candidate uses.
    pub tpu_count: usize,
    /// Segmentation strategy that chose the partition.
    pub strategy: Strategy,
    /// The concrete layer partition.
    pub partition: Partition,
    /// Batch-amortized per-inference seconds (simulated Edge TPU clock).
    pub per_item_s: f64,
    /// p99 of the simulated completion-time distribution for the profiling
    /// batch — the allocator's latency objective.
    pub p99_s: f64,
    /// Total on-chip weight footprint across segments.
    pub device_mib: f64,
    /// Total host-resident (streamed) weight footprint across segments.
    pub host_mib: f64,
    /// Whether any segment streams weights from the host.
    pub uses_host: bool,
    /// Whole-pipeline context-switch cost if this candidate time-shares
    /// its TPUs: re-loading every segment's on-chip weights from host
    /// memory over the off-chip bandwidth term (seconds per swap).
    pub switch_s: f64,
    /// Per-stage on-chip weight bytes, in stage order — the footprint
    /// the segment-parameter cache pins per device (stage `i` of a
    /// shared grant runs on its `i`-th device).
    pub stage_weight_bytes: Vec<u64>,
}

/// Why a tenant was not admitted.
#[derive(Debug, Clone)]
pub struct Rejection {
    /// The tenant's registry name.
    pub name: String,
    /// Human-readable reason it was queued/rejected.
    pub reason: String,
}

/// Final placement of one admitted tenant.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// The tenant's registry name.
    pub name: String,
    /// The tenant's scheduling weight (objective multiplier).
    pub weight: f64,
    /// The tenant's p99 SLO, if declared.
    pub slo_p99_s: Option<f64>,
    /// The winning `(tpu_count, strategy, partition)` candidate.
    pub candidate: Candidate,
    /// Data-parallel copies of the whole pipeline (>= 1).
    pub replicas: usize,
    /// How the TPUs are held: exclusive or a time-multiplexed slice.
    pub grant: DeviceGrant,
    /// Concrete pool device ids this assignment runs on, ascending:
    /// `tpu_count * replicas` ids for exclusive grants, the (possibly
    /// partially overlapping with other tenants') time-shared device set
    /// for shared grants.
    pub devices: Vec<usize>,
    /// Predicted p99 after replication (replicas split the batch) and,
    /// for shared grants, slice dilation + swap + quantum-wait overhead.
    pub effective_p99_s: f64,
}

impl Assignment {
    /// Predicted p99 inflation from co-residency (slice dilation + swap
    /// cost + quantum wait); 0 for exclusive grants.
    pub fn swap_overhead_s(&self) -> f64 {
        if self.grant.is_shared() {
            (self.effective_p99_s - self.candidate.p99_s).max(0.0)
        } else {
            0.0
        }
    }

    /// Whether the predicted p99 violates the tenant's SLO.
    pub fn slo_violated(&self) -> bool {
        matches!(self.slo_p99_s, Some(slo) if self.effective_p99_s > slo)
    }
}

/// The allocator's output: admitted placements + non-admitted tenants.
#[derive(Debug, Clone)]
pub struct PoolPlan {
    /// TPUs in the pool this plan was computed for.
    pub total_tpus: usize,
    /// Admitted tenants with their winning placements.
    pub assignments: Vec<Assignment>,
    /// Tenants that fit the device but lost the TPU auction on this pool.
    pub queued: Vec<Rejection>,
    /// Tenants no partition of which fits the pool's on-chip memory.
    pub rejected: Vec<Rejection>,
    /// Weighted effective-p99 objective over admitted tenants (after
    /// replica grants).
    pub objective_s: f64,
    /// Whether time-multiplexed sharing was enabled for this plan (drives
    /// the extended `repro schedule` columns).
    pub sharing_enabled: bool,
    /// Whether the segment-parameter cache was enabled (sharing on and
    /// a non-zero `cache_budget_bytes`) — drives the cache-hit-rate
    /// columns; off keeps every output byte-identical to the flat-cost
    /// allocator's.
    pub cache_enabled: bool,
}

impl PoolPlan {
    /// Distinct pool devices occupied across all admitted assignments
    /// (a time-shared device counts once, however many residents it has).
    pub fn tpus_used(&self) -> usize {
        let mut used: Vec<usize> =
            self.assignments.iter().flat_map(|a| a.devices.iter().copied()).collect();
        used.sort_unstable();
        used.dedup();
        used.len()
    }

    /// The admitted assignment for `name`, if it was admitted.
    pub fn assignment(&self, name: &str) -> Option<&Assignment> {
        self.assignments.iter().find(|a| a.name == name)
    }

    /// Number of admitted tenants holding a time-multiplexed grant.
    pub fn shared_count(&self) -> usize {
        self.assignments.iter().filter(|a| a.grant.is_shared()).count()
    }
}

/// Simulated-latency penalty (seconds) for queueing one unit of tenant
/// weight: large enough that admitting everyone always beats any latency
/// trade, small enough to stay finite in the objective.
const QUEUE_PENALTY_S: f64 = 1.0e4;

/// Per-weight-unit penalty (seconds) for admitting a tenant whose
/// predicted p99 violates its SLO: steers the auction toward SLO-meeting
/// placements, while staying far below [`QUEUE_PENALTY_S`] so a violating
/// admission still beats not running at all.
const SLO_PENALTY_S: f64 = 1.0e2;

/// Slack for residual-slice comparisons (slices are small rationals, so
/// accumulated float error stays far below this).
const SLICE_EPS: f64 = 1e-9;

/// Evaluate one concrete partition of `model` under the profiling batch.
fn evaluate(
    model: &Model,
    tpu_count: usize,
    strategy: Strategy,
    partition: Partition,
    cfg: &SystemConfig,
    batch: usize,
) -> Candidate {
    let mut device_bytes = 0u64;
    let mut host_bytes = 0u64;
    let mut uses_host = false;
    let mut stage_weight_bytes = Vec::new();
    for &(a, b) in &partition.bounds() {
        let placement = place(&model.layers[a..b], &cfg.device);
        device_bytes += placement.device_bytes();
        host_bytes += placement.host_bytes();
        uses_host |= placement.uses_host();
        stage_weight_bytes.push(placement.device_bytes() + placement.host_bytes());
    }
    let stages = build_stages(model, &partition, cfg);
    let link = Link::new(cfg.link.clone());
    let result = simulate(
        &stages,
        &link,
        &SimOptions { batch, queue_capacity: None, record_gantt: false },
    );
    let mut lat = Summary::new();
    for &l in &result.latencies_s {
        lat.add(l);
    }
    let switch_s: f64 =
        crate::serving::stage_switch_costs(model, &partition, cfg).iter().sum();
    Candidate {
        tpu_count,
        strategy,
        partition,
        per_item_s: result.per_item_s(batch),
        p99_s: lat.p99(),
        device_mib: mib(device_bytes),
        host_mib: mib(host_bytes),
        uses_host,
        switch_s,
        stage_weight_bytes,
    }
}

/// All admissible candidates for one model on this pool, best-p99 first.
/// Empty iff no `(tpu_count, strategy)` keeps the model on-chip (and
/// spilling is not allowed).
pub fn candidates_for(
    model: &Model,
    cfg: &SystemConfig,
    alloc: &AllocatorConfig,
) -> Vec<Candidate> {
    let max_k = alloc.max_tpus_per_model.min(alloc.total_tpus).min(model.len());
    let mut out: Vec<Candidate> = Vec::new();
    for k in 1..=max_k {
        let strategies = if k == 1 {
            vec![Strategy::Uniform]
        } else {
            vec![
                Strategy::Uniform,
                Strategy::MemoryBalanced,
                Strategy::ProfiledExhaustive { batch: alloc.batch },
            ]
        };
        for strategy in strategies {
            let partition = if k == 1 {
                Partition::whole(model.len())
            } else {
                strategy.partition(model, k, cfg)
            };
            // dedupe: different strategies often pick the same cuts
            if out.iter().any(|c| c.tpu_count == k && c.partition == partition) {
                continue;
            }
            let cand = evaluate(model, k, strategy, partition, cfg, alloc.batch);
            if cand.uses_host && !alloc.allow_host_spill {
                continue;
            }
            out.push(cand);
        }
    }
    out.sort_by(|a, b| a.p99_s.partial_cmp(&b.p99_s).unwrap());
    out
}

/// Predicted p99 of a tenant holding `slice` of device time: service
/// dilates by `1/slice`, every quantum re-loads the segment parameters
/// from host memory (`switch_s`), and in the worst case a request waits
/// out the co-residents' share of the quantum before the tenant's next
/// turn.  With `quantum_s = 0` (swap every flush) and `slice = 1/n` this
/// is PR 3's `p99 * n + switch_s`.
fn shared_eff_p99(p99_s: f64, slice: f64, switch_s: f64, quantum_s: f64) -> f64 {
    p99_s / slice + switch_s + (1.0 - slice) * quantum_s
}

/// Per-swap cost of a candidate under the allocator config: the
/// cost-model-derived re-load time ([`Candidate::switch_s`], the Table-I
/// off-chip-bandwidth term) unless the operator pinned `switch_cost_us`.
fn switch_cost_s(cand: &Candidate, alloc: &AllocatorConfig) -> f64 {
    match alloc.switch_cost_us {
        Some(us) => us * 1e-6,
        None => cand.switch_s,
    }
}

/// Search-cost step of admitting one tenant at `(candidate, slice)`:
/// weighted predicted p99 including slice dilation, swap overhead and
/// quantum wait, plus the soft SLO penalty for exclusive placements.
/// `None` when the hard gate refuses a *shared* placement whose inflated
/// p99 breaches the tenant's own SLO — co-residency must never be the
/// reason an SLO is missed.
fn admission_step(
    weight: f64,
    p99_s: f64,
    slo: Option<f64>,
    slice: f64,
    switch_s: f64,
    quantum_s: f64,
) -> Option<f64> {
    if slice >= 1.0 - SLICE_EPS {
        let mut step = weight * p99_s;
        if matches!(slo, Some(s) if p99_s > s) {
            step += weight * SLO_PENALTY_S;
        }
        Some(step)
    } else {
        let eff = shared_eff_p99(p99_s, slice, switch_s, quantum_s);
        if matches!(slo, Some(s) if eff > s) {
            return None;
        }
        Some(weight * eff)
    }
}

/// Per-device residual slice capacity + resident counts — the state every
/// search node carries (do/undo around recursion), and the replay state
/// that turns the winning choices into concrete device ids.
struct DevicePool {
    residual: Vec<f64>,
    residents: Vec<u32>,
    max_residents: u32,
    /// Per-device segment-parameter bytes staged by already-placed
    /// *shared* residents (cache pressure); tracked only when the
    /// cache budget is non-zero.
    load_bytes: Vec<u64>,
    cache_budget: u64,
}

impl DevicePool {
    fn new(
        total_tpus: usize,
        max_residents: usize,
        dead: &[usize],
        cache_budget: u64,
    ) -> Self {
        let mut pool = DevicePool {
            residual: vec![1.0; total_tpus],
            residents: vec![0; total_tpus],
            max_residents: max_residents as u32,
            load_bytes: vec![0; total_tpus],
            cache_budget,
        };
        for &d in dead {
            if d < total_tpus {
                // no residual slice, resident-saturated, excluded from
                // free_count: a dead device can host nothing
                pool.residual[d] = 0.0;
                pool.residents[d] = (max_residents as u32).max(1);
            }
        }
        pool
    }

    /// Deterministically pick `k` devices for a `slice` grant, or `None`
    /// when the pool cannot host it.  Exclusive grants (`slice = 1`) take
    /// the lowest-indexed fully free devices; fractional grants best-fit
    /// onto the most-loaded devices with enough residual (ties by device
    /// index), so riders overlap existing fractional tenants and whole
    /// devices stay available for exclusive grants and replicas.
    ///
    /// `stage_bytes` (stage `i` lands on the `i`-th chosen device) is
    /// non-empty only for fractional grants under a non-zero cache
    /// budget: the packing then *prefers* devices where the tenant's
    /// parameters still fit the staging budget next to the residents
    /// already there, and the returned pressure — the co-residents'
    /// staged bytes on the most loaded chosen device — prices the
    /// tenant's expected hit rate.  Empty `stage_bytes` leaves both the
    /// ordering and the returned pressure (0) exactly as before.
    fn place(
        &mut self,
        k: usize,
        slice: f64,
        stage_bytes: &[u64],
    ) -> Option<(Vec<usize>, u64)> {
        let exclusive = slice >= 1.0 - SLICE_EPS;
        let mut eligible: Vec<usize> = (0..self.residual.len())
            .filter(|&d| {
                self.residual[d] + SLICE_EPS >= slice
                    && (exclusive || self.residents[d] < self.max_residents)
            })
            .collect();
        if eligible.len() < k {
            return None;
        }
        if !exclusive {
            let rep_bytes = stage_bytes.iter().copied().max().unwrap_or(0);
            let cache_on = self.cache_budget > 0 && !stage_bytes.is_empty();
            eligible.sort_by(|&a, &b| {
                let overflows = |d: usize| {
                    cache_on && self.load_bytes[d] + rep_bytes > self.cache_budget
                };
                overflows(a)
                    .cmp(&overflows(b))
                    .then(
                        self.residual[a].partial_cmp(&self.residual[b]).unwrap(),
                    )
                    .then(a.cmp(&b))
            });
        }
        let mut chosen: Vec<usize> = eligible.into_iter().take(k).collect();
        chosen.sort_unstable();
        let mut pressure = 0u64;
        for (i, &d) in chosen.iter().enumerate() {
            self.residual[d] -= slice;
            self.residents[d] += 1;
            if let Some(&bytes) = stage_bytes.get(i) {
                pressure = pressure.max(self.load_bytes[d]);
                self.load_bytes[d] += bytes;
            }
        }
        Some((chosen, pressure))
    }

    fn unplace(&mut self, devices: &[usize], slice: f64, stage_bytes: &[u64]) {
        for (i, &d) in devices.iter().enumerate() {
            self.residual[d] += slice;
            self.residents[d] -= 1;
            if let Some(&bytes) = stage_bytes.get(i) {
                self.load_bytes[d] -= bytes;
            }
        }
    }

    /// Devices with no residents at all (whole-TPU leftovers).
    fn free_count(&self) -> usize {
        self.residents.iter().filter(|&&r| r == 0).count()
    }
}

/// Branch-and-bound over per-tenant `(candidate, slice)` choices with
/// per-device residual capacity in every node.
struct Search<'a> {
    /// (tenant index) -> admissible candidates, best-p99 first.
    cands: &'a [Vec<Candidate>],
    weights: &'a [f64],
    /// Per-tenant p99 SLO, if any (violating exclusive admissions are
    /// penalized; violating shared admissions are infeasible).
    slos: &'a [Option<f64>],
    /// Per-tenant per-candidate swap cost (operator override applied).
    switch: &'a [Vec<f64>],
    /// Grantable slice levels, descending: `1, 1/2, ..., 1/max_residents`
    /// (just `1` when sharing is off).
    slices: &'a [f64],
    quantum_s: f64,
    /// Segment-parameter cache knobs (0 budget = cache off: switch
    /// costs stay cold and the search explores exactly as before).
    cache_budget: u64,
    prefetch: bool,
    pool: DevicePool,
    /// Admissible lower bound on the cost of tenants `i..`: suffix sums
    /// of each tenant's cheapest option (swap overhead and SLO penalties
    /// included, device capacity relaxed).  All zeros when sharing is
    /// off, preserving PR 3's exact pruning behaviour.
    lb: Vec<f64>,
    best_cost: f64,
    /// Best `(candidate, slice)` per tenant; `None` = queued.
    best_choice: Vec<Option<(usize, usize)>>,
    current: Vec<Option<(usize, usize)>>,
}

impl Search<'_> {
    fn run(&mut self, idx: usize, cost: f64) {
        if cost + self.lb[idx] >= self.best_cost {
            return; // bound: even the relaxed remainder cannot improve
        }
        if idx == self.cands.len() {
            self.best_cost = cost;
            self.best_choice = self.current.clone();
            return;
        }
        // copy the shared references out so the loops below don't hold a
        // borrow of `self` across the recursive &mut calls
        let cands = self.cands;
        let slices = self.slices;
        let switch = self.switch;
        let (weight, slo) = (self.weights[idx], self.slos[idx]);
        for (ci, cand) in cands[idx].iter().enumerate() {
            for (si, &slice) in slices.iter().enumerate() {
                // cache pressure depends on the chosen devices, so
                // placement happens *before* pricing (with the cache
                // off the reorder is behaviour-neutral: the step never
                // reads the placement)
                let fractional = slice < 1.0 - SLICE_EPS;
                let stage_bytes: &[u64] = if fractional && self.cache_budget > 0 {
                    &cand.stage_weight_bytes
                } else {
                    &[]
                };
                let Some((devices, pressure)) =
                    self.pool.place(cand.tpu_count, slice, stage_bytes)
                else {
                    continue;
                };
                let switch_s = if stage_bytes.is_empty() {
                    switch[idx][ci]
                } else {
                    plan_effect(
                        stage_bytes,
                        self.cache_budget,
                        pressure,
                        self.prefetch,
                        slice,
                        self.quantum_s,
                    )
                    .effective_switch_s(switch[idx][ci])
                };
                // a None step is the hard SLO gate on a shared option;
                // the queue-reason flags are precomputed in allocate()
                let Some(step) = admission_step(
                    weight,
                    cand.p99_s,
                    slo,
                    slice,
                    switch_s,
                    self.quantum_s,
                ) else {
                    self.pool.unplace(&devices, slice, stage_bytes);
                    continue;
                };
                self.current[idx] = Some((ci, si));
                self.run(idx + 1, cost + step);
                self.pool.unplace(&devices, slice, stage_bytes);
            }
        }
        // or queue this tenant
        self.current[idx] = None;
        self.run(idx + 1, cost + weight * QUEUE_PENALTY_S);
        self.current[idx] = None;
    }
}

/// Run admission + placement search for every registered tenant.
pub fn allocate(
    registry: &ModelRegistry,
    cfg: &SystemConfig,
    alloc: &AllocatorConfig,
) -> Result<PoolPlan> {
    alloc.validate()?;
    anyhow::ensure!(!registry.is_empty(), "no models registered");
    let mut dead = alloc.dead_devices.clone();
    dead.sort_unstable();
    dead.dedup();
    let pool_desc = if dead.is_empty() {
        format!("{} total", alloc.total_tpus)
    } else {
        format!("{} total, {} dead", alloc.total_tpus, dead.len())
    };

    // deterministic order: weight desc, then name (registry order is
    // name-sorted already)
    let mut tenants: Vec<_> = registry.iter().collect();
    tenants.sort_by(|a, b| {
        b.weight.partial_cmp(&a.weight).unwrap().then_with(|| a.name.cmp(&b.name))
    });

    let mut rejected = Vec::new();
    let mut searchable: Vec<(&Tenant, Vec<Candidate>)> = Vec::new();
    for t in tenants {
        let mut cands = candidates_for(&t.model, cfg, alloc);
        // online calibration rewrites a tenant's profiled cost model as
        // a scale on its predicted latencies (observed/predicted); 1.0
        // (the default) leaves candidates bit-identical, and a uniform
        // positive scale preserves the best-p99-first order
        if t.cost_scale != 1.0 {
            for c in &mut cands {
                c.p99_s *= t.cost_scale;
                c.per_item_s *= t.cost_scale;
            }
        }
        if cands.is_empty() {
            let single = place(&t.model.layers, &cfg.device);
            rejected.push(Rejection {
                name: t.name.clone(),
                reason: format!(
                    "no (tpu_count <= {}, strategy) keeps its {:.2} MiB of weights \
                     in on-chip memory",
                    alloc.max_tpus_per_model.min(alloc.total_tpus),
                    mib(single.device_bytes() + single.host_bytes()),
                ),
            });
        } else {
            searchable.push((t, cands));
        }
    }

    let cand_sets: Vec<Vec<Candidate>> =
        searchable.iter().map(|(_, c)| c.clone()).collect();
    let weights: Vec<f64> = searchable.iter().map(|(t, _)| t.weight).collect();
    let slos: Vec<Option<f64>> = searchable.iter().map(|(t, _)| t.slo_p99_s).collect();
    let switch: Vec<Vec<f64>> = cand_sets
        .iter()
        .map(|cs| cs.iter().map(|c| switch_cost_s(c, alloc)).collect())
        .collect();
    let slices: Vec<f64> = if alloc.allow_sharing {
        let mut s = vec![1.0];
        s.extend((2..=alloc.max_residents).map(|n| 1.0 / n as f64));
        s
    } else {
        vec![1.0]
    };
    let quantum_s = alloc.quantum_us * 1e-6;
    let n = cand_sets.len();
    let cache_enabled = alloc.allow_sharing && alloc.cache_budget_bytes > 0;

    // best-case (zero-pressure) cache-adjusted switch cost of a shared
    // option: what the queue-reason flags and the suffix lower bound
    // price.  Never above any in-search, pressure-dependent cost, so
    // the bound stays admissible; with the cache off it is the cold
    // cost itself.
    let best_switch = |cand: &Candidate, cold: f64, slice: f64| -> f64 {
        if !cache_enabled {
            return cold;
        }
        plan_effect(
            &cand.stage_weight_bytes,
            alloc.cache_budget_bytes,
            0,
            alloc.prefetch,
            slice,
            quantum_s,
        )
        .effective_switch_s(cold)
    };

    // per-tenant queue-reason flags, pool-state-independent so they are
    // computed once up front: whether any shared option survives the
    // hard SLO gate, and whether any was refused by it
    let mut shared_open = vec![false; n];
    let mut shared_gated = vec![false; n];
    if alloc.allow_sharing {
        for i in 0..n {
            for (ci, cand) in cand_sets[i].iter().enumerate() {
                for &slice in slices.iter().filter(|&&s| s < 1.0) {
                    match admission_step(
                        weights[i],
                        cand.p99_s,
                        slos[i],
                        slice,
                        best_switch(cand, switch[i][ci], slice),
                        quantum_s,
                    ) {
                        Some(_) => shared_open[i] = true,
                        None => shared_gated[i] = true,
                    }
                }
            }
        }
    }

    // suffix lower bounds (sharing only: the exclusive-only auction keeps
    // PR 3's exact pruning, so whole-TPU plans are byte-identical)
    let mut lb = vec![0.0; n + 1];
    if alloc.allow_sharing {
        for i in (0..n).rev() {
            let mut cheapest = weights[i] * QUEUE_PENALTY_S;
            for (ci, cand) in cand_sets[i].iter().enumerate() {
                for &slice in &slices {
                    if let Some(step) = admission_step(
                        weights[i],
                        cand.p99_s,
                        slos[i],
                        slice,
                        best_switch(cand, switch[i][ci], slice),
                        quantum_s,
                    ) {
                        if step < cheapest {
                            cheapest = step;
                        }
                    }
                }
            }
            lb[i] = lb[i + 1] + cheapest;
        }
    }

    let mut search = Search {
        cands: &cand_sets,
        weights: &weights,
        slos: &slos,
        switch: &switch,
        slices: &slices,
        quantum_s,
        cache_budget: if cache_enabled { alloc.cache_budget_bytes } else { 0 },
        prefetch: alloc.prefetch,
        pool: DevicePool::new(
            alloc.total_tpus,
            alloc.max_residents,
            &dead,
            if cache_enabled { alloc.cache_budget_bytes } else { 0 },
        ),
        lb,
        best_cost: f64::INFINITY,
        best_choice: vec![None; n],
        current: vec![None; n],
    };
    search.run(0, 0.0);

    // replay the winning choices through a fresh pool: place() is a
    // deterministic function of the pool state, so the replayed device
    // picks are exactly the search's
    let mut pool = DevicePool::new(
        alloc.total_tpus,
        alloc.max_residents,
        &dead,
        if cache_enabled { alloc.cache_budget_bytes } else { 0 },
    );
    let mut assignments = Vec::new();
    let mut queued = Vec::new();
    for (i, (t, cands)) in searchable.iter().enumerate() {
        let Some((ci, si)) = search.best_choice[i] else {
            let min_k = cands.iter().map(|c| c.tpu_count).min().unwrap_or(0);
            let reason = if !alloc.allow_sharing {
                format!(
                    "needs {} TPU(s) but the pool auction left none \
                     ({pool_desc})",
                    min_k
                )
            } else if shared_gated[i] && !shared_open[i] {
                // sharing genuinely cannot help this tenant: every
                // fractional option's swap overhead breaches its SLO
                format!(
                    "needs {} TPU(s); every shared slice's swap overhead \
                     breaches the SLO",
                    min_k
                )
            } else {
                format!(
                    "needs {} TPU(s) but no device kept enough residual slice \
                     capacity ({pool_desc}, max {} residents)",
                    min_k, alloc.max_residents
                )
            };
            queued.push(Rejection { name: t.name.clone(), reason });
            continue;
        };
        let cand = cands[ci].clone();
        let slice = slices[si];
        let fractional = slice < 1.0 - SLICE_EPS;
        let stage_bytes: &[u64] = if fractional && cache_enabled {
            &cand.stage_weight_bytes
        } else {
            &[]
        };
        let (devices, _) = pool
            .place(cand.tpu_count, slice, stage_bytes)
            .expect("search placement must replay");
        let (grant, effective_p99_s) = if !fractional {
            (DeviceGrant::Exclusive, cand.p99_s)
        } else {
            let sw = switch[i][ci];
            (
                DeviceGrant::Shared {
                    slice,
                    switch_s: sw,
                    quantum_s,
                    residents: Vec::new(), // filled below, once all are placed
                    cache: None,           // packing pass fills it below
                },
                shared_eff_p99(cand.p99_s, slice, sw, quantum_s),
            )
        };
        assignments.push(Assignment {
            name: t.name.clone(),
            weight: t.weight,
            slo_p99_s: t.slo_p99_s,
            candidate: cand,
            replicas: 1,
            grant,
            devices,
            effective_p99_s,
        });
    }

    if alloc.replicate_leftover {
        grant_replicas(registry, cfg, alloc, &mut assignments, &mut pool);
    }

    // fill the per-device co-residency maps now that every placement
    // (including replica extensions) is known
    let maps: Vec<_> = assignments
        .iter()
        .map(|a| {
            if !a.grant.is_shared() {
                return None;
            }
            Some(
                a.devices
                    .iter()
                    .map(|&d| {
                        let mut names: Vec<String> = assignments
                            .iter()
                            .filter(|b| b.devices.contains(&d))
                            .map(|b| b.name.clone())
                            .collect();
                        names.sort();
                        (d, names)
                    })
                    .collect(),
            )
        })
        .collect();
    for (a, map) in assignments.iter_mut().zip(maps) {
        if let (DeviceGrant::Shared { residents, .. }, Some(map)) = (&mut a.grant, map) {
            *residents = map;
        }
    }

    // cache-aware packing pass: pin co-resident stages (smallest first,
    // ties by tenant name then stage index) into each device's staging
    // cache and attach the resulting warm/prefetch effect to every
    // shared grant, so the deployed effective p99 prices the *residual*
    // switch cost instead of the full cold one.  `switch_s` on the
    // grant stays the cold cost; consumers scale it by the effect.
    if cache_enabled {
        let mut pinned: std::collections::BTreeSet<(String, usize)> =
            std::collections::BTreeSet::new();
        let mut shared_devices: Vec<usize> = assignments
            .iter()
            .filter(|a| a.grant.is_shared())
            .flat_map(|a| a.devices.iter().copied())
            .collect();
        shared_devices.sort_unstable();
        shared_devices.dedup();
        for d in shared_devices {
            let mut entries: Vec<(u64, &str, usize)> = assignments
                .iter()
                .filter(|a| a.grant.is_shared())
                .filter_map(|a| {
                    a.devices.iter().position(|&dev| dev == d).map(|stage| {
                        (a.candidate.stage_weight_bytes[stage], a.name.as_str(), stage)
                    })
                })
                .collect();
            entries.sort();
            let mut cache = ParamCache::new(alloc.cache_budget_bytes);
            for (bytes, name, stage) in entries {
                if cache.pin(name, stage, bytes) {
                    pinned.insert((name.to_string(), stage));
                }
            }
        }
        for a in &mut assignments {
            let DeviceGrant::Shared { slice, switch_s, cache, .. } = &mut a.grant
            else {
                continue;
            };
            let total: u64 = a.candidate.stage_weight_bytes.iter().sum();
            let mut warm = 0u64;
            for (stage, &bytes) in a.candidate.stage_weight_bytes.iter().enumerate() {
                if pinned.contains(&(a.name.clone(), stage)) {
                    warm += bytes;
                }
            }
            let warm_frac = if total == 0 { 1.0 } else { warm as f64 / total as f64 };
            let prefetch_s =
                if alloc.prefetch { (1.0 - *slice) * quantum_s } else { 0.0 };
            let effect = CacheEffect { warm_frac, prefetch_s };
            *cache = Some(effect);
            a.effective_p99_s = shared_eff_p99(
                a.candidate.p99_s,
                *slice,
                effect.effective_switch_s(*switch_s),
                quantum_s,
            );
        }
    }

    // the reported objective reflects what will actually be deployed,
    // including the p99 improvement from replica grants, the swap
    // inflation of shared grants and the cache's warm-swap discount
    let objective_s =
        assignments.iter().map(|a| a.weight * a.effective_p99_s).sum();
    Ok(PoolPlan {
        total_tpus: alloc.total_tpus,
        assignments,
        queued,
        rejected,
        objective_s,
        sharing_enabled: alloc.allow_sharing,
        cache_enabled,
    })
}

/// Greedily hand leftover whole TPUs out as pipeline replicas: each round,
/// the admitted *exclusive* tenant with the largest weighted effective p99
/// whose pipeline still fits the remainder gets one more copy.  Replicas
/// split the batch, so the effective p99 is re-simulated on
/// `ceil(batch / r)` items per copy.  Shared tenants never replicate (a
/// copy would need a whole extra device set, defeating the slice).
fn grant_replicas(
    registry: &ModelRegistry,
    cfg: &SystemConfig,
    alloc: &AllocatorConfig,
    assignments: &mut [Assignment],
    pool: &mut DevicePool,
) {
    let mut leftover = pool.free_count();
    loop {
        let Some(best) = assignments
            .iter()
            .enumerate()
            .filter(|(_, a)| !a.grant.is_shared() && a.candidate.tpu_count <= leftover)
            .max_by(|a, b| {
                let wa = a.1.weight * a.1.effective_p99_s;
                let wb = b.1.weight * b.1.effective_p99_s;
                wa.partial_cmp(&wb).unwrap()
            })
            .map(|(i, _)| i)
        else {
            return;
        };
        let a = &mut assignments[best];
        let (extra, _) = pool
            .place(a.candidate.tpu_count, 1.0, &[])
            .expect("free-device count checked by the filter above");
        leftover -= a.candidate.tpu_count;
        a.devices.extend(extra);
        a.devices.sort_unstable();
        a.replicas += 1;
        // re-predict: each replica serves batch/replicas items
        let Ok(tenant) = registry.get(&a.name) else { return };
        let shard = ((alloc.batch + a.replicas - 1) / a.replicas).max(1);
        let re = evaluate(
            &tenant.model,
            a.candidate.tpu_count,
            a.candidate.strategy,
            a.candidate.partition.clone(),
            cfg,
            shard,
        );
        // the re-simulated prediction carries the tenant's calibration
        // scale, like the candidates did (x * 1.0 is exact, so the
        // uncalibrated path stays bit-identical)
        a.effective_p99_s = re.p99_s * tenant.cost_scale;
        if leftover == 0 {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::{conv_model, fc_model, hetero_fc_model};
    use crate::scheduler::registry::Tenant;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn registry(names: &[&str]) -> ModelRegistry {
        let mut r = ModelRegistry::new();
        for n in names {
            r.register_named(n).unwrap();
        }
        r
    }

    /// The search-internal cost of a plan: weighted effective p99 over
    /// admitted tenants plus the queue penalty for every queued one —
    /// the quantity the branch-and-bound minimizes.
    fn plan_search_cost(plan: &PoolPlan, reg: &ModelRegistry) -> f64 {
        let admitted: f64 =
            plan.assignments.iter().map(|a| a.weight * a.effective_p99_s).sum();
        let queued: f64 = plan
            .queued
            .iter()
            .map(|q| reg.get(&q.name).unwrap().weight * QUEUE_PENALTY_S)
            .sum();
        admitted + queued
    }

    #[test]
    fn candidates_respect_memory_admission() {
        let alloc = AllocatorConfig::default();
        // fc_big spills on one TPU -> no k=1 candidate, but k>=2 exists
        let cands = candidates_for(&fc_model(1980), &cfg(), &alloc);
        assert!(!cands.is_empty());
        assert!(cands.iter().all(|c| !c.uses_host));
        assert!(cands.iter().all(|c| c.tpu_count >= 2), "{cands:?}");
        // fc_small fits whole
        let cands = candidates_for(&fc_model(512), &cfg(), &alloc);
        assert!(cands.iter().any(|c| c.tpu_count == 1));
        // spill admission turns the k=1 fc_big candidate back on
        let spilling = AllocatorConfig { allow_host_spill: true, ..alloc };
        let cands = candidates_for(&fc_model(1980), &cfg(), &spilling);
        assert!(cands.iter().any(|c| c.tpu_count == 1 && c.uses_host));
    }

    #[test]
    fn acceptance_pool_admits_all_three() {
        // the ISSUE's acceptance scenario: fc_big needs 2 TPUs, each conv
        // fits on 1 -> exactly a 4-TPU pool
        let reg = registry(&["fc_big", "conv_a", "conv_b"]);
        let plan =
            allocate(&reg, &cfg(), &AllocatorConfig::default()).unwrap();
        assert_eq!(plan.assignments.len(), 3, "queued={:?}", plan.queued);
        assert!(plan.queued.is_empty());
        assert!(plan.rejected.is_empty());
        assert_eq!(plan.tpus_used(), 4);
        let fc = plan.assignment("fc_big").unwrap();
        assert_eq!(fc.candidate.tpu_count, 2);
        assert!(!fc.candidate.uses_host);
        for name in ["conv_a", "conv_b"] {
            assert_eq!(plan.assignment(name).unwrap().candidate.tpu_count, 1);
        }
    }

    #[test]
    fn exclusive_devices_are_concrete_and_disjoint() {
        let reg = registry(&["fc_big", "conv_a", "conv_b"]);
        let plan =
            allocate(&reg, &cfg(), &AllocatorConfig::default()).unwrap();
        let mut all: Vec<usize> = Vec::new();
        for a in &plan.assignments {
            assert_eq!(a.grant, DeviceGrant::Exclusive);
            assert_eq!(a.devices.len(), a.candidate.tpu_count * a.replicas, "{a:?}");
            assert!(a.devices.windows(2).all(|w| w[0] < w[1]), "sorted: {a:?}");
            all.extend(&a.devices);
        }
        all.sort_unstable();
        let before = all.len();
        all.dedup();
        assert_eq!(all.len(), before, "exclusive grants must not overlap");
        assert!(all.iter().all(|&d| d < plan.total_tpus));
    }

    #[test]
    fn oversubscribed_pool_queues_lowest_weight() {
        // fc_huge needs 3 TPUs, conv_big needs 4 -> a 4-TPU pool can only
        // hold one of them; the heavier tenant wins
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("conv_big", conv_model(592)).with_weight(5.0)).unwrap();
        reg.register(Tenant::new("fc_huge", fc_model(2580)).with_weight(1.0)).unwrap();
        let plan =
            allocate(&reg, &cfg(), &AllocatorConfig::default()).unwrap();
        assert_eq!(plan.assignments.len(), 1);
        assert_eq!(plan.assignments[0].name, "conv_big");
        assert_eq!(plan.queued.len(), 1);
        assert_eq!(plan.queued[0].name, "fc_huge");
        assert!(plan.queued[0].reason.contains("TPU"), "{}", plan.queued[0].reason);
    }

    #[test]
    fn impossible_model_is_rejected_with_reason() {
        // a single 3000-wide dense layer exceeds on-chip memory alone, so
        // NO partition can avoid host streaming
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("fc_n3000", fc_model(3000))).unwrap();
        reg.register_named("fc_small").unwrap();
        let plan =
            allocate(&reg, &cfg(), &AllocatorConfig::default()).unwrap();
        assert_eq!(plan.rejected.len(), 1);
        assert_eq!(plan.rejected[0].name, "fc_n3000");
        assert!(plan.rejected[0].reason.contains("on-chip"), "{}", plan.rejected[0].reason);
        assert_eq!(plan.assignments.len(), 1);
    }

    #[test]
    fn leftover_tpus_become_replicas() {
        let reg = registry(&["fc_small"]);
        let alloc = AllocatorConfig { total_tpus: 3, ..Default::default() };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        let a = plan.assignment("fc_small").unwrap();
        // fc_small fits one TPU; 3-TPU pool -> up to 3 replicas (the
        // allocator may also pick a deeper pipeline if it predicts faster)
        assert_eq!(plan.tpus_used(), 3, "replicas should soak the pool: {a:?}");
        assert!(a.replicas >= 1);
        assert_eq!(a.devices.len(), a.candidate.tpu_count * a.replicas);
        assert!(a.effective_p99_s <= a.candidate.p99_s + 1e-12);
    }

    #[test]
    fn replication_disabled_leaves_tpus_idle() {
        let reg = registry(&["fc_small"]);
        let alloc = AllocatorConfig {
            total_tpus: 4,
            replicate_leftover: false,
            ..Default::default()
        };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        assert_eq!(plan.assignment("fc_small").unwrap().replicas, 1);
    }

    #[test]
    fn weighted_objective_prefers_heavy_tenant() {
        // two tenants contending for the pool: the heavier one must never
        // end up queued while the lighter is admitted
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("heavy", fc_model(2580)).with_weight(10.0)).unwrap();
        reg.register(Tenant::new("light", fc_model(2580)).with_weight(1.0)).unwrap();
        let alloc = AllocatorConfig { total_tpus: 3, ..Default::default() };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        assert_eq!(plan.assignments.len(), 1);
        assert_eq!(plan.assignments[0].name, "heavy");
        assert_eq!(plan.queued[0].name, "light");
    }

    #[test]
    fn slo_penalty_steers_admission() {
        // equal-weight tie for one 3-TPU slot: without SLOs the search
        // keeps the first solution it finds (alphabetical tenant wins);
        // an unmeetable SLO on that tenant must flip the auction
        let mk = |with_slo: bool| {
            let mut reg = ModelRegistry::new();
            let mut alpha = Tenant::new("alpha", fc_model(2580));
            if with_slo {
                alpha = alpha.with_slo_p99_s(1e-9);
            }
            reg.register(alpha).unwrap();
            reg.register(Tenant::new("beta", fc_model(2580))).unwrap();
            let alloc = AllocatorConfig { total_tpus: 3, ..Default::default() };
            allocate(&reg, &cfg(), &alloc).unwrap()
        };
        let without = mk(false);
        assert_eq!(without.assignments[0].name, "alpha", "tie-break baseline");
        let with = mk(true);
        assert_eq!(with.assignments.len(), 1);
        assert_eq!(with.assignments[0].name, "beta", "SLO-meeting tenant must win");
        assert_eq!(with.queued[0].name, "alpha");
    }

    #[test]
    fn objective_matches_deployed_effective_p99() {
        let reg = registry(&["fc_small", "conv_a"]);
        let alloc = AllocatorConfig { total_tpus: 4, ..Default::default() };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        let want: f64 =
            plan.assignments.iter().map(|a| a.weight * a.effective_p99_s).sum();
        assert!((plan.objective_s - want).abs() < 1e-12, "{} vs {want}", plan.objective_s);
    }

    #[test]
    fn slo_violation_is_flagged() {
        let mut reg = ModelRegistry::new();
        reg.register(
            Tenant::new("strict", fc_model(512)).with_slo_p99_s(1e-9),
        )
        .unwrap();
        let plan =
            allocate(&reg, &cfg(), &AllocatorConfig::default()).unwrap();
        assert!(plan.assignments[0].slo_violated());
    }

    #[test]
    fn candidates_carry_a_positive_switch_cost() {
        let cands = candidates_for(&fc_model(512), &cfg(), &AllocatorConfig::default());
        assert!(cands.iter().all(|c| c.switch_s > 0.0), "{cands:?}");
        // the re-load crosses the slow host link, so it dwarfs the
        // on-chip per-inference time (the whole point of co-residency
        // being a *cost*, not free)
        assert!(cands[0].switch_s > cands[0].per_item_s, "{cands:?}");
    }

    #[test]
    fn sharing_off_plans_are_whole_tpu_and_deterministic() {
        let reg = registry(&["fc_big", "conv_a", "conv_b"]);
        let alloc = AllocatorConfig { quantum_us: 50_000.0, ..Default::default() };
        let a = allocate(&reg, &cfg(), &alloc).unwrap();
        // with sharing off the quantum knob must be inert and every grant
        // exclusive (the PR 3 byte-compat invariant)
        let b = allocate(&reg, &cfg(), &AllocatorConfig::default()).unwrap();
        assert!(a.assignments.iter().all(|x| x.grant == DeviceGrant::Exclusive));
        assert_eq!(a.assignments.len(), b.assignments.len());
        for (x, y) in a.assignments.iter().zip(&b.assignments) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.devices, y.devices);
            assert_eq!(x.replicas, y.replicas);
            assert_eq!(x.candidate.partition, y.candidate.partition);
            assert!((x.effective_p99_s - y.effective_p99_s).abs() < 1e-15);
        }
        assert_eq!(a.objective_s, b.objective_s);
    }

    #[test]
    fn sharing_admits_queued_tenant_with_swap_overhead() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("heavy", fc_model(2580)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("light", fc_model(2580)).with_weight(1.0)).unwrap();
        let alloc = AllocatorConfig { total_tpus: 3, ..Default::default() };
        let without = allocate(&reg, &cfg(), &alloc).unwrap();
        assert_eq!(without.queued.len(), 1, "whole-TPU allocator must queue one");
        assert!(!without.sharing_enabled);

        let sharing = AllocatorConfig { allow_sharing: true, ..alloc };
        let plan = allocate(&reg, &cfg(), &sharing).unwrap();
        assert!(plan.sharing_enabled);
        assert!(plan.queued.is_empty(), "{:?}", plan.queued);
        assert_eq!(plan.assignments.len(), 2);
        assert_eq!(plan.tpus_used(), 3, "co-residents occupy no extra TPUs");
        assert_eq!(plan.shared_count(), 2);
        let light = plan.assignment("light").unwrap();
        assert!(light.grant.is_shared());
        assert!(light.swap_overhead_s() > 0.0, "p99 must include swap overhead");
        assert!(light.effective_p99_s > light.candidate.p99_s);
        let heavy = plan.assignment("heavy").unwrap();
        assert!(heavy.grant.is_shared(), "both co-residents hold slices");
        assert!((heavy.grant.slice() - 0.5).abs() < 1e-12);
        assert!(heavy.swap_overhead_s() > 0.0);
        // same depth here, so the device sets coincide exactly
        assert_eq!(heavy.devices, light.devices);
        // the per-device residency map names both tenants on every device
        if let DeviceGrant::Shared { residents, .. } = &heavy.grant {
            assert_eq!(residents.len(), 3);
            for (_, names) in residents {
                assert_eq!(names, &["heavy".to_string(), "light".to_string()]);
            }
        } else {
            panic!("heavy must be shared");
        }
        // objective reflects the inflated p99s
        let want: f64 =
            plan.assignments.iter().map(|a| a.weight * a.effective_p99_s).sum();
        assert!((plan.objective_s - want).abs() < 1e-12);
    }

    #[test]
    fn two_tenants_saturate_one_tpu() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("a", fc_model(512))).unwrap();
        reg.register(Tenant::new("b", fc_model(512))).unwrap();
        let alloc = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            ..Default::default()
        };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        assert_eq!(plan.assignments.len(), 2, "queued={:?}", plan.queued);
        assert_eq!(plan.tpus_used(), 1, "both must fit the single TPU");
        assert_eq!(plan.shared_count(), 2);
        for a in &plan.assignments {
            assert_eq!(a.candidate.tpu_count, 1);
            assert_eq!(a.devices, vec![0]);
            assert!((a.grant.slice() - 0.5).abs() < 1e-12);
            assert!(a.grant.switch_s() > 0.0);
        }
        // max_residents caps the per-device co-residency: a third tenant
        // stays queued
        let mut reg3 = reg.clone();
        reg3.register(Tenant::new("c", fc_model(512))).unwrap();
        let plan3 = allocate(&reg3, &cfg(), &alloc).unwrap();
        assert_eq!(plan3.assignments.len(), 2);
        assert_eq!(plan3.queued.len(), 1);
        assert!(plan3.queued[0].reason.contains("slice"), "{}", plan3.queued[0].reason);
        // ...unless the cap is raised: then 1/3 slices fit all three
        let wide = AllocatorConfig { max_residents: 3, ..alloc };
        let plan3 = allocate(&reg3, &cfg(), &wide).unwrap();
        assert_eq!(plan3.assignments.len(), 3, "queued={:?}", plan3.queued);
        assert!(plan3
            .assignments
            .iter()
            .all(|a| (a.grant.slice() - 1.0 / 3.0).abs() < 1e-12));
    }

    #[test]
    fn shared_grant_breaching_slo_stays_queued() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("host", fc_model(512)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("strict", fc_model(512)).with_slo_p99_s(1e-9)).unwrap();
        let alloc = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            ..Default::default()
        };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        assert_eq!(plan.assignments.len(), 1);
        assert_eq!(plan.queued.len(), 1, "the SLO-breaching rider must stay queued");
        assert_eq!(plan.queued[0].name, "strict");
        assert!(plan.queued[0].reason.contains("SLO"), "{}", plan.queued[0].reason);
        assert_eq!(plan.assignment("host").unwrap().grant, DeviceGrant::Exclusive);
    }

    #[test]
    fn sharing_never_breaks_a_hosts_met_slo() {
        // learn the exclusive p99, then pin the host's SLO between the
        // exclusive and the time-shared prediction: the hard SLO gate
        // refuses the host's fractional options, so the rider finds no
        // residual capacity and stays queued — a met SLO survives
        let mut probe = ModelRegistry::new();
        probe.register(Tenant::new("host", fc_model(512)).with_weight(2.0)).unwrap();
        let alloc = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            ..Default::default()
        };
        let p99 = allocate(&probe, &cfg(), &alloc)
            .unwrap()
            .assignment("host")
            .unwrap()
            .candidate
            .p99_s;
        let mut reg = ModelRegistry::new();
        reg.register(
            Tenant::new("host", fc_model(512)).with_weight(2.0).with_slo_p99_s(p99 * 1.5),
        )
        .unwrap();
        reg.register(Tenant::new("rider", fc_model(512))).unwrap();
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        let host = plan.assignment("host").unwrap();
        assert_eq!(host.grant, DeviceGrant::Exclusive, "met SLO must survive");
        assert!(!host.slo_violated());
        assert_eq!(plan.queued.len(), 1);
        assert_eq!(plan.queued[0].name, "rider");
        assert!(plan.queued[0].reason.contains("slice"), "{}", plan.queued[0].reason);
    }

    #[test]
    fn switch_cost_override_applies() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("a", fc_model(512)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("b", fc_model(512))).unwrap();
        let alloc = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            switch_cost_us: Some(1234.0),
            ..Default::default()
        };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        let rider = plan.assignment("b").unwrap();
        assert!((rider.grant.switch_s() - 1234e-6).abs() < 1e-12);
        let want = rider.candidate.p99_s * 2.0 + 1234e-6;
        assert!((rider.effective_p99_s - want).abs() < 1e-9);
        // negative override is rejected
        let bad = AllocatorConfig { switch_cost_us: Some(-1.0), ..alloc };
        assert!(allocate(&reg, &cfg(), &bad).is_err());
    }

    /// A 2-layer dense chain that spills on one TPU but fits on two: its
    /// ONLY admissible depth is 2, so PR 3's same-depth greedy pass could
    /// never co-locate it with a depth-3 host.
    fn duo_model() -> Model {
        hetero_fc_model("duo", &[2100, 2100, 2100])
    }

    #[test]
    fn different_depth_tenants_co_reside_on_overlapping_devices() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("big", fc_model(2580)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("duo", duo_model())).unwrap();
        let alloc = AllocatorConfig {
            total_tpus: 3,
            allow_sharing: true,
            ..Default::default()
        };

        // fixture sanity: big only fits at depth 3, duo only at depth 2 —
        // the retired greedy pass required rider depth == host depth, so
        // it could never have placed duo
        let big_cands = candidates_for(&fc_model(2580), &cfg(), &alloc);
        assert!(big_cands.iter().all(|c| c.tpu_count == 3), "{big_cands:?}");
        let duo_cands = candidates_for(&duo_model(), &cfg(), &alloc);
        assert!(duo_cands.iter().all(|c| c.tpu_count == 2), "{duo_cands:?}");

        // whole-TPU auction: big takes all three devices, duo queues
        let whole = AllocatorConfig { allow_sharing: false, ..alloc.clone() };
        let excl = allocate(&reg, &cfg(), &whole).unwrap();
        assert_eq!(excl.assignments.len(), 1);
        assert_eq!(excl.queued[0].name, "duo");

        // unified search: both admitted, depths 3 and 2, duo's devices a
        // strict subset of big's — per-device slices at work
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        assert!(plan.queued.is_empty(), "{:?}", plan.queued);
        let big = plan.assignment("big").unwrap();
        let duo = plan.assignment("duo").unwrap();
        assert_eq!(big.candidate.tpu_count, 3);
        assert_eq!(duo.candidate.tpu_count, 2);
        assert!(big.grant.is_shared() && duo.grant.is_shared());
        assert_eq!(plan.tpus_used(), 3, "no extra devices consumed");
        assert!(duo.devices.iter().all(|d| big.devices.contains(d)));
        assert!(duo.devices.len() < big.devices.len());
        // the overlap devices carry both names, the private one only big's
        if let DeviceGrant::Shared { residents, .. } = &big.grant {
            let shared_devs: usize =
                residents.iter().filter(|(_, names)| names.len() == 2).count();
            assert_eq!(shared_devs, 2, "{residents:?}");
        } else {
            panic!("big must be shared");
        }
        // admission superset of the greedy pass at equal-or-lower cost
        let unified = plan_search_cost(&plan, &reg);
        let greedy = plan_search_cost(&excl, &reg); // greedy == exclusive here
        assert!(unified < greedy, "unified {unified} must beat greedy {greedy}");
    }

    #[test]
    fn unified_search_never_loses_to_the_greedy_pass() {
        // on the PR 3 sharing fixtures the greedy pass produced a known
        // configuration; the unified search must reach a search cost at
        // most that configuration's, with a superset of admissions
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("heavy", fc_model(2580)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("light", fc_model(2580)).with_weight(1.0)).unwrap();
        let alloc = AllocatorConfig {
            total_tpus: 3,
            allow_sharing: true,
            ..Default::default()
        };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        // the greedy configuration: both at 1/2 slices on the same 3-TPU
        // set, eff = 2*p99 + switch each (PR 3's shared_p99 formula)
        let cands = candidates_for(&fc_model(2580), &cfg(), &alloc);
        let best = &cands[0];
        let greedy_cost =
            2.0 * (2.0 * best.p99_s + best.switch_s) + 1.0 * (2.0 * best.p99_s + best.switch_s);
        let unified_cost = plan_search_cost(&plan, &reg);
        assert!(
            unified_cost <= greedy_cost + 1e-9,
            "unified {unified_cost} vs greedy {greedy_cost}"
        );
        // superset of the greedy admissions (greedy admitted both)
        for name in ["heavy", "light"] {
            let a = plan.assignment(name).unwrap();
            // equal-or-lower per-tenant predicted p99 than the greedy grant
            assert!(
                a.effective_p99_s <= 2.0 * best.p99_s + best.switch_s + 1e-9,
                "{name}: {a:?}"
            );
        }
    }

    #[test]
    fn quantum_knob_prices_the_wait_into_shared_p99() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("a", fc_model(512)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("b", fc_model(512))).unwrap();
        let base = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            ..Default::default()
        };
        let mut prev = 0.0;
        for quantum_us in [0.0, 1_000.0, 100_000.0] {
            let alloc = AllocatorConfig { quantum_us, ..base.clone() };
            let plan = allocate(&reg, &cfg(), &alloc).unwrap();
            let b = plan.assignment("b").unwrap();
            assert!(b.grant.is_shared());
            assert!((b.grant.quantum_s() - quantum_us * 1e-6).abs() < 1e-12);
            // eff = 2*p99 + switch + (1 - 1/2) * quantum
            let want = 2.0 * b.candidate.p99_s
                + b.grant.switch_s()
                + 0.5 * quantum_us * 1e-6;
            assert!((b.effective_p99_s - want).abs() < 1e-9, "{b:?}");
            assert!(
                b.effective_p99_s >= prev,
                "a longer quantum must not lower predicted p99"
            );
            prev = b.effective_p99_s;
        }
        // negative quantum is rejected
        let bad = AllocatorConfig { quantum_us: -1.0, ..base };
        assert!(allocate(&reg, &cfg(), &bad).is_err());
    }

    #[test]
    fn same_deployment_ignores_device_renumbering_only() {
        let shared = |devs: &[usize], names: &[&str], slice: f64| DeviceGrant::Shared {
            slice,
            switch_s: 1e-3,
            quantum_s: 0.0,
            residents: devs
                .iter()
                .map(|&d| (d, names.iter().map(|n| n.to_string()).collect()))
                .collect(),
            cache: None,
        };
        let a = shared(&[0, 1], &["a", "b"], 0.5);
        // same group on different device ids: same deployment, not ==
        let b = shared(&[2, 3], &["a", "b"], 0.5);
        assert!(a.same_deployment(&b));
        assert_ne!(a, b);
        // membership, slice or kind changes are real changes
        assert!(!a.same_deployment(&shared(&[0, 1], &["a", "c"], 0.5)));
        assert!(!a.same_deployment(&shared(&[0, 1], &["a", "b"], 1.0 / 3.0)));
        assert!(!a.same_deployment(&DeviceGrant::Exclusive));
        assert!(DeviceGrant::Exclusive.same_deployment(&DeviceGrant::Exclusive));
        // a changed cache effect is a real deployment change too (the
        // worker's swap charging depends on it)
        let mut warmed = shared(&[0, 1], &["a", "b"], 0.5);
        if let DeviceGrant::Shared { cache, .. } = &mut warmed {
            *cache = Some(CacheEffect { warm_frac: 1.0, prefetch_s: 0.0 });
        }
        assert!(!a.same_deployment(&warmed));
    }

    #[test]
    fn cache_budget_zero_keeps_flat_cost_plans_identical() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("a", fc_model(512)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("b", fc_model(512))).unwrap();
        let base = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            ..Default::default()
        };
        let flat = allocate(&reg, &cfg(), &base).unwrap();
        let zeroed = AllocatorConfig {
            cache_budget_bytes: 0,
            prefetch: false,
            ..base.clone()
        };
        let plan = allocate(&reg, &cfg(), &zeroed).unwrap();
        assert!(!plan.cache_enabled);
        assert_eq!(flat.assignments.len(), plan.assignments.len());
        for (x, y) in flat.assignments.iter().zip(&plan.assignments) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.devices, y.devices);
            assert_eq!(x.grant, y.grant);
            assert_eq!(x.grant.cache(), None, "budget 0 must never attach an effect");
            assert_eq!(x.effective_p99_s, y.effective_p99_s);
        }
        assert_eq!(flat.objective_s, plan.objective_s);
    }

    #[test]
    fn cache_budget_warms_co_residents_and_lowers_planned_p99() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("a", fc_model(512)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("b", fc_model(512))).unwrap();
        let base = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            ..Default::default()
        };
        let flat = allocate(&reg, &cfg(), &base).unwrap();
        let cached =
            AllocatorConfig { cache_budget_bytes: 1 << 30, ..base.clone() };
        let plan = allocate(&reg, &cfg(), &cached).unwrap();
        assert!(plan.cache_enabled);
        assert_eq!(plan.assignments.len(), 2, "queued={:?}", plan.queued);
        for a in &plan.assignments {
            let eff = a.grant.cache().expect("shared grants carry a cache effect");
            assert_eq!(eff.warm_frac, 1.0, "a 1 GiB budget pins both: {a:?}");
            // fully warm => the planned p99 is pure slice dilation
            assert!((a.effective_p99_s - 2.0 * a.candidate.p99_s).abs() < 1e-9);
            let was = flat.assignment(&a.name).unwrap().effective_p99_s;
            assert!(a.effective_p99_s < was, "warm swaps must beat cold: {a:?}");
            // the grant still records the cold cost (first swaps pay it)
            assert!(a.grant.switch_s() > 0.0);
        }
        assert!(plan.objective_s < flat.objective_s);
    }

    #[test]
    fn partial_budget_pins_smallest_entries_name_tie_broken() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("a", fc_model(512)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("b", fc_model(512))).unwrap();
        let probe = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            cache_budget_bytes: 1 << 30,
            ..Default::default()
        };
        let warm = allocate(&reg, &cfg(), &probe).unwrap();
        let bytes: u64 = warm
            .assignment("a")
            .unwrap()
            .candidate
            .stage_weight_bytes
            .iter()
            .sum();
        assert!(bytes > 0);
        // a budget that fits exactly one resident: equal sizes tie-break
        // by name, so "a" pins warm and "b" stays cold
        let alloc = AllocatorConfig { cache_budget_bytes: bytes, ..probe };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        let a = plan.assignment("a").unwrap().grant.cache().unwrap();
        let b = plan.assignment("b").unwrap().grant.cache().unwrap();
        assert_eq!(a.warm_frac, 1.0, "{plan:?}");
        assert_eq!(b.warm_frac, 0.0, "{plan:?}");
    }

    #[test]
    fn prefetch_hides_residual_cost_only_with_a_quantum_window() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("a", fc_model(512)).with_weight(2.0)).unwrap();
        reg.register(Tenant::new("b", fc_model(512))).unwrap();
        let probe = AllocatorConfig {
            total_tpus: 1,
            allow_sharing: true,
            cache_budget_bytes: 1 << 30,
            ..Default::default()
        };
        let bytes: u64 = allocate(&reg, &cfg(), &probe)
            .unwrap()
            .assignment("a")
            .unwrap()
            .candidate
            .stage_weight_bytes
            .iter()
            .sum();
        // budget fits one resident => "b" keeps a cold remainder
        let no_window = AllocatorConfig {
            cache_budget_bytes: bytes,
            prefetch: true,
            quantum_us: 0.0,
            ..probe.clone()
        };
        let plan = allocate(&reg, &cfg(), &no_window).unwrap();
        let cold = plan.assignment("b").unwrap();
        assert_eq!(
            cold.grant.cache().unwrap().prefetch_s,
            0.0,
            "zero quantum leaves no window to prefetch in"
        );
        // a long quantum gives the prefetch a window that swallows the
        // cold remainder entirely
        let windowed =
            AllocatorConfig { quantum_us: 1_000_000.0, ..no_window.clone() };
        let plan_w = allocate(&reg, &cfg(), &windowed).unwrap();
        let b = plan_w.assignment("b").unwrap();
        let eff = b.grant.cache().unwrap();
        assert!(eff.prefetch_s > 0.0);
        assert_eq!(eff.effective_switch_s(b.grant.switch_s()), 0.0, "{eff:?}");
    }

    #[test]
    fn nan_switch_cost_is_rejected_with_a_clear_error() {
        let reg = registry(&["fc_small"]);
        let bad = AllocatorConfig {
            switch_cost_us: Some(f64::NAN),
            ..Default::default()
        };
        let err = allocate(&reg, &cfg(), &bad).unwrap_err();
        assert!(err.to_string().contains("finite"), "{err}");
        let neg = AllocatorConfig {
            switch_cost_us: Some(-5.0),
            ..Default::default()
        };
        let err = allocate(&reg, &cfg(), &neg).unwrap_err();
        assert!(err.to_string().contains("non-negative"), "{err}");
    }

    #[test]
    fn dead_devices_are_never_granted() {
        let reg = registry(&["fc_big", "conv_a", "conv_b"]);
        // killing device 0 leaves 3 live devices: the 4-TPU plan no
        // longer fits, someone queues, and nobody lands on device 0
        let alloc = AllocatorConfig { dead_devices: vec![0], ..Default::default() };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        assert!(
            plan.assignments.iter().all(|a| !a.devices.contains(&0)),
            "dead device granted: {:?}",
            plan.assignments
        );
        let placed: usize = plan.assignments.iter().map(|a| a.devices.len()).sum();
        assert!(placed <= 3, "only 3 live devices exist");
        assert_eq!(plan.assignments.len() + plan.queued.len(), 3);
        assert!(!plan.queued.is_empty(), "3 live TPUs cannot hold the 4-TPU plan");
        assert!(plan.queued[0].reason.contains("dead"), "{}", plan.queued[0].reason);
    }

    #[test]
    fn dead_device_excluded_from_replica_grants() {
        let reg = registry(&["fc_small"]);
        let alloc = AllocatorConfig {
            total_tpus: 3,
            dead_devices: vec![1],
            ..Default::default()
        };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        let a = plan.assignment("fc_small").unwrap();
        assert!(!a.devices.contains(&1), "{a:?}");
        assert_eq!(plan.tpus_used(), 2, "replicas must soak only live devices: {a:?}");
    }

    #[test]
    fn dead_devices_never_host_shared_slices() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("a", fc_model(512))).unwrap();
        reg.register(Tenant::new("b", fc_model(512))).unwrap();
        let alloc = AllocatorConfig {
            total_tpus: 2,
            allow_sharing: true,
            dead_devices: vec![0],
            ..Default::default()
        };
        let plan = allocate(&reg, &cfg(), &alloc).unwrap();
        assert_eq!(plan.assignments.len(), 2, "queued={:?}", plan.queued);
        for a in &plan.assignments {
            assert_eq!(a.devices, vec![1], "only the live device may host: {a:?}");
        }
    }

    #[test]
    fn dead_device_validation_errors() {
        let reg = registry(&["fc_small"]);
        let oob = AllocatorConfig { dead_devices: vec![7], ..Default::default() };
        let err = allocate(&reg, &cfg(), &oob).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        let all_dead = AllocatorConfig {
            total_tpus: 1,
            dead_devices: vec![0],
            ..Default::default()
        };
        let err = allocate(&reg, &cfg(), &all_dead).unwrap_err();
        assert!(err.to_string().contains("dead"), "{err}");
    }

    #[test]
    fn empty_registry_is_an_error() {
        let reg = ModelRegistry::new();
        assert!(allocate(&reg, &cfg(), &AllocatorConfig::default()).is_err());
    }

    #[test]
    fn zero_batch_is_an_error_not_a_panic() {
        let reg = registry(&["fc_small"]);
        let alloc = AllocatorConfig { batch: 0, ..Default::default() };
        let err = allocate(&reg, &cfg(), &alloc).unwrap_err();
        assert!(err.to_string().contains("batch"), "{err}");
    }

    #[test]
    fn builder_matches_struct_literal_and_validates_eagerly() {
        let built = AllocatorConfig::builder()
            .total_tpus(3)
            .batch(25)
            .allow_sharing(true)
            .max_residents(3)
            .switch_cost_us(42.0)
            .quantum_us(500.0)
            .cache_budget_bytes(1 << 20)
            .prefetch(true)
            .build()
            .unwrap();
        assert_eq!(built.total_tpus, 3);
        assert_eq!(built.batch, 25);
        assert!(built.allow_sharing);
        assert_eq!(built.max_residents, 3);
        assert_eq!(built.switch_cost_us, Some(42.0));
        assert_eq!(built.quantum_us, 500.0);
        assert_eq!(built.cache_budget_bytes, 1 << 20);
        assert!(built.prefetch);
        // untouched knobs keep their defaults
        assert!(built.replicate_leftover);
        assert!(built.dead_devices.is_empty());
        // invalid combinations die at build(), with allocate()'s messages
        let err = AllocatorConfig::builder().total_tpus(0).build().unwrap_err();
        assert!(err.to_string().contains("at least one TPU"), "{err}");
        let err = AllocatorConfig::builder()
            .allow_sharing(true)
            .max_residents(1)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("max_residents"), "{err}");
        let err = AllocatorConfig::builder().quantum_us(f64::NAN).build().unwrap_err();
        assert!(err.to_string().contains("finite"), "{err}");
        let err = AllocatorConfig::builder().switch_cost_us(-1.0).build().unwrap_err();
        assert!(err.to_string().contains("non-negative"), "{err}");
    }

    #[test]
    fn validate_agrees_with_allocate_on_every_knob_error() {
        // validate() is the single source of truth allocate() defers to:
        // each invalid config must fail both, with the same message
        let reg = registry(&["fc_small"]);
        let bads = [
            AllocatorConfig { total_tpus: 0, ..Default::default() },
            AllocatorConfig { batch: 0, ..Default::default() },
            AllocatorConfig { allow_sharing: true, max_residents: 1, ..Default::default() },
            AllocatorConfig { quantum_us: -1.0, ..Default::default() },
            AllocatorConfig { quantum_us: f64::INFINITY, ..Default::default() },
            AllocatorConfig { switch_cost_us: Some(f64::NAN), ..Default::default() },
            AllocatorConfig { dead_devices: vec![9], ..Default::default() },
        ];
        for bad in bads {
            let v = bad.validate().unwrap_err().to_string();
            let a = allocate(&reg, &cfg(), &bad).unwrap_err().to_string();
            assert_eq!(v, a, "{bad:?}");
        }
        assert!(AllocatorConfig::default().validate().is_ok());
    }

    #[test]
    fn cost_scale_rewrites_predictions_and_default_is_inert() {
        let mut reg = ModelRegistry::new();
        reg.register(Tenant::new("fc_small", fc_model(512))).unwrap();
        let alloc = AllocatorConfig { total_tpus: 1, ..Default::default() };
        let base = allocate(&reg, &cfg(), &alloc).unwrap();
        // scale 1.0 (explicit) is bit-identical to the default path
        let mut reg1 = ModelRegistry::new();
        reg1.register(Tenant::new("fc_small", fc_model(512)).with_cost_scale(1.0)).unwrap();
        let same = allocate(&reg1, &cfg(), &alloc).unwrap();
        assert_eq!(
            base.assignment("fc_small").unwrap().effective_p99_s,
            same.assignment("fc_small").unwrap().effective_p99_s
        );
        assert_eq!(base.objective_s, same.objective_s);
        // a 2x observed/predicted ratio doubles the prediction
        let mut reg2 = ModelRegistry::new();
        reg2.register(Tenant::new("fc_small", fc_model(512)).with_cost_scale(2.0)).unwrap();
        let scaled = allocate(&reg2, &cfg(), &alloc).unwrap();
        let b = base.assignment("fc_small").unwrap();
        let s = scaled.assignment("fc_small").unwrap();
        assert!((s.candidate.p99_s - 2.0 * b.candidate.p99_s).abs() < 1e-12, "{s:?}");
        assert!((s.effective_p99_s - 2.0 * b.effective_p99_s).abs() < 1e-12, "{s:?}");
    }

    #[test]
    fn cost_scale_flips_a_weighted_auction() {
        // two equal-weight tenants tie for one 3-TPU slot; calibrating
        // alpha's cost model up makes it the more expensive admission, so
        // the auction flips to beta — the drift-triggered re-plan story
        let mk = |alpha_scale: f64| {
            let mut reg = ModelRegistry::new();
            reg.register(
                Tenant::new("alpha", fc_model(2580)).with_cost_scale(alpha_scale),
            )
            .unwrap();
            reg.register(Tenant::new("beta", fc_model(2580))).unwrap();
            let alloc = AllocatorConfig { total_tpus: 3, ..Default::default() };
            allocate(&reg, &cfg(), &alloc).unwrap()
        };
        assert_eq!(mk(1.0).assignments[0].name, "alpha", "tie-break baseline");
        let flipped = mk(3.0);
        assert_eq!(flipped.assignments[0].name, "beta");
        assert_eq!(flipped.queued[0].name, "alpha");
    }
}
