//! Per-model request router: one live deployment per admitted tenant.
//!
//! [`PoolRouter::deploy`] turns a [`PoolPlan`](super::allocator::PoolPlan)
//! into running [`Pipeline`]s — one per admitted model, or a
//! [`ReplicaRouter`] of full pipeline copies when the allocator granted
//! leftover-TPU replicas — and routes request batches by model name with
//! per-tenant metrics.  Every deployment of one router shares a single
//! buffer [`Arena`], so activation slabs retired by one tenant are
//! recycled by the next — pool-wide, the steady-state request path
//! allocates nothing.
//!
//! Two stage backends:
//!
//! * [`BackendKind::Pjrt`] — AOT-compiled HLO segments via the PJRT
//!   runtime (requires `make artifacts`; the offline `xla` stub reports
//!   itself unavailable at spawn time).
//! * [`BackendKind::Synthetic`] — a deterministic native executor with the
//!   same shape contract as the real segments: every **layer** of a model
//!   gets a keyed mixing transform from its input tensor to its output
//!   tensor, and a stage applies the transforms of the layers its segment
//!   covers, in order.  The end-to-end composition is therefore
//!   **partition-invariant**: any segmentation of the same model computes
//!   the same function, which is what lets online re-planning swap a
//!   tenant's partition mid-run while responses keep verifying against
//!   the same [`synthetic_reference`].  Order, routing and isolation bugs
//!   all corrupt the digest.  The stage executes whole batches through
//!   two reused scratch buffers — zero allocations per request.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::SystemConfig;
use crate::coordinator::{
    Arena, BreakerConfig, DelayInjector, HedgeConfig, Pipeline, PipelineConfig,
    ReplicaRouter, Request, Response, StageBackend, StageFactory,
};
use crate::metrics::{DataPlaneMetrics, SchedulerMetrics, TenantMetrics};
use crate::model::Model;
use crate::obs::span::{track_base, CACHE_TRACK};
use crate::obs::Tracer;
use crate::runtime::stage::pjrt_stage_factory;
use crate::runtime::Manifest;
use crate::serving::stage_sims_for_grant;
use crate::util::rng::Rng;

use super::allocator::{Assignment, DeviceGrant, PoolPlan};
use super::pool::DeployOptions;
use super::registry::ModelRegistry;

/// How deployed stages execute.
#[derive(Debug, Clone)]
pub enum BackendKind {
    /// Deterministic native synthetic executor (no artifacts needed).
    Synthetic,
    /// AOT artifacts served through PJRT, rooted at this directory.
    Pjrt { artifact_dir: PathBuf },
}

/// Stable per-tenant key for the synthetic executor (FNV-1a of the name).
pub fn tenant_salt(name: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn layer_salt(model_salt: u64, layer: usize) -> u64 {
    model_salt ^ (layer as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
}

/// One synthetic layer application written into a caller-provided output
/// buffer: a keyed, order-sensitive digest of the input tensor expanded
/// to the output tensor shape.  O(in + out), zero allocations.
pub fn synthetic_transform_into(salt: u64, input: &[i8], out: &mut [i8]) {
    let mut h = salt ^ 0xA076_1D64_78BD_642F;
    for &b in input {
        h = (h ^ (b as u8 as u64)).wrapping_mul(0x100000001b3);
    }
    for (j, o) in out.iter_mut().enumerate() {
        let mut x = h ^ (j as u64).wrapping_mul(0xD1B5_4A32_D192_ED03);
        x = x.wrapping_mul(0x2545_F491_4F6C_DD1D);
        x ^= x >> 29;
        *o = (x >> 56) as u8 as i8;
    }
}

/// Allocating convenience wrapper over [`synthetic_transform_into`]
/// (byte-identical output; the batched stage uses the in-place form).
pub fn synthetic_transform(salt: u64, input: &[i8], out_elems: usize) -> Vec<i8> {
    let mut out = vec![0i8; out_elems];
    synthetic_transform_into(salt, input, &mut out);
    out
}

/// Serial reference for a synthetic deployment: apply every **layer**'s
/// transform in chain order.  `layer_out_elems[i]` is layer i's output
/// tensor size over the whole model.  Any pipelined deployment of any
/// partition of the model must reproduce this exactly — the reference is
/// independent of where the cuts fall, so it stays valid across re-plans.
pub fn synthetic_reference(model_salt: u64, layer_out_elems: &[usize], input: &[i8]) -> Vec<i8> {
    let mut x = input.to_vec();
    for (i, &out) in layer_out_elems.iter().enumerate() {
        x = synthetic_transform(layer_salt(model_salt, i), &x, out);
    }
    x
}

/// Apply the layer chain `(salts[i] -> outs[i])` from `src` into `dst`
/// (`dst.len() == *outs.last()`), ping-ponging intermediates through the
/// two scratch buffers so nothing is allocated once they reach the chain's
/// high-water size.
fn synthetic_chain_into(
    salts: &[u64],
    outs: &[usize],
    scratch_a: &mut Vec<i8>,
    scratch_b: &mut Vec<i8>,
    src: &[i8],
    dst: &mut [i8],
) {
    let k = salts.len();
    debug_assert!(k >= 1 && outs.len() == k);
    if k == 1 {
        synthetic_transform_into(salts[0], src, dst);
        return;
    }
    if scratch_a.len() < outs[0] {
        scratch_a.resize(outs[0], 0);
    }
    synthetic_transform_into(salts[0], src, &mut scratch_a[..outs[0]]);
    let mut cur_in_a = true;
    let mut cur_len = outs[0];
    for j in 1..k - 1 {
        let out_len = outs[j];
        if cur_in_a {
            if scratch_b.len() < out_len {
                scratch_b.resize(out_len, 0);
            }
            synthetic_transform_into(salts[j], &scratch_a[..cur_len], &mut scratch_b[..out_len]);
        } else {
            if scratch_a.len() < out_len {
                scratch_a.resize(out_len, 0);
            }
            synthetic_transform_into(salts[j], &scratch_b[..cur_len], &mut scratch_a[..out_len]);
        }
        cur_in_a = !cur_in_a;
        cur_len = out_len;
    }
    let last_src: &[i8] = if cur_in_a { &scratch_a[..cur_len] } else { &scratch_b[..cur_len] };
    synthetic_transform_into(salts[k - 1], last_src, dst);
}

/// One pipeline stage of the synthetic backend: applies the keyed
/// transforms of the contiguous layer range its segment covers, a whole
/// batch per call, through reused scratch buffers.
struct SyntheticStage {
    /// Per-layer keys, in chain order within the segment.
    salts: Vec<u64>,
    /// Per-layer output tensor sizes, aligned with `salts`.
    outs: Vec<usize>,
    in_elems: usize,
    scratch_a: Vec<i8>,
    scratch_b: Vec<i8>,
}

impl StageBackend for SyntheticStage {
    fn run(&mut self, input: &[i8]) -> Result<Vec<i8>> {
        let out_len = *self.outs.last().expect("segment covers >= 1 layer");
        let mut out = vec![0i8; out_len];
        self.run_batch(1, input, &mut out)?;
        Ok(out)
    }

    fn out_elems(&self, _in_elems: usize) -> usize {
        *self.outs.last().expect("segment covers >= 1 layer")
    }

    fn run_batch(&mut self, n: usize, input: &[i8], output: &mut [i8]) -> Result<()> {
        anyhow::ensure!(
            input.len() == n * self.in_elems,
            "synthetic stage expects {} input elems per item, got {} for {n} items",
            self.in_elems,
            input.len()
        );
        let out_len = *self.outs.last().expect("segment covers >= 1 layer");
        debug_assert_eq!(output.len(), n * out_len);
        for i in 0..n {
            synthetic_chain_into(
                &self.salts,
                &self.outs,
                &mut self.scratch_a,
                &mut self.scratch_b,
                &input[i * self.in_elems..(i + 1) * self.in_elems],
                &mut output[i * out_len..(i + 1) * out_len],
            );
        }
        Ok(())
    }
}

/// Factory for the synthetic stage covering layers `[a, b)` of `model`.
fn synthetic_stage_factory(
    model_salt: u64,
    model: &Model,
    a: usize,
    b: usize,
) -> StageFactory {
    let salts: Vec<u64> = (a..b).map(|i| layer_salt(model_salt, i)).collect();
    let outs: Vec<usize> =
        model.layers[a..b].iter().map(|l| l.output_elems() as usize).collect();
    let in_elems = model.layers[a].input_elems() as usize;
    Box::new(move || {
        Ok(Box::new(SyntheticStage {
            salts,
            outs,
            in_elems,
            scratch_a: Vec::new(),
            scratch_b: Vec::new(),
        }) as Box<dyn StageBackend>)
    })
}

/// Immutable tensor-shape and verification info of one tenant's model,
/// shared by `Arc` across the routing layers (handles, clients, live
/// deployments) instead of deep-cloning the per-layer size vector at
/// every re-plan and `client()` call.
#[derive(Debug)]
pub struct TenantShape {
    /// Input tensor element count (what requests must carry).
    pub in_elems: usize,
    /// Output tensor element count.
    pub out_elems: usize,
    /// Per-layer output sizes over the whole model, for
    /// [`synthetic_reference`] checks (partition-invariant).
    pub layer_out_elems: Vec<usize>,
    /// Synthetic-backend key (stable across runs and re-plans).
    pub salt: u64,
}

impl TenantShape {
    /// Derive the shape info from a model (synthetic key from `name`).
    pub fn of(name: &str, model: &Model) -> TenantShape {
        TenantShape {
            in_elems: model.layers.first().map(|l| l.input_elems() as usize).unwrap_or(0),
            out_elems: model.layers.last().map(|l| l.output_elems() as usize).unwrap_or(0),
            layer_out_elems: model.layers.iter().map(|l| l.output_elems() as usize).collect(),
            salt: tenant_salt(name),
        }
    }

    /// Deterministic random request batch shaped for this tenant.
    pub fn synth_requests(&self, n: usize, seed: u64) -> Vec<Request> {
        let mut rng = Rng::new(seed ^ self.salt);
        (0..n as u64).map(|id| Request::new(id, rng.i8_vec(self.in_elems))).collect()
    }

    /// The serial reference output for one request (synthetic backend).
    pub fn reference(&self, input: &[i8]) -> Vec<i8> {
        synthetic_reference(self.salt, &self.layer_out_elems, input)
    }
}

/// One admitted tenant's running pipelines: a single [`Pipeline`] or a
/// [`ReplicaRouter`] over identical copies.  Shared by the closed-batch
/// [`PoolRouter`] and the open-loop `scheduler::pool::ServingPool`.
pub(crate) enum Deployment {
    Single(Pipeline),
    Replicated(ReplicaRouter),
}

impl Deployment {
    pub(crate) fn serve_batch(&self, requests: Vec<Request>) -> Result<Vec<Response>> {
        match self {
            Deployment::Single(p) => p.serve_batch(requests),
            Deployment::Replicated(r) => r.serve_batch(requests),
        }
    }

    pub(crate) fn wait_ready(&self) -> Result<()> {
        match self {
            Deployment::Single(p) => p.wait_ready(),
            Deployment::Replicated(r) => {
                for p in &r.replicas {
                    p.wait_ready()?;
                }
                Ok(())
            }
        }
    }

    pub(crate) fn shutdown(self) {
        match self {
            Deployment::Single(p) => p.shutdown(),
            Deployment::Replicated(r) => r.shutdown(),
        }
    }

    /// Requests dispatched twice by the hedging policy so far (0 for a
    /// single-pipeline deployment, which has nothing to hedge against).
    pub(crate) fn hedged_total(&self) -> u64 {
        match self {
            Deployment::Single(_) => 0,
            Deployment::Replicated(r) => r.hedged_total(),
        }
    }

    /// Circuit-breaker trips so far (0 for a single-pipeline deployment,
    /// which has no replica set to quarantine within).
    pub(crate) fn breaker_trips_total(&self) -> u64 {
        match self {
            Deployment::Single(_) => 0,
            Deployment::Replicated(r) => r.breaker_trips_total(),
        }
    }

    /// HalfOpen probe grants so far (0 for a single-pipeline deployment).
    pub(crate) fn breaker_probes_total(&self) -> u64 {
        match self {
            Deployment::Single(_) => 0,
            Deployment::Replicated(r) => r.breaker_probes_total(),
        }
    }
}

/// A freshly spawned deployment plus the shared shape/verification info
/// the routing layers index by.
pub(crate) struct BuiltTenant {
    pub(crate) deployment: Deployment,
    pub(crate) shape: Arc<TenantShape>,
    /// Chaos hook: per-replica artificial dispatch delays (replicated
    /// deployments only).  Lets fault-injection harnesses manufacture a
    /// straggler without touching the stage backends.
    pub(crate) injector: Option<DelayInjector>,
}

/// Spawn the pipelines for one plan assignment — the shared deployment
/// path of [`PoolRouter::deploy`] and the open-loop serving pool's
/// (re-)deployments.  `manifest` must be `Some` for the PJRT backend;
/// `pipe` carries the queue capacity plus the (typically pool-shared)
/// arena and data-plane counters.
pub(crate) fn build_deployment(
    a: &Assignment,
    registry: &ModelRegistry,
    cfg: &SystemConfig,
    backend: &BackendKind,
    manifest: Option<&Manifest>,
    pipe: &PipelineConfig,
    hedge: Option<&HedgeConfig>,
    breaker: Option<&BreakerConfig>,
) -> Result<BuiltTenant> {
    // reject nonsensical policies before any pipeline thread spawns
    if let Some(h) = hedge {
        h.validate()?;
    }
    if let Some(b) = breaker {
        b.validate()?;
    }
    let tenant = registry.get(&a.name)?;
    let model = &tenant.model;
    let partition = &a.candidate.partition;
    // a time-sliced grant dilates every stage's simulated service time by
    // 1/slice; the per-quantum swap cost is charged at batch boundaries
    // by the serving layers (see TenantMetrics::record_swap)
    let sims = stage_sims_for_grant(model, partition, cfg, &a.grant);
    let bounds = partition.bounds();
    let shape = Arc::new(TenantShape::of(&a.name, model));

    let mut pipelines = Vec::with_capacity(a.replicas);
    for rep in 0..a.replicas {
        // each replica gets its own run of stage tracks so live traces lay
        // out exactly like the deterministic sim's (rep-major, then stage)
        let rep_pipe = PipelineConfig {
            trace_track_base: pipe.trace_track_base + (rep * bounds.len()) as u32,
            ..pipe.clone()
        };
        let factories: Vec<StageFactory> = match backend {
            BackendKind::Synthetic => bounds
                .iter()
                .map(|&(s, e)| synthetic_stage_factory(shape.salt, model, s, e))
                .collect(),
            BackendKind::Pjrt { artifact_dir } => {
                let entry = manifest
                    .ok_or_else(|| anyhow::anyhow!("pjrt backend needs a manifest"))?
                    .model(&a.name)?;
                entry
                    .segments_for_cuts(&partition.cuts)?
                    .iter()
                    .map(|s| pjrt_stage_factory(artifact_dir.clone(), (*s).clone()))
                    .collect()
            }
        };
        pipelines.push(
            Pipeline::spawn(factories, sims.clone(), &rep_pipe)
                .with_context(|| format!("spawning pipeline for {}", a.name))?,
        );
    }
    if pipelines.len() == 1 {
        Ok(BuiltTenant {
            deployment: Deployment::Single(pipelines.pop().unwrap()),
            shape,
            injector: None,
        })
    } else {
        let mut router = ReplicaRouter::new(pipelines);
        if let Some(h) = hedge {
            router = router.with_hedging(h.clone());
        }
        if let Some(b) = breaker {
            router = router.with_breaker(*b);
        }
        let injector = Some(router.injector());
        Ok(BuiltTenant { deployment: Deployment::Replicated(router), shape, injector })
    }
}

/// Register the display names of one tenant's span tracks with `tracer`
/// (requests, batcher, then rep-major stage tracks), mirroring the track
/// layout of `workload::simulate_deployment_traced` so live and simulated
/// traces render identically.
pub(crate) fn name_tenant_tracks(
    tracer: &Tracer,
    name: &str,
    idx: usize,
    replicas: usize,
    n_stages: usize,
    cache: bool,
) {
    let base = track_base(idx);
    tracer.name_track(base, format!("{name}/requests"));
    tracer.name_track(base + 1, format!("{name}/batcher"));
    for rep in 0..replicas {
        for s in 0..n_stages {
            let t = base + 2 + (rep * n_stages + s) as u32;
            tracer.name_track(t, format!("{name}/rep{rep}/stage{s}"));
        }
    }
    // cache-enabled shared grants get a lane for their prefetch spans;
    // cache-off traces keep the exact track set they have today
    if cache {
        tracer.name_track(base + CACHE_TRACK, format!("{name}/cache"));
    }
}

/// One admitted tenant's live deployment.
pub struct TenantHandle {
    /// Registry/routing key.
    pub name: String,
    /// Pipeline depth (TPUs per replica).
    pub tpu_count: usize,
    /// Data-parallel pipeline copies (>= 1).
    pub replicas: usize,
    /// How the TPUs are held (exclusive or a time-multiplexed slice).
    pub grant: DeviceGrant,
    /// Paper-style segment-size label, e.g. `"2+2+1"`.
    pub partition_label: String,
    /// Name of the segmentation strategy the allocator chose.
    pub strategy_name: &'static str,
    /// Allocator-predicted p99 latency (seconds, simulated clock).
    pub predicted_p99_s: f64,
    /// Tensor shapes + synthetic verification key (shared, not cloned).
    pub shape: Arc<TenantShape>,
    /// This tenant's serving counters.
    pub metrics: Arc<TenantMetrics>,
    deployment: Deployment,
    /// Serializes `serve` calls per tenant: a deployment's response queue
    /// is shared, so two interleaved `serve_batch` drains would
    /// cross-deliver responses.
    serve_lock: std::sync::Mutex<()>,
    /// `(sim epoch, last swap)`: the tenant's simulated clock at the end
    /// of the last served batch, and the host-clock instant (seconds
    /// since `started`) of the last paid parameter re-load.  Pipeline sim
    /// clocks never reset, so per-batch sim latencies are recorded
    /// relative to the epoch (otherwise the metric would grow without
    /// bound across batches); the swap clock quantum-gates the per-batch
    /// re-load charge on the host clock, the live analogue of the
    /// deterministic sim's flush clock.
    sim_state: std::sync::Mutex<(f64, f64)>,
    /// Deployment birth, the origin of the swap clock above.
    started: std::time::Instant,
}

impl TenantHandle {
    /// Input tensor element count (what requests must carry).
    pub fn in_elems(&self) -> usize {
        self.shape.in_elems
    }

    /// Output tensor element count.
    pub fn out_elems(&self) -> usize {
        self.shape.out_elems
    }

    /// Synthetic-backend key (stable across runs; unused for PJRT).
    pub fn salt(&self) -> u64 {
        self.shape.salt
    }

    /// Deterministic random request batch shaped for this tenant.
    pub fn synth_requests(&self, n: usize, seed: u64) -> Vec<Request> {
        self.shape.synth_requests(n, seed)
    }

    /// The serial reference output for one request (synthetic backend).
    pub fn reference(&self, input: &[i8]) -> Vec<i8> {
        self.shape.reference(input)
    }
}

/// The per-model request router over all admitted deployments.
pub struct PoolRouter {
    tenants: BTreeMap<String, TenantHandle>,
    /// Pool-level routing/admission counters.
    pub metrics: Arc<SchedulerMetrics>,
    /// Handoff/allocation counters of the pool-shared data plane.
    pub data_plane: Arc<DataPlaneMetrics>,
}

impl PoolRouter {
    /// Spawn every admitted assignment of `plan` and index the deployments
    /// by model name.  All deployments share one buffer arena, so slabs
    /// recycle across tenants.
    ///
    /// The single deployment entry point: `opts` carries every serving
    /// knob ([`DeployOptions::queue_capacity`], an optional tracer — stage
    /// workers then record one `Stage` span per served batch on per-tenant
    /// track runs laid out by `obs::span::track_base` (DESIGN.md §13) —
    /// and an optional hedge policy).  The former `deploy_traced` fork is
    /// gone; pass [`DeployOptions::with_tracer`] instead.
    pub fn deploy(
        plan: &PoolPlan,
        registry: &ModelRegistry,
        cfg: &SystemConfig,
        backend: &BackendKind,
        opts: DeployOptions,
    ) -> Result<PoolRouter> {
        let queue_capacity = opts.queue_capacity;
        let tracer = opts.tracer.clone();
        // PJRT deployments resolve segments through the artifact manifest
        let manifest: Option<Manifest> = match backend {
            BackendKind::Pjrt { artifact_dir } => {
                Some(Manifest::load(&artifact_dir.join("manifest.json"))?)
            }
            BackendKind::Synthetic => None,
        };
        let data_plane = Arc::new(DataPlaneMetrics::default());
        let pipe = PipelineConfig {
            queue_capacity,
            arena: Some(Arena::new(data_plane.clone())),
            data_plane: Some(data_plane.clone()),
            tracer: tracer.clone(),
            trace_track_base: 0,
        };

        let mut tenants = BTreeMap::new();
        for (idx, a) in plan.assignments.iter().enumerate() {
            let n_stages = a.candidate.partition.n_segments();
            if let Some(t) = &tracer {
                name_tenant_tracks(t, &a.name, idx, a.replicas, n_stages, a.grant.cache().is_some());
            }
            let tenant_pipe =
                PipelineConfig { trace_track_base: track_base(idx) + 2, ..pipe.clone() };
            let built = build_deployment(
                a,
                registry,
                cfg,
                backend,
                manifest.as_ref(),
                &tenant_pipe,
                opts.hedge.as_ref(),
                opts.breaker.as_ref(),
            )?;
            tenants.insert(
                a.name.clone(),
                TenantHandle {
                    name: a.name.clone(),
                    tpu_count: a.candidate.tpu_count,
                    replicas: a.replicas,
                    grant: a.grant.clone(),
                    partition_label: a.candidate.partition.label(),
                    strategy_name: a.candidate.strategy.name(),
                    predicted_p99_s: a.effective_p99_s,
                    shape: built.shape,
                    metrics: Arc::new(TenantMetrics::default()),
                    deployment: built.deployment,
                    serve_lock: std::sync::Mutex::new(()),
                    sim_state: std::sync::Mutex::new((0.0, f64::NEG_INFINITY)),
                    started: std::time::Instant::now(),
                },
            );
        }
        let metrics = Arc::new(SchedulerMetrics::default());
        metrics.record_admission(
            registry.len() as u64,
            plan.assignments.len() as u64,
            plan.shared_count() as u64,
            plan.queued.len() as u64,
            plan.rejected.len() as u64,
        );
        Ok(PoolRouter { tenants, metrics, data_plane })
    }

    /// Block until every stage backend of every deployment is constructed.
    pub fn wait_ready(&self) -> Result<()> {
        for t in self.tenants.values() {
            t.deployment.wait_ready()?;
        }
        Ok(())
    }

    /// Route a request batch to the named model's deployment.  Safe to
    /// call concurrently: different tenants run fully in parallel, and
    /// calls for the *same* tenant are serialized (a deployment's response
    /// queue is shared, so interleaved drains would cross-deliver).
    pub fn serve(&self, model: &str, requests: Vec<Request>) -> Result<Vec<Response>> {
        let Some(t) = self.tenants.get(model) else {
            self.metrics.record_route_miss();
            anyhow::bail!(
                "model {model:?} has no deployment (admitted: {:?})",
                self.names()
            );
        };
        let n = requests.len() as u64;
        t.metrics.record_submitted(n);
        self.metrics.record_routed(n);
        let result = {
            let _exclusive = t.serve_lock.lock().unwrap();
            t.deployment.serve_batch(requests)
        };
        match result {
            Ok(responses) => {
                // a time-shared tenant swaps its parameters back in at
                // most once per scheduling quantum (the co-resident ran
                // in between); the re-load runs before the batch, so it
                // also delays every response's recorded sim latency.
                // sim latencies are relative to this tenant's sim clock
                // at batch start (the pipeline's simulated clock is
                // monotonic across batches)
                let mut st = t.sim_state.lock().unwrap();
                let (base, last_swap) = *st;
                let swap_s = if t.grant.is_shared() {
                    let now_s = t.started.elapsed().as_secs_f64();
                    if now_s >= last_swap + t.grant.quantum_s() {
                        let first = last_swap == f64::NEG_INFINITY;
                        st.1 = now_s;
                        let cold = t.grant.switch_s();
                        // a cache-enabled grant keeps part (or all) of
                        // the parameters staged, shrinking the re-load
                        let paid = match t.grant.cache() {
                            Some(eff) => {
                                let class = eff.classify(cold, first);
                                t.metrics.record_cache(class.hit, class.prefetched);
                                cold * class.frac
                            }
                            None => cold,
                        };
                        t.metrics.record_swap(paid);
                        paid
                    } else {
                        t.metrics.record_swap_skipped();
                        0.0
                    }
                } else {
                    0.0
                };
                for r in &responses {
                    t.metrics.record_response(
                        r.real_latency_s,
                        (r.sim_done_s - base).max(0.0) + swap_s,
                    );
                    if r.sim_done_s > st.0 {
                        st.0 = r.sim_done_s;
                    }
                }
                drop(st);
                Ok(responses)
            }
            Err(e) => {
                t.metrics.record_error();
                Err(e)
            }
        }
    }

    /// Look up one admitted tenant's handle by model name.
    pub fn tenant(&self, name: &str) -> Option<&TenantHandle> {
        self.tenants.get(name)
    }

    /// Iterate over every admitted tenant's handle (name order).
    pub fn tenants(&self) -> impl Iterator<Item = &TenantHandle> {
        self.tenants.values()
    }

    /// Admitted model names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.tenants.keys().cloned().collect()
    }

    /// Number of admitted (deployed) tenants.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    /// Whether the router has no deployments at all.
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Close every deployment and join all worker threads.
    pub fn shutdown(self) {
        for (_, t) in self.tenants {
            t.deployment.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::allocator::{allocate, AllocatorConfig};

    fn deploy(names: &[&str], tpus: usize) -> (PoolRouter, PoolPlan) {
        let mut reg = ModelRegistry::new();
        for n in names {
            reg.register_named(n).unwrap();
        }
        let cfg = SystemConfig::default();
        let alloc = AllocatorConfig { total_tpus: tpus, ..Default::default() };
        let plan = allocate(&reg, &cfg, &alloc).unwrap();
        let router =
            PoolRouter::deploy(
                &plan,
                &reg,
                &cfg,
                &BackendKind::Synthetic,
                DeployOptions::new().with_queue_capacity(16),
            )
            .unwrap();
        (router, plan)
    }

    #[test]
    fn synthetic_transform_is_deterministic_and_input_sensitive() {
        let a = synthetic_transform(7, &[1, 2, 3], 8);
        assert_eq!(a, synthetic_transform(7, &[1, 2, 3], 8));
        assert_eq!(a.len(), 8);
        assert_ne!(a, synthetic_transform(7, &[1, 2, 4], 8), "input must matter");
        assert_ne!(a, synthetic_transform(8, &[1, 2, 3], 8), "salt must matter");
        assert_ne!(a, synthetic_transform(7, &[2, 1, 3], 8), "order must matter");
        // the in-place form is the same function
        let mut buf = vec![0i8; 8];
        synthetic_transform_into(7, &[1, 2, 3], &mut buf);
        assert_eq!(a, buf);
    }

    #[test]
    fn batched_synthetic_stage_matches_per_item_reference() {
        // a 3-layer segment with shape changes, run as one batch, must
        // equal the per-layer serial reference for every item
        let salt = tenant_salt("batch-check");
        let salts: Vec<u64> = (0..3).map(|i| layer_salt(salt, i)).collect();
        let outs = vec![16usize, 32, 8];
        let mut stage = SyntheticStage {
            salts: salts.clone(),
            outs: outs.clone(),
            in_elems: 4,
            scratch_a: Vec::new(),
            scratch_b: Vec::new(),
        };
        let n = 5;
        let input: Vec<i8> = (0..(n * 4) as i64).map(|v| v as i8).collect();
        let mut output = vec![0i8; n * 8];
        stage.run_batch(n, &input, &mut output).unwrap();
        for i in 0..n {
            let item = &input[i * 4..(i + 1) * 4];
            let expect = synthetic_reference(salt, &[16, 32, 8], item);
            assert_eq!(&output[i * 8..(i + 1) * 8], expect.as_slice(), "item {i}");
        }
        // wrong input size is rejected
        assert!(stage.run_batch(2, &input[..7], &mut output[..16]).is_err());
    }

    #[test]
    fn routed_batches_match_reference_per_tenant() {
        let (router, plan) = deploy(&["fc_small", "conv_a"], 2);
        assert_eq!(plan.assignments.len(), 2);
        router.wait_ready().unwrap();
        for name in ["fc_small", "conv_a"] {
            let t = router.tenant(name).unwrap();
            let reqs = t.synth_requests(12, 42);
            let expected: Vec<Vec<i8>> =
                reqs.iter().map(|r| t.reference(&r.data)).collect();
            let out = router.serve(name, reqs).unwrap();
            assert_eq!(out.len(), 12);
            for (i, r) in out.iter().enumerate() {
                assert_eq!(r.id, i as u64, "{name}: order preserved");
                assert_eq!(r.data, expected[i], "{name}: item {i} digest mismatch");
                assert_eq!(r.data.len(), t.out_elems());
            }
            let snap = t.metrics.snapshot();
            assert_eq!(snap.submitted, 12);
            assert_eq!(snap.completed, 12);
            assert_eq!(snap.errors, 0);
        }
        let s = router.metrics.snapshot();
        assert_eq!(s.admitted, 2);
        assert_eq!(s.routed_requests, 24);
        router.shutdown();
    }

    #[test]
    fn concurrent_tenants_stay_isolated() {
        // two tenants served from two threads at once: responses must not
        // cross deployments (distinct salts => distinct digests)
        let (router, _plan) = deploy(&["fc_small", "conv_a"], 4);
        router.wait_ready().unwrap();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for name in ["fc_small", "conv_a"] {
                let router = &router;
                handles.push(scope.spawn(move || {
                    let t = router.tenant(name).unwrap();
                    let reqs = t.synth_requests(30, 7);
                    let expected: Vec<Vec<i8>> =
                        reqs.iter().map(|r| t.reference(&r.data)).collect();
                    let out = router.serve(name, reqs).unwrap();
                    for (r, e) in out.iter().zip(&expected) {
                        assert_eq!(&r.data, e, "{name} cross-tenant corruption");
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        router.shutdown();
    }

    #[test]
    fn concurrent_calls_for_the_same_tenant_do_not_cross_deliver() {
        // two threads hammer ONE deployment: serve() serializes them, so
        // each caller must get back exactly its own (id, digest) set
        let (router, _plan) = deploy(&["fc_small"], 1);
        router.wait_ready().unwrap();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for seed in [11u64, 22] {
                let router = &router;
                handles.push(scope.spawn(move || {
                    let t = router.tenant("fc_small").unwrap();
                    let reqs = t.synth_requests(20, seed);
                    let expected: Vec<Vec<i8>> =
                        reqs.iter().map(|r| t.reference(&r.data)).collect();
                    let out = router.serve("fc_small", reqs).unwrap();
                    assert_eq!(out.len(), 20);
                    for (i, r) in out.iter().enumerate() {
                        assert_eq!(r.id, i as u64, "seed {seed}");
                        assert_eq!(r.data, expected[i], "seed {seed}: cross-delivery");
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        assert_eq!(router.tenant("fc_small").unwrap().metrics.snapshot().completed, 40);
        router.shutdown();
    }

    #[test]
    fn replicated_deployment_serves_through_replica_router() {
        // one 1-TPU model on a 3-TPU pool -> leftover TPUs become replicas
        let (router, plan) = deploy(&["fc_small"], 3);
        let a = plan.assignment("fc_small").unwrap();
        assert!(a.replicas > 1, "expected replicas, got {a:?}");
        router.wait_ready().unwrap();
        let t = router.tenant("fc_small").unwrap();
        assert_eq!(t.replicas, a.replicas);
        let reqs = t.synth_requests(31, 3);
        let expected: Vec<Vec<i8>> = reqs.iter().map(|r| t.reference(&r.data)).collect();
        let out = router.serve("fc_small", reqs).unwrap();
        assert_eq!(out.len(), 31);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.data, expected[i]);
        }
        router.shutdown();
    }

    #[test]
    fn slabs_recycle_across_tenants() {
        // the router's arena is pool-shared: after tenant A's traffic
        // warmed it, same-shaped tenant B serves without allocating
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        reg.register(super::super::registry::Tenant::new(
            "fc_twin",
            super::super::resolve_model("fc_small").unwrap(),
        ))
        .unwrap();
        let cfg = SystemConfig::default();
        let alloc = AllocatorConfig { total_tpus: 2, ..Default::default() };
        let plan = allocate(&reg, &cfg, &alloc).unwrap();
        assert_eq!(plan.assignments.len(), 2, "queued={:?}", plan.queued);
        let router =
            PoolRouter::deploy(
                &plan,
                &reg,
                &cfg,
                &BackendKind::Synthetic,
                DeployOptions::new().with_queue_capacity(16),
            )
            .unwrap();
        router.wait_ready().unwrap();
        let reqs = router.tenant("fc_small").unwrap().synth_requests(24, 5);
        drop(router.serve("fc_small", reqs).unwrap());
        let warm = router.data_plane.snapshot();
        assert!(warm.slab_allocs > 0);
        // the twin's batches are the same sizes: everything recycles
        let reqs = router.tenant("fc_twin").unwrap().synth_requests(24, 6);
        drop(router.serve("fc_twin", reqs).unwrap());
        let after = router.data_plane.snapshot();
        assert_eq!(
            after.slab_allocs, warm.slab_allocs,
            "cross-tenant slab reuse must be allocation-free: {after:?}"
        );
        router.shutdown();
    }

    #[test]
    fn sim_latency_metrics_do_not_grow_across_batches() {
        // the pipeline's simulated clock is monotonic across batches;
        // recorded sim latencies must stay per-batch, not cumulative
        let (router, _plan) = deploy(&["fc_small"], 1);
        router.wait_ready().unwrap();
        let t = router.tenant("fc_small").unwrap();
        router.serve("fc_small", t.synth_requests(15, 1)).unwrap();
        let first = t.metrics.snapshot().sim_p99_s;
        for seed in 2..6u64 {
            router.serve("fc_small", t.synth_requests(15, seed)).unwrap();
        }
        let after = t.metrics.snapshot().sim_p99_s;
        assert!(
            after <= first * 2.0 + 1e-6,
            "sim latency must not accumulate across batches: {first} -> {after}"
        );
        router.shutdown();
    }

    #[test]
    fn unknown_model_is_a_route_miss() {
        let (router, _plan) = deploy(&["fc_small"], 1);
        let err = router.serve("nope", Vec::new()).unwrap_err();
        assert!(err.to_string().contains("no deployment"), "{err}");
        assert_eq!(router.metrics.snapshot().route_misses, 1);
        router.shutdown();
    }
}
