//! Per-model request router: one live deployment per admitted tenant.
//!
//! [`PoolRouter::deploy`] turns a [`PoolPlan`](super::allocator::PoolPlan)
//! into running [`Pipeline`]s — one per admitted model, or a
//! [`ReplicaRouter`] of full pipeline copies when the allocator granted
//! leftover-TPU replicas — and routes request batches by model name with
//! per-tenant metrics.
//!
//! Two stage backends:
//!
//! * [`BackendKind::Pjrt`] — AOT-compiled HLO segments via the PJRT
//!   runtime (requires `make artifacts`; the offline `xla` stub reports
//!   itself unavailable at spawn time).
//! * [`BackendKind::Synthetic`] — a deterministic native executor with the
//!   same shape contract as the real segments: every **layer** of a model
//!   gets a keyed mixing transform from its input tensor to its output
//!   tensor, and a stage applies the transforms of the layers its segment
//!   covers, in order.  The end-to-end composition is therefore
//!   **partition-invariant**: any segmentation of the same model computes
//!   the same function, which is what lets online re-planning swap a
//!   tenant's partition mid-run while responses keep verifying against
//!   the same [`synthetic_reference`].  Order, routing and isolation bugs
//!   all corrupt the digest.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::SystemConfig;
use crate::coordinator::{
    Pipeline, PipelineConfig, ReplicaRouter, Request, Response, StageBackend, StageFactory,
};
use crate::metrics::{SchedulerMetrics, TenantMetrics};
use crate::model::Model;
use crate::runtime::stage::pjrt_stage_factory;
use crate::runtime::Manifest;
use crate::serving::stage_sims_for_grant;
use crate::util::rng::Rng;

use super::allocator::{Assignment, DeviceGrant, PoolPlan};
use super::registry::ModelRegistry;

/// How deployed stages execute.
#[derive(Debug, Clone)]
pub enum BackendKind {
    /// Deterministic native synthetic executor (no artifacts needed).
    Synthetic,
    /// AOT artifacts served through PJRT, rooted at this directory.
    Pjrt { artifact_dir: PathBuf },
}

/// Stable per-tenant key for the synthetic executor (FNV-1a of the name).
pub fn tenant_salt(name: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn layer_salt(model_salt: u64, layer: usize) -> u64 {
    model_salt ^ (layer as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
}

/// One synthetic layer application: a keyed, order-sensitive digest of the
/// input tensor expanded to the output tensor shape.  O(in + out).
pub fn synthetic_transform(salt: u64, input: &[i8], out_elems: usize) -> Vec<i8> {
    let mut h = salt ^ 0xA076_1D64_78BD_642F;
    for &b in input {
        h = (h ^ (b as u8 as u64)).wrapping_mul(0x100000001b3);
    }
    (0..out_elems)
        .map(|j| {
            let mut x = h ^ (j as u64).wrapping_mul(0xD1B5_4A32_D192_ED03);
            x = x.wrapping_mul(0x2545_F491_4F6C_DD1D);
            x ^= x >> 29;
            (x >> 56) as u8 as i8
        })
        .collect()
}

/// Serial reference for a synthetic deployment: apply every **layer**'s
/// transform in chain order.  `layer_out_elems[i]` is layer i's output
/// tensor size over the whole model.  Any pipelined deployment of any
/// partition of the model must reproduce this exactly — the reference is
/// independent of where the cuts fall, so it stays valid across re-plans.
pub fn synthetic_reference(model_salt: u64, layer_out_elems: &[usize], input: &[i8]) -> Vec<i8> {
    let mut x = input.to_vec();
    for (i, &out) in layer_out_elems.iter().enumerate() {
        x = synthetic_transform(layer_salt(model_salt, i), &x, out);
    }
    x
}

/// One pipeline stage of the synthetic backend: applies the keyed
/// transforms of the contiguous layer range its segment covers.
struct SyntheticStage {
    /// Per-layer keys, in chain order within the segment.
    salts: Vec<u64>,
    /// Per-layer output tensor sizes, aligned with `salts`.
    outs: Vec<usize>,
    in_elems: usize,
}

impl StageBackend for SyntheticStage {
    fn run(&mut self, input: &[i8]) -> Result<Vec<i8>> {
        anyhow::ensure!(
            input.len() == self.in_elems,
            "synthetic stage expects {} input elems, got {}",
            self.in_elems,
            input.len()
        );
        let mut x = input.to_vec();
        for (salt, &out) in self.salts.iter().zip(&self.outs) {
            x = synthetic_transform(*salt, &x, out);
        }
        Ok(x)
    }
}

/// Factory for the synthetic stage covering layers `[a, b)` of `model`.
fn synthetic_stage_factory(
    model_salt: u64,
    model: &Model,
    a: usize,
    b: usize,
) -> StageFactory {
    let salts: Vec<u64> = (a..b).map(|i| layer_salt(model_salt, i)).collect();
    let outs: Vec<usize> =
        model.layers[a..b].iter().map(|l| l.output_elems() as usize).collect();
    let in_elems = model.layers[a].input_elems() as usize;
    Box::new(move || {
        Ok(Box::new(SyntheticStage { salts, outs, in_elems }) as Box<dyn StageBackend>)
    })
}

/// One admitted tenant's running pipelines: a single [`Pipeline`] or a
/// [`ReplicaRouter`] over identical copies.  Shared by the closed-batch
/// [`PoolRouter`] and the open-loop `scheduler::pool::ServingPool`.
pub(crate) enum Deployment {
    Single(Pipeline),
    Replicated(ReplicaRouter),
}

impl Deployment {
    pub(crate) fn serve_batch(&self, requests: Vec<Request>) -> Result<Vec<Response>> {
        match self {
            Deployment::Single(p) => p.serve_batch(requests),
            Deployment::Replicated(r) => r.serve_batch(requests),
        }
    }

    pub(crate) fn wait_ready(&self) -> Result<()> {
        match self {
            Deployment::Single(p) => p.wait_ready(),
            Deployment::Replicated(r) => {
                for p in &r.replicas {
                    p.wait_ready()?;
                }
                Ok(())
            }
        }
    }

    pub(crate) fn shutdown(self) {
        match self {
            Deployment::Single(p) => p.shutdown(),
            Deployment::Replicated(r) => r.shutdown(),
        }
    }
}

/// A freshly spawned deployment plus the shape/verification info the
/// routing layers index by.
pub(crate) struct BuiltTenant {
    pub(crate) deployment: Deployment,
    /// Input tensor element count (what requests must carry).
    pub(crate) in_elems: usize,
    /// Output tensor element count.
    pub(crate) out_elems: usize,
    /// Per-layer output sizes over the whole model, for
    /// [`synthetic_reference`] checks (partition-invariant).
    pub(crate) layer_out_elems: Vec<usize>,
    /// Synthetic-backend key (stable across runs and re-plans).
    pub(crate) salt: u64,
}

/// Spawn the pipelines for one plan assignment — the shared deployment
/// path of [`PoolRouter::deploy`] and the open-loop serving pool's
/// (re-)deployments.  `manifest` must be `Some` for the PJRT backend.
pub(crate) fn build_deployment(
    a: &Assignment,
    registry: &ModelRegistry,
    cfg: &SystemConfig,
    backend: &BackendKind,
    manifest: Option<&Manifest>,
    queue_capacity: usize,
) -> Result<BuiltTenant> {
    let tenant = registry.get(&a.name)?;
    let model = &tenant.model;
    let partition = &a.candidate.partition;
    // a time-sliced grant dilates every stage's simulated service time by
    // 1/slice; the per-quantum swap cost is charged at batch boundaries
    // by the serving layers (see TenantMetrics::record_swap)
    let sims = stage_sims_for_grant(model, partition, cfg, &a.grant);
    let bounds = partition.bounds();
    let salt = tenant_salt(&a.name);

    let mut pipelines = Vec::with_capacity(a.replicas);
    for _ in 0..a.replicas {
        let factories: Vec<StageFactory> = match backend {
            BackendKind::Synthetic => bounds
                .iter()
                .map(|&(s, e)| synthetic_stage_factory(salt, model, s, e))
                .collect(),
            BackendKind::Pjrt { artifact_dir } => {
                let entry = manifest
                    .ok_or_else(|| anyhow::anyhow!("pjrt backend needs a manifest"))?
                    .model(&a.name)?;
                entry
                    .segments_for_cuts(&partition.cuts)?
                    .iter()
                    .map(|s| pjrt_stage_factory(artifact_dir.clone(), (*s).clone()))
                    .collect()
            }
        };
        pipelines.push(
            Pipeline::spawn(factories, sims.clone(), &PipelineConfig { queue_capacity })
                .with_context(|| format!("spawning pipeline for {}", a.name))?,
        );
    }
    let deployment = if pipelines.len() == 1 {
        Deployment::Single(pipelines.pop().unwrap())
    } else {
        Deployment::Replicated(ReplicaRouter::new(pipelines))
    };
    Ok(BuiltTenant {
        deployment,
        in_elems: model.layers.first().map(|l| l.input_elems() as usize).unwrap_or(0),
        out_elems: model.layers.last().map(|l| l.output_elems() as usize).unwrap_or(0),
        layer_out_elems: model.layers.iter().map(|l| l.output_elems() as usize).collect(),
        salt,
    })
}

/// One admitted tenant's live deployment.
pub struct TenantHandle {
    /// Registry/routing key.
    pub name: String,
    /// Pipeline depth (TPUs per replica).
    pub tpu_count: usize,
    /// Data-parallel pipeline copies (>= 1).
    pub replicas: usize,
    /// How the TPUs are held (exclusive or a time-multiplexed slice).
    pub grant: DeviceGrant,
    /// Paper-style segment-size label, e.g. `"2+2+1"`.
    pub partition_label: String,
    /// Name of the segmentation strategy the allocator chose.
    pub strategy_name: &'static str,
    /// Allocator-predicted p99 latency (seconds, simulated clock).
    pub predicted_p99_s: f64,
    /// Input tensor element count (what requests must carry).
    pub in_elems: usize,
    /// Output tensor element count.
    pub out_elems: usize,
    /// Per-layer output sizes over the whole model, for
    /// [`synthetic_reference`] checks (partition-invariant).
    pub layer_out_elems: Vec<usize>,
    /// Synthetic-backend key (stable across runs; unused for PJRT).
    pub salt: u64,
    /// This tenant's serving counters.
    pub metrics: Arc<TenantMetrics>,
    deployment: Deployment,
    /// Serializes `serve` calls per tenant: a deployment's response queue
    /// is shared, so two interleaved `serve_batch` drains would
    /// cross-deliver responses.
    serve_lock: std::sync::Mutex<()>,
    /// `(sim epoch, last swap)`: the tenant's simulated clock at the end
    /// of the last served batch, and the host-clock instant (seconds
    /// since `started`) of the last paid parameter re-load.  Pipeline sim
    /// clocks never reset, so per-batch sim latencies are recorded
    /// relative to the epoch (otherwise the metric would grow without
    /// bound across batches); the swap clock quantum-gates the per-batch
    /// re-load charge on the host clock, the live analogue of the
    /// deterministic sim's flush clock.
    sim_state: std::sync::Mutex<(f64, f64)>,
    /// Deployment birth, the origin of the swap clock above.
    started: std::time::Instant,
}

impl TenantHandle {
    /// Deterministic random request batch shaped for this tenant.
    pub fn synth_requests(&self, n: usize, seed: u64) -> Vec<Request> {
        let mut rng = Rng::new(seed ^ self.salt);
        (0..n as u64).map(|id| Request { id, data: rng.i8_vec(self.in_elems) }).collect()
    }

    /// The serial reference output for one request (synthetic backend).
    pub fn reference(&self, input: &[i8]) -> Vec<i8> {
        synthetic_reference(self.salt, &self.layer_out_elems, input)
    }
}

/// The per-model request router over all admitted deployments.
pub struct PoolRouter {
    tenants: BTreeMap<String, TenantHandle>,
    /// Pool-level routing/admission counters.
    pub metrics: Arc<SchedulerMetrics>,
}

impl PoolRouter {
    /// Spawn every admitted assignment of `plan` and index the deployments
    /// by model name.
    pub fn deploy(
        plan: &PoolPlan,
        registry: &ModelRegistry,
        cfg: &SystemConfig,
        backend: &BackendKind,
        queue_capacity: usize,
    ) -> Result<PoolRouter> {
        // PJRT deployments resolve segments through the artifact manifest
        let manifest: Option<Manifest> = match backend {
            BackendKind::Pjrt { artifact_dir } => {
                Some(Manifest::load(&artifact_dir.join("manifest.json"))?)
            }
            BackendKind::Synthetic => None,
        };

        let mut tenants = BTreeMap::new();
        for a in &plan.assignments {
            let built =
                build_deployment(a, registry, cfg, backend, manifest.as_ref(), queue_capacity)?;
            tenants.insert(
                a.name.clone(),
                TenantHandle {
                    name: a.name.clone(),
                    tpu_count: a.candidate.tpu_count,
                    replicas: a.replicas,
                    grant: a.grant.clone(),
                    partition_label: a.candidate.partition.label(),
                    strategy_name: a.candidate.strategy.name(),
                    predicted_p99_s: a.effective_p99_s,
                    in_elems: built.in_elems,
                    out_elems: built.out_elems,
                    layer_out_elems: built.layer_out_elems,
                    salt: built.salt,
                    metrics: Arc::new(TenantMetrics::default()),
                    deployment: built.deployment,
                    serve_lock: std::sync::Mutex::new(()),
                    sim_state: std::sync::Mutex::new((0.0, f64::NEG_INFINITY)),
                    started: std::time::Instant::now(),
                },
            );
        }
        let metrics = Arc::new(SchedulerMetrics::default());
        metrics.record_admission(
            registry.len() as u64,
            plan.assignments.len() as u64,
            plan.shared_count() as u64,
            plan.queued.len() as u64,
            plan.rejected.len() as u64,
        );
        Ok(PoolRouter { tenants, metrics })
    }

    /// Block until every stage backend of every deployment is constructed.
    pub fn wait_ready(&self) -> Result<()> {
        for t in self.tenants.values() {
            t.deployment.wait_ready()?;
        }
        Ok(())
    }

    /// Route a request batch to the named model's deployment.  Safe to
    /// call concurrently: different tenants run fully in parallel, and
    /// calls for the *same* tenant are serialized (a deployment's response
    /// queue is shared, so interleaved drains would cross-deliver).
    pub fn serve(&self, model: &str, requests: Vec<Request>) -> Result<Vec<Response>> {
        let Some(t) = self.tenants.get(model) else {
            self.metrics.record_route_miss();
            anyhow::bail!(
                "model {model:?} has no deployment (admitted: {:?})",
                self.names()
            );
        };
        let n = requests.len() as u64;
        t.metrics.record_submitted(n);
        self.metrics.record_routed(n);
        let result = {
            let _exclusive = t.serve_lock.lock().unwrap();
            t.deployment.serve_batch(requests)
        };
        match result {
            Ok(responses) => {
                // a time-shared tenant swaps its parameters back in at
                // most once per scheduling quantum (the co-resident ran
                // in between); the re-load runs before the batch, so it
                // also delays every response's recorded sim latency.
                // sim latencies are relative to this tenant's sim clock
                // at batch start (the pipeline's simulated clock is
                // monotonic across batches)
                let mut st = t.sim_state.lock().unwrap();
                let (base, last_swap) = *st;
                let swap_s = if t.grant.is_shared() {
                    let now_s = t.started.elapsed().as_secs_f64();
                    if now_s >= last_swap + t.grant.quantum_s() {
                        st.1 = now_s;
                        t.metrics.record_swap(t.grant.switch_s());
                        t.grant.switch_s()
                    } else {
                        t.metrics.record_swap_skipped();
                        0.0
                    }
                } else {
                    0.0
                };
                for r in &responses {
                    t.metrics.record_response(
                        r.real_latency_s,
                        (r.sim_done_s - base).max(0.0) + swap_s,
                    );
                    if r.sim_done_s > st.0 {
                        st.0 = r.sim_done_s;
                    }
                }
                drop(st);
                Ok(responses)
            }
            Err(e) => {
                t.metrics.record_error();
                Err(e)
            }
        }
    }

    /// Look up one admitted tenant's handle by model name.
    pub fn tenant(&self, name: &str) -> Option<&TenantHandle> {
        self.tenants.get(name)
    }

    /// Iterate over every admitted tenant's handle (name order).
    pub fn tenants(&self) -> impl Iterator<Item = &TenantHandle> {
        self.tenants.values()
    }

    /// Admitted model names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.tenants.keys().cloned().collect()
    }

    /// Number of admitted (deployed) tenants.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    /// Whether the router has no deployments at all.
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Close every deployment and join all worker threads.
    pub fn shutdown(self) {
        for (_, t) in self.tenants {
            t.deployment.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::allocator::{allocate, AllocatorConfig};

    fn deploy(names: &[&str], tpus: usize) -> (PoolRouter, PoolPlan) {
        let mut reg = ModelRegistry::new();
        for n in names {
            reg.register_named(n).unwrap();
        }
        let cfg = SystemConfig::default();
        let alloc = AllocatorConfig { total_tpus: tpus, ..Default::default() };
        let plan = allocate(&reg, &cfg, &alloc).unwrap();
        let router =
            PoolRouter::deploy(&plan, &reg, &cfg, &BackendKind::Synthetic, 16).unwrap();
        (router, plan)
    }

    #[test]
    fn synthetic_transform_is_deterministic_and_input_sensitive() {
        let a = synthetic_transform(7, &[1, 2, 3], 8);
        assert_eq!(a, synthetic_transform(7, &[1, 2, 3], 8));
        assert_eq!(a.len(), 8);
        assert_ne!(a, synthetic_transform(7, &[1, 2, 4], 8), "input must matter");
        assert_ne!(a, synthetic_transform(8, &[1, 2, 3], 8), "salt must matter");
        assert_ne!(a, synthetic_transform(7, &[2, 1, 3], 8), "order must matter");
    }

    #[test]
    fn routed_batches_match_reference_per_tenant() {
        let (router, plan) = deploy(&["fc_small", "conv_a"], 2);
        assert_eq!(plan.assignments.len(), 2);
        router.wait_ready().unwrap();
        for name in ["fc_small", "conv_a"] {
            let t = router.tenant(name).unwrap();
            let reqs = t.synth_requests(12, 42);
            let expected: Vec<Vec<i8>> =
                reqs.iter().map(|r| t.reference(&r.data)).collect();
            let out = router.serve(name, reqs).unwrap();
            assert_eq!(out.len(), 12);
            for (i, r) in out.iter().enumerate() {
                assert_eq!(r.id, i as u64, "{name}: order preserved");
                assert_eq!(r.data, expected[i], "{name}: item {i} digest mismatch");
                assert_eq!(r.data.len(), t.out_elems);
            }
            let snap = t.metrics.snapshot();
            assert_eq!(snap.submitted, 12);
            assert_eq!(snap.completed, 12);
            assert_eq!(snap.errors, 0);
        }
        let s = router.metrics.snapshot();
        assert_eq!(s.admitted, 2);
        assert_eq!(s.routed_requests, 24);
        router.shutdown();
    }

    #[test]
    fn concurrent_tenants_stay_isolated() {
        // two tenants served from two threads at once: responses must not
        // cross deployments (distinct salts => distinct digests)
        let (router, _plan) = deploy(&["fc_small", "conv_a"], 4);
        router.wait_ready().unwrap();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for name in ["fc_small", "conv_a"] {
                let router = &router;
                handles.push(scope.spawn(move || {
                    let t = router.tenant(name).unwrap();
                    let reqs = t.synth_requests(30, 7);
                    let expected: Vec<Vec<i8>> =
                        reqs.iter().map(|r| t.reference(&r.data)).collect();
                    let out = router.serve(name, reqs).unwrap();
                    for (r, e) in out.iter().zip(&expected) {
                        assert_eq!(&r.data, e, "{name} cross-tenant corruption");
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        router.shutdown();
    }

    #[test]
    fn concurrent_calls_for_the_same_tenant_do_not_cross_deliver() {
        // two threads hammer ONE deployment: serve() serializes them, so
        // each caller must get back exactly its own (id, digest) set
        let (router, _plan) = deploy(&["fc_small"], 1);
        router.wait_ready().unwrap();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for seed in [11u64, 22] {
                let router = &router;
                handles.push(scope.spawn(move || {
                    let t = router.tenant("fc_small").unwrap();
                    let reqs = t.synth_requests(20, seed);
                    let expected: Vec<Vec<i8>> =
                        reqs.iter().map(|r| t.reference(&r.data)).collect();
                    let out = router.serve("fc_small", reqs).unwrap();
                    assert_eq!(out.len(), 20);
                    for (i, r) in out.iter().enumerate() {
                        assert_eq!(r.id, i as u64, "seed {seed}");
                        assert_eq!(r.data, expected[i], "seed {seed}: cross-delivery");
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        assert_eq!(router.tenant("fc_small").unwrap().metrics.snapshot().completed, 40);
        router.shutdown();
    }

    #[test]
    fn replicated_deployment_serves_through_replica_router() {
        // one 1-TPU model on a 3-TPU pool -> leftover TPUs become replicas
        let (router, plan) = deploy(&["fc_small"], 3);
        let a = plan.assignment("fc_small").unwrap();
        assert!(a.replicas > 1, "expected replicas, got {a:?}");
        router.wait_ready().unwrap();
        let t = router.tenant("fc_small").unwrap();
        assert_eq!(t.replicas, a.replicas);
        let reqs = t.synth_requests(31, 3);
        let expected: Vec<Vec<i8>> = reqs.iter().map(|r| t.reference(&r.data)).collect();
        let out = router.serve("fc_small", reqs).unwrap();
        assert_eq!(out.len(), 31);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.data, expected[i]);
        }
        router.shutdown();
    }

    #[test]
    fn sim_latency_metrics_do_not_grow_across_batches() {
        // the pipeline's simulated clock is monotonic across batches;
        // recorded sim latencies must stay per-batch, not cumulative
        let (router, _plan) = deploy(&["fc_small"], 1);
        router.wait_ready().unwrap();
        let t = router.tenant("fc_small").unwrap();
        router.serve("fc_small", t.synth_requests(15, 1)).unwrap();
        let first = t.metrics.snapshot().sim_p99_s;
        for seed in 2..6u64 {
            router.serve("fc_small", t.synth_requests(15, seed)).unwrap();
        }
        let after = t.metrics.snapshot().sim_p99_s;
        assert!(
            after <= first * 2.0 + 1e-6,
            "sim latency must not accumulate across batches: {first} -> {after}"
        );
        router.shutdown();
    }

    #[test]
    fn unknown_model_is_a_route_miss() {
        let (router, _plan) = deploy(&["fc_small"], 1);
        let err = router.serve("nope", Vec::new()).unwrap_err();
        assert!(err.to_string().contains("no deployment"), "{err}");
        assert_eq!(router.metrics.snapshot().route_misses, 1);
        router.shutdown();
    }
}
