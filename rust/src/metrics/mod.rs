//! Serving metrics: per-stage counters/timers, end-to-end latency
//! histograms, per-tenant batching counters (queue depth / flush reason),
//! pool-scheduler re-plan counters, and the data-plane handoff/allocation
//! counters behind the zero-copy batched request path, shared across
//! worker threads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::{LatencyHistogram, Summary};

/// Why a dynamic batch was flushed (see `coordinator::batcher`).  Defined
/// here so both the batcher and the metrics layer can name it without a
/// dependency cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushKind {
    /// The batch reached `max_batch` pending requests.
    Size,
    /// The oldest pending request hit the `max_wait` deadline.
    Deadline,
    /// The request queue was closed and drained.
    Closed,
}

/// Metrics for one pipeline stage (one TPU worker).
#[derive(Debug, Default)]
pub struct StageMetrics {
    inner: Mutex<StageInner>,
}

#[derive(Debug, Default)]
struct StageInner {
    items: u64,
    busy_s: f64,
    exec: Summary,
}

impl StageMetrics {
    pub fn record(&self, exec: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.items += 1;
        g.busy_s += exec.as_secs_f64();
        g.exec.add(exec.as_secs_f64());
    }

    /// Record one batched backend call covering `items` requests in
    /// `exec` total: the per-item timing sample is the batch mean (the
    /// data plane executes whole batches, so per-item wall times are no
    /// longer observed individually).
    pub fn record_batch(&self, items: u64, exec: Duration) {
        if items == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.items += items;
        g.busy_s += exec.as_secs_f64();
        g.exec.add(exec.as_secs_f64() / items as f64);
    }

    pub fn snapshot(&self) -> StageSnapshot {
        let g = self.inner.lock().unwrap();
        StageSnapshot {
            items: g.items,
            busy_s: g.busy_s,
            mean_exec_s: g.exec.mean(),
            p95_exec_s: if g.exec.is_empty() { f64::NAN } else { g.exec.p95() },
        }
    }
}

/// Immutable view of one stage's counters.
#[derive(Debug, Clone, Copy)]
pub struct StageSnapshot {
    pub items: u64,
    pub busy_s: f64,
    pub mean_exec_s: f64,
    pub p95_exec_s: f64,
}

/// End-to-end serving metrics.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    inner: Mutex<ServeInner>,
}

#[derive(Debug)]
struct ServeInner {
    completed: u64,
    real_latency: LatencyHistogram,
    sim_latency: LatencyHistogram,
}

impl Default for ServeInner {
    fn default() -> Self {
        ServeInner {
            completed: 0,
            real_latency: LatencyHistogram::new(),
            sim_latency: LatencyHistogram::new(),
        }
    }
}

impl ServeMetrics {
    pub fn record(&self, real_s: f64, sim_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        g.real_latency.record(real_s);
        g.sim_latency.record(sim_s);
    }

    pub fn snapshot(&self) -> ServeSnapshot {
        let g = self.inner.lock().unwrap();
        ServeSnapshot {
            completed: g.completed,
            real_p50_s: g.real_latency.percentile(50.0),
            real_p95_s: g.real_latency.percentile(95.0),
            real_p99_s: g.real_latency.percentile(99.0),
            real_mean_s: g.real_latency.mean(),
            sim_p50_s: g.sim_latency.percentile(50.0),
            sim_p99_s: g.sim_latency.percentile(99.0),
            sim_mean_s: g.sim_latency.mean(),
        }
    }
}

/// Immutable view of serving totals.
#[derive(Debug, Clone, Copy)]
pub struct ServeSnapshot {
    pub completed: u64,
    pub real_p50_s: f64,
    pub real_p95_s: f64,
    pub real_p99_s: f64,
    pub real_mean_s: f64,
    pub sim_p50_s: f64,
    pub sim_p99_s: f64,
    pub sim_mean_s: f64,
}

/// Per-tenant serving metrics for the multi-tenant pool router: the
/// shared [`ServeMetrics`] latency bookkeeping plus request accounting
/// (submitted / errors) that only exists at the routing layer.
#[derive(Debug, Default)]
pub struct TenantMetrics {
    core: ServeMetrics,
    extra: Mutex<TenantCounters>,
}

#[derive(Debug, Default)]
struct TenantCounters {
    submitted: u64,
    errors: u64,
    batches: u64,
    batched_requests: u64,
    flush_size: u64,
    flush_deadline: u64,
    flush_closed: u64,
    max_queue_depth: u64,
    swaps: u64,
    swaps_skipped: u64,
    swap_overhead_s: f64,
}

impl TenantMetrics {
    /// Count `n` requests handed to this tenant's deployment or queue.
    pub fn record_submitted(&self, n: u64) {
        self.extra.lock().unwrap().submitted += n;
    }

    /// Record one completed response's real and simulated latency.
    pub fn record_response(&self, real_s: f64, sim_s: f64) {
        self.core.record(real_s, sim_s);
    }

    /// Count one failed batch/serve call.
    pub fn record_error(&self) {
        self.extra.lock().unwrap().errors += 1;
    }

    /// Record one flushed batch: its size, the ingress-queue depth left
    /// behind at flush time, and why it flushed.
    pub fn record_batch(&self, batch_len: u64, queue_depth: u64, kind: FlushKind) {
        let mut g = self.extra.lock().unwrap();
        g.batches += 1;
        g.batched_requests += batch_len;
        match kind {
            FlushKind::Size => g.flush_size += 1,
            FlushKind::Deadline => g.flush_deadline += 1,
            FlushKind::Closed => g.flush_closed += 1,
        }
        if queue_depth > g.max_queue_depth {
            g.max_queue_depth = queue_depth;
        }
    }

    /// Record one context switch of a time-shared deployment: the
    /// co-resident ran in between, so this tenant's segment parameters
    /// were re-loaded from host memory at `overhead_s` simulated cost.
    pub fn record_swap(&self, overhead_s: f64) {
        let mut g = self.extra.lock().unwrap();
        g.swaps += 1;
        g.swap_overhead_s += overhead_s;
    }

    /// Record a batch flush that landed inside the tenant's current
    /// scheduling quantum: the parameters stayed resident and no re-load
    /// was paid (only time-shared deployments with `--quantum-us > 0`
    /// ever skip).
    pub fn record_swap_skipped(&self) {
        self.extra.lock().unwrap().swaps_skipped += 1;
    }

    /// Take an immutable snapshot of every counter.
    pub fn snapshot(&self) -> TenantSnapshot {
        let c = self.core.snapshot();
        let e = self.extra.lock().unwrap();
        TenantSnapshot {
            submitted: e.submitted,
            completed: c.completed,
            errors: e.errors,
            batches: e.batches,
            mean_batch: if e.batches == 0 {
                f64::NAN
            } else {
                e.batched_requests as f64 / e.batches as f64
            },
            flush_size: e.flush_size,
            flush_deadline: e.flush_deadline,
            flush_closed: e.flush_closed,
            max_queue_depth: e.max_queue_depth,
            swaps: e.swaps,
            swaps_skipped: e.swaps_skipped,
            swap_overhead_s: e.swap_overhead_s,
            real_p50_s: c.real_p50_s,
            real_p99_s: c.real_p99_s,
            sim_p50_s: c.sim_p50_s,
            sim_p99_s: c.sim_p99_s,
        }
    }
}

/// Immutable view of one tenant's counters.
#[derive(Debug, Clone, Copy)]
pub struct TenantSnapshot {
    /// Requests submitted (closed batches + open-loop arrivals).
    pub submitted: u64,
    /// Responses completed.
    pub completed: u64,
    /// Failed serve calls.
    pub errors: u64,
    /// Dynamic batches flushed into the pipeline.
    pub batches: u64,
    /// Mean flushed-batch size (NaN before the first flush).
    pub mean_batch: f64,
    /// Batches flushed because `max_batch` was reached.
    pub flush_size: u64,
    /// Batches flushed because `max_wait` expired.
    pub flush_deadline: u64,
    /// Batches flushed because the ingress queue closed.
    pub flush_closed: u64,
    /// Maximum ingress-queue depth observed at any flush.
    pub max_queue_depth: u64,
    /// Context switches of a time-shared deployment (0 when exclusive).
    pub swaps: u64,
    /// Batch flushes that stayed inside the scheduling quantum and
    /// skipped the re-load (0 when exclusive or `quantum_us = 0`).
    pub swaps_skipped: u64,
    /// Cumulative simulated parameter re-load time across those swaps.
    pub swap_overhead_s: f64,
    /// Real wall-clock latency p50 (seconds).
    pub real_p50_s: f64,
    /// Real wall-clock latency p99 (seconds).
    pub real_p99_s: f64,
    /// Simulated Edge TPU latency p50 (seconds).
    pub sim_p50_s: f64,
    /// Simulated Edge TPU latency p99 (seconds).
    pub sim_p99_s: f64,
}

/// Data-plane counters for the zero-copy batched request path: how many
/// batch messages crossed a host queue (handoffs), how many requests they
/// carried, and the buffer arena's allocation traffic.  Lock-free
/// (atomics): these sit on the per-batch hot path of every stage worker.
///
/// The steady-state invariant the `make smoke-dataplane` gate asserts is
/// `slab_allocs` staying **flat** while requests keep completing — the
/// arena recycles every activation slab, so the per-request allocation
/// count is zero once the pool is warm.
#[derive(Debug, Default)]
pub struct DataPlaneMetrics {
    handoffs: AtomicU64,
    handoff_items: AtomicU64,
    slab_allocs: AtomicU64,
    slab_alloc_bytes: AtomicU64,
    slab_reuses: AtomicU64,
}

impl DataPlaneMetrics {
    /// Count one batch message crossing a host queue with `items`
    /// requests aboard (one lock/wakeup moved the whole batch).
    pub fn record_handoff(&self, items: u64) {
        self.handoffs.fetch_add(1, Ordering::Relaxed);
        self.handoff_items.fetch_add(items, Ordering::Relaxed);
    }

    /// Count one arena miss: a fresh slab of `bytes` was heap-allocated.
    pub fn record_slab_alloc(&self, bytes: u64) {
        self.slab_allocs.fetch_add(1, Ordering::Relaxed);
        self.slab_alloc_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Count one arena hit: a retained slab was reused without allocating.
    pub fn record_slab_reuse(&self) {
        self.slab_reuses.fetch_add(1, Ordering::Relaxed);
    }

    /// Take an immutable snapshot of every counter.
    pub fn snapshot(&self) -> DataPlaneSnapshot {
        DataPlaneSnapshot {
            handoffs: self.handoffs.load(Ordering::Relaxed),
            handoff_items: self.handoff_items.load(Ordering::Relaxed),
            slab_allocs: self.slab_allocs.load(Ordering::Relaxed),
            slab_alloc_bytes: self.slab_alloc_bytes.load(Ordering::Relaxed),
            slab_reuses: self.slab_reuses.load(Ordering::Relaxed),
        }
    }
}

/// Immutable view of the data-plane counters.
#[derive(Debug, Clone, Copy)]
pub struct DataPlaneSnapshot {
    /// Batch messages moved across host queues (ingress + stage hops).
    pub handoffs: u64,
    /// Requests carried by those batch messages.
    pub handoff_items: u64,
    /// Fresh slab heap allocations (arena misses).
    pub slab_allocs: u64,
    /// Bytes of those fresh allocations.
    pub slab_alloc_bytes: u64,
    /// Slab takes served from the free list (arena hits).
    pub slab_reuses: u64,
}

impl DataPlaneSnapshot {
    /// Mean requests moved per channel handoff (NaN before any handoff).
    pub fn items_per_handoff(&self) -> f64 {
        self.handoff_items as f64 / self.handoffs as f64
    }
}

/// Pool-scheduler counters: registration, admission and routing totals.
#[derive(Debug, Default)]
pub struct SchedulerMetrics {
    inner: Mutex<SchedulerInner>,
}

#[derive(Debug, Default)]
struct SchedulerInner {
    registered: u64,
    admitted: u64,
    shared: u64,
    queued: u64,
    rejected: u64,
    routed_batches: u64,
    routed_requests: u64,
    route_misses: u64,
    replans: u64,
    drained_deployments: u64,
}

impl SchedulerMetrics {
    /// Overwrite the admission totals with the latest plan's outcome.
    /// `shared` counts admitted tenants holding a time-multiplexed grant.
    pub fn record_admission(
        &self,
        registered: u64,
        admitted: u64,
        shared: u64,
        queued: u64,
        rejected: u64,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.registered = registered;
        g.admitted = admitted;
        g.shared = shared;
        g.queued = queued;
        g.rejected = rejected;
    }

    /// Count one routed batch of `requests` requests.
    pub fn record_routed(&self, requests: u64) {
        let mut g = self.inner.lock().unwrap();
        g.routed_batches += 1;
        g.routed_requests += requests;
    }

    /// Count a request for a model with no live deployment.
    pub fn record_route_miss(&self) {
        self.inner.lock().unwrap().route_misses += 1;
    }

    /// Count one online re-plan (registration change on a live pool) that
    /// drained `drained` deployments before redeploying.
    pub fn record_replan(&self, drained: u64) {
        let mut g = self.inner.lock().unwrap();
        g.replans += 1;
        g.drained_deployments += drained;
    }

    /// Take an immutable snapshot of every counter.
    pub fn snapshot(&self) -> SchedulerSnapshot {
        let g = self.inner.lock().unwrap();
        SchedulerSnapshot {
            registered: g.registered,
            admitted: g.admitted,
            shared: g.shared,
            queued: g.queued,
            rejected: g.rejected,
            routed_batches: g.routed_batches,
            routed_requests: g.routed_requests,
            route_misses: g.route_misses,
            replans: g.replans,
            drained_deployments: g.drained_deployments,
        }
    }
}

/// Immutable view of the scheduler counters.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerSnapshot {
    /// Tenants registered at the last plan.
    pub registered: u64,
    /// Tenants admitted by the last plan.
    pub admitted: u64,
    /// Admitted tenants holding a time-multiplexed (shared) grant.
    pub shared: u64,
    /// Tenants queued (pool too small) by the last plan.
    pub queued: u64,
    /// Tenants rejected (can never fit) by the last plan.
    pub rejected: u64,
    /// Batches routed through the pool router.
    pub routed_batches: u64,
    /// Requests routed through the pool router.
    pub routed_requests: u64,
    /// Requests for models with no live deployment.
    pub route_misses: u64,
    /// Online re-plans triggered by register/deregister on a live pool.
    pub replans: u64,
    /// Deployments drained (and redeployed or retired) across all re-plans.
    pub drained_deployments: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_metrics_accumulate() {
        let m = StageMetrics::default();
        m.record(Duration::from_millis(2));
        m.record(Duration::from_millis(4));
        let s = m.snapshot();
        assert_eq!(s.items, 2);
        assert!((s.busy_s - 0.006).abs() < 1e-9);
        assert!((s.mean_exec_s - 0.003).abs() < 1e-9);
    }

    #[test]
    fn serve_metrics_histograms() {
        let m = ServeMetrics::default();
        for i in 1..=100 {
            m.record(i as f64 * 1e-3, i as f64 * 2e-3);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert!(s.real_p50_s > 0.03 && s.real_p50_s < 0.08, "{s:?}");
        assert!(s.sim_mean_s > s.real_mean_s);
    }

    #[test]
    fn tenant_metrics_accounting() {
        let m = TenantMetrics::default();
        m.record_submitted(10);
        for i in 1..=8 {
            m.record_response(i as f64 * 1e-3, i as f64 * 2e-3);
        }
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.submitted, 10);
        assert_eq!(s.completed, 8);
        assert_eq!(s.errors, 1);
        assert!(s.real_p99_s >= s.real_p50_s, "{s:?}");
        assert!(s.sim_p50_s > s.real_p50_s, "{s:?}");
    }

    #[test]
    fn scheduler_metrics_accounting() {
        let m = SchedulerMetrics::default();
        m.record_admission(5, 3, 1, 1, 1);
        m.record_routed(50);
        m.record_routed(20);
        m.record_route_miss();
        m.record_replan(2);
        m.record_replan(0);
        let s = m.snapshot();
        assert_eq!(s.registered, 5);
        assert_eq!(s.admitted, 3);
        assert_eq!(s.shared, 1);
        assert_eq!(s.queued, 1);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.routed_batches, 2);
        assert_eq!(s.routed_requests, 70);
        assert_eq!(s.route_misses, 1);
        assert_eq!(s.replans, 2);
        assert_eq!(s.drained_deployments, 2);
    }

    #[test]
    fn tenant_batch_counters() {
        let m = TenantMetrics::default();
        m.record_batch(8, 3, FlushKind::Size);
        m.record_batch(2, 0, FlushKind::Deadline);
        m.record_batch(1, 0, FlushKind::Closed);
        m.record_batch(5, 1, FlushKind::Size);
        let s = m.snapshot();
        assert_eq!(s.batches, 4);
        assert_eq!(s.flush_size, 2);
        assert_eq!(s.flush_deadline, 1);
        assert_eq!(s.flush_closed, 1);
        assert_eq!(s.max_queue_depth, 3);
        assert!((s.mean_batch - 4.0).abs() < 1e-12, "{s:?}");
        assert_eq!(s.swaps, 0, "exclusive tenants never swap");
    }

    #[test]
    fn tenant_swap_counters_accumulate() {
        let m = TenantMetrics::default();
        m.record_swap(2e-3);
        m.record_swap(2e-3);
        m.record_swap_skipped();
        let s = m.snapshot();
        assert_eq!(s.swaps, 2);
        assert_eq!(s.swaps_skipped, 1);
        assert!((s.swap_overhead_s - 4e-3).abs() < 1e-12, "{s:?}");
    }

    #[test]
    fn stage_metrics_batched_recording() {
        let m = StageMetrics::default();
        m.record_batch(10, Duration::from_millis(20));
        m.record_batch(0, Duration::from_millis(5)); // ignored
        let s = m.snapshot();
        assert_eq!(s.items, 10);
        assert!((s.busy_s - 0.020).abs() < 1e-9);
        assert!((s.mean_exec_s - 0.002).abs() < 1e-9, "per-item mean of the batch");
    }

    #[test]
    fn data_plane_counters_accumulate() {
        let m = DataPlaneMetrics::default();
        m.record_handoff(8);
        m.record_handoff(2);
        m.record_slab_alloc(512);
        m.record_slab_reuse();
        m.record_slab_reuse();
        let s = m.snapshot();
        assert_eq!(s.handoffs, 2);
        assert_eq!(s.handoff_items, 10);
        assert_eq!(s.slab_allocs, 1);
        assert_eq!(s.slab_alloc_bytes, 512);
        assert_eq!(s.slab_reuses, 2);
        assert!((s.items_per_handoff() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(StageMetrics::default());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..250 {
                        m.record(Duration::from_micros(10));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().items, 1000);
    }
}
