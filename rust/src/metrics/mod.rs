//! Serving metrics: per-stage counters/timers, end-to-end latency
//! histograms, per-tenant batching counters (queue depth / flush reason),
//! pool-scheduler re-plan counters, and the data-plane handoff/allocation
//! counters behind the zero-copy batched request path, shared across
//! worker threads.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::obs::{num, MetricSource};
use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

/// Build a stable-order JSON object from metric fields.
fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn uint(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Why a dynamic batch was flushed (see `coordinator::batcher`).  Defined
/// here so both the batcher and the metrics layer can name it without a
/// dependency cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushKind {
    /// The batch reached `max_batch` pending requests.
    Size,
    /// The oldest pending request hit the `max_wait` deadline.
    Deadline,
    /// The request queue was closed and drained.
    Closed,
}

/// Metrics for one pipeline stage (one TPU worker).
#[derive(Debug, Default)]
pub struct StageMetrics {
    inner: Mutex<StageInner>,
}

#[derive(Debug, Default)]
struct StageInner {
    items: u64,
    busy_s: f64,
    /// Streaming log-bucketed histogram of per-item execution time — O(1)
    /// memory under open-loop load (the former full-sample `Summary` grew
    /// one `f64` per batch forever).  Its mean stays exact.
    exec: LatencyHistogram,
}

impl StageMetrics {
    pub fn record(&self, exec: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.items += 1;
        g.busy_s += exec.as_secs_f64();
        g.exec.record(exec.as_secs_f64());
    }

    /// Record one batched backend call covering `items` requests in
    /// `exec` total: the per-item timing sample is the batch mean (the
    /// data plane executes whole batches, so per-item wall times are no
    /// longer observed individually).
    pub fn record_batch(&self, items: u64, exec: Duration) {
        if items == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.items += items;
        g.busy_s += exec.as_secs_f64();
        g.exec.record(exec.as_secs_f64() / items as f64);
    }

    pub fn snapshot(&self) -> StageSnapshot {
        let g = self.inner.lock().unwrap();
        StageSnapshot {
            items: g.items,
            busy_s: g.busy_s,
            mean_exec_s: g.exec.mean(),
            p95_exec_s: g.exec.percentile(95.0),
        }
    }
}

impl MetricSource for StageMetrics {
    fn metric_kind(&self) -> &'static str {
        "stage"
    }

    fn metric_json(&self) -> Json {
        let s = self.snapshot();
        obj(vec![
            ("items", uint(s.items)),
            ("busy_s", Json::Num(s.busy_s)),
            ("mean_exec_s", num(s.mean_exec_s)),
            ("p95_exec_s", num(s.p95_exec_s)),
        ])
    }
}

/// Immutable view of one stage's counters.
#[derive(Debug, Clone, Copy)]
pub struct StageSnapshot {
    pub items: u64,
    pub busy_s: f64,
    pub mean_exec_s: f64,
    pub p95_exec_s: f64,
}

/// End-to-end serving metrics.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    inner: Mutex<ServeInner>,
}

#[derive(Debug)]
struct ServeInner {
    completed: u64,
    real_latency: LatencyHistogram,
    sim_latency: LatencyHistogram,
}

impl Default for ServeInner {
    fn default() -> Self {
        ServeInner {
            completed: 0,
            real_latency: LatencyHistogram::new(),
            sim_latency: LatencyHistogram::new(),
        }
    }
}

impl ServeMetrics {
    pub fn record(&self, real_s: f64, sim_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        g.real_latency.record(real_s);
        g.sim_latency.record(sim_s);
    }

    fn snapshot_inner(g: &ServeInner) -> ServeSnapshot {
        ServeSnapshot {
            completed: g.completed,
            real_p50_s: g.real_latency.percentile(50.0),
            real_p95_s: g.real_latency.percentile(95.0),
            real_p99_s: g.real_latency.percentile(99.0),
            real_p999_s: g.real_latency.percentile(99.9),
            real_mean_s: g.real_latency.mean(),
            sim_p50_s: g.sim_latency.percentile(50.0),
            sim_p99_s: g.sim_latency.percentile(99.0),
            sim_mean_s: g.sim_latency.mean(),
        }
    }

    pub fn snapshot(&self) -> ServeSnapshot {
        Self::snapshot_inner(&self.inner.lock().unwrap())
    }
}

impl MetricSource for ServeMetrics {
    fn metric_kind(&self) -> &'static str {
        "serve"
    }

    fn metric_json(&self) -> Json {
        let s = self.snapshot();
        obj(vec![
            ("completed", uint(s.completed)),
            ("real_p50_s", num(s.real_p50_s)),
            ("real_p95_s", num(s.real_p95_s)),
            ("real_p99_s", num(s.real_p99_s)),
            ("real_p999_s", num(s.real_p999_s)),
            ("real_mean_s", num(s.real_mean_s)),
            ("sim_p50_s", num(s.sim_p50_s)),
            ("sim_p99_s", num(s.sim_p99_s)),
            ("sim_mean_s", num(s.sim_mean_s)),
        ])
    }
}

/// Immutable view of serving totals.
#[derive(Debug, Clone, Copy)]
pub struct ServeSnapshot {
    pub completed: u64,
    pub real_p50_s: f64,
    pub real_p95_s: f64,
    pub real_p99_s: f64,
    /// p99.9 from the streaming histogram (NaN before the first sample).
    pub real_p999_s: f64,
    pub real_mean_s: f64,
    pub sim_p50_s: f64,
    pub sim_p99_s: f64,
    pub sim_mean_s: f64,
}

/// Per-tenant serving metrics for the multi-tenant pool router: the
/// shared [`ServeMetrics`] latency bookkeeping plus request accounting
/// (submitted / errors) that only exists at the routing layer.
#[derive(Debug, Default)]
pub struct TenantMetrics {
    core: ServeMetrics,
    extra: Mutex<TenantCounters>,
    /// Mutation generation, bumped after every recording call.  `core`
    /// and `extra` sit behind separate locks, so two independent lock
    /// acquisitions could observe a torn cross-lock view (e.g. a swap
    /// counted whose response is missing); `snapshot` retries until a
    /// read round saw no bump.
    gen: AtomicU64,
}

#[derive(Debug, Default, Clone, Copy)]
struct TenantCounters {
    submitted: u64,
    errors: u64,
    batches: u64,
    batched_requests: u64,
    flush_size: u64,
    flush_deadline: u64,
    flush_closed: u64,
    max_queue_depth: u64,
    swaps: u64,
    swaps_skipped: u64,
    swap_overhead_s: f64,
    cache_hits: u64,
    cache_misses: u64,
    prefetches: u64,
    hedges: u64,
    shed: u64,
    deadline_shed: u64,
    drift: f64,
}

impl TenantMetrics {
    /// Publish one completed mutation (called after the lock section).
    fn bump(&self) {
        self.gen.fetch_add(1, Ordering::Release);
    }

    /// Count `n` requests handed to this tenant's deployment or queue.
    pub fn record_submitted(&self, n: u64) {
        self.extra.lock().unwrap().submitted += n;
        self.bump();
    }

    /// Record one completed response's real and simulated latency.
    pub fn record_response(&self, real_s: f64, sim_s: f64) {
        self.core.record(real_s, sim_s);
        self.bump();
    }

    /// Count one failed batch/serve call.
    pub fn record_error(&self) {
        self.extra.lock().unwrap().errors += 1;
        self.bump();
    }

    /// Record one flushed batch: its size, the ingress-queue depth left
    /// behind at flush time, and why it flushed.
    pub fn record_batch(&self, batch_len: u64, queue_depth: u64, kind: FlushKind) {
        let mut g = self.extra.lock().unwrap();
        g.batches += 1;
        g.batched_requests += batch_len;
        match kind {
            FlushKind::Size => g.flush_size += 1,
            FlushKind::Deadline => g.flush_deadline += 1,
            FlushKind::Closed => g.flush_closed += 1,
        }
        if queue_depth > g.max_queue_depth {
            g.max_queue_depth = queue_depth;
        }
        drop(g);
        self.bump();
    }

    /// Record one context switch of a time-shared deployment: the
    /// co-resident ran in between, so this tenant's segment parameters
    /// were re-loaded from host memory at `overhead_s` simulated cost.
    pub fn record_swap(&self, overhead_s: f64) {
        let mut g = self.extra.lock().unwrap();
        g.swaps += 1;
        g.swap_overhead_s += overhead_s;
        drop(g);
        self.bump();
    }

    /// Record a batch flush that landed inside the tenant's current
    /// scheduling quantum: the parameters stayed resident and no re-load
    /// was paid (only time-shared deployments with `--quantum-us > 0`
    /// ever skip).
    pub fn record_swap_skipped(&self) {
        self.extra.lock().unwrap().swaps_skipped += 1;
        self.bump();
    }

    /// Record the parameter-cache outcome of one quantum-gated swap: a
    /// warm hit skipped the re-load entirely, a miss paid (part of) the
    /// cold cost, and a prefetch overlapped some of that cost with the
    /// tail of the previous quantum.  Only recorded when the deployment
    /// carries a cache effect (`--cache-budget-bytes > 0`), so cache-off
    /// runs keep every counter at zero.
    pub fn record_cache(&self, hit: bool, prefetched: bool) {
        let mut g = self.extra.lock().unwrap();
        if hit {
            g.cache_hits += 1;
        } else {
            g.cache_misses += 1;
        }
        if prefetched {
            g.prefetches += 1;
        }
        drop(g);
        self.bump();
    }

    /// Count `n` requests duplicated onto a healthy replica because their
    /// assigned replica's tail latency breached the straggler threshold.
    pub fn record_hedges(&self, n: u64) {
        self.extra.lock().unwrap().hedges += n;
        self.bump();
    }

    /// Count one request turned away by priority-tiered load shedding
    /// (accounted, never silently lost).
    pub fn record_shed(&self) {
        self.extra.lock().unwrap().shed += 1;
        self.bump();
    }

    /// Count `n` requests shed because their deadline expired before
    /// dispatch (the caller still receives a typed `Expired` outcome —
    /// deadline sheds are accounted, never silently dropped).
    pub fn record_deadline_shed(&self, n: u64) {
        self.extra.lock().unwrap().deadline_shed += n;
        self.bump();
    }

    /// Publish the calibrator's latest predicted-vs-observed p99 drift
    /// for this tenant — a gauge, overwritten at every calibration
    /// window (`scheduler::calibrate`), not an accumulating counter.
    pub fn record_drift(&self, drift: f64) {
        self.extra.lock().unwrap().drift = drift;
        self.bump();
    }

    /// Clone of the tenant's lifetime *simulated*-latency histogram.  The
    /// online calibrator diffs successive clones (`delta_since`) to build
    /// its windowed view of recent behavior, so the hot recording path
    /// needs no extra per-window state.
    pub fn sim_latency_hist(&self) -> LatencyHistogram {
        self.core.inner.lock().unwrap().sim_latency.clone()
    }

    /// Take an immutable snapshot of every counter, consistent across the
    /// two lock domains: optimistic generation-checked reads first, then
    /// a fallback that holds both locks at once (which blocks every
    /// mutator, so the cut is exact).
    pub fn snapshot(&self) -> TenantSnapshot {
        for _ in 0..8 {
            let g0 = self.gen.load(Ordering::Acquire);
            let c = self.core.snapshot();
            let e = *self.extra.lock().unwrap();
            if self.gen.load(Ordering::Acquire) == g0 {
                return Self::assemble(c, e);
            }
        }
        let core_guard = self.core.inner.lock().unwrap();
        let extra_guard = self.extra.lock().unwrap();
        let c = ServeMetrics::snapshot_inner(&core_guard);
        let e = *extra_guard;
        Self::assemble(c, e)
    }

    fn assemble(c: ServeSnapshot, e: TenantCounters) -> TenantSnapshot {
        TenantSnapshot {
            submitted: e.submitted,
            completed: c.completed,
            errors: e.errors,
            batches: e.batches,
            mean_batch: if e.batches == 0 {
                f64::NAN
            } else {
                e.batched_requests as f64 / e.batches as f64
            },
            flush_size: e.flush_size,
            flush_deadline: e.flush_deadline,
            flush_closed: e.flush_closed,
            max_queue_depth: e.max_queue_depth,
            swaps: e.swaps,
            swaps_skipped: e.swaps_skipped,
            swap_overhead_s: e.swap_overhead_s,
            cache_hits: e.cache_hits,
            cache_misses: e.cache_misses,
            prefetches: e.prefetches,
            hedges: e.hedges,
            shed: e.shed,
            deadline_shed: e.deadline_shed,
            drift: e.drift,
            real_p50_s: c.real_p50_s,
            real_p99_s: c.real_p99_s,
            real_p999_s: c.real_p999_s,
            sim_p50_s: c.sim_p50_s,
            sim_p99_s: c.sim_p99_s,
        }
    }
}

impl MetricSource for TenantMetrics {
    fn metric_kind(&self) -> &'static str {
        "tenant"
    }

    fn metric_json(&self) -> Json {
        let s = self.snapshot();
        let mut fields = vec![
            ("submitted", uint(s.submitted)),
            ("completed", uint(s.completed)),
            ("errors", uint(s.errors)),
            ("batches", uint(s.batches)),
            ("mean_batch", num(s.mean_batch)),
            ("flush_size", uint(s.flush_size)),
            ("flush_deadline", uint(s.flush_deadline)),
            ("flush_closed", uint(s.flush_closed)),
            ("max_queue_depth", uint(s.max_queue_depth)),
            ("swaps", uint(s.swaps)),
            ("swaps_skipped", uint(s.swaps_skipped)),
            ("swap_overhead_s", Json::Num(s.swap_overhead_s)),
            ("hedges", uint(s.hedges)),
            ("shed", uint(s.shed)),
            ("real_p50_s", num(s.real_p50_s)),
            ("real_p99_s", num(s.real_p99_s)),
            ("real_p999_s", num(s.real_p999_s)),
            ("sim_p50_s", num(s.sim_p50_s)),
            ("sim_p99_s", num(s.sim_p99_s)),
        ];
        // cache counters only exist on cache-enabled deployments; omit
        // them when untouched so cache-off exports stay byte-identical
        if s.cache_hits + s.cache_misses + s.prefetches > 0 {
            fields.push(("cache_hits", uint(s.cache_hits)));
            fields.push(("cache_misses", uint(s.cache_misses)));
            fields.push(("prefetches", uint(s.prefetches)));
        }
        // deadline sheds only happen on deadline-enabled pools; omit the
        // field at zero so deadline-off exports stay byte-identical
        if s.deadline_shed > 0 {
            fields.push(("deadline_shed", uint(s.deadline_shed)));
        }
        // the drift gauge only moves when online calibration is enabled;
        // omit it at rest so calibration-off exports stay byte-identical
        if s.drift != 0.0 {
            fields.push(("drift", num(s.drift)));
        }
        obj(fields)
    }
}

/// Immutable view of one tenant's counters.
#[derive(Debug, Clone, Copy)]
pub struct TenantSnapshot {
    /// Requests submitted (closed batches + open-loop arrivals).
    pub submitted: u64,
    /// Responses completed.
    pub completed: u64,
    /// Failed serve calls.
    pub errors: u64,
    /// Dynamic batches flushed into the pipeline.
    pub batches: u64,
    /// Mean flushed-batch size (NaN before the first flush).
    pub mean_batch: f64,
    /// Batches flushed because `max_batch` was reached.
    pub flush_size: u64,
    /// Batches flushed because `max_wait` expired.
    pub flush_deadline: u64,
    /// Batches flushed because the ingress queue closed.
    pub flush_closed: u64,
    /// Maximum ingress-queue depth observed at any flush.
    pub max_queue_depth: u64,
    /// Context switches of a time-shared deployment (0 when exclusive).
    pub swaps: u64,
    /// Batch flushes that stayed inside the scheduling quantum and
    /// skipped the re-load (0 when exclusive or `quantum_us = 0`).
    pub swaps_skipped: u64,
    /// Cumulative simulated parameter re-load time across those swaps.
    pub swap_overhead_s: f64,
    /// Quantum-gated swaps whose parameters were still cache-resident
    /// (0 unless the plan was cache-enabled).
    pub cache_hits: u64,
    /// Quantum-gated swaps that paid a (partial) cold re-load
    /// (0 unless the plan was cache-enabled; `hits + misses == swaps`).
    pub cache_misses: u64,
    /// Swaps whose residual re-load overlapped the previous quantum's
    /// tail via prefetch (0 unless `--prefetch`).
    pub prefetches: u64,
    /// Requests duplicated onto a healthy replica by hedged dispatch.
    pub hedges: u64,
    /// Requests turned away by priority-tiered load shedding.
    pub shed: u64,
    /// Requests shed because their deadline expired before dispatch
    /// (callers received typed `Expired` outcomes; 0 unless deadlines
    /// are enabled).
    pub deadline_shed: u64,
    /// Latest calibration-window p99 drift (observed/expected − 1); 0
    /// until the online calibrator publishes a window for this tenant.
    pub drift: f64,
    /// Real wall-clock latency p50 (seconds).
    pub real_p50_s: f64,
    /// Real wall-clock latency p99 (seconds).
    pub real_p99_s: f64,
    /// Real wall-clock latency p99.9 (seconds; NaN before any response).
    pub real_p999_s: f64,
    /// Simulated Edge TPU latency p50 (seconds).
    pub sim_p50_s: f64,
    /// Simulated Edge TPU latency p99 (seconds).
    pub sim_p99_s: f64,
}

/// Data-plane counters for the zero-copy batched request path: how many
/// batch messages crossed a host queue (handoffs), how many requests they
/// carried, and the buffer arena's allocation traffic.  Atomics only (no
/// mutex, no allocation): these sit on the per-batch hot path of every
/// stage worker.  Related counters are updated under a seqlock-style
/// generation word, so `snapshot` never observes e.g. `handoff_items`
/// ahead of its `handoffs` increment (`items_per_handoff` used to exceed
/// the batch size mid-run).
///
/// The steady-state invariant the `make smoke-dataplane` gate asserts is
/// `slab_allocs` staying **flat** while requests keep completing — the
/// arena recycles every activation slab, so the per-request allocation
/// count is zero once the pool is warm.
#[derive(Debug, Default)]
pub struct DataPlaneMetrics {
    /// Seqlock generation: odd while a writer is inside an update.
    gen: AtomicU64,
    handoffs: AtomicU64,
    handoff_items: AtomicU64,
    slab_allocs: AtomicU64,
    slab_alloc_bytes: AtomicU64,
    slab_reuses: AtomicU64,
}

impl DataPlaneMetrics {
    /// Run `f` inside the write side of the seqlock: flip the generation
    /// odd (spinning out other writers — updates are a handful of relaxed
    /// adds, so the critical section is a few nanoseconds), then even.
    fn write_locked(&self, f: impl FnOnce(&Self)) {
        let mut cur = self.gen.load(Ordering::Relaxed);
        loop {
            if cur & 1 == 0 {
                match self.gen.compare_exchange_weak(
                    cur,
                    cur + 1,
                    Ordering::Acquire,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            } else {
                std::hint::spin_loop();
                cur = self.gen.load(Ordering::Relaxed);
            }
        }
        f(self);
        self.gen.fetch_add(1, Ordering::Release);
    }

    /// Count one batch message crossing a host queue with `items`
    /// requests aboard (one lock/wakeup moved the whole batch).
    pub fn record_handoff(&self, items: u64) {
        self.write_locked(|m| {
            m.handoffs.fetch_add(1, Ordering::Relaxed);
            m.handoff_items.fetch_add(items, Ordering::Relaxed);
        });
    }

    /// Count one arena miss: a fresh slab of `bytes` was heap-allocated.
    pub fn record_slab_alloc(&self, bytes: u64) {
        self.write_locked(|m| {
            m.slab_allocs.fetch_add(1, Ordering::Relaxed);
            m.slab_alloc_bytes.fetch_add(bytes, Ordering::Relaxed);
        });
    }

    /// Count one arena hit: a retained slab was reused without allocating.
    pub fn record_slab_reuse(&self) {
        self.write_locked(|m| {
            m.slab_reuses.fetch_add(1, Ordering::Relaxed);
        });
    }

    /// Take an immutable snapshot, consistent across every counter: retry
    /// until a read round saw a stable even generation.
    pub fn snapshot(&self) -> DataPlaneSnapshot {
        loop {
            let g0 = self.gen.load(Ordering::Acquire);
            if g0 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let snap = DataPlaneSnapshot {
                handoffs: self.handoffs.load(Ordering::Relaxed),
                handoff_items: self.handoff_items.load(Ordering::Relaxed),
                slab_allocs: self.slab_allocs.load(Ordering::Relaxed),
                slab_alloc_bytes: self.slab_alloc_bytes.load(Ordering::Relaxed),
                slab_reuses: self.slab_reuses.load(Ordering::Relaxed),
            };
            if self.gen.load(Ordering::Acquire) == g0 {
                return snap;
            }
        }
    }
}

impl MetricSource for DataPlaneMetrics {
    fn metric_kind(&self) -> &'static str {
        "data_plane"
    }

    fn metric_json(&self) -> Json {
        let s = self.snapshot();
        obj(vec![
            ("handoffs", uint(s.handoffs)),
            ("handoff_items", uint(s.handoff_items)),
            ("items_per_handoff", num(s.items_per_handoff())),
            ("slab_allocs", uint(s.slab_allocs)),
            ("slab_alloc_bytes", uint(s.slab_alloc_bytes)),
            ("slab_reuses", uint(s.slab_reuses)),
        ])
    }
}

/// Immutable view of the data-plane counters.
#[derive(Debug, Clone, Copy)]
pub struct DataPlaneSnapshot {
    /// Batch messages moved across host queues (ingress + stage hops).
    pub handoffs: u64,
    /// Requests carried by those batch messages.
    pub handoff_items: u64,
    /// Fresh slab heap allocations (arena misses).
    pub slab_allocs: u64,
    /// Bytes of those fresh allocations.
    pub slab_alloc_bytes: u64,
    /// Slab takes served from the free list (arena hits).
    pub slab_reuses: u64,
}

impl DataPlaneSnapshot {
    /// Mean requests moved per channel handoff (NaN before any handoff).
    pub fn items_per_handoff(&self) -> f64 {
        self.handoff_items as f64 / self.handoffs as f64
    }
}

/// Pool-scheduler counters: registration, admission and routing totals.
#[derive(Debug, Default)]
pub struct SchedulerMetrics {
    inner: Mutex<SchedulerInner>,
}

#[derive(Debug, Default)]
struct SchedulerInner {
    registered: u64,
    admitted: u64,
    shared: u64,
    queued: u64,
    rejected: u64,
    routed_batches: u64,
    routed_requests: u64,
    route_misses: u64,
    replans: u64,
    drained_deployments: u64,
    device_kills: u64,
    kill_repeats: u64,
    replans_calibration: u64,
    breaker_trips: u64,
    breaker_probes: u64,
    recoveries: u64,
}

impl SchedulerMetrics {
    /// Overwrite the admission totals with the latest plan's outcome.
    /// `shared` counts admitted tenants holding a time-multiplexed grant.
    pub fn record_admission(
        &self,
        registered: u64,
        admitted: u64,
        shared: u64,
        queued: u64,
        rejected: u64,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.registered = registered;
        g.admitted = admitted;
        g.shared = shared;
        g.queued = queued;
        g.rejected = rejected;
    }

    /// Count one routed batch of `requests` requests.
    pub fn record_routed(&self, requests: u64) {
        let mut g = self.inner.lock().unwrap();
        g.routed_batches += 1;
        g.routed_requests += requests;
    }

    /// Count a request for a model with no live deployment.
    pub fn record_route_miss(&self) {
        self.inner.lock().unwrap().route_misses += 1;
    }

    /// Count one online re-plan (registration change on a live pool) that
    /// drained `drained` deployments before redeploying.
    pub fn record_replan(&self, drained: u64) {
        let mut g = self.inner.lock().unwrap();
        g.replans += 1;
        g.drained_deployments += drained;
    }

    /// Count one injected/observed device death the pool re-planned
    /// around (`ServingPool::kill_device`).
    pub fn record_device_kill(&self) {
        self.inner.lock().unwrap().device_kills += 1;
    }

    /// Count one rejected kill of a device that was already dead — a
    /// repeated kill is a typed error, not a silent no-op, and this
    /// counter is how operators see retry storms.
    pub fn record_kill_repeat(&self) {
        self.inner.lock().unwrap().kill_repeats += 1;
    }

    /// Count one replica circuit breaker tripping open (consecutive
    /// watchdog breaches quarantined the replica from dispatch/hedging).
    pub fn record_breaker_trip(&self) {
        self.inner.lock().unwrap().breaker_trips += 1;
    }

    /// Count one half-open probe sent to a tripped replica after its
    /// cooldown (success closes the breaker, failure re-opens it).
    pub fn record_breaker_probe(&self) {
        self.inner.lock().unwrap().breaker_probes += 1;
    }

    /// Count one control-plane warm restart from the recovery journal
    /// (`ServingPool::recover`).
    pub fn record_recovery(&self) {
        self.inner.lock().unwrap().recoveries += 1;
    }

    /// Count `n` tenants recalibrated by a drift-triggered re-plan (the
    /// online calibrator's write-back path; the re-plan itself is also
    /// counted in `replans` by the caller).
    pub fn record_replan_calibration(&self, n: u64) {
        self.inner.lock().unwrap().replans_calibration += n;
    }

    /// Take an immutable snapshot of every counter.
    pub fn snapshot(&self) -> SchedulerSnapshot {
        let g = self.inner.lock().unwrap();
        SchedulerSnapshot {
            registered: g.registered,
            admitted: g.admitted,
            shared: g.shared,
            queued: g.queued,
            rejected: g.rejected,
            routed_batches: g.routed_batches,
            routed_requests: g.routed_requests,
            route_misses: g.route_misses,
            replans: g.replans,
            drained_deployments: g.drained_deployments,
            device_kills: g.device_kills,
            kill_repeats: g.kill_repeats,
            replans_calibration: g.replans_calibration,
            breaker_trips: g.breaker_trips,
            breaker_probes: g.breaker_probes,
            recoveries: g.recoveries,
        }
    }
}

impl MetricSource for SchedulerMetrics {
    fn metric_kind(&self) -> &'static str {
        "scheduler"
    }

    fn metric_json(&self) -> Json {
        let s = self.snapshot();
        let mut fields = vec![
            ("registered", uint(s.registered)),
            ("admitted", uint(s.admitted)),
            ("shared", uint(s.shared)),
            ("queued", uint(s.queued)),
            ("rejected", uint(s.rejected)),
            ("routed_batches", uint(s.routed_batches)),
            ("routed_requests", uint(s.routed_requests)),
            ("route_misses", uint(s.route_misses)),
            ("replans", uint(s.replans)),
            ("drained_deployments", uint(s.drained_deployments)),
            ("device_kills", uint(s.device_kills)),
        ];
        // only calibration-enabled pools ever move this counter; omit it
        // at zero so calibration-off exports stay byte-identical
        if s.replans_calibration > 0 {
            fields.push(("replans_calibration", uint(s.replans_calibration)));
        }
        // reliability counters only move under faults/recovery drills;
        // omit them at zero so existing exports stay byte-identical
        if s.kill_repeats > 0 {
            fields.push(("kill_repeats", uint(s.kill_repeats)));
        }
        if s.breaker_trips + s.breaker_probes > 0 {
            fields.push(("breaker_probes", uint(s.breaker_probes)));
            fields.push(("breaker_trips", uint(s.breaker_trips)));
        }
        if s.recoveries > 0 {
            fields.push(("recoveries", uint(s.recoveries)));
        }
        obj(fields)
    }
}

/// Immutable view of the scheduler counters.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerSnapshot {
    /// Tenants registered at the last plan.
    pub registered: u64,
    /// Tenants admitted by the last plan.
    pub admitted: u64,
    /// Admitted tenants holding a time-multiplexed (shared) grant.
    pub shared: u64,
    /// Tenants queued (pool too small) by the last plan.
    pub queued: u64,
    /// Tenants rejected (can never fit) by the last plan.
    pub rejected: u64,
    /// Batches routed through the pool router.
    pub routed_batches: u64,
    /// Requests routed through the pool router.
    pub routed_requests: u64,
    /// Requests for models with no live deployment.
    pub route_misses: u64,
    /// Online re-plans triggered by register/deregister on a live pool.
    pub replans: u64,
    /// Deployments drained (and redeployed or retired) across all re-plans.
    pub drained_deployments: u64,
    /// Device deaths the pool re-planned around (chaos or operator).
    pub device_kills: u64,
    /// Rejected kills of already-dead devices (typed error, metered).
    pub kill_repeats: u64,
    /// Tenants recalibrated by drift-triggered re-plans (also in `replans`).
    pub replans_calibration: u64,
    /// Replica circuit breakers tripped open by watchdog breaches.
    pub breaker_trips: u64,
    /// Half-open probes dispatched to cooled-down tripped replicas.
    pub breaker_probes: u64,
    /// Control-plane warm restarts from the recovery journal.
    pub recoveries: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_metrics_accumulate() {
        let m = StageMetrics::default();
        m.record(Duration::from_millis(2));
        m.record(Duration::from_millis(4));
        let s = m.snapshot();
        assert_eq!(s.items, 2);
        assert!((s.busy_s - 0.006).abs() < 1e-9);
        assert!((s.mean_exec_s - 0.003).abs() < 1e-9);
    }

    #[test]
    fn serve_metrics_histograms() {
        let m = ServeMetrics::default();
        for i in 1..=100 {
            m.record(i as f64 * 1e-3, i as f64 * 2e-3);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert!(s.real_p50_s > 0.03 && s.real_p50_s < 0.08, "{s:?}");
        assert!(s.sim_mean_s > s.real_mean_s);
    }

    #[test]
    fn tenant_metrics_accounting() {
        let m = TenantMetrics::default();
        m.record_submitted(10);
        for i in 1..=8 {
            m.record_response(i as f64 * 1e-3, i as f64 * 2e-3);
        }
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.submitted, 10);
        assert_eq!(s.completed, 8);
        assert_eq!(s.errors, 1);
        assert!(s.real_p99_s >= s.real_p50_s, "{s:?}");
        assert!(s.sim_p50_s > s.real_p50_s, "{s:?}");
    }

    #[test]
    fn scheduler_metrics_accounting() {
        let m = SchedulerMetrics::default();
        m.record_admission(5, 3, 1, 1, 1);
        m.record_routed(50);
        m.record_routed(20);
        m.record_route_miss();
        m.record_replan(2);
        m.record_replan(0);
        m.record_device_kill();
        // calibration-off pools never move the counter: it stays out of
        // the export entirely (pinned metric lines keep their bytes)
        assert!(!crate::obs::metric_line(&m, "pool").contains("replans_calibration"));
        m.record_replan_calibration(1);
        let s = m.snapshot();
        assert_eq!(s.replans_calibration, 1);
        assert!(
            crate::obs::metric_line(&m, "pool").contains("\"replans_calibration\":1"),
            "non-zero calibration re-plans must export"
        );
        let s = m.snapshot();
        assert_eq!(s.registered, 5);
        assert_eq!(s.admitted, 3);
        assert_eq!(s.shared, 1);
        assert_eq!(s.queued, 1);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.routed_batches, 2);
        assert_eq!(s.routed_requests, 70);
        assert_eq!(s.route_misses, 1);
        assert_eq!(s.replans, 2);
        assert_eq!(s.drained_deployments, 2);
        assert_eq!(s.device_kills, 1);
    }

    #[test]
    fn tenant_chaos_counters_accumulate() {
        let m = TenantMetrics::default();
        m.record_hedges(3);
        m.record_hedges(2);
        m.record_shed();
        let s = m.snapshot();
        assert_eq!(s.hedges, 5);
        assert_eq!(s.shed, 1);
        let line = crate::obs::metric_line(&m, "fc_small");
        assert!(line.contains("\"hedges\":5"), "{line}");
        assert!(line.contains("\"shed\":1"), "{line}");
    }

    #[test]
    fn tenant_deadline_shed_accumulates_and_gates_the_export() {
        let m = TenantMetrics::default();
        // deadline-off runs never move the counter: it stays out of the
        // export entirely, keeping today's metric lines byte-identical
        let off = crate::obs::metric_line(&m, "fc_small");
        assert!(!off.contains("deadline_shed"), "{off}");
        m.record_deadline_shed(3);
        m.record_deadline_shed(1);
        let s = m.snapshot();
        assert_eq!(s.deadline_shed, 4);
        let line = crate::obs::metric_line(&m, "fc_small");
        assert!(line.contains("\"deadline_shed\":4"), "{line}");
    }

    #[test]
    fn scheduler_reliability_counters_gate_the_export() {
        let m = SchedulerMetrics::default();
        let off = crate::obs::metric_line(&m, "pool");
        for field in ["kill_repeats", "breaker_trips", "breaker_probes", "recoveries"] {
            assert!(!off.contains(field), "{field} must gate at zero: {off}");
        }
        m.record_kill_repeat();
        m.record_breaker_trip();
        m.record_breaker_probe();
        m.record_recovery();
        let s = m.snapshot();
        assert_eq!(s.kill_repeats, 1);
        assert_eq!(s.breaker_trips, 1);
        assert_eq!(s.breaker_probes, 1);
        assert_eq!(s.recoveries, 1);
        let line = crate::obs::metric_line(&m, "pool");
        assert!(line.contains("\"kill_repeats\":1"), "{line}");
        assert!(line.contains("\"breaker_trips\":1"), "{line}");
        assert!(line.contains("\"breaker_probes\":1"), "{line}");
        assert!(line.contains("\"recoveries\":1"), "{line}");
    }

    #[test]
    fn tenant_batch_counters() {
        let m = TenantMetrics::default();
        m.record_batch(8, 3, FlushKind::Size);
        m.record_batch(2, 0, FlushKind::Deadline);
        m.record_batch(1, 0, FlushKind::Closed);
        m.record_batch(5, 1, FlushKind::Size);
        let s = m.snapshot();
        assert_eq!(s.batches, 4);
        assert_eq!(s.flush_size, 2);
        assert_eq!(s.flush_deadline, 1);
        assert_eq!(s.flush_closed, 1);
        assert_eq!(s.max_queue_depth, 3);
        assert!((s.mean_batch - 4.0).abs() < 1e-12, "{s:?}");
        assert_eq!(s.swaps, 0, "exclusive tenants never swap");
    }

    #[test]
    fn tenant_swap_counters_accumulate() {
        let m = TenantMetrics::default();
        m.record_swap(2e-3);
        m.record_swap(2e-3);
        m.record_swap_skipped();
        let s = m.snapshot();
        assert_eq!(s.swaps, 2);
        assert_eq!(s.swaps_skipped, 1);
        assert!((s.swap_overhead_s - 4e-3).abs() < 1e-12, "{s:?}");
    }

    #[test]
    fn tenant_cache_counters_accumulate_and_gate_the_export() {
        let m = TenantMetrics::default();
        // untouched counters stay out of the JSON export entirely, so
        // cache-off runs keep today's byte-identical metric lines
        let off = crate::obs::metric_line(&m, "fc_small");
        assert!(!off.contains("cache_hits"), "{off}");
        m.record_cache(false, false); // compulsory first miss
        m.record_cache(true, false);
        m.record_cache(false, true); // partial miss, prefetch-overlapped
        let s = m.snapshot();
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.cache_misses, 2);
        assert_eq!(s.prefetches, 1);
        let line = crate::obs::metric_line(&m, "fc_small");
        assert!(line.contains("\"cache_hits\":1"), "{line}");
        assert!(line.contains("\"cache_misses\":2"), "{line}");
        assert!(line.contains("\"prefetches\":1"), "{line}");
    }

    #[test]
    fn tenant_drift_gauge_overwrites_and_gates_the_export() {
        let m = TenantMetrics::default();
        // calibration-off runs never record drift: the field stays out of
        // the JSON export, keeping today's metric lines byte-identical
        let off = crate::obs::metric_line(&m, "fc_small");
        assert!(!off.contains("drift"), "{off}");
        m.record_drift(0.42);
        m.record_drift(0.17); // a gauge: the newer window overwrites
        let s = m.snapshot();
        assert!((s.drift - 0.17).abs() < 1e-12, "{s:?}");
        let line = crate::obs::metric_line(&m, "fc_small");
        assert!(line.contains("\"drift\":0.17"), "{line}");
    }

    #[test]
    fn tenant_sim_latency_hist_is_cloneable_and_diffable() {
        let m = TenantMetrics::default();
        m.record_response(1e-3, 2e-3);
        m.record_response(1e-3, 2e-3);
        let early = m.sim_latency_hist();
        assert_eq!(early.count(), 2);
        for _ in 0..10 {
            m.record_response(1e-3, 8e-3);
        }
        let late = m.sim_latency_hist();
        let delta = late.delta_since(&early);
        assert_eq!(delta.count(), 10, "delta must cover only the new window");
        assert!(delta.percentile(99.0) > 4e-3, "window p99 reflects recent samples only");
    }

    #[test]
    fn stage_metrics_batched_recording() {
        let m = StageMetrics::default();
        m.record_batch(10, Duration::from_millis(20));
        m.record_batch(0, Duration::from_millis(5)); // ignored
        let s = m.snapshot();
        assert_eq!(s.items, 10);
        assert!((s.busy_s - 0.020).abs() < 1e-9);
        assert!((s.mean_exec_s - 0.002).abs() < 1e-9, "per-item mean of the batch");
    }

    #[test]
    fn data_plane_counters_accumulate() {
        let m = DataPlaneMetrics::default();
        m.record_handoff(8);
        m.record_handoff(2);
        m.record_slab_alloc(512);
        m.record_slab_reuse();
        m.record_slab_reuse();
        let s = m.snapshot();
        assert_eq!(s.handoffs, 2);
        assert_eq!(s.handoff_items, 10);
        assert_eq!(s.slab_allocs, 1);
        assert_eq!(s.slab_alloc_bytes, 512);
        assert_eq!(s.slab_reuses, 2);
        assert!((s.items_per_handoff() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn data_plane_snapshot_is_never_torn() {
        // regression: `record_handoff` updates two counters; independent
        // loads used to let `handoff_items` run ahead of `handoffs`, so
        // items_per_handoff could exceed the batch size mid-run
        let m = std::sync::Arc::new(DataPlaneMetrics::default());
        let writers: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..2000 {
                        m.record_handoff(32);
                    }
                })
            })
            .collect();
        for _ in 0..4000 {
            let s = m.snapshot();
            assert_eq!(
                s.handoff_items,
                s.handoffs * 32,
                "torn data-plane snapshot: {} items across {} handoffs",
                s.handoff_items,
                s.handoffs
            );
        }
        for w in writers {
            w.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.handoffs, 8000);
        assert_eq!(s.handoff_items, 8000 * 32);
    }

    #[test]
    fn tenant_snapshot_is_consistent_across_lock_domains() {
        // regression: `snapshot` took the two internal locks one after
        // the other, so a writer alternating response (core lock) and
        // swap (extra lock) could be observed with the later swap but not
        // the earlier response.  With the generation check the two counts
        // never drift more than the single in-flight pair apart.
        let m = std::sync::Arc::new(TenantMetrics::default());
        let writer = {
            let m = m.clone();
            std::thread::spawn(move || {
                for _ in 0..4000 {
                    m.record_response(1e-3, 2e-3);
                    m.record_swap(1e-4);
                }
            })
        };
        for _ in 0..4000 {
            let s = m.snapshot();
            assert!(s.swaps <= s.completed, "swap counted before its response: {s:?}");
            assert!(s.completed - s.swaps <= 1, "{s:?}");
        }
        writer.join().unwrap();
        let s = m.snapshot();
        assert_eq!(s.completed, 4000);
        assert_eq!(s.swaps, 4000);
    }

    #[test]
    fn metric_sources_export_stable_json() {
        let m = TenantMetrics::default();
        m.record_submitted(3);
        m.record_response(1e-3, 2e-3);
        assert_eq!(m.metric_kind(), "tenant");
        let line_a = crate::obs::metric_line(&m, "fc_small");
        let line_b = crate::obs::metric_line(&m, "fc_small");
        assert_eq!(line_a, line_b, "snapshot export must be deterministic at rest");
        let doc = crate::util::json::Json::parse(line_a.trim_end()).unwrap();
        assert_eq!(doc.get("kind").and_then(Json::as_str), Some("tenant"));
        assert_eq!(doc.get("submitted").and_then(Json::as_u64), Some(3));
        assert_eq!(doc.get("completed").and_then(Json::as_u64), Some(1));
        // empty histograms export as null, not NaN (invalid JSON)
        let empty = crate::obs::metric_line(&StageMetrics::default(), "s0");
        let doc = crate::util::json::Json::parse(empty.trim_end()).unwrap();
        assert_eq!(doc.get("p95_exec_s"), Some(&Json::Null));
        let dp = DataPlaneMetrics::default();
        assert_eq!(dp.metric_kind(), "data_plane");
        assert!(crate::obs::metric_line(&dp, "pool").contains("\"handoffs\":0"));
        let sched = SchedulerMetrics::default();
        assert_eq!(sched.metric_kind(), "scheduler");
        assert!(crate::obs::metric_line(&sched, "pool").contains("\"admitted\":0"));
    }

    #[test]
    fn serve_metrics_p999_tracks_tail() {
        let m = ServeMetrics::default();
        for _ in 0..998 {
            m.record(1e-3, 1e-3);
        }
        m.record(0.5, 0.5); // two 500ms outliers in 1000 samples:
        m.record(0.5, 0.5); // p99 ignores them, p99.9 must not
        let s = m.snapshot();
        assert!(s.real_p99_s < 0.01, "{s:?}");
        assert!(s.real_p999_s > 0.3, "p99.9 must surface the outlier: {s:?}");
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(StageMetrics::default());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..250 {
                        m.record(Duration::from_micros(10));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().items, 1000);
    }
}
