//! Glue between [`crate::runtime`] and [`crate::coordinator`]: a
//! [`StageBackend`] that executes one AOT-compiled HLO segment via PJRT.
//!
//! The factory builds the client + executable *inside* the worker thread
//! (PJRT handles are not `Send`; one client per worker mirrors one host
//! process per physical TPU).

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::{StageBackend, StageFactory};
use crate::runtime::{LoadedSegment, SegmentEntry, TpuRuntime};

/// A PJRT-backed pipeline stage.
pub struct PjrtStage {
    /// Keep the client alive for the executable's lifetime.
    _runtime: TpuRuntime,
    segment: LoadedSegment,
}

impl StageBackend for PjrtStage {
    fn run(&mut self, input: &[i8]) -> Result<Vec<i8>> {
        self.segment.run(input)
    }

    /// Sizes the batch output slab from the segment's boundary shape; the
    /// trait's default `run_batch` then writes per-sample results straight
    /// into the slab.  Compiling batched executables (leading batch
    /// dimension) to replace the per-sample execute loop is an open
    /// ROADMAP item — overriding `run_batch` then is the one change.
    fn out_elems(&self, _in_elems: usize) -> usize {
        self.segment.out_elems
    }
}

/// Build a [`StageFactory`] for one segment artifact.
pub fn pjrt_stage_factory(artifact_dir: PathBuf, seg: SegmentEntry) -> StageFactory {
    Box::new(move || {
        let runtime = TpuRuntime::new(&artifact_dir)?;
        let segment = runtime.load_segment(&seg)?;
        Ok(Box::new(PjrtStage { _runtime: runtime, segment }) as Box<dyn StageBackend>)
    })
}
