//! Artifact manifest (`artifacts/manifest.json`, written by `aot.py`):
//! which models exist, their layer accounting, the per-segment HLO files
//! with boundary quantization, and golden test vectors.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::model::{Layer, Model};
use crate::util::json::Json;

/// Quantization parameters of a tensor boundary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantInfo {
    pub scale: f32,
    pub zero_point: i32,
}

impl QuantInfo {
    pub fn to_qparams(self) -> crate::quant::QParams {
        crate::quant::QParams { scale: self.scale, zero_point: self.zero_point }
    }
}

/// One contiguous segment artifact `[start, end)`.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentEntry {
    pub start: usize,
    pub end: usize,
    pub file: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub in_q: QuantInfo,
    pub out_q: QuantInfo,
}

/// Golden input/output vectors for the whole model (oracle-computed).
#[derive(Debug, Clone, PartialEq)]
pub struct Golden {
    pub input: Vec<i8>,
    pub input_shape: Vec<usize>,
    pub output: Vec<i8>,
    pub output_shape: Vec<usize>,
}

/// One model in the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelEntry {
    pub name: String,
    pub kind: String,
    pub macs: u64,
    pub layers: Vec<Layer>,
    pub segments: Vec<SegmentEntry>,
    pub golden: Golden,
}

impl ModelEntry {
    /// The layer-IR model (for placement / cost / segmentation search).
    pub fn to_model(&self) -> Model {
        Model::new(self.name.clone(), self.layers.clone())
    }

    /// Find the artifact covering exactly `[start, end)`.
    pub fn segment(&self, start: usize, end: usize) -> Option<&SegmentEntry> {
        self.segments.iter().find(|s| s.start == start && s.end == end)
    }

    /// Artifacts realizing a partition given by cut positions.
    pub fn segments_for_cuts(&self, cuts: &[usize]) -> Result<Vec<&SegmentEntry>> {
        let mut bounds = vec![0usize];
        bounds.extend_from_slice(cuts);
        bounds.push(self.layers.len());
        bounds
            .windows(2)
            .map(|w| {
                self.segment(w[0], w[1]).with_context(|| {
                    format!("{}: no artifact for segment [{}, {})", self.name, w[0], w[1])
                })
            })
            .collect()
    }
}

/// The whole manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelEntry>,
}

fn parse_qinfo(j: &Json) -> Result<QuantInfo> {
    Ok(QuantInfo {
        scale: j.get("scale").and_then(Json::as_f64).context("scale")? as f32,
        zero_point: j.get("zero_point").and_then(Json::as_i64).context("zero_point")? as i32,
    })
}

fn parse_usize_vec(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .context("expected array")?
        .iter()
        .map(|v| v.as_u64().map(|x| x as usize).context("expected u64"))
        .collect()
}

fn parse_i8_vec(j: &Json) -> Result<Vec<i8>> {
    j.as_arr()
        .context("expected array")?
        .iter()
        .map(|v| v.as_i64().map(|x| x as i8).context("expected i8"))
        .collect()
}

fn parse_layer(j: &Json) -> Result<Layer> {
    match j.get("kind").and_then(Json::as_str) {
        Some("fc") => Ok(Layer::Fc {
            in_features: j.get("in_features").and_then(Json::as_u64).context("in_features")?,
            out_features: j.get("out_features").and_then(Json::as_u64).context("out_features")?,
        }),
        Some("conv") => Ok(Layer::Conv {
            height: j.get("height").and_then(Json::as_u64).context("height")?,
            width: j.get("width").and_then(Json::as_u64).context("width")?,
            cin: j.get("cin").and_then(Json::as_u64).context("cin")?,
            filters: j.get("filters").and_then(Json::as_u64).context("filters")?,
            ksize: j.get("ksize").and_then(Json::as_u64).unwrap_or(3),
        }),
        k => anyhow::bail!("unknown layer kind {k:?}"),
    }
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let mut models = BTreeMap::new();
        let obj = j.get("models").and_then(Json::as_obj).context("manifest: models")?;
        for (name, m) in obj {
            let layers: Vec<Layer> = m
                .get("layers")
                .and_then(Json::as_arr)
                .context("layers")?
                .iter()
                .map(parse_layer)
                .collect::<Result<_>>()?;
            let segments: Vec<SegmentEntry> = m
                .get("segments")
                .and_then(Json::as_arr)
                .context("segments")?
                .iter()
                .map(|s| {
                    Ok(SegmentEntry {
                        start: s.get("start").and_then(Json::as_u64).context("start")? as usize,
                        end: s.get("end").and_then(Json::as_u64).context("end")? as usize,
                        file: s.get("file").and_then(Json::as_str).context("file")?.to_string(),
                        input_shape: parse_usize_vec(s.get("input_shape").context("input_shape")?)?,
                        output_shape: parse_usize_vec(
                            s.get("output_shape").context("output_shape")?,
                        )?,
                        in_q: parse_qinfo(s.get("in_q").context("in_q")?)?,
                        out_q: parse_qinfo(s.get("out_q").context("out_q")?)?,
                    })
                })
                .collect::<Result<_>>()?;
            let g = m.get("golden").context("golden")?;
            let golden = Golden {
                input: parse_i8_vec(g.get("input").context("golden.input")?)?,
                input_shape: parse_usize_vec(g.get("input_shape").context("shape")?)?,
                output: parse_i8_vec(g.get("output").context("golden.output")?)?,
                output_shape: parse_usize_vec(g.get("output_shape").context("shape")?)?,
            };
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    kind: m.get("kind").and_then(Json::as_str).unwrap_or("fc").to_string(),
                    macs: m.get("macs").and_then(Json::as_u64).context("macs")?,
                    layers,
                    segments,
                    golden,
                },
            );
        }
        Ok(Manifest { models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).with_context(|| {
            format!(
                "model {name:?} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "models": {
        "fc_tiny": {
          "kind": "fc",
          "seed": 1,
          "macs": 1234,
          "layers": [
            {"kind": "fc", "in_features": 8, "out_features": 16,
             "macs": 128, "weight_bytes": 128,
             "in_q": {"scale": 0.03, "zero_point": 0},
             "out_q": {"scale": 0.015, "zero_point": -128}},
            {"kind": "fc", "in_features": 16, "out_features": 4,
             "macs": 64, "weight_bytes": 64,
             "in_q": {"scale": 0.015, "zero_point": -128},
             "out_q": {"scale": 0.03, "zero_point": 0}}
          ],
          "segments": [
            {"start": 0, "end": 1, "file": "a.hlo.txt",
             "input_shape": [8], "output_shape": [16],
             "in_q": {"scale": 0.03, "zero_point": 0},
             "out_q": {"scale": 0.015, "zero_point": -128}},
            {"start": 1, "end": 2, "file": "b.hlo.txt",
             "input_shape": [16], "output_shape": [4],
             "in_q": {"scale": 0.015, "zero_point": -128},
             "out_q": {"scale": 0.03, "zero_point": 0}},
            {"start": 0, "end": 2, "file": "c.hlo.txt",
             "input_shape": [8], "output_shape": [4],
             "in_q": {"scale": 0.03, "zero_point": 0},
             "out_q": {"scale": 0.03, "zero_point": 0}}
          ],
          "golden": {"input": [1, -2, 3, 4, 5, 6, 7, 8], "input_shape": [8],
                     "output": [0, 1, -1, 127], "output_shape": [4]}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = m.model("fc_tiny").unwrap();
        assert_eq!(e.macs, 1234);
        assert_eq!(e.layers.len(), 2);
        assert_eq!(e.segments.len(), 3);
        assert_eq!(e.golden.input.len(), 8);
        assert_eq!(e.golden.output, vec![0, 1, -1, 127]);
        let model = e.to_model();
        assert_eq!(model.macs(), 128 + 64);
    }

    #[test]
    fn segments_for_cuts() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = m.model("fc_tiny").unwrap();
        let whole = e.segments_for_cuts(&[]).unwrap();
        assert_eq!(whole.len(), 1);
        assert_eq!((whole[0].start, whole[0].end), (0, 2));
        let two = e.segments_for_cuts(&[1]).unwrap();
        assert_eq!(two.len(), 2);
        assert_eq!(two[0].file, "a.hlo.txt");
        assert_eq!(two[1].file, "b.hlo.txt");
        // boundary consistency
        assert_eq!(two[0].out_q, two[1].in_q);
        assert!(e.segments_for_cuts(&[3]).is_err());
    }

    #[test]
    fn missing_model_is_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if !p.exists() {
            return; // `make artifacts` not run — covered by integration tests
        }
        let m = Manifest::load(&p).unwrap();
        assert!(m.models.contains_key("fc_n256"));
        let e = m.model("fc_n256").unwrap();
        assert_eq!(e.layers.len(), 5);
        assert_eq!(e.segments.len(), 15); // all contiguous sub-runs
    }
}
