//! PJRT runtime: loads the AOT-lowered HLO artifacts (`make artifacts`)
//! and executes them on the CPU PJRT client — the only place the compute
//! graph runs at serving time; Python is never on this path.
//!
//! Interchange is HLO **text**: `HloModuleProto::from_text_file` reparses
//! and reassigns instruction ids, sidestepping the 64-bit-id protos that
//! jax >= 0.5 emits and xla_extension 0.5.1 rejects (see aot.py).

pub mod manifest;
pub mod stage;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use manifest::{Manifest, ModelEntry, QuantInfo, SegmentEntry};

/// A PJRT client plus the artifact directory it loads from.
pub struct TpuRuntime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

/// A compiled segment executable with its boundary metadata.
pub struct LoadedSegment {
    exe: xla::PjRtLoadedExecutable,
    /// Element count of the input tensor.
    pub in_elems: usize,
    /// Element count of the output tensor.
    pub out_elems: usize,
    /// Input tensor dims (row-major), e.g. `[64]` or `[32, 32, 3]`.
    pub in_shape: Vec<usize>,
    /// Quantization of the input boundary.
    pub in_q: QuantInfo,
    /// Quantization of the output boundary.
    pub out_q: QuantInfo,
    /// Layer index range `[start, end)` in the source model.
    pub start: usize,
    pub end: usize,
}

impl TpuRuntime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(TpuRuntime { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Read + parse `manifest.json` from the artifact directory.
    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(&self.artifact_dir.join("manifest.json"))
    }

    /// Load and compile one segment artifact.
    pub fn load_segment(&self, seg: &SegmentEntry) -> Result<LoadedSegment> {
        let path = self.artifact_dir.join(&seg.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", seg.file))?;
        Ok(LoadedSegment {
            exe,
            in_elems: seg.input_shape.iter().product(),
            out_elems: seg.output_shape.iter().product(),
            in_shape: seg.input_shape.clone(),
            in_q: seg.in_q,
            out_q: seg.out_q,
            start: seg.start,
            end: seg.end,
        })
    }
}

impl LoadedSegment {
    /// Execute on an int8 activation tensor (flattened row-major).
    pub fn run(&self, input: &[i8]) -> Result<Vec<i8>> {
        anyhow::ensure!(
            input.len() == self.in_elems,
            "segment [{}, {}) expects {} input elems, got {}",
            self.start,
            self.end,
            self.in_elems,
            input.len()
        );
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(input.as_ptr() as *const u8, input.len()) };
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S8,
            &self.in_shape,
            bytes,
        )
        .map_err(|e| anyhow::anyhow!("building input literal: {e}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow::anyhow!("executing segment: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e}"))?;
        // lowered with return_tuple=True -> unwrap the 1-tuple
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        let v = out.to_vec::<i8>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
        anyhow::ensure!(
            v.len() == self.out_elems,
            "segment [{}, {}) produced {} elems, expected {}",
            self.start,
            self.end,
            v.len(),
            self.out_elems
        );
        Ok(v)
    }
}

/// Execute a chain of segments end-to-end (single-threaded reference path;
/// the pipelined path lives in [`crate::coordinator`]).
pub fn run_chain(segments: &[LoadedSegment], input: &[i8]) -> Result<Vec<i8>> {
    let mut x = input.to_vec();
    for seg in segments {
        x = seg.run(&x)?;
    }
    Ok(x)
}
