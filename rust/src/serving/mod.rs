//! High-level serving assembly: manifest + segmentation strategy + cost
//! model + PJRT stages -> a running [`Pipeline`] serving real numerics,
//! with the simulated Edge TPU clock attached to every stage; plus the
//! closed-batch multi-tenant driver ([`serve_pool`]) and the live
//! open-loop driver ([`serve_open_loop`]) that paces seeded arrival
//! processes against a `ServingPool`.
//!
//! Used by `examples/serve_pipeline.rs`, `examples/open_loop.rs`,
//! `repro serve`, `repro serve-pool` and `repro loadgen`.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::compiler::place;
use crate::config::SystemConfig;
use crate::coordinator::{Pipeline, PipelineConfig, Request, StageSim};
use crate::device::CostModel;
use crate::link::Link;
use crate::model::Model;
use crate::pipeline::single_tpu_latency_s;
use crate::runtime::stage::pjrt_stage_factory;
use crate::runtime::{Manifest, ModelEntry};
use crate::scheduler::ServingPool;
use crate::segment::strategy::Strategy;
use crate::segment::Partition;
use crate::util::rng::Rng;
use crate::workload::{arrival_times, Arrivals, TenantLoad};

pub use crate::coordinator::ReplicaRouter;

/// A serving deployment plan for one model.
#[derive(Debug)]
pub struct ServePlan {
    pub model_name: String,
    pub partition: Partition,
    pub sims: Vec<StageSim>,
    /// Simulated single-TPU per-inference latency (the paper baseline).
    pub single_tpu_s: f64,
    pub input_shape: Vec<usize>,
}

/// Per-stage simulated-clock parameters for a model/partition pair — the
/// live-pipeline twin of `pipeline::build_stages` (shared by the
/// single-model `plan` and the multi-tenant scheduler's deployments).
pub fn stage_sims(model: &Model, partition: &Partition, cfg: &SystemConfig) -> Vec<StageSim> {
    let cm = CostModel::new(cfg.clone());
    let link = Link::new(cfg.link.clone());
    partition
        .bounds()
        .iter()
        .map(|&(a, b)| {
            let seg = &model.layers[a..b];
            let placement = place(seg, &cfg.device);
            let in_bytes = seg.first().unwrap().input_elems();
            let out_bytes = seg.last().unwrap().output_elems();
            StageSim {
                // DMA in/out occupies the device (no overlap) — same
                // service-time model as pipeline::simulate
                exec_s: link.xfer_s(in_bytes)
                    + cm.stage_cost(&placement).exec_s()
                    + link.xfer_s(out_bytes),
                hop_out_s: if b == model.len() { 0.0 } else { link.hop_latency_s() },
                overhead_s: cfg.link.stage_overhead_s,
            }
        })
        .collect()
}

/// Per-stage context-switch cost of time-multiplexing this partition: to
/// swap a co-resident tenant back onto stage `i`'s TPU, the segment's
/// on-chip weights must be re-loaded from host memory over the cost
/// model's off-chip bandwidth term — the same link whose non-overlap is
/// the paper's Table-I cliff (cf. arXiv 2102.10423 on host-memory-access
/// penalties).  Returns seconds per swap, one entry per segment.
pub fn stage_switch_costs(model: &Model, partition: &Partition, cfg: &SystemConfig) -> Vec<f64> {
    partition
        .bounds()
        .iter()
        .map(|&(a, b)| {
            model.layers[a..b]
                .iter()
                .map(|l| {
                    let bw = match l.kind() {
                        crate::model::LayerKind::Fc => cfg.link.host_weight_bw_fc,
                        crate::model::LayerKind::Conv => cfg.link.host_weight_bw_conv,
                    };
                    l.weight_bytes() as f64 / bw
                })
                .sum::<f64>()
        })
        .collect()
}

/// [`stage_sims`] adjusted for a
/// [`DeviceGrant`](crate::scheduler::DeviceGrant): a time-sliced tenant
/// sees only `slice` of each device's cycles, so its per-item service
/// time dilates by `1/slice`.  The per-quantum swap cost is charged at
/// batch boundaries (by the workload sim and the pool's swap counters),
/// not per item.
pub fn stage_sims_for_grant(
    model: &Model,
    partition: &Partition,
    cfg: &SystemConfig,
    grant: &crate::scheduler::DeviceGrant,
) -> Vec<StageSim> {
    let mut sims = stage_sims(model, partition, cfg);
    let slice = grant.slice();
    if slice < 1.0 {
        for s in &mut sims {
            s.exec_s /= slice;
        }
    }
    sims
}

/// Deterministic model of one admitted assignment's deployment: the
/// grant-dilated stage sims, the replica fan-out, and (for shared
/// grants) the per-stage context-switch costs, normalized so their sum
/// matches the grant's `switch_s` even under a `--switch-cost-us`
/// override, plus the grant's scheduling-quantum length (a flush inside
/// the quantum keeps the parameters resident and skips the re-load).
/// `repro loadgen` simulates exactly this, so the deterministic table
/// always matches the plan the live pool deploys.
pub fn deployment_sim(
    tenant: &crate::scheduler::Tenant,
    a: &crate::scheduler::Assignment,
    cfg: &SystemConfig,
) -> crate::workload::DeploymentSim {
    let sims = stage_sims_for_grant(&tenant.model, &a.candidate.partition, cfg, &a.grant);
    let switch_s = if a.grant.is_shared() {
        let natural = stage_switch_costs(&tenant.model, &a.candidate.partition, cfg);
        let total: f64 = natural.iter().sum();
        if total > 0.0 {
            let scale = a.grant.switch_s() / total;
            natural.iter().map(|c| c * scale).collect()
        } else {
            vec![a.grant.switch_s() / sims.len() as f64; sims.len()]
        }
    } else {
        Vec::new()
    };
    crate::workload::DeploymentSim {
        sims,
        replicas: a.replicas,
        switch_s,
        quantum_s: a.grant.quantum_s(),
        cache: a.grant.cache(),
    }
}

/// Build the plan: pick the partition, derive per-stage simulated costs.
pub fn plan(
    entry: &ModelEntry,
    n_tpus: usize,
    strategy: Strategy,
    cfg: &SystemConfig,
) -> Result<ServePlan> {
    let model: Model = entry.to_model();
    anyhow::ensure!(
        n_tpus >= 1 && n_tpus <= model.len(),
        "n_tpus {n_tpus} out of range for {} layers",
        model.len()
    );
    let partition = if n_tpus == 1 {
        Partition::whole(model.len())
    } else {
        strategy.partition(&model, n_tpus, cfg)
    };
    let sims = stage_sims(&model, &partition, cfg);
    let whole = entry
        .segment(0, model.len())
        .context("whole-model artifact missing")?;
    Ok(ServePlan {
        model_name: entry.name.clone(),
        partition,
        sims,
        single_tpu_s: single_tpu_latency_s(&model, cfg),
        input_shape: whole.input_shape.clone(),
    })
}

/// Spawn the PJRT-backed pipeline for a plan.
pub fn spawn_pipeline(
    artifact_dir: &Path,
    entry: &ModelEntry,
    plan: &ServePlan,
    queue_capacity: usize,
) -> Result<Pipeline> {
    let segs = entry.segments_for_cuts(&plan.partition.cuts)?;
    let factories = segs
        .iter()
        .map(|s| pjrt_stage_factory(PathBuf::from(artifact_dir), (*s).clone()))
        .collect();
    Pipeline::spawn(
        factories,
        plan.sims.clone(),
        &PipelineConfig { queue_capacity, ..Default::default() },
    )
    .context("spawning pipeline")
}

/// Spawn a replicated single-model deployment: `replicas` full copies of
/// the plan's pipeline behind a round-robin [`ReplicaRouter`] — the
/// data-parallel alternative of the paper's closing remark, now a
/// first-class serving path (the multi-tenant scheduler uses the same
/// router for leftover-TPU replicas).
pub fn spawn_replicated_pipeline(
    artifact_dir: &Path,
    entry: &ModelEntry,
    plan: &ServePlan,
    replicas: usize,
    queue_capacity: usize,
) -> Result<ReplicaRouter> {
    anyhow::ensure!(replicas >= 1, "need at least one replica");
    let mut pipelines = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        pipelines.push(spawn_pipeline(artifact_dir, entry, plan, queue_capacity)?);
    }
    Ok(ReplicaRouter::new(pipelines))
}

/// Deterministic random int8 request batch for a plan.
pub fn synth_requests(plan: &ServePlan, batch: usize, seed: u64) -> Vec<Request> {
    let elems: usize = plan.input_shape.iter().product();
    let mut rng = Rng::new(seed);
    (0..batch as u64)
        .map(|id| Request::new(id, rng.i8_vec(elems)))
        .collect()
}

/// Results of one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub n_tpus: usize,
    pub partition_label: String,
    pub batch: usize,
    /// Real wall-clock for the whole batch on this host (PJRT CPU).
    pub wall_s: f64,
    pub real_throughput: f64,
    /// Simulated Edge TPU makespan and per-inference time.
    pub sim_makespan_s: f64,
    pub sim_per_item_s: f64,
    /// Simulated speedup vs the single-TPU baseline.
    pub sim_speedup_vs_one_tpu: f64,
}

/// Serve one closed batch and summarize.
pub fn serve_batch(
    pipeline: &Pipeline,
    plan: &ServePlan,
    requests: Vec<Request>,
) -> Result<ServeReport> {
    let batch = requests.len();
    // exclude backend construction (artifact compilation) from the timing
    pipeline.wait_ready()?;
    let t0 = std::time::Instant::now();
    let responses = pipeline.serve_batch(requests)?;
    let wall = t0.elapsed().as_secs_f64();
    let sim_makespan = responses.iter().map(|r| r.sim_done_s).fold(0.0, f64::max);
    let per_item = sim_makespan / batch as f64;
    Ok(ServeReport {
        n_tpus: plan.partition.n_segments(),
        partition_label: plan.partition.label(),
        batch,
        wall_s: wall,
        real_throughput: batch as f64 / wall,
        sim_makespan_s: sim_makespan,
        sim_per_item_s: per_item,
        sim_speedup_vs_one_tpu: plan.single_tpu_s / per_item,
    })
}

/// Per-tenant result of one multi-tenant pool serving run.
#[derive(Debug, Clone)]
pub struct TenantServeReport {
    pub name: String,
    pub tpu_count: usize,
    pub replicas: usize,
    /// Grant kind, e.g. `excl` or `shared 1/2`.
    pub grant_label: String,
    pub partition_label: String,
    pub batch: usize,
    /// Real wall-clock for this tenant's whole batch on this host.
    pub wall_s: f64,
    pub real_throughput: f64,
    /// p99 of the simulated Edge TPU completion times.
    pub sim_p99_s: f64,
    /// Allocator-predicted p99 (for predicted-vs-served comparison).
    pub predicted_p99_s: f64,
    /// Whether responses were checked against the serial reference.
    pub verified: bool,
}

/// Serve one closed batch per admitted tenant, **concurrently** across
/// tenants, through a deployed [`PoolRouter`] — the multi-tenant
/// counterpart of [`serve_batch`].  With `verify` set (synthetic
/// backend), every response is checked bit-for-bit against the tenant's
/// serial reference, so cross-tenant routing or ordering bugs fail loudly.
pub fn serve_pool(
    router: &crate::scheduler::PoolRouter,
    batch: usize,
    seed: u64,
    verify: bool,
) -> Result<Vec<TenantServeReport>> {
    router.wait_ready()?;
    let names = router.names();
    let mut reports = Vec::with_capacity(names.len());
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for name in &names {
            handles.push(scope.spawn(move || -> Result<TenantServeReport> {
                let t = router.tenant(name).expect("deployed tenant");
                let requests = t.synth_requests(batch, seed);
                let expected: Option<Vec<Vec<i8>>> = if verify {
                    Some(requests.iter().map(|r| t.reference(&r.data)).collect())
                } else {
                    None
                };
                let t0 = std::time::Instant::now();
                let responses = router.serve(name, requests)?;
                let wall = t0.elapsed().as_secs_f64();
                if let Some(exp) = &expected {
                    for (r, e) in responses.iter().zip(exp) {
                        anyhow::ensure!(
                            &r.data == e,
                            "{name}: response {} mismatches the serial reference",
                            r.id
                        );
                    }
                }
                let mut sim = crate::util::stats::Summary::new();
                for r in &responses {
                    sim.add(r.sim_done_s);
                }
                Ok(TenantServeReport {
                    name: name.clone(),
                    tpu_count: t.tpu_count,
                    replicas: t.replicas,
                    grant_label: t.grant.label(),
                    partition_label: t.partition_label.clone(),
                    batch,
                    wall_s: wall,
                    real_throughput: batch as f64 / wall.max(1e-12),
                    sim_p99_s: sim.p99(),
                    predicted_p99_s: t.predicted_p99_s,
                    verified: verify,
                })
            }));
        }
        for h in handles {
            reports.push(h.join().expect("tenant serving thread panicked")?);
        }
        Ok(())
    })?;
    reports.sort_by(|a, b| a.name.cmp(&b.name));
    Ok(reports)
}

/// Per-tenant result of one live open-loop serving run.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    /// Model/routing name.
    pub name: String,
    /// The arrival process driven against this tenant (label form).
    pub arrivals: String,
    /// Requests accepted by the tenant's ingress queue.
    pub submitted: usize,
    /// Responses received back.  Equals `submitted` unless the tenant was
    /// deregistered mid-run (then it equals the accepted count — accepted
    /// requests are never lost).
    pub completed: usize,
    /// Whether every response was checked against the serial reference.
    pub verified: bool,
    /// Real wall-clock of this tenant's whole run.
    pub wall_s: f64,
}

/// Drive a **live** open-loop run against a [`ServingPool`]: one
/// submitter+collector pair per tenant, pacing submissions on the same
/// seeded arrival schedule the deterministic simulation uses
/// (`workload::arrival_times`), while responses stream back through the
/// tenant's completion queue.
///
/// With `verify` set (synthetic backend), every response is checked
/// bit-for-bit against the tenant's serial reference — and because the
/// synthetic transforms are per-layer, the check stays valid even if a
/// concurrent `register`/`deregister` re-plans the tenant's partition
/// mid-run.  A tenant deregistered mid-run stops early and cleanly: its
/// accepted requests all complete before its stream closes.
pub fn serve_open_loop(
    pool: &ServingPool,
    loads: &[TenantLoad],
    seed: u64,
    verify: bool,
) -> Result<Vec<OpenLoopReport>> {
    let mut reports = Vec::with_capacity(loads.len());
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for load in loads {
            handles.push(scope.spawn(move || serve_one_open_loop(pool, load, seed, verify)));
        }
        for h in handles {
            reports.push(h.join().expect("open-loop tenant thread panicked")?);
        }
        Ok(())
    })?;
    reports.sort_by(|a, b| a.name.cmp(&b.name));
    Ok(reports)
}

fn serve_one_open_loop(
    pool: &ServingPool,
    load: &TenantLoad,
    seed: u64,
    verify: bool,
) -> Result<OpenLoopReport> {
    let client = pool.client(&load.model)?;
    let n = load.requests;
    let tenant_seed = seed ^ crate::scheduler::tenant_salt(&load.model);
    let requests = client.synth_requests(n, tenant_seed);
    let expected: Option<Vec<Vec<i8>>> = if verify {
        Some(requests.iter().map(|r| client.reference(&r.data)).collect())
    } else {
        None
    };
    let check = |r: &crate::coordinator::Response| -> Result<()> {
        if let Some(exp) = &expected {
            let want = exp
                .get(r.id as usize)
                .ok_or_else(|| anyhow::anyhow!("{}: unknown response id {}", load.model, r.id))?;
            anyhow::ensure!(
                &r.data == want,
                "{}: response {} mismatches the serial reference",
                load.model,
                r.id
            );
        }
        Ok(())
    };

    let t0 = std::time::Instant::now();
    let mut submitted = 0usize;
    let mut completed = 0usize;
    match load.arrivals {
        Arrivals::Closed { concurrency, think_s } => {
            // one virtual-client loop: keep `concurrency` outstanding
            let mut it = requests.into_iter();
            for _ in 0..concurrency.min(n.max(1)) {
                let Some(r) = it.next() else { break };
                if pool.submit(&load.model, r).is_err() {
                    break;
                }
                submitted += 1;
            }
            while completed < submitted {
                match client.done.recv() {
                    Some(r) => {
                        check(&r)?;
                        completed += 1;
                        if think_s > 0.0 {
                            std::thread::sleep(std::time::Duration::from_secs_f64(think_s));
                        }
                        if let Some(next) = it.next() {
                            if pool.submit(&load.model, next).is_ok() {
                                submitted += 1;
                            }
                        }
                    }
                    None => break, // tenant deregistered mid-run
                }
            }
        }
        _ => {
            let offsets =
                arrival_times(&load.arrivals, n, crate::workload::arrival_seed(seed, &load.model));
            std::thread::scope(|scope| -> Result<()> {
                let model = &load.model;
                let submitter = scope.spawn(move || {
                    let start = std::time::Instant::now();
                    let mut accepted = 0usize;
                    for (r, &at) in requests.into_iter().zip(&offsets) {
                        let target = std::time::Duration::from_secs_f64(at);
                        let elapsed = start.elapsed();
                        if target > elapsed {
                            std::thread::sleep(target - elapsed);
                        }
                        if pool.submit(model, r).is_err() {
                            break; // tenant deregistered mid-run
                        }
                        accepted += 1;
                    }
                    accepted
                });
                while completed < n {
                    match client.done.recv() {
                        Some(r) => {
                            check(&r)?;
                            completed += 1;
                        }
                        // deregistered: every accepted request's response
                        // was delivered before the stream closed
                        None => break,
                    }
                }
                submitted = submitter.join().expect("submitter panicked");
                Ok(())
            })?;
        }
    }
    anyhow::ensure!(
        completed == submitted,
        "{}: {} accepted requests but only {} responses — in-flight loss",
        load.model,
        submitted,
        completed
    );
    Ok(OpenLoopReport {
        name: load.model.clone(),
        arrivals: load.arrivals.label(),
        submitted,
        completed,
        verified: verify,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// Load the manifest from an artifact dir (helper for binaries).
pub fn load_manifest(artifact_dir: &Path) -> Result<Manifest> {
    Manifest::load(&artifact_dir.join("manifest.json"))
}

/// Default artifact directory: `$REPO/artifacts` (overridable by env).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("TPU_PIPELINE_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn sample_manifest() -> Manifest {
        // reuse the sample from runtime::manifest tests via a minimal JSON
        Manifest::parse(
            r#"{"models": {"m": {
                "kind": "fc", "seed": 1, "macs": 192,
                "layers": [
                  {"kind": "fc", "in_features": 8, "out_features": 16},
                  {"kind": "fc", "in_features": 16, "out_features": 4}],
                "segments": [
                  {"start": 0, "end": 2, "file": "w.hlo.txt",
                   "input_shape": [8], "output_shape": [4],
                   "in_q": {"scale": 0.1, "zero_point": 0},
                   "out_q": {"scale": 0.1, "zero_point": 0}},
                  {"start": 0, "end": 1, "file": "a.hlo.txt",
                   "input_shape": [8], "output_shape": [16],
                   "in_q": {"scale": 0.1, "zero_point": 0},
                   "out_q": {"scale": 0.05, "zero_point": -128}},
                  {"start": 1, "end": 2, "file": "b.hlo.txt",
                   "input_shape": [16], "output_shape": [4],
                   "in_q": {"scale": 0.05, "zero_point": -128},
                   "out_q": {"scale": 0.1, "zero_point": 0}}],
                "golden": {"input": [0,0,0,0,0,0,0,0], "input_shape": [8],
                           "output": [0,0,0,0], "output_shape": [4]}}}}"#,
        )
        .unwrap()
    }

    #[test]
    fn plan_builds_sims_per_stage() {
        let m = sample_manifest();
        let entry = m.model("m").unwrap();
        let cfg = SystemConfig::default();
        let p = plan(entry, 2, Strategy::Uniform, &cfg).unwrap();
        assert_eq!(p.sims.len(), 2);
        assert_eq!(p.partition.label(), "1+1");
        assert!(p.single_tpu_s > 0.0);
        assert_eq!(p.input_shape, vec![8]);
        // last stage's hop is an output transfer (cheaper than a full hop)
        assert!(p.sims[1].hop_out_s < p.sims[0].hop_out_s + 1e-9);
    }

    #[test]
    fn plan_rejects_bad_arity() {
        let m = sample_manifest();
        let entry = m.model("m").unwrap();
        let cfg = SystemConfig::default();
        assert!(plan(entry, 3, Strategy::Uniform, &cfg).is_err());
        assert!(plan(entry, 0, Strategy::Uniform, &cfg).is_err());
    }

    #[test]
    fn spawn_replicated_pipeline_builds_replica_set() {
        let m = sample_manifest();
        let entry = m.model("m").unwrap();
        let cfg = SystemConfig::default();
        let p = plan(entry, 2, Strategy::Uniform, &cfg).unwrap();
        let dir = std::env::temp_dir();
        // spawn succeeds even without artifacts: PJRT backends are built
        // lazily inside the worker threads (wait_ready would surface the
        // stub/missing-artifact error)
        let router = spawn_replicated_pipeline(&dir, entry, &p, 3, 4).unwrap();
        assert_eq!(router.replicas.len(), 3);
        router.shutdown();
        let p1 = plan(entry, 1, Strategy::Uniform, &cfg).unwrap();
        assert!(spawn_replicated_pipeline(&dir, entry, &p1, 0, 4).is_err());
    }

    #[test]
    fn serve_pool_serves_multiple_tenants_concurrently() {
        use crate::scheduler::{
            allocate, AllocatorConfig, BackendKind, DeployOptions, ModelRegistry, PoolRouter,
        };
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        reg.register_named("conv_a").unwrap();
        let cfg = SystemConfig::default();
        let alloc = AllocatorConfig { total_tpus: 2, ..Default::default() };
        let plan = allocate(&reg, &cfg, &alloc).unwrap();
        let router = PoolRouter::deploy(
            &plan,
            &reg,
            &cfg,
            &BackendKind::Synthetic,
            DeployOptions::new().with_queue_capacity(8),
        )
        .unwrap();
        let reports = serve_pool(&router, 10, 1, true).unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].name, "conv_a");
        assert_eq!(reports[1].name, "fc_small");
        for r in &reports {
            assert_eq!(r.batch, 10);
            assert!(r.verified);
            assert!(r.wall_s > 0.0);
            assert!(r.sim_p99_s > 0.0);
            let t = router.tenant(&r.name).unwrap();
            assert_eq!(t.metrics.snapshot().completed, 10);
        }
        router.shutdown();
    }

    #[test]
    fn open_loop_driver_serves_and_verifies_every_process() {
        use crate::scheduler::{AllocatorConfig, BackendKind, DeployOptions, ModelRegistry};
        use crate::workload::{Arrivals, TenantLoad};
        let mut reg = ModelRegistry::new();
        reg.register_named("fc_small").unwrap();
        reg.register_named("conv_a").unwrap();
        let pool = ServingPool::deploy(
            reg,
            SystemConfig::default(),
            AllocatorConfig { total_tpus: 2, ..Default::default() },
            BackendKind::Synthetic,
            DeployOptions::default(),
        )
        .unwrap();
        let loads = vec![
            TenantLoad {
                model: "fc_small".into(),
                arrivals: Arrivals::Poisson { rate_hz: 2000.0 },
                requests: 30,
            },
            TenantLoad {
                model: "conv_a".into(),
                arrivals: Arrivals::Closed { concurrency: 3, think_s: 0.0 },
                requests: 30,
            },
        ];
        let reports = serve_open_loop(&pool, &loads, 7, true).unwrap();
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert_eq!(r.submitted, 30, "{}", r.name);
            assert_eq!(r.completed, 30, "{}", r.name);
            assert!(r.verified);
        }
        for name in ["fc_small", "conv_a"] {
            let s = pool.tenant_metrics(name).unwrap().snapshot();
            assert_eq!(s.completed, 30, "{name}");
            assert_eq!(s.errors, 0, "{name}");
            assert!(s.batches >= 1, "{name}");
        }
        pool.shutdown();
    }

    #[test]
    fn switch_costs_follow_partition_and_grants_dilate_service() {
        use crate::model::synthetic::fc_model;
        use crate::scheduler::DeviceGrant;
        use crate::segment::{uniform_cuts, Partition};
        let cfg = SystemConfig::default();
        let m = fc_model(512);
        let part = uniform_cuts(m.len(), 2);
        let costs = stage_switch_costs(&m, &part, &cfg);
        assert_eq!(costs.len(), 2);
        assert!(costs.iter().all(|&c| c > 0.0));
        // total re-load time is partition-invariant: same bytes cross the
        // same host link wherever the cuts fall
        let whole = stage_switch_costs(&m, &Partition::whole(m.len()), &cfg);
        let total: f64 = costs.iter().sum();
        assert!((total - whole[0]).abs() < 1e-12, "{total} vs {whole:?}");

        // a 1/2 slice doubles every stage's service time, nothing else
        let excl = stage_sims(&m, &part, &cfg);
        let grant = DeviceGrant::Shared {
            slice: 0.5,
            switch_s: total,
            quantum_s: 0.0,
            residents: vec![(0, vec!["a".into(), "b".into()])],
            cache: None,
        };
        let shared = stage_sims_for_grant(&m, &part, &cfg, &grant);
        for (e, s) in excl.iter().zip(&shared) {
            assert!((s.exec_s - 2.0 * e.exec_s).abs() < 1e-12);
            assert_eq!(s.hop_out_s, e.hop_out_s);
            assert_eq!(s.overhead_s, e.overhead_s);
        }
        let excl2 = stage_sims_for_grant(&m, &part, &cfg, &DeviceGrant::Exclusive);
        for (e, s) in excl.iter().zip(&excl2) {
            assert_eq!(e.exec_s, s.exec_s);
        }
    }

    #[test]
    fn synth_requests_deterministic() {
        let m = sample_manifest();
        let entry = m.model("m").unwrap();
        let cfg = SystemConfig::default();
        let p = plan(entry, 1, Strategy::Uniform, &cfg).unwrap();
        let a = synth_requests(&p, 5, 42);
        let b = synth_requests(&p, 5, 42);
        assert_eq!(a.len(), 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data, y.data);
            assert_eq!(x.data.len(), 8);
        }
    }
}
