//! High-level serving assembly: manifest + segmentation strategy + cost
//! model + PJRT stages -> a running [`Pipeline`] serving real numerics,
//! with the simulated Edge TPU clock attached to every stage.
//!
//! Used by `examples/serve_pipeline.rs` and `repro serve`.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::compiler::place;
use crate::config::SystemConfig;
use crate::coordinator::{Pipeline, PipelineConfig, Request, StageSim};
use crate::device::CostModel;
use crate::link::Link;
use crate::model::Model;
use crate::pipeline::single_tpu_latency_s;
use crate::runtime::stage::pjrt_stage_factory;
use crate::runtime::{Manifest, ModelEntry};
use crate::segment::strategy::Strategy;
use crate::segment::Partition;
use crate::util::rng::Rng;

/// A serving deployment plan for one model.
#[derive(Debug)]
pub struct ServePlan {
    pub model_name: String,
    pub partition: Partition,
    pub sims: Vec<StageSim>,
    /// Simulated single-TPU per-inference latency (the paper baseline).
    pub single_tpu_s: f64,
    pub input_shape: Vec<usize>,
}

/// Build the plan: pick the partition, derive per-stage simulated costs.
pub fn plan(
    entry: &ModelEntry,
    n_tpus: usize,
    strategy: Strategy,
    cfg: &SystemConfig,
) -> Result<ServePlan> {
    let model: Model = entry.to_model();
    anyhow::ensure!(
        n_tpus >= 1 && n_tpus <= model.len(),
        "n_tpus {n_tpus} out of range for {} layers",
        model.len()
    );
    let partition = if n_tpus == 1 {
        Partition::whole(model.len())
    } else {
        strategy.partition(&model, n_tpus, cfg)
    };
    let cm = CostModel::new(cfg.clone());
    let link = Link::new(cfg.link.clone());
    let bounds = partition.bounds();
    let sims: Vec<StageSim> = bounds
        .iter()
        .map(|&(a, b)| {
            let seg = &model.layers[a..b];
            let placement = place(seg, &cfg.device);
            let in_bytes = seg.first().unwrap().input_elems();
            let out_bytes = seg.last().unwrap().output_elems();
            StageSim {
                // DMA in/out occupies the device (no overlap) — same
                // service-time model as pipeline::simulate
                exec_s: link.xfer_s(in_bytes)
                    + cm.stage_cost(&placement).exec_s()
                    + link.xfer_s(out_bytes),
                hop_out_s: if b == model.len() { 0.0 } else { link.hop_latency_s() },
                overhead_s: cfg.link.stage_overhead_s,
            }
        })
        .collect();
    let whole = entry
        .segment(0, model.len())
        .context("whole-model artifact missing")?;
    Ok(ServePlan {
        model_name: entry.name.clone(),
        partition,
        sims,
        single_tpu_s: single_tpu_latency_s(&model, cfg),
        input_shape: whole.input_shape.clone(),
    })
}

/// Spawn the PJRT-backed pipeline for a plan.
pub fn spawn_pipeline(
    artifact_dir: &Path,
    entry: &ModelEntry,
    plan: &ServePlan,
    queue_capacity: usize,
) -> Result<Pipeline> {
    let segs = entry.segments_for_cuts(&plan.partition.cuts)?;
    let factories = segs
        .iter()
        .map(|s| pjrt_stage_factory(PathBuf::from(artifact_dir), (*s).clone()))
        .collect();
    Pipeline::spawn(factories, plan.sims.clone(), &PipelineConfig { queue_capacity })
        .context("spawning pipeline")
}

/// Deterministic random int8 request batch for a plan.
pub fn synth_requests(plan: &ServePlan, batch: usize, seed: u64) -> Vec<Request> {
    let elems: usize = plan.input_shape.iter().product();
    let mut rng = Rng::new(seed);
    (0..batch as u64)
        .map(|id| Request { id, data: rng.i8_vec(elems) })
        .collect()
}

/// Results of one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub n_tpus: usize,
    pub partition_label: String,
    pub batch: usize,
    /// Real wall-clock for the whole batch on this host (PJRT CPU).
    pub wall_s: f64,
    pub real_throughput: f64,
    /// Simulated Edge TPU makespan and per-inference time.
    pub sim_makespan_s: f64,
    pub sim_per_item_s: f64,
    /// Simulated speedup vs the single-TPU baseline.
    pub sim_speedup_vs_one_tpu: f64,
}

/// Serve one closed batch and summarize.
pub fn serve_batch(
    pipeline: &Pipeline,
    plan: &ServePlan,
    requests: Vec<Request>,
) -> Result<ServeReport> {
    let batch = requests.len();
    // exclude backend construction (artifact compilation) from the timing
    pipeline.wait_ready()?;
    let t0 = std::time::Instant::now();
    let responses = pipeline.serve_batch(requests)?;
    let wall = t0.elapsed().as_secs_f64();
    let sim_makespan = responses.iter().map(|r| r.sim_done_s).fold(0.0, f64::max);
    let per_item = sim_makespan / batch as f64;
    Ok(ServeReport {
        n_tpus: plan.partition.n_segments(),
        partition_label: plan.partition.label(),
        batch,
        wall_s: wall,
        real_throughput: batch as f64 / wall,
        sim_makespan_s: sim_makespan,
        sim_per_item_s: per_item,
        sim_speedup_vs_one_tpu: plan.single_tpu_s / per_item,
    })
}

/// Load the manifest from an artifact dir (helper for binaries).
pub fn load_manifest(artifact_dir: &Path) -> Result<Manifest> {
    Manifest::load(&artifact_dir.join("manifest.json"))
}

/// Default artifact directory: `$REPO/artifacts` (overridable by env).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("TPU_PIPELINE_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn sample_manifest() -> Manifest {
        // reuse the sample from runtime::manifest tests via a minimal JSON
        Manifest::parse(
            r#"{"models": {"m": {
                "kind": "fc", "seed": 1, "macs": 192,
                "layers": [
                  {"kind": "fc", "in_features": 8, "out_features": 16},
                  {"kind": "fc", "in_features": 16, "out_features": 4}],
                "segments": [
                  {"start": 0, "end": 2, "file": "w.hlo.txt",
                   "input_shape": [8], "output_shape": [4],
                   "in_q": {"scale": 0.1, "zero_point": 0},
                   "out_q": {"scale": 0.1, "zero_point": 0}},
                  {"start": 0, "end": 1, "file": "a.hlo.txt",
                   "input_shape": [8], "output_shape": [16],
                   "in_q": {"scale": 0.1, "zero_point": 0},
                   "out_q": {"scale": 0.05, "zero_point": -128}},
                  {"start": 1, "end": 2, "file": "b.hlo.txt",
                   "input_shape": [16], "output_shape": [4],
                   "in_q": {"scale": 0.05, "zero_point": -128},
                   "out_q": {"scale": 0.1, "zero_point": 0}}],
                "golden": {"input": [0,0,0,0,0,0,0,0], "input_shape": [8],
                           "output": [0,0,0,0], "output_shape": [4]}}}}"#,
        )
        .unwrap()
    }

    #[test]
    fn plan_builds_sims_per_stage() {
        let m = sample_manifest();
        let entry = m.model("m").unwrap();
        let cfg = SystemConfig::default();
        let p = plan(entry, 2, Strategy::Uniform, &cfg).unwrap();
        assert_eq!(p.sims.len(), 2);
        assert_eq!(p.partition.label(), "1+1");
        assert!(p.single_tpu_s > 0.0);
        assert_eq!(p.input_shape, vec![8]);
        // last stage's hop is an output transfer (cheaper than a full hop)
        assert!(p.sims[1].hop_out_s < p.sims[0].hop_out_s + 1e-9);
    }

    #[test]
    fn plan_rejects_bad_arity() {
        let m = sample_manifest();
        let entry = m.model("m").unwrap();
        let cfg = SystemConfig::default();
        assert!(plan(entry, 3, Strategy::Uniform, &cfg).is_err());
        assert!(plan(entry, 0, Strategy::Uniform, &cfg).is_err());
    }

    #[test]
    fn synth_requests_deterministic() {
        let m = sample_manifest();
        let entry = m.model("m").unwrap();
        let cfg = SystemConfig::default();
        let p = plan(entry, 1, Strategy::Uniform, &cfg).unwrap();
        let a = synth_requests(&p, 5, 42);
        let b = synth_requests(&p, 5, 42);
        assert_eq!(a.len(), 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data, y.data);
            assert_eq!(x.data.len(), 8);
        }
    }
}
