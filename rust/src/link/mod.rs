//! PCIe link model: host<->device DMA for inputs, outputs and the
//! inter-TPU intermediate tensors that pipelined segmentation introduces.
//!
//! In the paper's implementation every inter-TPU handoff goes *through the
//! host* (device A -> host queue -> device B).  The byte movement occupies
//! the devices themselves (DMA does not overlap compute on the Edge TPU),
//! so it is charged to the producing/consuming stage's service time; what
//! remains between stages is the host-queue latency.

use crate::config::LinkConfig;

/// The PCIe link + host-queue relay model.
#[derive(Debug, Clone)]
pub struct Link {
    pub cfg: LinkConfig,
}

impl Link {
    pub fn new(cfg: LinkConfig) -> Self {
        Link { cfg }
    }

    /// One-direction activation DMA time (charged to the device that
    /// sources or sinks the tensor).
    pub fn xfer_s(&self, bytes: u64) -> f64 {
        bytes as f64 / self.cfg.act_bw
    }

    /// Host-queue handoff latency between consecutive stages.
    pub fn hop_latency_s(&self) -> f64 {
        self.cfg.hop_latency_s
    }

    /// End-to-end byte cost of one inter-TPU hop (both DMAs + latency) —
    /// the single-input view of a handoff.
    pub fn hop_s(&self, bytes: u64) -> f64 {
        2.0 * self.xfer_s(bytes) + self.cfg.hop_latency_s
    }

    /// Host-side per-item pipeline stage overhead (GIL-serialized).
    pub fn stage_overhead_s(&self) -> f64 {
        self.cfg.stage_overhead_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LinkConfig;

    fn link() -> Link {
        Link::new(LinkConfig::default())
    }

    #[test]
    fn hop_is_two_transfers_plus_latency() {
        let l = link();
        let b = 1_000_000;
        assert!((l.hop_s(b) - (2.0 * l.xfer_s(b) + l.hop_latency_s())).abs() < 1e-12);
    }

    #[test]
    fn fc_intermediates_negligible_conv_not() {
        // paper §V: FC intermediate (n ints) is tiny vs CONV (W*H*f bytes)
        let l = link();
        let fc_hop = l.hop_s(2100); // n=2100 int8 activations
        let conv_hop = l.hop_s(64 * 64 * 500); // f=500 feature map
        assert!(fc_hop < 0.3e-3, "fc_hop={fc_hop}");
        assert!(conv_hop > 5e-3, "conv_hop={conv_hop}");
    }

    #[test]
    fn latency_floor() {
        let l = link();
        assert!(l.hop_s(0) >= l.cfg.hop_latency_s);
        assert_eq!(l.xfer_s(0), 0.0);
    }
}
