//! Pooled activation-buffer arena: recycled slabs for the zero-copy
//! batched data plane.
//!
//! The paper's argument is that off-chip data movement, not compute,
//! bounds Edge-TPU inference; the host-side twin of that argument is that
//! the serving path must not re-allocate and re-copy activations at every
//! pipeline hop.  The arena keeps a free list of previously used slabs
//! keyed by capacity: a request batch's tensors are written **once** into
//! a [`SlabBuf`] at ingress, every stage writes its output into a recycled
//! slab from the same arena, and responses hand the final slab back to the
//! caller as ref-counted [`Tensor`] views — when the last view drops, the
//! slab returns to the free list.  In steady state the request path
//! performs **zero** heap allocations; [`DataPlaneMetrics`] counts the
//! misses so the `make smoke-dataplane` gate can assert exactly that.
//!
//! Ownership model (double-release is unrepresentable by construction):
//!
//! ```text
//! Arena::take  ->  SlabBuf (unique, writable)
//!                     | .share()
//!                     v
//!                  SharedSlab (Arc, read-only)  --slice-->  Tensor views
//!                     |  last clone dropped
//!                     v
//!                  slab returns to the arena free list
//! ```

use std::collections::BTreeMap;
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{Arc, Mutex};

use crate::metrics::DataPlaneMetrics;

/// Free slabs keyed by capacity; `take` reuses the smallest adequate one.
type FreeList = BTreeMap<usize, Vec<Box<[i8]>>>;

struct ArenaShared {
    free: Mutex<FreeList>,
    metrics: Arc<DataPlaneMetrics>,
}

/// A shared pool of recycled activation slabs (cheaply cloneable handle).
///
/// One arena is typically shared by every pipeline of a serving pool, so
/// a slab retired by one tenant's deployment is reused by another's —
/// retained memory is bounded by the pool-wide high-water mark, not by
/// the sum of per-tenant peaks.
#[derive(Clone)]
pub struct Arena {
    inner: Arc<ArenaShared>,
}

impl fmt::Debug for Arena {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Arena").field("retained", &self.retained()).finish()
    }
}

impl Arena {
    /// An empty arena reporting its alloc/reuse traffic into `metrics`.
    pub fn new(metrics: Arc<DataPlaneMetrics>) -> Arena {
        Arena { inner: Arc::new(ArenaShared { free: Mutex::new(BTreeMap::new()), metrics }) }
    }

    /// Take a writable slab of exactly `len` logical bytes, reusing the
    /// smallest retained slab whose capacity is at least `len` (so a
    /// partial batch rides a full-batch slab instead of allocating).
    /// Falls back to one heap allocation — counted as a miss — when no
    /// retained slab fits.  Contents of a reused slab are unspecified;
    /// every producer writes its full output.
    pub fn take(&self, len: usize) -> SlabBuf {
        if len == 0 {
            return SlabBuf { arena: None, buf: Some(Vec::new().into_boxed_slice()), len: 0 };
        }
        let recycled = {
            let mut free = self.inner.free.lock().unwrap();
            let cap = free.range(len..).next().map(|(&c, _)| c);
            match cap {
                Some(c) => {
                    let bucket = free.get_mut(&c).expect("capacity class present");
                    let buf = bucket.pop();
                    let now_empty = bucket.is_empty();
                    if now_empty {
                        free.remove(&c);
                    }
                    buf
                }
                None => None,
            }
        };
        let buf = match recycled {
            Some(buf) => {
                self.inner.metrics.record_slab_reuse();
                buf
            }
            None => {
                self.inner.metrics.record_slab_alloc(len as u64);
                vec![0i8; len].into_boxed_slice()
            }
        };
        SlabBuf { arena: Some(self.clone()), buf: Some(buf), len }
    }

    /// Number of slabs currently retained on the free list.
    pub fn retained(&self) -> usize {
        self.inner.free.lock().unwrap().values().map(Vec::len).sum()
    }

    fn recycle(&self, buf: Box<[i8]>) {
        if buf.is_empty() {
            return;
        }
        self.inner.free.lock().unwrap().entry(buf.len()).or_default().push(buf);
    }
}

/// A uniquely owned, writable slab leased from an [`Arena`].  Dropping it
/// returns the buffer to the arena; [`SlabBuf::share`] converts it into a
/// read-only ref-counted [`SharedSlab`] instead.  Derefs to the logical
/// `len` bytes (the underlying capacity may be larger).
pub struct SlabBuf {
    /// `None` for detached buffers ([`SlabBuf::from_vec`]): they drop
    /// normally instead of recycling.
    arena: Option<Arena>,
    /// `Some` until dropped or shared.
    buf: Option<Box<[i8]>>,
    len: usize,
}

impl SlabBuf {
    /// Wrap a plain vector as a detached slab (not arena-recycled).  Used
    /// where a tensor exists outside any pipeline, e.g. in unit tests.
    pub fn from_vec(v: Vec<i8>) -> SlabBuf {
        let len = v.len();
        SlabBuf { arena: None, buf: Some(v.into_boxed_slice()), len }
    }

    /// Logical length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the slab holds zero logical bytes.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Freeze into a read-only ref-counted slab; the buffer returns to
    /// the arena when the last [`SharedSlab`]/[`Tensor`] clone drops.
    pub fn share(mut self) -> SharedSlab {
        SharedSlab {
            inner: Arc::new(SlabShared {
                arena: self.arena.take(),
                buf: self.buf.take(),
                len: self.len,
            }),
        }
    }
}

impl Deref for SlabBuf {
    type Target = [i8];
    fn deref(&self) -> &[i8] {
        &self.buf.as_ref().expect("slab present until dropped/shared")[..self.len]
    }
}

impl DerefMut for SlabBuf {
    fn deref_mut(&mut self) -> &mut [i8] {
        let len = self.len;
        &mut self.buf.as_mut().expect("slab present until dropped/shared")[..len]
    }
}

impl Drop for SlabBuf {
    fn drop(&mut self) {
        if let (Some(arena), Some(buf)) = (self.arena.take(), self.buf.take()) {
            arena.recycle(buf);
        }
    }
}

impl fmt::Debug for SlabBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "SlabBuf(len={})", self.len)
    }
}

struct SlabShared {
    arena: Option<Arena>,
    buf: Option<Box<[i8]>>,
    len: usize,
}

impl Drop for SlabShared {
    fn drop(&mut self) {
        if let (Some(arena), Some(buf)) = (self.arena.take(), self.buf.take()) {
            arena.recycle(buf);
        }
    }
}

/// Read-only ref-counted slab; cloning shares the same buffer.  The slab
/// returns to its arena exactly once: when the last clone (including
/// every [`Tensor`] sliced from it) drops.
#[derive(Clone)]
pub struct SharedSlab {
    inner: Arc<SlabShared>,
}

impl SharedSlab {
    /// The slab's logical bytes.
    pub fn bytes(&self) -> &[i8] {
        &self.inner.buf.as_ref().expect("slab present until last drop")[..self.inner.len]
    }
}

impl fmt::Debug for SharedSlab {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "SharedSlab(len={})", self.inner.len)
    }
}

/// A ref-counted view of one tensor inside a [`SharedSlab`] — what a
/// batched response carries instead of an owned `Vec<i8>`.  All views of
/// one batch share the batch's output slab; no per-request copy is made.
/// Derefs to `[i8]` and compares against slices and `Vec<i8>`, so
/// existing `response.data == expected` call sites keep working.
#[derive(Clone)]
pub struct Tensor {
    slab: SharedSlab,
    off: usize,
    len: usize,
}

impl Tensor {
    /// View `len` bytes of `slab` starting at `off`.
    pub fn slice(slab: &SharedSlab, off: usize, len: usize) -> Tensor {
        assert!(off + len <= slab.inner.len, "tensor view out of slab bounds");
        Tensor { slab: slab.clone(), off, len }
    }

    /// A detached tensor owning a plain vector (no arena involved).
    pub fn from_vec(v: Vec<i8>) -> Tensor {
        let len = v.len();
        Tensor { slab: SlabBuf::from_vec(v).share(), off: 0, len }
    }

    /// The viewed bytes.
    pub fn as_slice(&self) -> &[i8] {
        &self.slab.bytes()[self.off..self.off + self.len]
    }

    /// Copy the viewed bytes into an owned vector.
    pub fn to_vec(&self) -> Vec<i8> {
        self.as_slice().to_vec()
    }
}

impl Deref for Tensor {
    type Target = [i8];
    fn deref(&self) -> &[i8] {
        self.as_slice()
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self.as_slice(), f)
    }
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Tensor) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Tensor {}

impl PartialEq<[i8]> for Tensor {
    fn eq(&self, other: &[i8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<&[i8]> for Tensor {
    fn eq(&self, other: &&[i8]) -> bool {
        self.as_slice() == *other
    }
}

impl PartialEq<Vec<i8>> for Tensor {
    fn eq(&self, other: &Vec<i8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Tensor> for Vec<i8> {
    fn eq(&self, other: &Tensor) -> bool {
        self.as_slice() == other.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena() -> (Arena, Arc<DataPlaneMetrics>) {
        let m = Arc::new(DataPlaneMetrics::default());
        (Arena::new(m.clone()), m)
    }

    #[test]
    fn take_allocates_then_recycles() {
        let (a, m) = arena();
        {
            let mut s = a.take(64);
            s[0] = 7;
            assert_eq!(s.len(), 64);
        } // dropped -> recycled
        assert_eq!(a.retained(), 1);
        let s2 = a.take(64);
        assert_eq!(s2.len(), 64);
        let snap = m.snapshot();
        assert_eq!(snap.slab_allocs, 1, "second take must reuse");
        assert_eq!(snap.slab_reuses, 1);
        assert_eq!(snap.slab_alloc_bytes, 64);
    }

    #[test]
    fn smaller_request_reuses_larger_slab() {
        let (a, m) = arena();
        drop(a.take(400)); // retained with capacity 400
        let s = a.take(64);
        assert_eq!(s.len(), 64, "logical length is the requested one");
        assert_eq!(m.snapshot().slab_reuses, 1);
        assert_eq!(m.snapshot().slab_allocs, 1, "only the first take allocated");
    }

    #[test]
    fn shared_slab_returns_once_after_last_view_drops() {
        let (a, m) = arena();
        let mut s = a.take(8);
        for (i, b) in s.iter_mut().enumerate() {
            *b = i as i8;
        }
        let shared = s.share();
        let t0 = Tensor::slice(&shared, 0, 4);
        let t1 = Tensor::slice(&shared, 4, 4);
        let t1b = t1.clone();
        drop(shared);
        assert_eq!(a.retained(), 0, "views keep the slab alive");
        assert_eq!(t0.as_slice(), &[0, 1, 2, 3]);
        drop(t0);
        drop(t1);
        assert_eq!(a.retained(), 0, "one view still alive");
        assert_eq!(t1b.as_slice(), &[4, 5, 6, 7]);
        drop(t1b);
        assert_eq!(a.retained(), 1, "slab recycled exactly once");
        // and it is reusable afterwards
        let again = a.take(8);
        assert_eq!(again.len(), 8);
        assert_eq!(m.snapshot().slab_allocs, 1);
    }

    #[test]
    fn tensor_comparisons_and_debug() {
        let t = Tensor::from_vec(vec![1, -2, 3]);
        assert_eq!(t, vec![1, -2, 3]);
        assert_eq!(vec![1, -2, 3], t);
        assert_eq!(t, t.clone());
        assert_ne!(t, vec![1, -2, 4]);
        assert_eq!(t.len(), 3);
        assert_eq!(t.to_vec(), vec![1, -2, 3]);
        assert_eq!(format!("{t:?}"), "[1, -2, 3]");
    }

    #[test]
    fn zero_len_take_is_detached() {
        let (a, m) = arena();
        let s = a.take(0);
        assert!(s.is_empty());
        drop(s);
        assert_eq!(a.retained(), 0);
        assert_eq!(m.snapshot().slab_allocs, 0);
    }

    #[test]
    fn distinct_sizes_get_distinct_classes() {
        let (a, m) = arena();
        drop(a.take(16));
        drop(a.take(32));
        assert_eq!(a.retained(), 2);
        // 24 fits in the 32-capacity slab, not the 16 one
        let s = a.take(24);
        assert_eq!(s.len(), 24);
        assert_eq!(m.snapshot().slab_allocs, 2);
        assert_eq!(m.snapshot().slab_reuses, 1);
        assert_eq!(a.retained(), 1, "only the 16-byte slab remains free");
    }

    #[test]
    fn steady_state_cycle_never_allocates_again() {
        let (a, m) = arena();
        for _ in 0..100 {
            let s = a.take(128).share();
            let t = Tensor::slice(&s, 0, 128);
            drop(s);
            drop(t);
        }
        let snap = m.snapshot();
        assert_eq!(snap.slab_allocs, 1, "steady state must be allocation-free");
        assert_eq!(snap.slab_reuses, 99);
    }
}
