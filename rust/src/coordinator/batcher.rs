//! Dynamic batcher: groups incoming requests into batches bounded by size
//! and wait time before injection into the pipeline.  The paper's workload
//! is a closed 50-input batch; a serving deployment sees an open arrival
//! stream, which this component adapts.

use std::time::{Duration, Instant};

use super::queue::Receiver;
use super::Request;

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Flush when this many requests are pending.
    pub max_batch: usize,
    /// Flush when the oldest pending request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 50, max_wait: Duration::from_millis(5) }
    }
}

/// Pull-based batcher over a request queue.
pub struct Batcher {
    rx: Receiver<Request>,
    policy: BatchPolicy,
}

impl Batcher {
    pub fn new(rx: Receiver<Request>, policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        Batcher { rx, policy }
    }

    /// Collect the next batch.  Blocks for the first request, then fills
    /// until `max_batch` or `max_wait`.  `None` when the queue is closed
    /// and drained.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let first = self.rx.recv()?;
        let deadline = Instant::now() + self.policy.max_wait;
        let mut batch = vec![first];
        while batch.len() < self.policy.max_batch {
            if Instant::now() >= deadline {
                break;
            }
            match self.rx.try_recv() {
                Some(r) => batch.push(r),
                None => std::thread::sleep(Duration::from_micros(50)),
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::bounded;

    fn reqs(n: usize) -> Vec<Request> {
        (0..n).map(|i| Request { id: i as u64, data: vec![0; 4] }).collect()
    }

    #[test]
    fn flushes_at_max_batch() {
        let (tx, rx) = bounded(128);
        for r in reqs(25) {
            tx.send(r).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 10, max_wait: Duration::from_secs(1) });
        assert_eq!(b.next_batch().unwrap().len(), 10);
        assert_eq!(b.next_batch().unwrap().len(), 10);
        assert_eq!(b.next_batch().unwrap().len(), 5);
    }

    #[test]
    fn flushes_at_deadline_with_partial_batch() {
        let (tx, rx) = bounded(16);
        tx.send(Request { id: 0, data: vec![] }).unwrap();
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(10) },
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn none_after_close() {
        let (tx, rx) = bounded::<Request>(4);
        tx.close();
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn ids_preserved_in_order() {
        let (tx, rx) = bounded(64);
        for r in reqs(30) {
            tx.send(r).unwrap();
        }
        tx.close();
        let b = Batcher::new(rx, BatchPolicy { max_batch: 30, max_wait: Duration::from_millis(20) });
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..30).collect::<Vec<_>>());
    }
}
