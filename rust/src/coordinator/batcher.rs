//! Dynamic batcher: groups incoming requests into batches bounded by size
//! and wait time before injection into the pipeline.  The paper's workload
//! is a closed 50-input batch; a serving deployment sees an open arrival
//! stream, which this component adapts.
//!
//! The fill loop parks on the queue's condvar with a deadline
//! ([`super::queue::Receiver::recv_many_deadline`]) — there is no
//! sleep/poll spin, so an idle batcher burns no CPU and a request
//! arriving mid-wait wakes it immediately.  Everything already queued is
//! drained under **one** lock acquisition per wakeup, so filling a batch
//! from a burst costs O(1) locks, not one lock per request.

use std::time::{Duration, Instant};

use crate::metrics::FlushKind;

use super::queue::{Receiver, RecvMany};
use super::Request;

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Flush when this many requests are pending.
    pub max_batch: usize,
    /// Flush when the oldest pending request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 50, max_wait: Duration::from_millis(5) }
    }
}

/// Share of a tenant's p99 SLO the batcher may burn waiting to fill a
/// batch (the rest is left for queueing + pipeline service).
const SLO_WAIT_FRACTION: f64 = 0.25;

impl BatchPolicy {
    /// Derive a tenant-specific policy from its p99 SLO: a tight SLO
    /// shrinks `max_wait` to a quarter of the budget so the flush
    /// deadline can never eat the whole latency target.  Tenants
    /// without an SLO (or with a generous one) keep the base policy.
    pub fn for_slo(self, slo_p99_s: Option<f64>) -> BatchPolicy {
        match slo_p99_s {
            Some(slo) if slo > 0.0 => BatchPolicy {
                max_batch: self.max_batch,
                max_wait: self
                    .max_wait
                    .min(Duration::from_secs_f64(slo * SLO_WAIT_FRACTION)),
            },
            _ => self,
        }
    }
}

/// Pull-based batcher over a request queue.
pub struct Batcher {
    rx: Receiver<Request>,
    policy: BatchPolicy,
}

impl Batcher {
    /// Wrap a request queue with a batching policy (`max_batch >= 1`).
    pub fn new(rx: Receiver<Request>, policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        Batcher { rx, policy }
    }

    /// The policy this batcher flushes under.
    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Requests currently waiting in the ingress queue (not yet batched).
    pub fn queue_depth(&self) -> usize {
        self.rx.len()
    }

    /// Collect the next batch.  Blocks for the first request, then fills
    /// until `max_batch` or `max_wait`.  `None` when the queue is closed
    /// and drained.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        self.next_batch_with_reason().map(|(batch, _)| batch)
    }

    /// Like [`Batcher::next_batch`], but also reports why the batch
    /// flushed: `Size` (hit `max_batch`), `Deadline` (oldest request
    /// waited `max_wait`) or `Closed` (queue closed mid-fill).
    ///
    /// With `max_wait == 0` the deadline is immediately in the past, so
    /// the batch takes only requests that are already queued and never
    /// waits — "immediate flush" semantics.
    pub fn next_batch_with_reason(&self) -> Option<(Vec<Request>, FlushKind)> {
        let first = self.rx.recv()?;
        let deadline = Instant::now() + self.policy.max_wait;
        let mut batch = Vec::with_capacity(self.policy.max_batch.min(256));
        batch.push(first);
        let reason = loop {
            if batch.len() >= self.policy.max_batch {
                break FlushKind::Size;
            }
            let want = self.policy.max_batch - batch.len();
            match self.rx.recv_many_deadline(deadline, want, &mut batch) {
                RecvMany::Items(_) => continue, // re-check the size bound
                RecvMany::TimedOut => break FlushKind::Deadline,
                RecvMany::Closed => break FlushKind::Closed,
            }
        };
        Some((batch, reason))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::bounded;
    use std::time::Duration;

    fn reqs(n: usize) -> Vec<Request> {
        (0..n).map(|i| Request::new(i as u64, vec![0; 4])).collect()
    }

    #[test]
    fn flushes_at_max_batch() {
        let (tx, rx) = bounded(128);
        for r in reqs(25) {
            tx.send(r).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 10, max_wait: Duration::from_secs(1) });
        assert_eq!(b.next_batch().unwrap().len(), 10);
        assert_eq!(b.next_batch().unwrap().len(), 10);
        assert_eq!(b.next_batch().unwrap().len(), 5);
    }

    #[test]
    fn flushes_at_deadline_with_partial_batch() {
        let (tx, rx) = bounded(16);
        tx.send(Request::new(0, vec![])).unwrap();
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(10) },
        );
        let t0 = Instant::now();
        let (batch, reason) = b.next_batch_with_reason().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(reason, FlushKind::Deadline);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn none_after_close() {
        let (tx, rx) = bounded::<Request>(4);
        tx.close();
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn ids_preserved_in_order() {
        let (tx, rx) = bounded(64);
        for r in reqs(30) {
            tx.send(r).unwrap();
        }
        tx.close();
        let b = Batcher::new(rx, BatchPolicy { max_batch: 30, max_wait: Duration::from_millis(20) });
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..30).collect::<Vec<_>>());
    }

    #[test]
    fn max_batch_one_flushes_each_request_by_size() {
        let (tx, rx) = bounded(16);
        for r in reqs(3) {
            tx.send(r).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 1, max_wait: Duration::from_secs(5) });
        for i in 0..3u64 {
            let (batch, reason) = b.next_batch_with_reason().unwrap();
            assert_eq!(batch.len(), 1);
            assert_eq!(batch[0].id, i);
            // must not wait out the 5s deadline: size bound fires first
            assert_eq!(reason, FlushKind::Size);
        }
    }

    #[test]
    fn queue_closed_mid_batch_flushes_partial_with_closed_reason() {
        let (tx, rx) = bounded(16);
        for r in reqs(4) {
            tx.send(r).unwrap();
        }
        tx.close();
        let b = Batcher::new(rx, BatchPolicy { max_batch: 10, max_wait: Duration::from_secs(5) });
        let (batch, reason) = b.next_batch_with_reason().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(reason, FlushKind::Closed);
        assert!(b.next_batch_with_reason().is_none(), "drained queue yields None");
    }

    #[test]
    fn zero_max_wait_flushes_immediately_without_waiting() {
        let (tx, rx) = bounded(16);
        for r in reqs(3) {
            tx.send(r).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 10, max_wait: Duration::ZERO });
        // already-queued requests are all taken (no waiting needed)...
        let t0 = Instant::now();
        let (batch, reason) = b.next_batch_with_reason().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(reason, FlushKind::Deadline);
        // ...and the flush never blocks on future arrivals
        assert!(t0.elapsed() < Duration::from_millis(100));
        tx.send(Request::new(9, vec![])).unwrap();
        let (batch, _) = b.next_batch_with_reason().unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn slo_derived_policy_shrinks_max_wait_only_under_tight_slos() {
        let base = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) };
        // no SLO: unchanged
        let p = base.for_slo(None);
        assert_eq!(p.max_wait, base.max_wait);
        assert_eq!(p.max_batch, 8);
        // generous SLO (100 ms): 25 ms cap is above the base wait
        let p = base.for_slo(Some(0.1));
        assert_eq!(p.max_wait, base.max_wait);
        // tight SLO (4 ms): wait shrinks to a quarter of the budget
        let p = base.for_slo(Some(0.004));
        assert_eq!(p.max_wait, Duration::from_millis(1));
        assert_eq!(p.max_batch, 8, "only the wait shrinks");
        // nonsense SLO is ignored
        assert_eq!(base.for_slo(Some(0.0)).max_wait, base.max_wait);
    }

    #[test]
    fn queue_depth_reports_pending() {
        let (tx, rx) = bounded(16);
        for r in reqs(6) {
            tx.send(r).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(1) });
        assert_eq!(b.queue_depth(), 6);
        assert_eq!(b.next_batch().unwrap().len(), 4);
        assert_eq!(b.queue_depth(), 2);
    }
}
