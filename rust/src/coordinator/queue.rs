//! Bounded MPMC channel on `Mutex` + `Condvar` (no crossbeam offline) —
//! the host-side queues of the paper's pipeline ("a queue implementing
//! thread-safe mechanisms on the host to communicate intermediate
//! results").  Bounded capacity gives the serving pipeline backpressure.
//!
//! Two data-plane properties keep the hot path cheap:
//!
//! * **waiter-gated wakeups** — the channel tracks how many receivers and
//!   senders are parked on each condvar and skips the (syscall-bound)
//!   `notify_one` entirely when nobody is waiting, so an enqueue onto a
//!   busy pipeline costs one uncontended lock and nothing else;
//! * **batch transfer** — [`Sender::send_many`] moves a whole flush under
//!   one lock acquisition and at most one wakeup, and
//!   [`Receiver::recv_many_deadline`] drains everything queued in one
//!   lock, which is what makes the batcher's fill loop O(1) locks per
//!   batch instead of O(1) per request.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

struct Inner<T> {
    queue: VecDeque<T>,
    capacity: usize,
    closed: bool,
    /// Receivers currently parked on `not_empty` (gates sender wakeups).
    recv_waiters: usize,
    /// Senders currently parked on `not_full` (gates receiver wakeups).
    send_waiters: usize,
}

struct Shared<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> Shared<T> {
    /// Park a receiver on `not_empty` until woken or `deadline`.  The
    /// remaining timeout is recomputed from `deadline` on every call, so
    /// a spurious condvar wakeup — or a wakeup whose items another
    /// receiver already stole — re-waits only the *remaining* time,
    /// never the full original timeout again.  Returns `None` once the
    /// deadline has passed (the caller reports a timeout), `Some(guard)`
    /// after a wakeup (the caller re-checks queue state and loops back
    /// here).  Both deadline-bounded receives funnel through this single
    /// wait, so the re-wait arithmetic cannot drift between them.
    fn park_recv_until<'a>(
        &'a self,
        mut inner: std::sync::MutexGuard<'a, Inner<T>>,
        deadline: Instant,
    ) -> Option<std::sync::MutexGuard<'a, Inner<T>>> {
        let now = Instant::now();
        if now >= deadline {
            return None;
        }
        inner.recv_waiters += 1;
        let (mut guard, _timeout) =
            self.not_empty.wait_timeout(inner, deadline - now).unwrap();
        guard.recv_waiters -= 1;
        Some(guard)
    }
}

/// Sending half (cloneable).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half (cloneable).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Receiver { shared: self.shared.clone() }
    }
}

/// Error returned when sending into a closed queue.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Outcome of a deadline-bounded receive ([`Receiver::recv_deadline`]).
#[derive(Debug, PartialEq, Eq)]
pub enum RecvDeadline<T> {
    /// An item was received before the deadline.
    Item(T),
    /// The deadline passed with the queue still open and empty.
    TimedOut,
    /// The queue is closed and fully drained.
    Closed,
}

/// Outcome of a batched deadline-bounded receive
/// ([`Receiver::recv_many_deadline`]).
#[derive(Debug, PartialEq, Eq)]
pub enum RecvMany {
    /// This many items (>= 1) were appended to the caller's buffer.
    Items(usize),
    /// The deadline passed with the queue still open and empty.
    TimedOut,
    /// The queue is closed and fully drained.
    Closed,
}

/// Create a bounded channel with the given capacity (>= 1).
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity >= 1);
    let shared = Arc::new(Shared {
        inner: Mutex::new(Inner {
            queue: VecDeque::new(),
            capacity,
            closed: false,
            recv_waiters: 0,
            send_waiters: 0,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Sender<T> {
    /// Blocking send; returns the value if the channel is closed.  The
    /// `not_empty` wakeup is skipped when no receiver is parked — on a
    /// busy pipeline an enqueue is one uncontended lock, no syscall.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if inner.closed {
                return Err(SendError(value));
            }
            if inner.queue.len() < inner.capacity {
                inner.queue.push_back(value);
                if inner.recv_waiters > 0 {
                    self.shared.not_empty.notify_one();
                }
                return Ok(());
            }
            inner.send_waiters += 1;
            inner = self.shared.not_full.wait(inner).unwrap();
            inner.send_waiters -= 1;
        }
    }

    /// Blocking batched send: move every item of `items` into the queue
    /// under one lock acquisition per free-capacity window and at most
    /// one wakeup per window, blocking for room as needed.  On a closed
    /// channel the **unsent** remainder comes back in the error (items
    /// already enqueued before the close stay drainable, exactly like a
    /// sequence of single sends racing a close).  Returns how many items
    /// were enqueued.
    pub fn send_many<I>(&self, items: I) -> Result<usize, SendError<Vec<T>>>
    where
        I: IntoIterator<Item = T>,
    {
        let mut it = items.into_iter();
        let mut pending = it.next();
        if pending.is_none() {
            return Ok(0);
        }
        let mut sent = 0usize;
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if inner.closed {
                let mut rest: Vec<T> = Vec::new();
                rest.extend(pending.take());
                rest.extend(it);
                return Err(SendError(rest));
            }
            let mut pushed = 0usize;
            while inner.queue.len() < inner.capacity {
                match pending.take() {
                    Some(v) => {
                        inner.queue.push_back(v);
                        pushed += 1;
                        pending = it.next();
                    }
                    None => break,
                }
            }
            sent += pushed;
            if pushed > 0 && inner.recv_waiters > 0 {
                // several items may satisfy several parked receivers
                if pushed == 1 {
                    self.shared.not_empty.notify_one();
                } else {
                    self.shared.not_empty.notify_all();
                }
            }
            if pending.is_none() {
                return Ok(sent);
            }
            inner.send_waiters += 1;
            inner = self.shared.not_full.wait(inner).unwrap();
            inner.send_waiters -= 1;
        }
    }

    /// Close the channel: receivers drain what's left, then get `None`.
    pub fn close(&self) {
        let mut inner = self.shared.inner.lock().unwrap();
        inner.closed = true;
        if inner.recv_waiters > 0 {
            self.shared.not_empty.notify_all();
        }
        if inner.send_waiters > 0 {
            self.shared.not_full.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` once the channel is closed AND drained.
    pub fn recv(&self) -> Option<T> {
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if let Some(v) = inner.queue.pop_front() {
                if inner.send_waiters > 0 {
                    self.shared.not_full.notify_one();
                }
                return Some(v);
            }
            if inner.closed {
                return None;
            }
            inner.recv_waiters += 1;
            inner = self.shared.not_empty.wait(inner).unwrap();
            inner.recv_waiters -= 1;
        }
    }

    /// Blocking receive bounded by a deadline: parks on the condvar (no
    /// spinning) until an item arrives, the queue closes, or `deadline`
    /// passes.  An already-queued item is always returned, even when the
    /// deadline is in the past — "deadline passed" only means "do not
    /// *wait* any longer".
    pub fn recv_deadline(&self, deadline: Instant) -> RecvDeadline<T> {
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if let Some(v) = inner.queue.pop_front() {
                if inner.send_waiters > 0 {
                    self.shared.not_full.notify_one();
                }
                return RecvDeadline::Item(v);
            }
            if inner.closed {
                return RecvDeadline::Closed;
            }
            match self.shared.park_recv_until(inner, deadline) {
                Some(guard) => inner = guard,
                None => return RecvDeadline::TimedOut,
            }
        }
    }

    /// Batched deadline-bounded receive: append up to `max` queued items
    /// to `out` under **one** lock acquisition, parking (no spin) only
    /// while the queue is empty.  Returns as soon as at least one item
    /// moved — it never waits to fill `max` — so a batcher drains a burst
    /// in O(1) locks instead of one lock per request.  Like
    /// [`Receiver::recv_deadline`], queued items are returned even when
    /// the deadline already passed.
    pub fn recv_many_deadline(
        &self,
        deadline: Instant,
        max: usize,
        out: &mut Vec<T>,
    ) -> RecvMany {
        if max == 0 {
            return RecvMany::Items(0);
        }
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if !inner.queue.is_empty() {
                let k = max.min(inner.queue.len());
                out.extend(inner.queue.drain(..k));
                if inner.send_waiters > 0 {
                    // k freed slots may unblock several parked senders
                    if k == 1 {
                        self.shared.not_full.notify_one();
                    } else {
                        self.shared.not_full.notify_all();
                    }
                }
                return RecvMany::Items(k);
            }
            if inner.closed {
                return RecvMany::Closed;
            }
            match self.shared.park_recv_until(inner, deadline) {
                Some(guard) => inner = guard,
                None => return RecvMany::TimedOut,
            }
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut inner = self.shared.inner.lock().unwrap();
        let v = inner.queue.pop_front();
        if v.is_some() && inner.send_waiters > 0 {
            self.shared.not_full.notify_one();
        }
        v
    }

    /// Number of items currently buffered in the queue.
    pub fn len(&self) -> usize {
        self.shared.inner.lock().unwrap().queue.len()
    }

    /// Whether the queue is currently empty (it may still be open).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv(), Some(i));
        }
    }

    #[test]
    fn close_drains_then_none() {
        let (tx, rx) = bounded(8);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        tx.close();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
        assert_eq!(tx.send(3), Err(SendError(3)));
    }

    #[test]
    fn backpressure_blocks_until_consumed() {
        let (tx, rx) = bounded(1);
        tx.send(0u32).unwrap();
        let t = thread::spawn(move || {
            // this send must block until the consumer pops
            tx.send(1).unwrap();
            tx.close();
        });
        thread::sleep(Duration::from_millis(30));
        assert_eq!(rx.len(), 1, "second send must be blocked");
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), None);
        t.join().unwrap();
    }

    #[test]
    fn cross_thread_pipeline() {
        let (tx1, rx1) = bounded::<u64>(4);
        let (tx2, rx2) = bounded::<u64>(4);
        let stage = thread::spawn(move || {
            while let Some(v) = rx1.recv() {
                tx2.send(v * 2).unwrap();
            }
            tx2.close();
        });
        // producer must run concurrently with the drain: with bounded
        // queues, feeding 100 items inline would (correctly) deadlock
        let producer = thread::spawn(move || {
            for i in 0..100 {
                tx1.send(i).unwrap();
            }
            tx1.close();
        });
        let mut got = Vec::new();
        while let Some(v) = rx2.recv() {
            got.push(v);
        }
        stage.join().unwrap();
        producer.join().unwrap();
        assert_eq!(got, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn try_recv_on_empty_returns_none_without_blocking() {
        let (tx, rx) = bounded::<u32>(4);
        assert_eq!(rx.try_recv(), None, "empty open channel");
        tx.send(7).unwrap();
        assert_eq!(rx.try_recv(), Some(7));
        assert_eq!(rx.try_recv(), None, "drained again");
        tx.close();
        assert_eq!(rx.try_recv(), None, "empty closed channel");
        assert!(rx.is_empty());
    }

    #[test]
    fn send_after_close_returns_the_value() {
        let (tx, rx) = bounded::<String>(2);
        tx.close();
        // the rejected value comes back to the caller intact
        let err = tx.send("payload".to_string()).unwrap_err();
        assert_eq!(err, SendError("payload".to_string()));
        let SendError(v) = err;
        assert_eq!(v, "payload");
        assert_eq!(rx.recv(), None);
        // closing twice is idempotent
        tx.close();
        assert!(tx.send("again".to_string()).is_err());
    }

    #[test]
    fn recv_drains_buffered_items_after_close_then_none_forever() {
        let (tx, rx) = bounded(8);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        tx.close();
        // closed-but-nonempty: recv keeps draining in FIFO order
        for i in 0..4 {
            assert_eq!(rx.len(), 4 - i as usize);
            assert_eq!(rx.recv(), Some(i));
        }
        // closed-and-empty: every further recv is None (no hang)
        assert_eq!(rx.recv(), None);
        assert_eq!(rx.recv(), None);
    }

    /// Poll `cond` until it holds or the deadline passes (scheduling-safe
    /// alternative to a fixed sleep before asserting cross-thread state).
    fn eventually(deadline: Duration, cond: impl Fn() -> bool) -> bool {
        let t0 = std::time::Instant::now();
        while t0.elapsed() < deadline {
            if cond() {
                return true;
            }
            thread::sleep(Duration::from_millis(2));
        }
        cond()
    }

    #[test]
    fn capacity_blocks_sender_and_unblocks_per_recv() {
        let (tx, rx) = bounded::<u32>(2);
        tx.send(0).unwrap();
        tx.send(1).unwrap();
        let unblocked = Arc::new(Mutex::new(Vec::new()));
        let log = unblocked.clone();
        let t = thread::spawn(move || {
            for v in [2u32, 3] {
                tx.send(v).unwrap(); // must block while 2 items sit queued
                log.lock().unwrap().push(v);
            }
        });
        // these hold regardless of scheduling: a blocked send can neither
        // grow the queue past capacity nor reach the post-send log line
        thread::sleep(Duration::from_millis(40));
        assert_eq!(rx.len(), 2, "queue must stay at capacity");
        assert!(unblocked.lock().unwrap().is_empty(), "sender must still be blocked");
        // each recv frees exactly one slot
        assert_eq!(rx.recv(), Some(0));
        assert!(
            eventually(Duration::from_secs(5), || *unblocked.lock().unwrap() == [2]),
            "sender should wake after one recv frees a slot"
        );
        assert_eq!(rx.recv(), Some(1));
        t.join().unwrap();
        assert_eq!(unblocked.lock().unwrap().as_slice(), &[2, 3]);
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(3));
    }

    #[test]
    fn close_wakes_blocked_sender() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(0).unwrap();
        let tx2 = tx.clone();
        let t = thread::spawn(move || tx2.send(1));
        thread::sleep(Duration::from_millis(30));
        tx.close(); // the blocked send must wake and fail
        assert_eq!(t.join().unwrap(), Err(SendError(1)));
        // the pre-close item is still drainable
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn recv_deadline_returns_buffered_item_even_past_deadline() {
        let (tx, rx) = bounded(4);
        tx.send(42u32).unwrap();
        // deadline already passed: the queued item must still come out
        let past = Instant::now() - Duration::from_millis(5);
        assert_eq!(rx.recv_deadline(past), RecvDeadline::Item(42));
        // empty + past deadline -> immediate timeout, no blocking
        assert_eq!(rx.recv_deadline(past), RecvDeadline::TimedOut);
    }

    #[test]
    fn recv_deadline_times_out_then_sees_closed() {
        let (tx, rx) = bounded::<u32>(4);
        let t0 = Instant::now();
        let r = rx.recv_deadline(t0 + Duration::from_millis(20));
        assert_eq!(r, RecvDeadline::TimedOut);
        assert!(t0.elapsed() >= Duration::from_millis(20));
        tx.close();
        assert_eq!(
            rx.recv_deadline(Instant::now() + Duration::from_secs(5)),
            RecvDeadline::Closed
        );
    }

    #[test]
    fn recv_deadline_wakes_on_send() {
        let (tx, rx) = bounded::<u32>(4);
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            tx.send(7).unwrap();
        });
        let r = rx.recv_deadline(Instant::now() + Duration::from_secs(5));
        assert_eq!(r, RecvDeadline::Item(7));
        t.join().unwrap();
    }

    #[test]
    fn send_many_moves_a_whole_batch() {
        let (tx, rx) = bounded(16);
        assert_eq!(tx.send_many(0..5), Ok(5));
        assert_eq!(rx.len(), 5);
        for i in 0..5 {
            assert_eq!(rx.recv(), Some(i));
        }
        // empty batch is a no-op
        assert_eq!(tx.send_many(std::iter::empty::<i32>()), Ok(0));
        assert!(rx.is_empty());
    }

    #[test]
    fn send_many_blocks_for_room_and_completes() {
        let (tx, rx) = bounded(3);
        let t = thread::spawn(move || tx.send_many(0..10));
        let mut got = Vec::new();
        while got.len() < 10 {
            if let Some(v) = rx.recv() {
                got.push(v);
            }
        }
        assert_eq!(t.join().unwrap(), Ok(10));
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn send_many_returns_unsent_remainder_on_close() {
        let (tx, rx) = bounded(2);
        let tx2 = tx.clone();
        // capacity 2: the batch stalls with items 10, 11 enqueued
        let t = thread::spawn(move || tx2.send_many(vec![10u32, 11, 12, 13]));
        thread::sleep(Duration::from_millis(30));
        tx.close();
        let err = t.join().unwrap().unwrap_err();
        assert_eq!(err, SendError(vec![12, 13]), "unsent tail comes back");
        // the enqueued prefix still drains
        assert_eq!(rx.recv(), Some(10));
        assert_eq!(rx.recv(), Some(11));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_many_wakes_multiple_parked_receivers() {
        let (tx, rx) = bounded::<u32>(8);
        let results = Arc::new(Mutex::new(Vec::new()));
        let mut workers = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            let results = results.clone();
            workers.push(thread::spawn(move || {
                while let Some(v) = rx.recv() {
                    results.lock().unwrap().push(v);
                }
            }));
        }
        thread::sleep(Duration::from_millis(20)); // let them park
        assert_eq!(tx.send_many(0..6), Ok(6));
        tx.close();
        for w in workers {
            w.join().unwrap();
        }
        let mut got = results.lock().unwrap().clone();
        got.sort_unstable();
        assert_eq!(got, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn recv_many_drains_burst_in_one_call() {
        let (tx, rx) = bounded(16);
        tx.send_many(0..7).unwrap();
        let mut out = Vec::new();
        let past = Instant::now() - Duration::from_millis(1);
        // queued items come out even past the deadline, capped at max
        assert_eq!(rx.recv_many_deadline(past, 5, &mut out), RecvMany::Items(5));
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        assert_eq!(rx.recv_many_deadline(past, 5, &mut out), RecvMany::Items(2));
        assert_eq!(out, (0..7).collect::<Vec<_>>());
        // empty + past deadline -> immediate timeout
        assert_eq!(rx.recv_many_deadline(past, 5, &mut out), RecvMany::TimedOut);
        tx.close();
        assert_eq!(
            rx.recv_many_deadline(Instant::now() + Duration::from_secs(5), 5, &mut out),
            RecvMany::Closed
        );
        // max == 0 never blocks
        assert_eq!(rx.recv_many_deadline(past, 0, &mut out), RecvMany::Items(0));
    }

    #[test]
    fn recv_many_wakes_on_send_and_returns_what_arrived() {
        let (tx, rx) = bounded::<u32>(8);
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            tx.send(7).unwrap();
        });
        let mut out = Vec::new();
        let r = rx.recv_many_deadline(Instant::now() + Duration::from_secs(5), 4, &mut out);
        assert_eq!(r, RecvMany::Items(1), "returns as soon as anything arrived");
        assert_eq!(out, vec![7]);
        t.join().unwrap();
    }

    #[test]
    fn stolen_wakeup_rewaits_only_remaining_deadline() {
        // two receivers park on the same deadline; a 2-item send_many
        // wakes BOTH (notify_all), one drains both items, and the loser's
        // wakeup finds the queue empty again.  The loser must re-wait
        // only the remaining window and time out at ~total — restarting
        // the full timeout on the stolen wakeup would push it to
        // ~(wake_at + total), well past the assertion bound.
        let total = Duration::from_millis(500);
        let wake_at = Duration::from_millis(200);
        let (tx, rx) = bounded::<u32>(8);
        let t0 = Instant::now();
        let deadline = t0 + total;
        let mut threads = Vec::new();
        for _ in 0..2 {
            let rx = rx.clone();
            threads.push(thread::spawn(move || {
                let mut out = Vec::new();
                let r = rx.recv_many_deadline(deadline, 8, &mut out);
                (r, out.len(), t0.elapsed())
            }));
        }
        thread::sleep(wake_at); // let both receivers park
        tx.send_many(vec![1u32, 2]).unwrap();
        let results: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
        let mut timed_out = Vec::new();
        let mut drained = 0usize;
        for (r, n, elapsed) in &results {
            match r {
                RecvMany::Items(k) => {
                    assert_eq!(k, n);
                    drained += k;
                }
                RecvMany::TimedOut => timed_out.push(*elapsed),
                RecvMany::Closed => panic!("queue was never closed: {results:?}"),
            }
        }
        assert_eq!(drained, 2, "both items drained exactly once: {results:?}");
        assert_eq!(timed_out.len(), 1, "one receiver must lose the race: {results:?}");
        assert!(
            timed_out[0] >= total,
            "loser returned before its deadline: {results:?}"
        );
        assert!(
            timed_out[0] < total + Duration::from_millis(150),
            "loser re-waited more than the remaining window \
             (full-timeout restart after a stolen wakeup): {results:?}"
        );
    }

    #[test]
    fn recv_many_unblocks_parked_senders() {
        let (tx, rx) = bounded::<u32>(2);
        tx.send(0).unwrap();
        tx.send(1).unwrap();
        let t = thread::spawn(move || tx.send_many(vec![2, 3]));
        thread::sleep(Duration::from_millis(20));
        let mut out = Vec::new();
        // draining both slots must wake the blocked batch send
        let r = rx.recv_many_deadline(Instant::now() + Duration::from_secs(5), 8, &mut out);
        assert_eq!(r, RecvMany::Items(2));
        assert_eq!(t.join().unwrap(), Ok(2));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(3));
    }

    #[test]
    fn waiter_gated_notifies_preserve_delivery() {
        // hammer the channel from several senders and receivers: the
        // skip-notify-when-nobody-parked optimization must never lose a
        // wakeup (every item is delivered exactly once, nothing hangs)
        let (tx, rx) = bounded::<u64>(4);
        let results = Arc::new(Mutex::new(Vec::new()));
        let mut receivers = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            let results = results.clone();
            receivers.push(thread::spawn(move || {
                let mut local = Vec::new();
                loop {
                    // alternate the two receive paths
                    match rx.recv_deadline(Instant::now() + Duration::from_millis(1)) {
                        RecvDeadline::Item(v) => local.push(v),
                        RecvDeadline::TimedOut => match rx.recv() {
                            Some(v) => local.push(v),
                            None => break,
                        },
                        RecvDeadline::Closed => break,
                    }
                }
                results.lock().unwrap().extend(local);
            }));
        }
        let mut senders = Vec::new();
        for s in 0..2u64 {
            let tx = tx.clone();
            senders.push(thread::spawn(move || {
                for i in 0..500 {
                    tx.send(s * 500 + i).unwrap();
                }
            }));
        }
        for s in senders {
            s.join().unwrap();
        }
        tx.close();
        for r in receivers {
            r.join().unwrap();
        }
        let mut got = results.lock().unwrap().clone();
        got.sort_unstable();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded::<u64>(16);
        let mut workers = Vec::new();
        let results = Arc::new(Mutex::new(Vec::new()));
        for _ in 0..4 {
            let rx = rx.clone();
            let results = results.clone();
            workers.push(thread::spawn(move || {
                while let Some(v) = rx.recv() {
                    results.lock().unwrap().push(v);
                }
            }));
        }
        for i in 0..1000 {
            tx.send(i).unwrap();
        }
        tx.close();
        for w in workers {
            w.join().unwrap();
        }
        let mut got = results.lock().unwrap().clone();
        got.sort_unstable();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }
}
