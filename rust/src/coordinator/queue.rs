//! Bounded MPMC channel on `Mutex` + `Condvar` (no crossbeam offline) —
//! the host-side queues of the paper's pipeline ("a queue implementing
//! thread-safe mechanisms on the host to communicate intermediate
//! results").  Bounded capacity gives the serving pipeline backpressure.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

struct Inner<T> {
    queue: VecDeque<T>,
    capacity: usize,
    closed: bool,
}

struct Shared<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

/// Sending half (cloneable).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half (cloneable).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Receiver { shared: self.shared.clone() }
    }
}

/// Error returned when sending into a closed queue.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Outcome of a deadline-bounded receive ([`Receiver::recv_deadline`]).
#[derive(Debug, PartialEq, Eq)]
pub enum RecvDeadline<T> {
    /// An item was received before the deadline.
    Item(T),
    /// The deadline passed with the queue still open and empty.
    TimedOut,
    /// The queue is closed and fully drained.
    Closed,
}

/// Create a bounded channel with the given capacity (>= 1).
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity >= 1);
    let shared = Arc::new(Shared {
        inner: Mutex::new(Inner { queue: VecDeque::new(), capacity, closed: false }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Sender<T> {
    /// Blocking send; returns the value if the channel is closed.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if inner.closed {
                return Err(SendError(value));
            }
            if inner.queue.len() < inner.capacity {
                inner.queue.push_back(value);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            inner = self.shared.not_full.wait(inner).unwrap();
        }
    }

    /// Close the channel: receivers drain what's left, then get `None`.
    pub fn close(&self) {
        let mut inner = self.shared.inner.lock().unwrap();
        inner.closed = true;
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` once the channel is closed AND drained.
    pub fn recv(&self) -> Option<T> {
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if let Some(v) = inner.queue.pop_front() {
                self.shared.not_full.notify_one();
                return Some(v);
            }
            if inner.closed {
                return None;
            }
            inner = self.shared.not_empty.wait(inner).unwrap();
        }
    }

    /// Blocking receive bounded by a deadline: parks on the condvar (no
    /// spinning) until an item arrives, the queue closes, or `deadline`
    /// passes.  An already-queued item is always returned, even when the
    /// deadline is in the past — "deadline passed" only means "do not
    /// *wait* any longer".
    pub fn recv_deadline(&self, deadline: Instant) -> RecvDeadline<T> {
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if let Some(v) = inner.queue.pop_front() {
                self.shared.not_full.notify_one();
                return RecvDeadline::Item(v);
            }
            if inner.closed {
                return RecvDeadline::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return RecvDeadline::TimedOut;
            }
            let (guard, _timeout) = self
                .shared
                .not_empty
                .wait_timeout(inner, deadline - now)
                .unwrap();
            inner = guard;
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut inner = self.shared.inner.lock().unwrap();
        let v = inner.queue.pop_front();
        if v.is_some() {
            self.shared.not_full.notify_one();
        }
        v
    }

    /// Number of items currently buffered in the queue.
    pub fn len(&self) -> usize {
        self.shared.inner.lock().unwrap().queue.len()
    }

    /// Whether the queue is currently empty (it may still be open).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv(), Some(i));
        }
    }

    #[test]
    fn close_drains_then_none() {
        let (tx, rx) = bounded(8);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        tx.close();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
        assert_eq!(tx.send(3), Err(SendError(3)));
    }

    #[test]
    fn backpressure_blocks_until_consumed() {
        let (tx, rx) = bounded(1);
        tx.send(0u32).unwrap();
        let t = thread::spawn(move || {
            // this send must block until the consumer pops
            tx.send(1).unwrap();
            tx.close();
        });
        thread::sleep(Duration::from_millis(30));
        assert_eq!(rx.len(), 1, "second send must be blocked");
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), None);
        t.join().unwrap();
    }

    #[test]
    fn cross_thread_pipeline() {
        let (tx1, rx1) = bounded::<u64>(4);
        let (tx2, rx2) = bounded::<u64>(4);
        let stage = thread::spawn(move || {
            while let Some(v) = rx1.recv() {
                tx2.send(v * 2).unwrap();
            }
            tx2.close();
        });
        // producer must run concurrently with the drain: with bounded
        // queues, feeding 100 items inline would (correctly) deadlock
        let producer = thread::spawn(move || {
            for i in 0..100 {
                tx1.send(i).unwrap();
            }
            tx1.close();
        });
        let mut got = Vec::new();
        while let Some(v) = rx2.recv() {
            got.push(v);
        }
        stage.join().unwrap();
        producer.join().unwrap();
        assert_eq!(got, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn try_recv_on_empty_returns_none_without_blocking() {
        let (tx, rx) = bounded::<u32>(4);
        assert_eq!(rx.try_recv(), None, "empty open channel");
        tx.send(7).unwrap();
        assert_eq!(rx.try_recv(), Some(7));
        assert_eq!(rx.try_recv(), None, "drained again");
        tx.close();
        assert_eq!(rx.try_recv(), None, "empty closed channel");
        assert!(rx.is_empty());
    }

    #[test]
    fn send_after_close_returns_the_value() {
        let (tx, rx) = bounded::<String>(2);
        tx.close();
        // the rejected value comes back to the caller intact
        let err = tx.send("payload".to_string()).unwrap_err();
        assert_eq!(err, SendError("payload".to_string()));
        let SendError(v) = err;
        assert_eq!(v, "payload");
        assert_eq!(rx.recv(), None);
        // closing twice is idempotent
        tx.close();
        assert!(tx.send("again".to_string()).is_err());
    }

    #[test]
    fn recv_drains_buffered_items_after_close_then_none_forever() {
        let (tx, rx) = bounded(8);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        tx.close();
        // closed-but-nonempty: recv keeps draining in FIFO order
        for i in 0..4 {
            assert_eq!(rx.len(), 4 - i as usize);
            assert_eq!(rx.recv(), Some(i));
        }
        // closed-and-empty: every further recv is None (no hang)
        assert_eq!(rx.recv(), None);
        assert_eq!(rx.recv(), None);
    }

    /// Poll `cond` until it holds or the deadline passes (scheduling-safe
    /// alternative to a fixed sleep before asserting cross-thread state).
    fn eventually(deadline: Duration, cond: impl Fn() -> bool) -> bool {
        let t0 = std::time::Instant::now();
        while t0.elapsed() < deadline {
            if cond() {
                return true;
            }
            thread::sleep(Duration::from_millis(2));
        }
        cond()
    }

    #[test]
    fn capacity_blocks_sender_and_unblocks_per_recv() {
        let (tx, rx) = bounded::<u32>(2);
        tx.send(0).unwrap();
        tx.send(1).unwrap();
        let unblocked = Arc::new(Mutex::new(Vec::new()));
        let log = unblocked.clone();
        let t = thread::spawn(move || {
            for v in [2u32, 3] {
                tx.send(v).unwrap(); // must block while 2 items sit queued
                log.lock().unwrap().push(v);
            }
        });
        // these hold regardless of scheduling: a blocked send can neither
        // grow the queue past capacity nor reach the post-send log line
        thread::sleep(Duration::from_millis(40));
        assert_eq!(rx.len(), 2, "queue must stay at capacity");
        assert!(unblocked.lock().unwrap().is_empty(), "sender must still be blocked");
        // each recv frees exactly one slot
        assert_eq!(rx.recv(), Some(0));
        assert!(
            eventually(Duration::from_secs(5), || *unblocked.lock().unwrap() == [2]),
            "sender should wake after one recv frees a slot"
        );
        assert_eq!(rx.recv(), Some(1));
        t.join().unwrap();
        assert_eq!(unblocked.lock().unwrap().as_slice(), &[2, 3]);
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(3));
    }

    #[test]
    fn close_wakes_blocked_sender() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(0).unwrap();
        let tx2 = tx.clone();
        let t = thread::spawn(move || tx2.send(1));
        thread::sleep(Duration::from_millis(30));
        tx.close(); // the blocked send must wake and fail
        assert_eq!(t.join().unwrap(), Err(SendError(1)));
        // the pre-close item is still drainable
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn recv_deadline_returns_buffered_item_even_past_deadline() {
        let (tx, rx) = bounded(4);
        tx.send(42u32).unwrap();
        // deadline already passed: the queued item must still come out
        let past = Instant::now() - Duration::from_millis(5);
        assert_eq!(rx.recv_deadline(past), RecvDeadline::Item(42));
        // empty + past deadline -> immediate timeout, no blocking
        assert_eq!(rx.recv_deadline(past), RecvDeadline::TimedOut);
    }

    #[test]
    fn recv_deadline_times_out_then_sees_closed() {
        let (tx, rx) = bounded::<u32>(4);
        let t0 = Instant::now();
        let r = rx.recv_deadline(t0 + Duration::from_millis(20));
        assert_eq!(r, RecvDeadline::TimedOut);
        assert!(t0.elapsed() >= Duration::from_millis(20));
        tx.close();
        assert_eq!(
            rx.recv_deadline(Instant::now() + Duration::from_secs(5)),
            RecvDeadline::Closed
        );
    }

    #[test]
    fn recv_deadline_wakes_on_send() {
        let (tx, rx) = bounded::<u32>(4);
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            tx.send(7).unwrap();
        });
        let r = rx.recv_deadline(Instant::now() + Duration::from_secs(5));
        assert_eq!(r, RecvDeadline::Item(7));
        t.join().unwrap();
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded::<u64>(16);
        let mut workers = Vec::new();
        let results = Arc::new(Mutex::new(Vec::new()));
        for _ in 0..4 {
            let rx = rx.clone();
            let results = results.clone();
            workers.push(thread::spawn(move || {
                while let Some(v) = rx.recv() {
                    results.lock().unwrap().push(v);
                }
            }));
        }
        for i in 0..1000 {
            tx.send(i).unwrap();
        }
        tx.close();
        for w in workers {
            w.join().unwrap();
        }
        let mut got = results.lock().unwrap().clone();
        got.sort_unstable();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }
}
