//! L3 serving coordinator: the paper's pipelined multi-TPU runtime as a
//! real threaded system — one worker thread per (simulated) Edge TPU,
//! bounded host queues between stages (Fig 3), a dynamic batcher at the
//! front, and an optional replica router (the "data parallelism"
//! alternative the paper's conclusion mentions).
//!
//! ## Zero-copy batched data plane
//!
//! The paper's whole argument is that off-chip data movement, not
//! compute, bounds Edge-TPU inference; the host must not re-create that
//! bottleneck in software.  Requests therefore move through the pipeline
//! **batch-at-once**: a flush is packed into one contiguous arena slab at
//! ingress ([`arena::Arena`]), every stage executes the whole slab with a
//! single [`StageBackend::run_batch`] call writing into a recycled output
//! slab, and each hop moves one batch message under one lock/wakeup
//! instead of one per request.  Responses are ref-counted
//! [`Tensor`] views of the final slab — no per-request copy — and when
//! the caller drops them the slab returns to the arena.  In steady state
//! the request path performs zero heap allocations
//! ([`crate::metrics::DataPlaneMetrics`] proves it).
//!
//! Numerics are real: each stage executes its AOT-compiled HLO segment via
//! PJRT (or any other [`StageBackend`]).  Time is tracked twice — real
//! wall-clock of this host, and the **simulated Edge TPU clock** driven by
//! the calibrated cost model, which is what reproduces the paper's
//! latency/speedup numbers.  The simulated clock is computed per item
//! from the same pipeline recurrence as before batching: batch-granular
//! transport changes how bytes move, not what the simulation reports.

pub mod arena;
pub mod batcher;
pub mod queue;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::{DataPlaneMetrics, ServeMetrics, StageMetrics};
use crate::obs::{SpanKind, SpanSink, Tracer};

pub use arena::{Arena, SharedSlab, SlabBuf, Tensor};

use queue::{bounded, Receiver, Sender};

/// What a pipeline stage executes.  Implementations: PJRT segments
/// (production), native CPU chains, or pure-sim no-ops (tests).
///
/// The data plane calls [`StageBackend::run_batch`] once per batch; the
/// per-item [`StageBackend::run`] remains the reference contract (and the
/// default `run_batch` falls back to it, so shape-preserving test
/// backends only implement `run`).
pub trait StageBackend {
    /// Execute one inference on the stage's segment.
    fn run(&mut self, input: &[i8]) -> Result<Vec<i8>>;

    /// Output tensor element count for a given input element count.
    /// Defaults to shape-preserving; backends with known boundary shapes
    /// (PJRT segments, synthetic stages) override it so the pipeline can
    /// size the batch output slab before executing.
    fn out_elems(&self, in_elems: usize) -> usize {
        in_elems
    }

    /// Execute `n` inferences packed contiguously in `input`, writing the
    /// `n` outputs contiguously into `output` (sized
    /// `n * out_elems(input.len() / n)` by the caller).  Backends
    /// override this to execute the slab without per-item allocation;
    /// the default delegates to [`StageBackend::run`] per item.
    fn run_batch(&mut self, n: usize, input: &[i8], output: &mut [i8]) -> Result<()> {
        debug_assert!(n > 0);
        let in_len = input.len() / n;
        let out_len = output.len() / n;
        for i in 0..n {
            let out = self.run(&input[i * in_len..(i + 1) * in_len])?;
            anyhow::ensure!(
                out.len() == out_len,
                "stage produced {} elems for item {i}, slab expects {out_len}",
                out.len()
            );
            output[i * out_len..(i + 1) * out_len].copy_from_slice(&out);
        }
        Ok(())
    }
}

/// Factory that builds a stage backend *inside* its worker thread (PJRT
/// clients/executables are not `Send`, so they must be born where they
/// run — exactly like one process per physical TPU).
pub type StageFactory = Box<dyn FnOnce() -> Result<Box<dyn StageBackend>> + Send>;

/// Simulated-clock parameters of one stage (from the cost model).
#[derive(Debug, Clone, Copy)]
pub struct StageSim {
    /// On-TPU service seconds per item: input DMA + execution (incl. host
    /// weight streaming) + output DMA.
    pub exec_s: f64,
    /// Host-queue handoff latency to the next stage (0 for the last).
    pub hop_out_s: f64,
    /// Host thread/queue overhead per item — GIL-serialized across ALL
    /// stages via the pipeline's shared host clock.
    pub overhead_s: f64,
}

/// Simulated host-server reservation calendar (the GIL): worker threads
/// reach it in *real* order, which may differ from simulated order, so
/// instead of a single free-time watermark it keeps busy intervals and
/// grants each request the first gap at or after its simulated request
/// time.  Throughput is thus capped at one item per
/// `n_stages * stage_overhead`, like the paper's Python-thread pipeline.
#[derive(Debug, Default)]
pub struct HostCalendar {
    busy: Vec<(f64, f64)>, // disjoint, sorted by start
}

/// Retained busy-interval backstop.  Under backlog, back-to-back grants
/// coalesce into few intervals (see below), so many *retained* intervals
/// imply idle gaps between them — and an idle pipeline has few items in
/// flight, which is what bounds how far a lagging stage's clock can sit
/// behind the newest reservation (in-flight items <= queue_capacity *
/// batch size * n_stages hops).  The two regimes cannot both produce a
/// request older than thousands of retained intervals, so pruning the
/// oldest history is safe in practice; without a bound, a long-lived
/// fragmented calendar would degrade `reserve` to a linear scan over the
/// whole serving history.
const MAX_BUSY_INTERVALS: usize = 4096;

impl HostCalendar {
    /// Reserve `dur` seconds at the earliest instant >= `request_t`.
    pub fn reserve(&mut self, request_t: f64, dur: f64) -> f64 {
        if dur <= 0.0 {
            return request_t;
        }
        let mut t = request_t;
        let mut idx = self.busy.len();
        for (i, &(s, e)) in self.busy.iter().enumerate() {
            if e <= t {
                continue;
            }
            if s >= t + dur {
                idx = i;
                break;
            }
            t = t.max(e);
        }
        // find insertion point for sorted order
        if idx == self.busy.len() {
            idx = self.busy.partition_point(|&(s, _)| s < t);
        }
        // coalesce exact back-to-back reservations (the saturated steady
        // state: a grant starting precisely where the previous interval
        // ends, which `t = t.max(e)` produces bit-exactly) so the busy
        // list stays small instead of growing per item served
        let end = t + dur;
        let merge_prev = idx > 0 && self.busy[idx - 1].1 == t;
        let merge_next = idx < self.busy.len() && self.busy[idx].0 == end;
        match (merge_prev, merge_next) {
            (true, true) => {
                self.busy[idx - 1].1 = self.busy[idx].1;
                self.busy.remove(idx);
            }
            (true, false) => self.busy[idx - 1].1 = end,
            (false, true) => self.busy[idx].0 = t,
            (false, false) => self.busy.insert(idx, (t, end)),
        }
        // backstop for idle-gap fragmentation: drop the oldest history
        if self.busy.len() > MAX_BUSY_INTERVALS {
            let cut = self.busy.len() - MAX_BUSY_INTERVALS;
            self.busy.drain(..cut);
        }
        t
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-chosen id; responses of one serve call are ordered by it.
    pub id: u64,
    /// Input activation tensor (int8, row-major).  Copied **once** into
    /// an arena slab at pipeline ingress; stages never see this vector.
    pub data: Vec<i8>,
    /// Absolute wall-clock deadline.  `None` (the default) never expires.
    /// The serving pool stamps it from the tenant SLO at submit (a caller
    /// deadline takes precedence), and every handoff — batcher flush,
    /// router dispatch, pool worker — checks it *before* doing work, so
    /// an expired request is shed instead of burning a TPU quantum.
    pub deadline: Option<Instant>,
}

impl Request {
    /// A request with no deadline (never expires).
    pub fn new(id: u64, data: Vec<i8>) -> Request {
        Request { id, data, deadline: None }
    }

    /// Attach an absolute deadline (builder style).
    pub fn with_deadline(mut self, deadline: Instant) -> Request {
        self.deadline = Some(deadline);
        self
    }

    /// Whether the deadline has passed at `now` (deadline-free requests
    /// never expire; the off-path cost is one `Option` compare).
    pub fn expired_at(&self, now: Instant) -> bool {
        matches!(self.deadline, Some(d) if now >= d)
    }
}

/// One completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    /// The originating request's id.
    pub id: u64,
    /// Output activation tensor (int8, row-major): a ref-counted view of
    /// the batch's output slab, not an owned copy.  Compares against
    /// slices/`Vec<i8>` and derefs to `[i8]`.
    pub data: Tensor,
    /// Real wall-clock latency on this host (PJRT CPU execution).
    pub real_latency_s: f64,
    /// Simulated Edge TPU pipeline completion time for this item.
    pub sim_done_s: f64,
}

/// Per-item bookkeeping that rides a batch (ids, clocks); the tensor
/// bytes themselves live in the batch slab.
struct ItemMeta {
    id: u64,
    submitted: Instant,
    /// Simulated time at which this item is available to the next stage.
    sim_arrive_s: f64,
}

/// The unit of transfer on the data plane: one contiguous slab holding
/// `metas.len()` tensors of `elem_len` bytes each, moved through the host
/// queues as a single message.
struct Batch {
    data: SlabBuf,
    elem_len: usize,
    metas: Vec<ItemMeta>,
    /// A batch-level failure poisons the whole flush (the pre-batching
    /// path likewise failed the serve call on the first errored item).
    err: Option<String>,
}

/// A running pipeline: stage threads + front/back queues.
pub struct Pipeline {
    input: Sender<Batch>,
    output: Receiver<Batch>,
    workers: Vec<JoinHandle<()>>,
    /// (receiver, stages-seen-ready) — mutex'd so `&Pipeline` stays `Sync`
    /// for the replica router's scoped threads.
    ready: std::sync::Mutex<(std::sync::mpsc::Receiver<Result<(), String>>, usize)>,
    n_stages: usize,
    arena: Arena,
    /// Per-stage execution counters (one entry per TPU worker).
    pub stage_metrics: Vec<Arc<StageMetrics>>,
    /// End-to-end latency histograms for this pipeline.
    pub serve_metrics: Arc<ServeMetrics>,
    /// Handoff/allocation counters of this pipeline's data plane (shared
    /// pool-wide when [`PipelineConfig::data_plane`] was supplied).
    pub data_plane: Arc<DataPlaneMetrics>,
}

/// Configuration for pipeline construction.
#[derive(Clone)]
pub struct PipelineConfig {
    /// Host queue capacity between stages, counted in **batches** (the
    /// paper used unbounded `queue.Queue()`; bounded gives backpressure).
    pub queue_capacity: usize,
    /// Buffer arena for activation slabs.  Supply one to share recycled
    /// slabs across pipelines (the serving pool passes a pool-wide
    /// arena); `None` gives the pipeline a private arena.
    pub arena: Option<Arena>,
    /// Data-plane counters.  Supply one to aggregate across pipelines;
    /// `None` gives the pipeline private counters.
    pub data_plane: Option<Arc<DataPlaneMetrics>>,
    /// Span tracer for `--trace-out` (DESIGN.md §13).  `None` (the
    /// default) disables tracing entirely: workers skip span recording
    /// behind a single branch, keeping the disabled path inside the data
    /// plane's zero-alloc budget.
    pub tracer: Option<Arc<Tracer>>,
    /// First render track of this pipeline's stage spans (stage `i`
    /// records on `trace_track_base + i`); see `obs::span::track_base`.
    pub trace_track_base: u32,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            queue_capacity: 64,
            arena: None,
            data_plane: None,
            tracer: None,
            trace_track_base: 0,
        }
    }
}

impl Pipeline {
    /// Spawn one worker per stage.  `factories[i]` builds stage i's
    /// backend inside its thread; `sims[i]` drives the simulated clock.
    pub fn spawn(
        factories: Vec<StageFactory>,
        sims: Vec<StageSim>,
        cfg: &PipelineConfig,
    ) -> Result<Self> {
        assert_eq!(factories.len(), sims.len());
        assert!(!factories.is_empty());
        let n = factories.len();
        let stage_metrics: Vec<Arc<StageMetrics>> =
            (0..n).map(|_| Arc::new(StageMetrics::default())).collect();
        let data_plane = cfg.data_plane.clone().unwrap_or_default();
        let arena =
            cfg.arena.clone().unwrap_or_else(|| Arena::new(data_plane.clone()));

        // shared simulated host calendar (the GIL serialization point)
        let host_clock = Arc::new(std::sync::Mutex::new(HostCalendar::default()));
        // readiness channel: each worker reports once its backend is built
        let (ready_tx, ready_rx) = std::sync::mpsc::channel();
        // build the chain of queues: input -> s0 -> s1 -> ... -> output
        let (input_tx, mut prev_rx) = bounded::<Batch>(cfg.queue_capacity);
        let mut workers = Vec::with_capacity(n);
        for (i, (factory, sim)) in factories.into_iter().zip(sims).enumerate() {
            let (tx, rx) = bounded::<Batch>(cfg.queue_capacity);
            let metrics = stage_metrics[i].clone();
            let rx_in = prev_rx;
            let host = host_clock.clone();
            let ready = ready_tx.clone();
            let stage_arena = arena.clone();
            let dp = data_plane.clone();
            // per-worker span sink (its own lock-free ring); None keeps
            // the worker loop span-free
            let obs = cfg.tracer.as_ref().map(|t| (t.handle(), cfg.trace_track_base + i as u32));
            workers.push(std::thread::spawn(move || {
                stage_loop(factory, sim, rx_in, tx, metrics, host, ready, stage_arena, dp, obs);
            }));
            prev_rx = rx;
        }
        Ok(Pipeline {
            input: input_tx,
            output: prev_rx,
            workers,
            ready: std::sync::Mutex::new((ready_rx, 0)),
            n_stages: n,
            arena,
            stage_metrics,
            serve_metrics: Arc::new(ServeMetrics::default()),
            data_plane,
        })
    }

    /// Block until every stage backend is constructed (artifact compile is
    /// the dominant startup cost — call this before timing a batch).
    /// Returns the first backend-construction error, if any.
    pub fn wait_ready(&self) -> Result<()> {
        let mut guard = self.ready.lock().unwrap();
        while guard.1 < self.n_stages {
            match guard.0.recv() {
                Ok(Ok(())) => guard.1 += 1,
                Ok(Err(e)) => anyhow::bail!("stage backend init failed: {e}"),
                Err(_) => anyhow::bail!("pipeline worker exited before ready"),
            }
        }
        Ok(())
    }

    /// Run a closed batch through the pipeline (the paper's §V-B workload:
    /// all inputs available up front), blocking until every response is
    /// back.  The whole batch moves as one slab; responses are returned
    /// in request order.
    pub fn serve_batch(&self, requests: Vec<Request>) -> Result<Vec<Response>> {
        self.serve_batch_chunked(requests, usize::MAX)
    }

    /// Like [`Pipeline::serve_batch`], but splits the requests into
    /// chunks of at most `max_chunk` items, each moving through the
    /// pipeline as its own slab — chunks overlap across stages, trading
    /// per-hop handoff cost for intra-batch pipelining.  `max_chunk = 1`
    /// reproduces the retired per-request transfer granularity (kept as
    /// the benchmark baseline in `benches/dataplane.rs`).
    pub fn serve_batch_chunked(
        &self,
        requests: Vec<Request>,
        max_chunk: usize,
    ) -> Result<Vec<Response>> {
        let n = requests.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let max_chunk = max_chunk.max(1);
        let elem_len = requests[0].data.len();
        let now = Instant::now();
        for r in &requests {
            anyhow::ensure!(
                !r.expired_at(now),
                "request {} deadline expired before dispatch",
                r.id
            );
            anyhow::ensure!(
                r.data.len() == elem_len,
                "request {} carries {} elems, batch expects {elem_len}",
                r.id,
                r.data.len()
            );
        }
        let start = Instant::now();
        if max_chunk >= n {
            // single-message fast path (the serve_batch default): pack in
            // the caller and skip the feeder thread entirely — one batch
            // in flight can neither deadlock nor need concurrent draining
            let batch = pack_batch(&self.arena, &self.data_plane, &requests, elem_len, start);
            return self.serve_prepacked(batch);
        }
        // feed from a separate thread so draining proceeds concurrently
        // (several in-flight chunks through bounded queues would
        // otherwise deadlock)
        let input = self.input.clone();
        let arena = self.arena.clone();
        let dp = self.data_plane.clone();
        let feeder = std::thread::spawn(move || {
            let mut it = requests.into_iter();
            let mut remaining = n;
            while remaining > 0 {
                let k = remaining.min(max_chunk);
                let mut chunk = Vec::with_capacity(k);
                for _ in 0..k {
                    chunk.push(it.next().expect("remaining tracks the iterator"));
                }
                remaining -= k;
                let batch = pack_batch(&arena, &dp, &chunk, elem_len, start);
                if input.send(batch).is_err() {
                    break;
                }
            }
        });
        let responses = self.drain_responses(n);
        if responses.is_ok() {
            // the feeder consumed every request; on error it may instead
            // be blocked on a bounded queue and unblocks at shutdown (the
            // pre-batching path behaved identically on stage errors)
            feeder.join().unwrap();
        }
        let mut responses = responses?;
        responses.sort_by_key(|r| r.id);
        Ok(responses)
    }

    /// Send one pre-packed batch and block for its responses (in request
    /// order).  Used by [`ReplicaRouter`], which packs every shard in the
    /// caller thread first so the arena sees the full replica-parallel
    /// demand deterministically on every call.
    fn serve_prepacked(&self, batch: Batch) -> Result<Vec<Response>> {
        let n = batch.metas.len();
        if self.input.send(batch).is_err() {
            anyhow::bail!("pipeline closed");
        }
        let mut responses = self.drain_responses(n)?;
        responses.sort_by_key(|r| r.id);
        Ok(responses)
    }

    /// Pack a request shard into a batch using this pipeline's arena.
    fn pack(&self, shard: &[Request], elem_len: usize, start: Instant) -> Batch {
        pack_batch(&self.arena, &self.data_plane, shard, elem_len, start)
    }

    /// Receive batches until `n` responses are collected (not yet sorted).
    fn drain_responses(&self, n: usize) -> Result<Vec<Response>> {
        let mut responses = Vec::with_capacity(n);
        while responses.len() < n {
            let batch = self
                .output
                .recv()
                .ok_or_else(|| anyhow::anyhow!("pipeline closed early"))?;
            if let Some(e) = batch.err {
                anyhow::bail!("stage error on batch of {}: {e}", batch.metas.len());
            }
            let slab = batch.data.share();
            for (i, m) in batch.metas.iter().enumerate() {
                let real = m.submitted.elapsed().as_secs_f64();
                self.serve_metrics.record(real, m.sim_arrive_s);
                responses.push(Response {
                    id: m.id,
                    data: Tensor::slice(&slab, i * batch.elem_len, batch.elem_len),
                    real_latency_s: real,
                    sim_done_s: m.sim_arrive_s,
                });
            }
        }
        Ok(responses)
    }

    /// Close the input and join all workers.
    pub fn shutdown(self) {
        self.input.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Write `shard` into one contiguous arena slab (the single ingress copy
/// of the data plane) and attach per-item metadata.
fn pack_batch(
    arena: &Arena,
    dp: &DataPlaneMetrics,
    shard: &[Request],
    elem_len: usize,
    start: Instant,
) -> Batch {
    let k = shard.len();
    let mut slab = arena.take(k * elem_len);
    let mut metas = Vec::with_capacity(k);
    for (i, r) in shard.iter().enumerate() {
        debug_assert_eq!(r.data.len(), elem_len);
        if elem_len > 0 {
            slab[i * elem_len..(i + 1) * elem_len].copy_from_slice(&r.data);
        }
        metas.push(ItemMeta { id: r.id, submitted: start, sim_arrive_s: 0.0 });
    }
    dp.record_handoff(k as u64);
    Batch { data: slab, elem_len, metas, err: None }
}

#[allow(clippy::too_many_arguments)] // worker wiring, called once per stage
fn stage_loop(
    factory: StageFactory,
    sim: StageSim,
    rx: Receiver<Batch>,
    tx: Sender<Batch>,
    metrics: Arc<StageMetrics>,
    host_clock: Arc<std::sync::Mutex<HostCalendar>>,
    ready: std::sync::mpsc::Sender<Result<(), String>>,
    arena: Arena,
    dp: Arc<DataPlaneMetrics>,
    obs: Option<(SpanSink, u32)>,
) {
    let mut backend = match factory() {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            // propagate construction failure on every batch, then drain
            while let Some(mut batch) = rx.recv() {
                batch.err = Some(format!("backend init failed: {e}"));
                if tx.send(batch).is_err() {
                    break;
                }
            }
            tx.close();
            return;
        }
    };
    // simulated clock of THIS stage: when the simulated TPU becomes free
    let mut sim_free_s = 0.0f64;
    while let Some(mut batch) = rx.recv() {
        let n = batch.metas.len();
        if batch.err.is_none() && n > 0 {
            let start_us = obs.as_ref().map(|(sink, _)| sink.now_us());
            let t0 = Instant::now();
            let out_len = backend.out_elems(batch.elem_len);
            let mut out = arena.take(n * out_len);
            match backend.run_batch(n, &batch.data, &mut out) {
                Ok(()) => {
                    // the input slab drops here and returns to the arena
                    batch.data = out;
                    batch.elem_len = out_len;
                }
                Err(e) => batch.err = Some(e.to_string()),
            }
            let exec = t0.elapsed();
            if let Some((sink, track)) = &obs {
                let id = batch.metas.first().map(|m| m.id).unwrap_or(0);
                sink.record(
                    SpanKind::Stage,
                    *track,
                    id,
                    start_us.unwrap_or(0),
                    exec.as_micros() as u64,
                );
            }
            metrics.record_batch(n as u64, exec);
        }
        // simulated pipeline recurrence per item (same math as
        // pipeline::simulate): dispatch waits for input, the TPU, and the
        // GIL-shared host.  One calendar lock covers the whole batch.
        {
            let mut cal = host_clock.lock().unwrap();
            for m in &mut batch.metas {
                let request = m.sim_arrive_s.max(sim_free_s);
                let dispatch = cal.reserve(request, sim.overhead_s);
                let finish = dispatch + sim.overhead_s + sim.exec_s;
                sim_free_s = finish;
                m.sim_arrive_s = finish + sim.hop_out_s;
            }
        }
        dp.record_handoff(n as u64);
        if tx.send(batch).is_err() {
            break;
        }
    }
    tx.close();
}

/// Policy for hedged dispatch in [`ReplicaRouter`]: a replica whose
/// recorded real p99 exceeds `p99_factor` times the healthiest replica's
/// p99 (both with at least `min_samples` completions) is treated as a
/// straggler, and its shard is *also* dispatched to the healthiest
/// replica.  Both copies compute identical bytes (stage backends are
/// deterministic), so the faster copy defines each response and the
/// duplicate is dropped on merge — the classic tail-tolerance hedge.
#[derive(Debug, Clone, Copy)]
pub struct HedgeConfig {
    /// Straggler threshold: hedge when `p99 > p99_factor * best_p99`.
    pub p99_factor: f64,
    /// Completions a replica must have recorded before its p99 is
    /// trusted for the hedging decision (cold replicas never hedge).
    pub min_samples: u64,
}

impl Default for HedgeConfig {
    fn default() -> Self {
        HedgeConfig { p99_factor: 3.0, min_samples: 16 }
    }
}

impl HedgeConfig {
    /// Reject nonsensical hedge policies with pinned messages.  A factor
    /// below 1 (or NaN/inf) would hedge the *healthiest* replica; a zero
    /// sample window would trust a p99 computed from nothing.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.p99_factor.is_finite() && self.p99_factor >= 1.0,
            "hedge p99 factor must be finite and >= 1 (got {})",
            self.p99_factor
        );
        anyhow::ensure!(
            self.min_samples >= 1,
            "hedge window must cover at least 1 sample (got 0)"
        );
        Ok(())
    }
}

/// Watchdog + circuit-breaker policy for [`ReplicaRouter`] replicas
/// (DESIGN.md §17).  A replica dispatch that errors or outlives the
/// `watchdog` deadline counts as a breach; `trip_after` *consecutive*
/// breaches trip the replica's breaker Closed → Open, excluding it from
/// round-robin sharding and from hedged dispatch.  Once `cooldown` has
/// elapsed the breaker turns HalfOpen and the replica receives its next
/// shard as a probe: a clean probe closes the breaker, another breach
/// re-opens it.
#[derive(Debug, Clone, Copy)]
pub struct BreakerConfig {
    /// Watchdog deadline around one replica dispatch (pack → serve →
    /// drain); a slower dispatch is a breach even if it succeeds.
    pub watchdog: Duration,
    /// Consecutive breaches that trip the breaker Closed → Open.
    pub trip_after: u32,
    /// Time a tripped replica stays Open before the HalfOpen probe.
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            watchdog: Duration::from_millis(250),
            trip_after: 3,
            cooldown: Duration::from_millis(50),
        }
    }
}

impl BreakerConfig {
    /// Reject degenerate breaker policies with pinned messages.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.watchdog.is_zero(), "breaker watchdog must be non-zero");
        anyhow::ensure!(
            self.trip_after >= 1,
            "breaker trip threshold must be >= 1 (got 0)"
        );
        Ok(())
    }
}

/// Per-replica breaker state (Closed → Open → HalfOpen → Closed).
#[derive(Debug, Clone, Copy)]
enum BreakerState {
    /// Healthy; counts consecutive watchdog breaches.
    Closed { breaches: u32 },
    /// Quarantined since the recorded instant; excluded from dispatch.
    Open { since: Instant },
    /// Cooldown elapsed; the next dispatch is a probe.
    HalfOpen,
}

/// Shared handle for injecting artificial per-replica dispatch delays —
/// the chaos suite's straggler fault.  Clones reach into the same map,
/// so a delay can be injected after the router has moved into a pool
/// worker thread.  The delay is slept in the dispatch thread after the
/// shard is packed, which inflates that replica's recorded real latency
/// exactly as a contended or thermally-throttled device would.
#[derive(Debug, Clone, Default)]
pub struct DelayInjector {
    delays: Arc<std::sync::Mutex<BTreeMap<usize, Duration>>>,
}

impl DelayInjector {
    /// Delay every dispatch to `replica` by `delay` until cleared.
    pub fn set(&self, replica: usize, delay: Duration) {
        self.delays.lock().unwrap().insert(replica, delay);
    }

    /// Remove the injected delay on `replica`, if any.
    pub fn clear(&self, replica: usize) {
        self.delays.lock().unwrap().remove(&replica);
    }

    fn get(&self, replica: usize) -> Option<Duration> {
        self.delays.lock().unwrap().get(&replica).copied()
    }
}

/// Round-robin router over pipeline replicas — the data-parallel
/// alternative (paper §V-C closing remark).  Each replica is a full copy
/// of the model on its own TPU set.
pub struct ReplicaRouter {
    /// The replica pipelines; requests are sharded round-robin across them.
    pub replicas: Vec<Pipeline>,
    /// Hedged-dispatch policy; `None` (the default) disables hedging.
    hedge: Option<HedgeConfig>,
    /// Requests dispatched twice because their home replica straggled.
    hedged: AtomicU64,
    /// Injected per-replica dispatch delays (chaos straggler faults).
    injector: DelayInjector,
    /// Watchdog/circuit-breaker policy; `None` (the default) disables it.
    breaker: Option<BreakerConfig>,
    /// Per-replica breaker state (sized only when the breaker is on).
    breaker_state: std::sync::Mutex<Vec<BreakerState>>,
    /// Closed→Open and HalfOpen→Open transitions so far.
    trips: AtomicU64,
    /// Open→HalfOpen probe grants so far.
    probes: AtomicU64,
}

impl ReplicaRouter {
    /// Wrap a non-empty set of identical pipelines as one deployment.
    pub fn new(replicas: Vec<Pipeline>) -> Self {
        assert!(!replicas.is_empty());
        ReplicaRouter {
            replicas,
            hedge: None,
            hedged: AtomicU64::new(0),
            injector: DelayInjector::default(),
            breaker: None,
            breaker_state: std::sync::Mutex::new(Vec::new()),
            trips: AtomicU64::new(0),
            probes: AtomicU64::new(0),
        }
    }

    /// Enable hedged dispatch with the given policy (builder style).
    pub fn with_hedging(mut self, cfg: HedgeConfig) -> Self {
        self.hedge = Some(cfg);
        self
    }

    /// Enable the replica watchdog + circuit breaker (builder style).
    /// Callers validate the config first ([`BreakerConfig::validate`]).
    pub fn with_breaker(mut self, cfg: BreakerConfig) -> Self {
        let k = self.replicas.len();
        self.breaker = Some(cfg);
        *self.breaker_state.lock().unwrap() =
            vec![BreakerState::Closed { breaches: 0 }; k];
        self
    }

    /// Handle for injecting straggler delays into this router's replicas.
    pub fn injector(&self) -> DelayInjector {
        self.injector.clone()
    }

    /// Requests dispatched twice so far because their home replica's
    /// recorded p99 breached the straggler threshold.
    pub fn hedged_total(&self) -> u64 {
        self.hedged.load(Ordering::Relaxed)
    }

    /// Breaker trips so far (Closed→Open and failed-probe re-opens).
    pub fn breaker_trips_total(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// HalfOpen probe grants so far (Open replicas re-admitted for one
    /// trial dispatch after their cooldown).
    pub fn breaker_probes_total(&self) -> u64 {
        self.probes.load(Ordering::Relaxed)
    }

    /// Indices of replicas currently quarantined (breaker Open).
    pub fn open_replicas(&self) -> Vec<usize> {
        self.breaker_state
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter_map(|(i, s)| matches!(s, BreakerState::Open { .. }).then_some(i))
            .collect()
    }

    /// Replicas eligible for dispatch at `now`.  Open replicas whose
    /// cooldown elapsed transition to HalfOpen here (counted as probes);
    /// still-Open replicas are excluded.  If *every* replica is Open the
    /// router serves on all of them anyway — total quarantine must
    /// degrade to best-effort dispatch, not a refused batch.  With the
    /// breaker off this is the identity permutation, so default
    /// round-robin placement is unchanged.
    fn available(&self, now: Instant) -> Vec<usize> {
        let k = self.replicas.len();
        let Some(cfg) = self.breaker else {
            return (0..k).collect();
        };
        let mut st = self.breaker_state.lock().unwrap();
        let mut avail = Vec::with_capacity(k);
        for (i, s) in st.iter_mut().enumerate() {
            match *s {
                BreakerState::Open { since }
                    if now.duration_since(since) >= cfg.cooldown =>
                {
                    *s = BreakerState::HalfOpen;
                    self.probes.fetch_add(1, Ordering::Relaxed);
                    avail.push(i);
                }
                BreakerState::Open { .. } => {}
                _ => avail.push(i),
            }
        }
        if avail.is_empty() {
            (0..k).collect()
        } else {
            avail
        }
    }

    /// Feed one dispatch outcome into the breaker state machine.
    fn observe(&self, replica: usize, ok: bool, elapsed: Duration) {
        let Some(cfg) = self.breaker else { return };
        let breach = !ok || elapsed > cfg.watchdog;
        let mut st = self.breaker_state.lock().unwrap();
        st[replica] = match (st[replica], breach) {
            (BreakerState::Closed { breaches }, true) => {
                let b = breaches + 1;
                if b >= cfg.trip_after {
                    self.trips.fetch_add(1, Ordering::Relaxed);
                    BreakerState::Open { since: Instant::now() }
                } else {
                    BreakerState::Closed { breaches: b }
                }
            }
            (BreakerState::Closed { .. }, false) => BreakerState::Closed { breaches: 0 },
            (BreakerState::HalfOpen, true) => {
                self.trips.fetch_add(1, Ordering::Relaxed);
                BreakerState::Open { since: Instant::now() }
            }
            (BreakerState::HalfOpen, false) => BreakerState::Closed { breaches: 0 },
            // a shard lands on an Open replica only in the everyone-
            // tripped fallback; it stays quarantined regardless
            (s @ BreakerState::Open { .. }, _) => s,
        };
    }

    /// For each replica, the backup its shard should also go to —
    /// `Some(best)` iff hedging is on, the replica's recorded p99
    /// breached the threshold, and a healthier replica exists.  Based on
    /// history up to the previous call: the decision must be made before
    /// dispatch, exactly like a production hedger working from the last
    /// metrics scrape.  Only replicas in `avail` participate — a
    /// quarantined (breaker-Open) replica is neither hedged around nor
    /// used as a hedge target.
    fn hedge_targets(&self, avail: &[usize]) -> Vec<Option<usize>> {
        let k = self.replicas.len();
        let mut out = vec![None; k];
        let Some(cfg) = self.hedge else {
            return out;
        };
        if avail.len() < 2 {
            return out;
        }
        let mut eligible = vec![false; k];
        for &i in avail {
            eligible[i] = true;
        }
        let stats: Vec<(u64, f64)> = self
            .replicas
            .iter()
            .map(|r| {
                let s = r.serve_metrics.snapshot();
                (s.completed, s.real_p99_s)
            })
            .collect();
        // healthiest replica with enough history (ties -> lowest index)
        let mut best: Option<(usize, f64)> = None;
        for (i, &(n, p99)) in stats.iter().enumerate() {
            if eligible[i] && n >= cfg.min_samples && p99.is_finite() {
                let better = match best {
                    Some((_, b)) => p99 < b,
                    None => true,
                };
                if better {
                    best = Some((i, p99));
                }
            }
        }
        let Some((best_i, best_p99)) = best else {
            return out;
        };
        for (i, &(n, p99)) in stats.iter().enumerate() {
            if i != best_i
                && eligible[i]
                && n >= cfg.min_samples
                && p99.is_finite()
                && p99 > cfg.p99_factor * best_p99
            {
                out[i] = Some(best_i);
            }
        }
        out
    }

    /// Split a batch round-robin across replicas, run them concurrently,
    /// return responses in request order.  Every shard is packed into its
    /// slab **in the caller thread before the fan-out**, so the arena
    /// sees the full replica-parallel demand on every call — steady-state
    /// allocation behaviour is deterministic, not thread-timing-luck.
    ///
    /// With hedging enabled, a straggling replica's shard is packed and
    /// dispatched a second time to the healthiest replica; the copy with
    /// the lower real latency is kept per id (the bytes are identical
    /// either way).
    pub fn serve_batch(&self, requests: Vec<Request>) -> Result<Vec<Response>> {
        if requests.is_empty() {
            return Ok(Vec::new());
        }
        let elem_len = requests[0].data.len();
        let now = Instant::now();
        for r in &requests {
            anyhow::ensure!(
                !r.expired_at(now),
                "request {} deadline expired before dispatch",
                r.id
            );
            anyhow::ensure!(
                r.data.len() == elem_len,
                "request {} carries {} elems, batch expects {elem_len}",
                r.id,
                r.data.len()
            );
        }
        let k = self.replicas.len();
        // round-robin only across currently-available replicas; with the
        // breaker off `avail` is the identity permutation, so placement
        // is byte-for-byte what it always was
        let avail = self.available(now);
        let m = avail.len();
        let mut shards: Vec<Vec<Request>> = (0..k).map(|_| Vec::new()).collect();
        for (i, r) in requests.into_iter().enumerate() {
            shards[avail[i % m]].push(r);
        }
        let targets = self.hedge_targets(&avail);
        let start = Instant::now();
        // per-replica dispatch queues: a replica's own shard plus any
        // hedged copies routed to it.  One thread serves each queue
        // sequentially, preserving the invariant of at most one batch in
        // flight per pipeline (concurrent drains of one output queue
        // would steal each other's responses).
        let mut per_rep: Vec<Vec<Batch>> = (0..k).map(|_| Vec::new()).collect();
        for (i, shard) in shards.iter().enumerate() {
            if shard.is_empty() {
                continue;
            }
            per_rep[i].push(self.replicas[i].pack(shard, elem_len, start));
            if let Some(alt) = targets[i] {
                per_rep[alt].push(self.replicas[alt].pack(shard, elem_len, start));
                self.hedged.fetch_add(shard.len() as u64, Ordering::Relaxed);
            }
        }
        let mut all = Vec::new();
        // replicas whose dispatch errored under the breaker; their own
        // shards are replayed on a healthy replica below
        let mut failed: Vec<usize> = Vec::new();
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for (i, batches) in per_rep.into_iter().enumerate() {
                if batches.is_empty() {
                    continue;
                }
                let rep = &self.replicas[i];
                let delay = self.injector.get(i);
                handles.push((
                    i,
                    scope.spawn(move || -> (Result<Vec<Response>>, Duration) {
                        let t0 = Instant::now();
                        if let Some(d) = delay {
                            std::thread::sleep(d);
                        }
                        let mut got = Vec::new();
                        for batch in batches {
                            match rep.serve_prepacked(batch) {
                                Ok(r) => got.extend(r),
                                Err(e) => return (Err(e), t0.elapsed()),
                            }
                        }
                        (Ok(got), t0.elapsed())
                    }),
                ));
            }
            for (i, h) in handles {
                let (res, elapsed) = h.join().expect("replica thread panicked");
                match res {
                    Ok(got) => {
                        self.observe(i, true, elapsed);
                        all.extend(got);
                    }
                    Err(e) => {
                        self.observe(i, false, elapsed);
                        // without a breaker the error propagates exactly
                        // as before; with one, the failed replica's own
                        // shard is replayed after the fan-in
                        if self.breaker.is_none() {
                            return Err(e);
                        }
                        failed.push(i);
                    }
                }
            }
            Ok(())
        })?;
        // replay: re-dispatch each failed replica's own shard on a
        // healthy replica.  Hedged *copies* lost with a failed replica
        // need no replay — their primaries either succeeded or sit in
        // `failed` themselves.  The dedup below keeps exactly one
        // response per id, so a replay can never double-complete.
        for &i in &failed {
            if shards[i].is_empty() {
                continue;
            }
            let target = self
                .available(Instant::now())
                .into_iter()
                .find(|j| !failed.contains(j))
                .ok_or_else(|| {
                    anyhow::anyhow!("no healthy replica to replay shard of replica {i}")
                })?;
            let batch = self.replicas[target].pack(&shards[i], elem_len, start);
            let t0 = Instant::now();
            let got = self.replicas[target].serve_prepacked(batch);
            self.observe(target, got.is_ok(), t0.elapsed());
            all.extend(got?);
        }
        // hedged ids come back twice with identical bytes; keep the
        // faster copy of each
        all.sort_by(|a, b| {
            a.id.cmp(&b.id)
                .then(a.real_latency_s.partial_cmp(&b.real_latency_s).unwrap())
        });
        all.dedup_by_key(|r| r.id);
        Ok(all)
    }

    /// Close every replica's input and join all worker threads.
    pub fn shutdown(self) {
        for r in self.replicas {
            r.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_calendar_packs_and_orders() {
        let mut c = HostCalendar::default();
        // sequential reservations chain
        assert_eq!(c.reserve(0.0, 1.0), 0.0);
        assert_eq!(c.reserve(0.5, 1.0), 1.0); // pushed past [0,1)
        // a later out-of-order request fills the gap after [1,2)
        assert_eq!(c.reserve(2.0, 0.5), 2.0);
        // request inside an existing busy interval lands after it
        assert_eq!(c.reserve(2.1, 0.5), 2.5);
        // zero-duration requests are free
        assert_eq!(c.reserve(0.25, 0.0), 0.25);
    }

    #[test]
    fn host_calendar_first_fit_gap() {
        let mut c = HostCalendar::default();
        c.reserve(0.0, 1.0); // [0,1)
        c.reserve(3.0, 1.0); // [3,4)
        // fits in the [1,3) gap
        assert_eq!(c.reserve(1.5, 1.0), 1.5);
        // no longer fits there -> goes after [3,4)
        assert_eq!(c.reserve(1.0, 1.0), 4.0);
    }

    #[test]
    fn host_calendar_property_no_overlap() {
        crate::util::proptest::forall(64, |rng| {
            let mut c = HostCalendar::default();
            let mut granted: Vec<(f64, f64)> = Vec::new();
            for _ in 0..40 {
                let req = rng.f64_range(0.0, 10.0);
                let dur = rng.f64_range(0.01, 0.8);
                let t = c.reserve(req, dur);
                crate::check!(t >= req - 1e-12, "grant before request");
                granted.push((t, t + dur));
            }
            granted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in granted.windows(2) {
                crate::check!(w[1].0 >= w[0].1 - 1e-9, "overlap {w:?}");
            }
            Ok(())
        });
    }

    /// A backend that applies an affine int8 map (cheap, deterministic).
    /// Implements only `run`, so it exercises the default batched path.
    struct AddOne;

    impl StageBackend for AddOne {
        fn run(&mut self, input: &[i8]) -> Result<Vec<i8>> {
            Ok(input.iter().map(|&v| v.saturating_add(1)).collect())
        }
    }

    fn factories(n: usize) -> Vec<StageFactory> {
        (0..n)
            .map(|_| {
                Box::new(|| Ok(Box::new(AddOne) as Box<dyn StageBackend>)) as StageFactory
            })
            .collect()
    }

    fn sims(n: usize, exec: f64) -> Vec<StageSim> {
        (0..n)
            .map(|_| StageSim { exec_s: exec, hop_out_s: 1e-4, overhead_s: 2e-4 })
            .collect()
    }

    fn reqs(n: usize) -> Vec<Request> {
        (0..n).map(|i| Request::new(i as u64, vec![i as i8; 8])).collect()
    }

    #[test]
    fn three_stage_pipeline_preserves_order_and_values() {
        let p = Pipeline::spawn(factories(3), sims(3, 1e-3), &PipelineConfig::default())
            .unwrap();
        let out = p.serve_batch(reqs(50)).unwrap();
        assert_eq!(out.len(), 50);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.data, vec![(i as i8).saturating_add(3); 8]);
            assert!(r.real_latency_s > 0.0);
            assert!(r.sim_done_s > 0.0);
        }
        assert_eq!(p.serve_metrics.snapshot().completed, 50);
        assert_eq!(p.stage_metrics[0].snapshot().items, 50);
        p.shutdown();
    }

    #[test]
    fn sim_clock_matches_pipeline_recurrence() {
        // 2 stages, service 1.2ms (exec 1 + overhead 0.2), hop 0.1ms,
        // batch 10: makespan ~ fill + (b-1)*bottleneck.  The shared host
        // clock is granted in real thread order, so allow slack of a few
        // overhead quanta around the deterministic recurrence value.
        let p = Pipeline::spawn(factories(2), sims(2, 1e-3), &PipelineConfig::default())
            .unwrap();
        let out = p.serve_batch(reqs(10)).unwrap();
        let sim_makespan = out.iter().map(|r| r.sim_done_s).fold(0.0, f64::max);
        let expect = (2.0 * 1.2e-3 + 1e-4) + 9.0 * 1.2e-3;
        assert!(
            (sim_makespan - expect).abs() < 3e-3,
            "sim={sim_makespan} expect~{expect}"
        );
        // and never below the bottleneck bound
        assert!(sim_makespan >= 10.0 * 1.2e-3 - 1e-9);
        p.shutdown();
    }

    #[test]
    fn failing_backend_surfaces_error() {
        struct Boom;
        impl StageBackend for Boom {
            fn run(&mut self, _input: &[i8]) -> Result<Vec<i8>> {
                anyhow::bail!("boom")
            }
        }
        let f: Vec<StageFactory> =
            vec![Box::new(|| Ok(Box::new(Boom) as Box<dyn StageBackend>))];
        let p = Pipeline::spawn(f, sims(1, 1e-4), &PipelineConfig::default()).unwrap();
        let err = p.serve_batch(reqs(1)).unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
        p.shutdown();
    }

    #[test]
    fn failing_factory_surfaces_error() {
        let f: Vec<StageFactory> = vec![Box::new(|| anyhow::bail!("no device"))];
        let p = Pipeline::spawn(f, sims(1, 1e-4), &PipelineConfig::default()).unwrap();
        let err = p.serve_batch(reqs(2)).unwrap_err();
        assert!(err.to_string().contains("no device"), "{err}");
        p.shutdown();
    }

    #[test]
    fn bounded_queue_many_chunks_no_deadlock() {
        // 500 requests as 63 in-flight chunk messages through capacity-2
        // queues: the feeder thread + drain loop must not deadlock
        let p = Pipeline::spawn(
            factories(4),
            sims(4, 1e-5),
            &PipelineConfig { queue_capacity: 2, ..Default::default() },
        )
        .unwrap();
        let out = p.serve_batch_chunked(reqs(500), 8).unwrap();
        assert_eq!(out.len(), 500);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
        p.shutdown();
    }

    #[test]
    fn chunked_and_batched_paths_agree() {
        let mk = || {
            Pipeline::spawn(factories(3), sims(3, 1e-5), &PipelineConfig::default()).unwrap()
        };
        let a = mk();
        let b = mk();
        let whole = a.serve_batch(reqs(40)).unwrap();
        let chunked = b.serve_batch_chunked(reqs(40), 1).unwrap();
        assert_eq!(whole.len(), chunked.len());
        for (x, y) in whole.iter().zip(&chunked) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.data, y.data, "transfer granularity must not change bytes");
        }
        a.shutdown();
        b.shutdown();
    }

    #[test]
    fn mismatched_request_sizes_are_rejected_at_ingress() {
        let p = Pipeline::spawn(factories(1), sims(1, 1e-5), &PipelineConfig::default())
            .unwrap();
        let bad = vec![
            Request::new(0, vec![0; 8]),
            Request::new(1, vec![0; 4]),
        ];
        let err = p.serve_batch(bad).unwrap_err();
        assert!(err.to_string().contains("carries"), "{err}");
        p.shutdown();
    }

    #[test]
    fn steady_state_serving_is_allocation_free() {
        // after the first batch warmed the arena, identical batches must
        // recycle every slab: the alloc counter freezes
        let p = Pipeline::spawn(factories(4), sims(4, 1e-6), &PipelineConfig::default())
            .unwrap();
        p.wait_ready().unwrap();
        drop(p.serve_batch(reqs(32)).unwrap()); // warm-up, responses dropped
        let warm = p.data_plane.snapshot();
        assert!(warm.slab_allocs > 0, "warm-up must have allocated slabs");
        for _ in 0..5 {
            drop(p.serve_batch(reqs(32)).unwrap());
        }
        let after = p.data_plane.snapshot();
        assert_eq!(
            after.slab_allocs, warm.slab_allocs,
            "steady state must perform zero per-request allocations: {after:?}"
        );
        assert!(after.slab_reuses > warm.slab_reuses);
        // one handoff per hop per batch: 6 batches x (1 ingress + 4 stages)
        assert_eq!(after.handoffs, 6 * 5);
        assert_eq!(after.handoff_items, 6 * 5 * 32);
        p.shutdown();
    }

    #[test]
    fn out_elems_override_sizes_the_output_slab() {
        // a shape-changing backend using the default run_batch: the slab
        // is sized by out_elems, and values/order survive
        struct Doubler;
        impl StageBackend for Doubler {
            fn run(&mut self, input: &[i8]) -> Result<Vec<i8>> {
                let mut out = Vec::with_capacity(input.len() * 2);
                for &v in input {
                    out.push(v);
                    out.push(v.saturating_neg());
                }
                Ok(out)
            }
            fn out_elems(&self, in_elems: usize) -> usize {
                in_elems * 2
            }
        }
        let f: Vec<StageFactory> =
            vec![Box::new(|| Ok(Box::new(Doubler) as Box<dyn StageBackend>))];
        let p = Pipeline::spawn(f, sims(1, 1e-6), &PipelineConfig::default()).unwrap();
        let out = p.serve_batch(reqs(9)).unwrap();
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.data.len(), 16);
            assert_eq!(r.data[0], i as i8);
            assert_eq!(r.data[1], (i as i8).saturating_neg());
        }
        p.shutdown();
    }

    /// Cross-validation: the live coordinator's simulated clock must agree
    /// with the deterministic `pipeline::simulate` within a few host
    /// quanta (thread-order slack), across random stage shapes.
    #[test]
    fn live_sim_clock_tracks_event_sim() {
        use crate::config::LinkConfig;
        use crate::link::Link;
        use crate::pipeline::{simulate, SimOptions, StageSpec};
        crate::util::proptest::forall(8, |rng| {
            let s = rng.below(3) as usize + 2;
            let b = 20usize;
            let oh = 2e-4;
            let hop = 1e-4;
            let execs: Vec<f64> = (0..s).map(|_| rng.f64_range(1e-4, 2e-3)).collect();

            // deterministic reference
            let link = Link::new(LinkConfig {
                act_bw: f64::INFINITY,
                hop_latency_s: hop,
                stage_overhead_s: oh,
                ..Default::default()
            });
            let stages: Vec<StageSpec> = execs
                .iter()
                .map(|&e| StageSpec { exec_s: e, in_bytes: 0, out_bytes: 0 })
                .collect();
            let want = simulate(&stages, &link, &SimOptions { batch: b, ..Default::default() })
                .makespan_s;

            // live pipeline with the same stage sims
            let factories: Vec<StageFactory> = (0..s)
                .map(|_| {
                    Box::new(|| Ok(Box::new(AddOne) as Box<dyn StageBackend>)) as StageFactory
                })
                .collect();
            let sims: Vec<StageSim> = execs
                .iter()
                .enumerate()
                .map(|(i, &e)| StageSim {
                    exec_s: e,
                    hop_out_s: if i + 1 == s { 0.0 } else { hop },
                    overhead_s: oh,
                })
                .collect();
            let p = Pipeline::spawn(factories, sims, &PipelineConfig::default()).unwrap();
            let out = p.serve_batch(reqs(b)).unwrap();
            let got = out.iter().map(|r| r.sim_done_s).fold(0.0, f64::max);
            p.shutdown();

            // thread-order slack both ways: the live calendar backfills
            // gaps (slightly better than strict FCFS), and real thread
            // order can delay grants (slightly worse)
            let slack = 8.0 * oh + 1e-9;
            crate::check!(
                got >= want * 0.85 - 1e-9 && got <= want * 1.25 + slack,
                "s={s} got={got} want={want}"
            );
            Ok(())
        });
    }

    #[test]
    fn replica_router_covers_all_requests() {
        let mk = || {
            Pipeline::spawn(factories(2), sims(2, 1e-4), &PipelineConfig::default()).unwrap()
        };
        let router = ReplicaRouter::new(vec![mk(), mk(), mk()]);
        let out = router.serve_batch(reqs(101)).unwrap();
        assert_eq!(out.len(), 101);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.data[0], (i as i8).saturating_add(2));
        }
        assert_eq!(router.hedged_total(), 0, "hedging is off by default");
        router.shutdown();
    }

    #[test]
    fn hedged_dispatch_fires_on_straggling_replica() {
        let mk = || {
            Pipeline::spawn(factories(2), sims(2, 1e-5), &PipelineConfig::default()).unwrap()
        };
        let router = ReplicaRouter::new(vec![mk(), mk()])
            .with_hedging(HedgeConfig { p99_factor: 2.0, min_samples: 4 });
        let injector = router.injector();
        let delay = Duration::from_millis(40);
        injector.set(0, delay);
        // warm-up: both replicas are cold (below min_samples), so no
        // hedge fires, but replica 0 records ~delay-inflated latencies
        let warm = router.serve_batch(reqs(16)).unwrap();
        assert_eq!(warm.len(), 16);
        assert_eq!(router.hedged_total(), 0, "cold replicas must not hedge");
        // replica 0's p99 now dwarfs replica 1's -> its 8-item shard is
        // dispatched twice and the fast copy wins
        let out = router.serve_batch(reqs(16)).unwrap();
        assert_eq!(out.len(), 16);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.data[0], (i as i8).saturating_add(2), "hedge must not change bytes");
        }
        assert_eq!(router.hedged_total(), 8, "replica 0's whole shard hedges");
        // the kept copy of every hedged (even-id) request beat the
        // injected delay, so the hedge actually cut the tail
        for r in out.iter().filter(|r| r.id % 2 == 0) {
            assert!(
                r.real_latency_s < delay.as_secs_f64(),
                "id {} kept the straggler copy: {}s",
                r.id,
                r.real_latency_s
            );
        }
        injector.clear(0);
        router.shutdown();
    }

    #[test]
    fn hedge_and_breaker_validation_pin_messages() {
        let err = HedgeConfig { p99_factor: 0.5, min_samples: 4 }.validate().unwrap_err();
        assert_eq!(
            err.to_string(),
            "hedge p99 factor must be finite and >= 1 (got 0.5)"
        );
        let err =
            HedgeConfig { p99_factor: f64::NAN, min_samples: 4 }.validate().unwrap_err();
        assert!(err.to_string().contains("hedge p99 factor"), "{err}");
        let err = HedgeConfig { p99_factor: 2.0, min_samples: 0 }.validate().unwrap_err();
        assert_eq!(err.to_string(), "hedge window must cover at least 1 sample (got 0)");
        HedgeConfig::default().validate().unwrap();

        let err = BreakerConfig { watchdog: Duration::ZERO, ..Default::default() }
            .validate()
            .unwrap_err();
        assert_eq!(err.to_string(), "breaker watchdog must be non-zero");
        let err = BreakerConfig { trip_after: 0, ..Default::default() }
            .validate()
            .unwrap_err();
        assert_eq!(err.to_string(), "breaker trip threshold must be >= 1 (got 0)");
        BreakerConfig::default().validate().unwrap();
    }

    #[test]
    fn expired_request_is_rejected_before_dispatch() {
        let p = Pipeline::spawn(factories(1), sims(1, 1e-5), &PipelineConfig::default())
            .unwrap();
        // a deadline of "now" is in the past by dispatch time
        let expired = Request::new(7, vec![0; 8]).with_deadline(Instant::now());
        let err = p.serve_batch(vec![expired]).unwrap_err();
        assert!(err.to_string().contains("deadline expired before dispatch"), "{err}");
        // a generous deadline sails through untouched
        let live = Request::new(8, vec![1; 8])
            .with_deadline(Instant::now() + Duration::from_secs(60));
        let out = p.serve_batch(vec![live]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 8);
        p.shutdown();

        // the router guards the same invariant before sharding
        let mk = || {
            Pipeline::spawn(factories(1), sims(1, 1e-5), &PipelineConfig::default()).unwrap()
        };
        let router = ReplicaRouter::new(vec![mk(), mk()]);
        let expired = Request::new(9, vec![0; 8]).with_deadline(Instant::now());
        let err = router.serve_batch(vec![expired]).unwrap_err();
        assert!(err.to_string().contains("deadline expired before dispatch"), "{err}");
        router.shutdown();
    }

    #[test]
    fn breaker_trips_quarantines_and_reprobes() {
        let mk = || {
            Pipeline::spawn(factories(1), sims(1, 1e-5), &PipelineConfig::default()).unwrap()
        };
        let router = ReplicaRouter::new(vec![mk(), mk()]).with_breaker(BreakerConfig {
            watchdog: Duration::from_millis(50),
            trip_after: 2,
            cooldown: Duration::from_millis(100),
        });
        let injector = router.injector();
        injector.set(0, Duration::from_millis(150)); // breach every dispatch
        for _ in 0..2 {
            assert_eq!(router.serve_batch(reqs(8)).unwrap().len(), 8);
        }
        assert_eq!(router.breaker_trips_total(), 1, "two breaches trip once");
        assert_eq!(router.open_replicas(), vec![0]);
        // while Open (cooldown not yet elapsed) replica 0 receives nothing
        injector.clear(0);
        let before = router.replicas[0].serve_metrics.snapshot().completed;
        assert_eq!(router.serve_batch(reqs(6)).unwrap().len(), 6);
        assert_eq!(
            router.replicas[0].serve_metrics.snapshot().completed,
            before,
            "open replica must be excluded from dispatch"
        );
        // after the cooldown the replica gets a probe; healthy now, so
        // the probe closes the breaker and it rejoins the rotation
        std::thread::sleep(Duration::from_millis(150));
        assert_eq!(router.serve_batch(reqs(6)).unwrap().len(), 6);
        assert_eq!(router.breaker_probes_total(), 1, "one HalfOpen probe granted");
        assert!(router.open_replicas().is_empty(), "clean probe closes the breaker");
        assert!(
            router.replicas[0].serve_metrics.snapshot().completed > before,
            "probed replica served its shard"
        );
        router.shutdown();
    }

    #[test]
    fn breaker_replays_failed_shard_without_leaks_or_double_completion() {
        struct Boom;
        impl StageBackend for Boom {
            fn run(&mut self, _input: &[i8]) -> Result<Vec<i8>> {
                anyhow::bail!("boom")
            }
        }
        let bad = Pipeline::spawn(
            vec![Box::new(|| Ok(Box::new(Boom) as Box<dyn StageBackend>)) as StageFactory],
            sims(1, 1e-5),
            &PipelineConfig::default(),
        )
        .unwrap();
        let good =
            Pipeline::spawn(factories(1), sims(1, 1e-5), &PipelineConfig::default()).unwrap();
        // trip threshold high enough that the bad replica stays Closed and
        // keeps receiving (and failing) shards: every call exercises the
        // fail -> replay path, which must neither leak slabs nor complete
        // any id twice
        let router = ReplicaRouter::new(vec![bad, good]).with_breaker(BreakerConfig {
            watchdog: Duration::from_secs(5),
            trip_after: u32::MAX,
            cooldown: Duration::from_secs(60),
        });
        drop(router.serve_batch(reqs(10)).unwrap()); // warm both arenas
        let warm: Vec<u64> = router
            .replicas
            .iter()
            .map(|p| p.data_plane.snapshot().slab_allocs)
            .collect();
        for round in 1..=4u64 {
            let out = router.serve_batch(reqs(10)).unwrap();
            assert_eq!(out.len(), 10, "round {round}");
            for (i, r) in out.iter().enumerate() {
                assert_eq!(r.id, i as u64, "round {round}: exactly one response per id");
                assert_eq!(r.data[0], (i as i8).saturating_add(1));
            }
        }
        let after: Vec<u64> = router
            .replicas
            .iter()
            .map(|p| p.data_plane.snapshot().slab_allocs)
            .collect();
        assert_eq!(
            after, warm,
            "failed + replayed batches must return every slab to the arena"
        );
        // every request completed exactly once, all on the healthy replica
        assert_eq!(router.replicas[1].serve_metrics.snapshot().completed, 5 * 10);
        assert_eq!(router.replicas[0].serve_metrics.snapshot().completed, 0);
        router.shutdown();
    }

    #[test]
    fn breaker_open_replica_excluded_after_error_trip() {
        struct Boom;
        impl StageBackend for Boom {
            fn run(&mut self, _input: &[i8]) -> Result<Vec<i8>> {
                anyhow::bail!("boom")
            }
        }
        let bad = Pipeline::spawn(
            vec![Box::new(|| Ok(Box::new(Boom) as Box<dyn StageBackend>)) as StageFactory],
            sims(1, 1e-5),
            &PipelineConfig::default(),
        )
        .unwrap();
        let good =
            Pipeline::spawn(factories(1), sims(1, 1e-5), &PipelineConfig::default()).unwrap();
        let router = ReplicaRouter::new(vec![bad, good]).with_breaker(BreakerConfig {
            watchdog: Duration::from_secs(5),
            trip_after: 1,
            cooldown: Duration::from_secs(60),
        });
        // first call: replica 0 errors, trips immediately, shard replays
        let out = router.serve_batch(reqs(10)).unwrap();
        assert_eq!(out.len(), 10);
        assert_eq!(router.breaker_trips_total(), 1);
        assert_eq!(router.open_replicas(), vec![0]);
        // second call: the Open replica is excluded entirely, no new trips
        let out = router.serve_batch(reqs(4)).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(router.breaker_trips_total(), 1, "no dispatch, no further trips");
        router.shutdown();
    }
}
