//! L3 serving coordinator: the paper's pipelined multi-TPU runtime as a
//! real threaded system — one worker thread per (simulated) Edge TPU,
//! bounded host queues between stages (Fig 3), a dynamic batcher at the
//! front, and an optional replica router (the "data parallelism"
//! alternative the paper's conclusion mentions).
//!
//! Numerics are real: each stage executes its AOT-compiled HLO segment via
//! PJRT (or any other [`StageBackend`]).  Time is tracked twice — real
//! wall-clock of this host, and the **simulated Edge TPU clock** driven by
//! the calibrated cost model, which is what reproduces the paper's
//! latency/speedup numbers.

pub mod batcher;
pub mod queue;

use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::metrics::{ServeMetrics, StageMetrics};

use queue::{bounded, Receiver, Sender};

/// What a pipeline stage executes.  Implementations: PJRT segments
/// (production), native CPU chains, or pure-sim no-ops (tests).
pub trait StageBackend {
    /// Execute one inference on the stage's segment.
    fn run(&mut self, input: &[i8]) -> Result<Vec<i8>>;
}

/// Factory that builds a stage backend *inside* its worker thread (PJRT
/// clients/executables are not `Send`, so they must be born where they
/// run — exactly like one process per physical TPU).
pub type StageFactory = Box<dyn FnOnce() -> Result<Box<dyn StageBackend>> + Send>;

/// Simulated-clock parameters of one stage (from the cost model).
#[derive(Debug, Clone, Copy)]
pub struct StageSim {
    /// On-TPU service seconds per item: input DMA + execution (incl. host
    /// weight streaming) + output DMA.
    pub exec_s: f64,
    /// Host-queue handoff latency to the next stage (0 for the last).
    pub hop_out_s: f64,
    /// Host thread/queue overhead per item — GIL-serialized across ALL
    /// stages via the pipeline's shared host clock.
    pub overhead_s: f64,
}

/// Simulated host-server reservation calendar (the GIL): worker threads
/// reach it in *real* order, which may differ from simulated order, so
/// instead of a single free-time watermark it keeps busy intervals and
/// grants each request the first gap at or after its simulated request
/// time.  Throughput is thus capped at one item per
/// `n_stages * stage_overhead`, like the paper's Python-thread pipeline.
#[derive(Debug, Default)]
pub struct HostCalendar {
    busy: Vec<(f64, f64)>, // disjoint, sorted by start
}

impl HostCalendar {
    /// Reserve `dur` seconds at the earliest instant >= `request_t`.
    pub fn reserve(&mut self, request_t: f64, dur: f64) -> f64 {
        if dur <= 0.0 {
            return request_t;
        }
        let mut t = request_t;
        let mut idx = self.busy.len();
        for (i, &(s, e)) in self.busy.iter().enumerate() {
            if e <= t {
                continue;
            }
            if s >= t + dur {
                idx = i;
                break;
            }
            t = t.max(e);
        }
        // find insertion point for sorted order
        if idx == self.busy.len() {
            idx = self.busy.partition_point(|&(s, _)| s < t);
        }
        self.busy.insert(idx, (t, t + dur));
        t
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-chosen id; responses of one serve call are ordered by it.
    pub id: u64,
    /// Input activation tensor (int8, row-major).
    pub data: Vec<i8>,
}

/// One completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    /// The originating request's id.
    pub id: u64,
    /// Output activation tensor (int8, row-major).
    pub data: Vec<i8>,
    /// Real wall-clock latency on this host (PJRT CPU execution).
    pub real_latency_s: f64,
    /// Simulated Edge TPU pipeline completion time for this item.
    pub sim_done_s: f64,
}

struct Item {
    id: u64,
    data: Vec<i8>,
    submitted: Instant,
    /// Simulated time at which this item is available to the next stage.
    sim_arrive_s: f64,
    err: Option<String>,
}

/// A running pipeline: stage threads + front/back queues.
pub struct Pipeline {
    input: Sender<Item>,
    output: Receiver<Item>,
    workers: Vec<JoinHandle<()>>,
    /// (receiver, stages-seen-ready) — mutex'd so `&Pipeline` stays `Sync`
    /// for the replica router's scoped threads.
    ready: std::sync::Mutex<(std::sync::mpsc::Receiver<Result<(), String>>, usize)>,
    n_stages: usize,
    /// Per-stage execution counters (one entry per TPU worker).
    pub stage_metrics: Vec<Arc<StageMetrics>>,
    /// End-to-end latency histograms for this pipeline.
    pub serve_metrics: Arc<ServeMetrics>,
}

/// Configuration for pipeline construction.
pub struct PipelineConfig {
    /// Host queue capacity between stages (the paper used unbounded
    /// `queue.Queue()`; bounded gives backpressure).
    pub queue_capacity: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { queue_capacity: 64 }
    }
}

impl Pipeline {
    /// Spawn one worker per stage.  `factories[i]` builds stage i's
    /// backend inside its thread; `sims[i]` drives the simulated clock.
    pub fn spawn(
        factories: Vec<StageFactory>,
        sims: Vec<StageSim>,
        cfg: &PipelineConfig,
    ) -> Result<Self> {
        assert_eq!(factories.len(), sims.len());
        assert!(!factories.is_empty());
        let n = factories.len();
        let stage_metrics: Vec<Arc<StageMetrics>> =
            (0..n).map(|_| Arc::new(StageMetrics::default())).collect();

        // shared simulated host calendar (the GIL serialization point)
        let host_clock = Arc::new(std::sync::Mutex::new(HostCalendar::default()));
        // readiness channel: each worker reports once its backend is built
        let (ready_tx, ready_rx) = std::sync::mpsc::channel();
        // build the chain of queues: input -> s0 -> s1 -> ... -> output
        let (input_tx, mut prev_rx) = bounded::<Item>(cfg.queue_capacity);
        let mut workers = Vec::with_capacity(n);
        for (i, (factory, sim)) in factories.into_iter().zip(sims).enumerate() {
            let (tx, rx) = bounded::<Item>(cfg.queue_capacity);
            let metrics = stage_metrics[i].clone();
            let rx_in = prev_rx;
            let host = host_clock.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                stage_loop(factory, sim, rx_in, tx, metrics, host, ready);
            }));
            prev_rx = rx;
        }
        Ok(Pipeline {
            input: input_tx,
            output: prev_rx,
            workers,
            ready: std::sync::Mutex::new((ready_rx, 0)),
            n_stages: n,
            stage_metrics,
            serve_metrics: Arc::new(ServeMetrics::default()),
        })
    }

    /// Block until every stage backend is constructed (artifact compile is
    /// the dominant startup cost — call this before timing a batch).
    /// Returns the first backend-construction error, if any.
    pub fn wait_ready(&self) -> Result<()> {
        let mut guard = self.ready.lock().unwrap();
        while guard.1 < self.n_stages {
            match guard.0.recv() {
                Ok(Ok(())) => guard.1 += 1,
                Ok(Err(e)) => anyhow::bail!("stage backend init failed: {e}"),
                Err(_) => anyhow::bail!("pipeline worker exited before ready"),
            }
        }
        Ok(())
    }

    /// Run a closed batch through the pipeline (the paper's §V-B workload:
    /// all inputs available up front), blocking until every response is
    /// back.  Responses are returned in request order.
    pub fn serve_batch(&self, requests: Vec<Request>) -> Result<Vec<Response>> {
        let n = requests.len();
        let start = Instant::now();
        // feed from a separate thread so we can drain concurrently
        // (bounded queues would otherwise deadlock for large batches)
        let input = self.input.clone();
        let feeder = std::thread::spawn(move || {
            for r in requests {
                let item = Item {
                    id: r.id,
                    data: r.data,
                    submitted: start,
                    sim_arrive_s: 0.0,
                    err: None,
                };
                if input.send(item).is_err() {
                    break;
                }
            }
        });
        let mut responses = Vec::with_capacity(n);
        for _ in 0..n {
            let item = self
                .output
                .recv()
                .ok_or_else(|| anyhow::anyhow!("pipeline closed early"))?;
            if let Some(e) = item.err {
                anyhow::bail!("stage error on item {}: {e}", item.id);
            }
            let real = item.submitted.elapsed().as_secs_f64();
            self.serve_metrics.record(real, item.sim_arrive_s);
            responses.push(Response {
                id: item.id,
                data: item.data,
                real_latency_s: real,
                sim_done_s: item.sim_arrive_s,
            });
        }
        feeder.join().unwrap();
        responses.sort_by_key(|r| r.id);
        Ok(responses)
    }

    /// Close the input and join all workers.
    pub fn shutdown(self) {
        self.input.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn stage_loop(
    factory: StageFactory,
    sim: StageSim,
    rx: Receiver<Item>,
    tx: Sender<Item>,
    metrics: Arc<StageMetrics>,
    host_clock: Arc<std::sync::Mutex<HostCalendar>>,
    ready: std::sync::mpsc::Sender<Result<(), String>>,
) {
    let mut backend = match factory() {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            // propagate construction failure on every item, then drain
            while let Some(mut item) = rx.recv() {
                item.err = Some(format!("backend init failed: {e}"));
                if tx.send(item).is_err() {
                    break;
                }
            }
            tx.close();
            return;
        }
    };
    // simulated clock of THIS stage: when the simulated TPU becomes free
    let mut sim_free_s = 0.0f64;
    while let Some(mut item) = rx.recv() {
        let t0 = Instant::now();
        if item.err.is_none() {
            match backend.run(&item.data) {
                Ok(out) => item.data = out,
                Err(e) => item.err = Some(e.to_string()),
            }
        }
        metrics.record(t0.elapsed());
        // simulated pipeline recurrence (same math as pipeline::simulate):
        // dispatch waits for input, the TPU, and the GIL-shared host
        let sim_finish = {
            let request = item.sim_arrive_s.max(sim_free_s);
            let dispatch =
                host_clock.lock().unwrap().reserve(request, sim.overhead_s);
            dispatch + sim.overhead_s + sim.exec_s
        };
        sim_free_s = sim_finish;
        item.sim_arrive_s = sim_finish + sim.hop_out_s;
        if tx.send(item).is_err() {
            break;
        }
    }
    tx.close();
}

/// Round-robin router over pipeline replicas — the data-parallel
/// alternative (paper §V-C closing remark).  Each replica is a full copy
/// of the model on its own TPU set.
pub struct ReplicaRouter {
    /// The replica pipelines; requests are sharded round-robin across them.
    pub replicas: Vec<Pipeline>,
}

impl ReplicaRouter {
    /// Wrap a non-empty set of identical pipelines as one deployment.
    pub fn new(replicas: Vec<Pipeline>) -> Self {
        assert!(!replicas.is_empty());
        ReplicaRouter { replicas }
    }

    /// Split a batch round-robin across replicas, run them concurrently,
    /// return responses in request order.
    pub fn serve_batch(&self, requests: Vec<Request>) -> Result<Vec<Response>> {
        let k = self.replicas.len();
        let mut shards: Vec<Vec<Request>> = (0..k).map(|_| Vec::new()).collect();
        for (i, r) in requests.into_iter().enumerate() {
            shards[i % k].push(r);
        }
        let mut all = Vec::new();
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for (rep, shard) in self.replicas.iter().zip(shards) {
                handles.push(scope.spawn(move || rep.serve_batch(shard)));
            }
            for h in handles {
                all.extend(h.join().expect("replica thread panicked")?);
            }
            Ok(())
        })?;
        all.sort_by_key(|r| r.id);
        Ok(all)
    }

    /// Close every replica's input and join all worker threads.
    pub fn shutdown(self) {
        for r in self.replicas {
            r.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_calendar_packs_and_orders() {
        let mut c = HostCalendar::default();
        // sequential reservations chain
        assert_eq!(c.reserve(0.0, 1.0), 0.0);
        assert_eq!(c.reserve(0.5, 1.0), 1.0); // pushed past [0,1)
        // a later out-of-order request fills the gap after [1,2)
        assert_eq!(c.reserve(2.0, 0.5), 2.0);
        // request inside an existing busy interval lands after it
        assert_eq!(c.reserve(2.1, 0.5), 2.5);
        // zero-duration requests are free
        assert_eq!(c.reserve(0.25, 0.0), 0.25);
    }

    #[test]
    fn host_calendar_first_fit_gap() {
        let mut c = HostCalendar::default();
        c.reserve(0.0, 1.0); // [0,1)
        c.reserve(3.0, 1.0); // [3,4)
        // fits in the [1,3) gap
        assert_eq!(c.reserve(1.5, 1.0), 1.5);
        // no longer fits there -> goes after [3,4)
        assert_eq!(c.reserve(1.0, 1.0), 4.0);
    }

    #[test]
    fn host_calendar_property_no_overlap() {
        crate::util::proptest::forall(64, |rng| {
            let mut c = HostCalendar::default();
            let mut granted: Vec<(f64, f64)> = Vec::new();
            for _ in 0..40 {
                let req = rng.f64_range(0.0, 10.0);
                let dur = rng.f64_range(0.01, 0.8);
                let t = c.reserve(req, dur);
                crate::check!(t >= req - 1e-12, "grant before request");
                granted.push((t, t + dur));
            }
            granted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in granted.windows(2) {
                crate::check!(w[1].0 >= w[0].1 - 1e-9, "overlap {w:?}");
            }
            Ok(())
        });
    }

    /// A backend that applies an affine int8 map (cheap, deterministic).
    struct AddOne;

    impl StageBackend for AddOne {
        fn run(&mut self, input: &[i8]) -> Result<Vec<i8>> {
            Ok(input.iter().map(|&v| v.saturating_add(1)).collect())
        }
    }

    fn factories(n: usize) -> Vec<StageFactory> {
        (0..n)
            .map(|_| {
                Box::new(|| Ok(Box::new(AddOne) as Box<dyn StageBackend>)) as StageFactory
            })
            .collect()
    }

    fn sims(n: usize, exec: f64) -> Vec<StageSim> {
        (0..n)
            .map(|_| StageSim { exec_s: exec, hop_out_s: 1e-4, overhead_s: 2e-4 })
            .collect()
    }

    fn reqs(n: usize) -> Vec<Request> {
        (0..n).map(|i| Request { id: i as u64, data: vec![i as i8; 8] }).collect()
    }

    #[test]
    fn three_stage_pipeline_preserves_order_and_values() {
        let p = Pipeline::spawn(factories(3), sims(3, 1e-3), &PipelineConfig::default())
            .unwrap();
        let out = p.serve_batch(reqs(50)).unwrap();
        assert_eq!(out.len(), 50);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.data, vec![(i as i8).saturating_add(3); 8]);
            assert!(r.real_latency_s > 0.0);
            assert!(r.sim_done_s > 0.0);
        }
        assert_eq!(p.serve_metrics.snapshot().completed, 50);
        assert_eq!(p.stage_metrics[0].snapshot().items, 50);
        p.shutdown();
    }

    #[test]
    fn sim_clock_matches_pipeline_recurrence() {
        // 2 stages, service 1.2ms (exec 1 + overhead 0.2), hop 0.1ms,
        // batch 10: makespan ~ fill + (b-1)*bottleneck.  The shared host
        // clock is granted in real thread order, so allow slack of a few
        // overhead quanta around the deterministic recurrence value.
        let p = Pipeline::spawn(factories(2), sims(2, 1e-3), &PipelineConfig::default())
            .unwrap();
        let out = p.serve_batch(reqs(10)).unwrap();
        let sim_makespan = out.iter().map(|r| r.sim_done_s).fold(0.0, f64::max);
        let expect = (2.0 * 1.2e-3 + 1e-4) + 9.0 * 1.2e-3;
        assert!(
            (sim_makespan - expect).abs() < 3e-3,
            "sim={sim_makespan} expect~{expect}"
        );
        // and never below the bottleneck bound
        assert!(sim_makespan >= 10.0 * 1.2e-3 - 1e-9);
        p.shutdown();
    }

    #[test]
    fn failing_backend_surfaces_error() {
        struct Boom;
        impl StageBackend for Boom {
            fn run(&mut self, _input: &[i8]) -> Result<Vec<i8>> {
                anyhow::bail!("boom")
            }
        }
        let f: Vec<StageFactory> =
            vec![Box::new(|| Ok(Box::new(Boom) as Box<dyn StageBackend>))];
        let p = Pipeline::spawn(f, sims(1, 1e-4), &PipelineConfig::default()).unwrap();
        let err = p.serve_batch(reqs(1)).unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
        p.shutdown();
    }

    #[test]
    fn failing_factory_surfaces_error() {
        let f: Vec<StageFactory> = vec![Box::new(|| anyhow::bail!("no device"))];
        let p = Pipeline::spawn(f, sims(1, 1e-4), &PipelineConfig::default()).unwrap();
        let err = p.serve_batch(reqs(2)).unwrap_err();
        assert!(err.to_string().contains("no device"), "{err}");
        p.shutdown();
    }

    #[test]
    fn bounded_queue_large_batch_no_deadlock() {
        let p = Pipeline::spawn(
            factories(4),
            sims(4, 1e-5),
            &PipelineConfig { queue_capacity: 2 },
        )
        .unwrap();
        let out = p.serve_batch(reqs(500)).unwrap();
        assert_eq!(out.len(), 500);
        p.shutdown();
    }

    /// Cross-validation: the live coordinator's simulated clock must agree
    /// with the deterministic `pipeline::simulate` within a few host
    /// quanta (thread-order slack), across random stage shapes.
    #[test]
    fn live_sim_clock_tracks_event_sim() {
        use crate::config::LinkConfig;
        use crate::link::Link;
        use crate::pipeline::{simulate, SimOptions, StageSpec};
        crate::util::proptest::forall(8, |rng| {
            let s = rng.below(3) as usize + 2;
            let b = 20usize;
            let oh = 2e-4;
            let hop = 1e-4;
            let execs: Vec<f64> = (0..s).map(|_| rng.f64_range(1e-4, 2e-3)).collect();

            // deterministic reference
            let link = Link::new(LinkConfig {
                act_bw: f64::INFINITY,
                hop_latency_s: hop,
                stage_overhead_s: oh,
                ..Default::default()
            });
            let stages: Vec<StageSpec> = execs
                .iter()
                .map(|&e| StageSpec { exec_s: e, in_bytes: 0, out_bytes: 0 })
                .collect();
            let want = simulate(&stages, &link, &SimOptions { batch: b, ..Default::default() })
                .makespan_s;

            // live pipeline with the same stage sims
            let factories: Vec<StageFactory> = (0..s)
                .map(|_| {
                    Box::new(|| Ok(Box::new(AddOne) as Box<dyn StageBackend>)) as StageFactory
                })
                .collect();
            let sims: Vec<StageSim> = execs
                .iter()
                .enumerate()
                .map(|(i, &e)| StageSim {
                    exec_s: e,
                    hop_out_s: if i + 1 == s { 0.0 } else { hop },
                    overhead_s: oh,
                })
                .collect();
            let p = Pipeline::spawn(factories, sims, &PipelineConfig::default()).unwrap();
            let out = p.serve_batch(reqs(b)).unwrap();
            let got = out.iter().map(|r| r.sim_done_s).fold(0.0, f64::max);
            p.shutdown();

            // thread-order slack both ways: the live calendar backfills
            // gaps (slightly better than strict FCFS), and real thread
            // order can delay grants (slightly worse)
            let slack = 8.0 * oh + 1e-9;
            crate::check!(
                got >= want * 0.85 - 1e-9 && got <= want * 1.25 + slack,
                "s={s} got={got} want={want}"
            );
            Ok(())
        });
    }

    #[test]
    fn replica_router_covers_all_requests() {
        let mk = || {
            Pipeline::spawn(factories(2), sims(2, 1e-4), &PipelineConfig::default()).unwrap()
        };
        let router = ReplicaRouter::new(vec![mk(), mk(), mk()]);
        let out = router.serve_batch(reqs(101)).unwrap();
        assert_eq!(out.len(), 101);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.data[0], (i as i8).saturating_add(2));
        }
        router.shutdown();
    }
}
