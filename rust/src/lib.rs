//! # tpu-pipeline
//!
//! Reproduction of *"Improving inference time in multi-TPU systems with
//! profiled model segmentation"* (Villarrubia, Costero, Igual, Olcoz — PDP
//! 2023) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordination contribution: the Edge TPU
//!   placement/cost simulator, segmentation strategies (uniform /
//!   memory-balanced / profiled-exhaustive), the pipelined multi-TPU
//!   executor, and a thread-per-TPU serving runtime that executes real
//!   numerics via PJRT.
//! * **L2 (`python/compile/model.py`)** — JAX forward graphs of the paper's
//!   synthetic FC/CONV models, AOT-lowered per segment to HLO text.
//! * **L1 (`python/compile/kernels/`)** — quantized Pallas kernels (int8
//!   matmul, 3x3 conv) the L2 graphs call.
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! segments once; [`runtime`] loads them through the PJRT C API.
//!
//! See DESIGN.md for the full system inventory and the experiment index
//! mapping every paper table/figure to a harness entry point.

pub mod compiler;
pub mod config;
pub mod device;
pub mod hostexec;
pub mod link;
pub mod model;
pub mod quant;
pub mod util;
pub mod pipeline;
pub mod profiler;
pub mod runtime;
pub mod segment;
pub mod coordinator;
pub mod metrics;
pub mod obs;
pub mod report;
pub mod sweep;
pub mod trace;
pub mod cli;
pub mod serving;
pub mod scheduler;
pub mod workload;
pub mod ablation;
