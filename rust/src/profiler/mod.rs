//! The profiling partitioner (paper §V-C).
//!
//! For small chains the space of contiguous partitions is tiny
//! (`C(l-1, s-1)`; 14 for the paper's 5-layer models), so we do what the
//! paper does: **profile every partition** on the pipelined-batch workload
//! and keep the best.  Google's own tool instead stops at the first
//! partition whose fastest/slowest stage delta meets a threshold — that
//! mode is implemented too (`threshold_search`) for comparison/ablation.
//!
//! Per-segment costs are memoized over `[start, end)` so the search does
//! O(l²) placements instead of O(l² · C).

use crate::compiler::place;
use crate::config::SystemConfig;
use crate::device::CostModel;
use crate::link::Link;
use crate::model::Model;
use crate::pipeline::{simulate, PipelineResult, SimOptions, StageSpec};
use crate::segment::{enumerate_partitions, Partition};

/// Profile of one candidate partition.
#[derive(Debug, Clone)]
pub struct PartitionProfile {
    pub partition: Partition,
    /// Per-stage exec times (on-TPU, incl. host streaming).
    pub stage_exec_s: Vec<f64>,
    /// Single-input end-to-end latency.
    pub single_latency_s: f64,
    /// Batched per-inference time (the selection objective).
    pub per_item_s: f64,
    /// Whether any segment spills to host memory.
    pub uses_host: bool,
}

impl PartitionProfile {
    /// Max/min stage-time imbalance (Google tool's threshold metric).
    pub fn stage_delta_s(&self) -> f64 {
        let max = self.stage_exec_s.iter().cloned().fold(0.0, f64::max);
        let min = self.stage_exec_s.iter().cloned().fold(f64::INFINITY, f64::min);
        max - min
    }
}

/// Memoized per-segment cost table for one model.
pub struct SegmentCostTable {
    /// `exec[(start, end)]` -> (exec_s, uses_host)
    exec: Vec<Vec<Option<(f64, bool)>>>,
    n_layers: usize,
}

impl SegmentCostTable {
    pub fn build(model: &Model, cfg: &SystemConfig) -> Self {
        let cm = CostModel::new(cfg.clone());
        let l = model.len();
        let mut exec = vec![vec![None; l + 1]; l];
        for start in 0..l {
            for end in start + 1..=l {
                let placement = place(&model.layers[start..end], &cfg.device);
                let cost = cm.stage_cost(&placement);
                exec[start][end] = Some((cost.exec_s(), placement.uses_host()));
            }
        }
        SegmentCostTable { exec, n_layers: l }
    }

    pub fn exec_s(&self, start: usize, end: usize) -> f64 {
        self.exec[start][end].expect("valid range").0
    }

    pub fn uses_host(&self, start: usize, end: usize) -> bool {
        self.exec[start][end].expect("valid range").1
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }
}

/// Profile one partition under the batched pipelined workload.
pub fn profile_partition(
    model: &Model,
    table: &SegmentCostTable,
    partition: &Partition,
    cfg: &SystemConfig,
    batch: usize,
) -> PartitionProfile {
    let link = Link::new(cfg.link.clone());
    let bounds = partition.bounds();
    let stages: Vec<StageSpec> = bounds
        .iter()
        .map(|&(a, b)| StageSpec {
            exec_s: table.exec_s(a, b),
            in_bytes: model.layers[a].input_elems(),
            out_bytes: model.layers[b - 1].output_elems(),
        })
        .collect();
    let single = simulate(&stages, &link, &SimOptions { batch: 1, ..Default::default() });
    let batched = simulate(&stages, &link, &SimOptions { batch, ..Default::default() });
    PartitionProfile {
        partition: partition.clone(),
        stage_exec_s: stages.iter().map(|s| s.exec_s).collect(),
        single_latency_s: single.makespan_s,
        per_item_s: batched.per_item_s(batch),
        uses_host: bounds.iter().any(|&(a, b)| table.uses_host(a, b)),
    }
}

/// Exhaustively profile all partitions into `n_segments`; returns profiles
/// sorted best-first by batched per-inference time.
pub fn exhaustive_search(
    model: &Model,
    cfg: &SystemConfig,
    n_segments: usize,
    batch: usize,
) -> Vec<PartitionProfile> {
    let table = SegmentCostTable::build(model, cfg);
    let mut profiles: Vec<PartitionProfile> = enumerate_partitions(model.len(), n_segments)
        .iter()
        .map(|p| profile_partition(model, &table, p, cfg, batch))
        .collect();
    profiles.sort_by(|a, b| a.per_item_s.partial_cmp(&b.per_item_s).unwrap());
    profiles
}

/// The best partition by batched per-inference time.
pub fn best_partition(
    model: &Model,
    cfg: &SystemConfig,
    n_segments: usize,
    batch: usize,
) -> PartitionProfile {
    exhaustive_search(model, cfg, n_segments, batch).remove(0)
}

/// Google-tool-style search: test partitions in enumeration order, return
/// the first whose stage delta meets `max_delta_s`; if none does, the last
/// tested one (documented tool behaviour the paper describes).
pub fn threshold_search(
    model: &Model,
    cfg: &SystemConfig,
    n_segments: usize,
    batch: usize,
    max_delta_s: f64,
) -> PartitionProfile {
    let table = SegmentCostTable::build(model, cfg);
    let parts = enumerate_partitions(model.len(), n_segments);
    let mut last = None;
    for p in &parts {
        let prof = profile_partition(model, &table, p, cfg, batch);
        if prof.stage_delta_s() <= max_delta_s {
            return prof;
        }
        last = Some(prof);
    }
    last.expect("at least one partition")
}

/// The pipeline simulation for a chosen profile (for reports/traces).
pub fn simulate_profile(
    model: &Model,
    profile: &PartitionProfile,
    cfg: &SystemConfig,
    opts: &SimOptions,
) -> PipelineResult {
    crate::pipeline::simulate_partition(model, &profile.partition, cfg, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic::{conv_model, fc_model};
    use crate::segment::uniform_cuts;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn cost_table_covers_all_ranges() {
        let m = fc_model(500);
        let t = SegmentCostTable::build(&m, &cfg());
        for a in 0..5 {
            for b in a + 1..=5 {
                assert!(t.exec_s(a, b) > 0.0, "({a},{b})");
            }
        }
    }

    /// Paper §V-C / Tables V–VI: for FC models where the uniform 3-way
    /// split wastes TPU1 on the tiny input layer, profiling moves a big
    /// layer there and avoids host memory entirely.
    #[test]
    fn profiled_3tpu_fc_avoids_host() {
        let cfg = cfg();
        for n in [2100u64, 2340, 2580] {
            let m = fc_model(n);
            let table = SegmentCostTable::build(&m, &cfg);
            let uni = profile_partition(&m, &table, &uniform_cuts(5, 3), &cfg, 50);
            let best = best_partition(&m, &cfg, 3, 50);
            assert!(uni.uses_host, "n={n}: uniform should spill");
            assert!(!best.uses_host, "n={n}: profiled should fit");
            assert!(best.per_item_s < uni.per_item_s, "n={n}");
            // the winning split gives TPU1 real work: first segment holds 2 layers
            assert_eq!(best.partition.bounds()[0], (0, 2), "n={n}: {:?}", best.partition);
        }
    }

    /// Paper: CONV 4-TPU default split leaves two big layers on TPU4;
    /// profiling splits them and fits everything on-device.
    #[test]
    fn profiled_4tpu_conv_avoids_host() {
        let cfg = cfg();
        for f in [592u64, 652] {
            let m = conv_model(f);
            let table = SegmentCostTable::build(&m, &cfg);
            let uni = profile_partition(&m, &table, &uniform_cuts(5, 4), &cfg, 50);
            let best = best_partition(&m, &cfg, 4, 50);
            assert!(uni.uses_host, "f={f}: uniform should spill");
            assert!(!best.uses_host, "f={f}: profiled should fit");
        }
    }

    /// Profiled choice is never worse than the uniform default (it searches
    /// a superset) — the core invariant of the paper's method.
    #[test]
    fn property_profiled_never_worse_than_uniform() {
        crate::util::proptest::forall(48, |rng| {
            let cfg = cfg();
            let fc = rng.below(2) == 0;
            let m = if fc {
                fc_model(rng.below(2500) + 100)
            } else {
                conv_model(rng.below(600) + 32)
            };
            let s = rng.below(4) as usize + 1;
            let batch = rng.below(60) as usize + 1;
            let table = SegmentCostTable::build(&m, &cfg);
            let uni = profile_partition(&m, &table, &uniform_cuts(5, s), &cfg, batch);
            let best = best_partition(&m, &cfg, s, batch);
            crate::check!(
                best.per_item_s <= uni.per_item_s + 1e-12,
                "model={} s={s} batch={batch}",
                m.name
            );
            Ok(())
        });
    }

    #[test]
    fn threshold_mode_returns_valid_partition() {
        let cfg = cfg();
        let m = fc_model(2100);
        // generous threshold: first partition tested wins
        let loose = threshold_search(&m, &cfg, 3, 50, f64::INFINITY);
        assert_eq!(loose.partition.n_segments(), 3);
        // impossible threshold: falls back to last tested
        let strict = threshold_search(&m, &cfg, 3, 50, 0.0);
        assert_eq!(strict.partition.n_segments(), 3);
        // exhaustive beats (or ties) threshold mode
        let best = best_partition(&m, &cfg, 3, 50);
        assert!(best.per_item_s <= loose.per_item_s + 1e-15);
        assert!(best.per_item_s <= strict.per_item_s + 1e-15);
    }

    #[test]
    fn stage_delta_metric() {
        let p = PartitionProfile {
            partition: Partition::whole(5),
            stage_exec_s: vec![1.0, 4.0, 2.0],
            single_latency_s: 0.0,
            per_item_s: 0.0,
            uses_host: false,
        };
        assert_eq!(p.stage_delta_s(), 3.0);
    }
}
