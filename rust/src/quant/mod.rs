//! int8 affine quantization — the Rust twin of `python/compile/quantize.py`.
//!
//! Only the pieces the runtime needs at the serving edges (quantize inputs,
//! dequantize outputs) plus the requantization primitive, kept bit-exact
//! with the Python/XLA side: f32 multiply, round-ties-to-even, clamp.
//! Cross-language golden vectors are asserted in both test suites.

pub const QMIN: i32 = -128;
pub const QMAX: i32 = 127;

/// Per-tensor affine parameters: `real = scale * (q - zero_point)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: i32,
}

impl QParams {
    pub fn quantize(&self, real: f32) -> i8 {
        let q = (real / self.scale).round_ties_even() as i64 + self.zero_point as i64;
        q.clamp(QMIN as i64, QMAX as i64) as i8
    }

    pub fn dequantize(&self, q: i8) -> f32 {
        (q as i32 - self.zero_point) as f32 * self.scale
    }

    pub fn quantize_slice(&self, real: &[f32]) -> Vec<i8> {
        real.iter().map(|&r| self.quantize(r)).collect()
    }

    pub fn dequantize_slice(&self, q: &[i8]) -> Vec<f32> {
        q.iter().map(|&v| self.dequantize(v)).collect()
    }
}

/// int32 accumulator -> int8, matching `quantize.requantize_jnp` /
/// XLA `round_nearest_even` bit-for-bit.
pub fn requantize(acc: i32, mult: f32, zp_out: i32) -> i8 {
    let scaled = (acc as f32 * mult).round_ties_even();
    let q = scaled as i32 + zp_out;
    q.clamp(QMIN, QMAX) as i8
}

/// Combined rescale factor (computed in f32 like the Python side).
pub fn requant_multiplier(in_scale: f32, w_scale: f32, out_scale: f32) -> f32 {
    in_scale * w_scale / out_scale
}

/// Bias quantization: int32 at scale `in_scale * w_scale`.
pub fn bias_quantize(b: f32, in_scale: f32, w_scale: f32) -> i32 {
    (b / (in_scale * w_scale)).round_ties_even() as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mirrors python/tests/test_quantize.py::test_cross_language_vectors.
    /// If these change, change the Python test too.
    #[test]
    fn cross_language_vectors() {
        let accs = [0i32, 1000, -1000, 123_456, -123_456, 1 << 30];
        let want = [3i8, 7, -1, 127, -128, 127];
        for (a, w) in accs.iter().zip(want) {
            assert_eq!(requantize(*a, 0.003_906_25, 3), w, "acc={a}");
        }
        let q = QParams { scale: 0.05, zero_point: -10 };
        let reals = [-1.0f32, 0.0, 0.024, 0.026, 7.0];
        let want = [-30i8, -10, -10, -9, 127];
        for (r, w) in reals.iter().zip(want) {
            assert_eq!(q.quantize(*r), w, "real={r}");
        }
        assert_eq!(bias_quantize(0.5, 0.1, 0.02), 250);
        assert_eq!(bias_quantize(-0.25, 0.1, 0.02), -125);
        assert!((requant_multiplier(0.1, 0.02, 0.05) - 0.04).abs() < 1e-7);
    }

    #[test]
    fn requantize_ties_to_even() {
        // acc * mult == 0.5 and 1.5 exactly -> 0 and 2
        assert_eq!(requantize(1, 0.5, 0), 0);
        assert_eq!(requantize(3, 0.5, 0), 2);
        assert_eq!(requantize(-1, 0.5, 0), 0);
        assert_eq!(requantize(-3, 0.5, 0), -2);
    }

    #[test]
    fn requantize_saturates() {
        assert_eq!(requantize(i32::MAX, 1.0, 0), 127);
        assert_eq!(requantize(i32::MIN, 1.0, 0), -128);
    }

    #[test]
    fn quantize_dequantize_roundtrip_error() {
        let q = QParams { scale: 0.1, zero_point: 5 };
        for i in -50..50 {
            let real = i as f32 * 0.07;
            let err = (q.dequantize(q.quantize(real)) - real).abs();
            assert!(err <= 0.05 + 1e-6, "real={real} err={err}");
        }
    }

    #[test]
    fn zero_exactly_representable() {
        let q = QParams { scale: 0.03, zero_point: -7 };
        assert_eq!(q.dequantize(q.quantize(0.0)), 0.0);
    }

    #[test]
    fn property_requantize_monotone() {
        crate::util::proptest::forall(256, |rng| {
            let mult = rng.f64_range(1e-6, 0.5) as f32;
            let zp = rng.range_i64(-128, 127) as i32;
            let a = rng.range_i64(-1 << 20, 1 << 20) as i32;
            let b = rng.range_i64(-1 << 20, 1 << 20) as i32;
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            crate::check!(
                requantize(lo, mult, zp) <= requantize(hi, mult, zp),
                "lo={lo} hi={hi} mult={mult} zp={zp}"
            );
            Ok(())
        });
    }
}
