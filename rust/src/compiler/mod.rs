//! The `edgetpu_compiler` placement model.
//!
//! The real compiler stores weights **whole-layer-at-a-time**: it walks the
//! layers in order and parks each one in on-chip memory until the next
//! layer no longer fits, after which that layer (and, layer-by-layer, any
//! later one that does not fit in the remaining space) lives in **host**
//! memory and is streamed over PCIe on every inference (paper §IV: "the
//! neural layer is the minimum storage unit").  The compile report (device
//! MiB / host MiB per TPU) is what Tables I–IV print.

use crate::config::DeviceConfig;
use crate::model::Layer;
use crate::util::mib;

/// Where a layer's weights live during inference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Location {
    Device,
    Host,
}

/// One layer's placement decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlacedLayer {
    pub layer: Layer,
    pub location: Location,
    /// Storage footprint: raw weight bytes x metadata ratio + fixed
    /// per-layer overhead (this is also what the compile report prints).
    pub footprint_bytes: u64,
}

/// Placement of one contiguous segment onto one TPU.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub layers: Vec<PlacedLayer>,
}

impl Placement {
    pub fn device_bytes(&self) -> u64 {
        self.sum(Location::Device)
    }

    pub fn host_bytes(&self) -> u64 {
        self.sum(Location::Host)
    }

    fn sum(&self, loc: Location) -> u64 {
        self.layers
            .iter()
            .filter(|p| p.location == loc)
            .map(|p| p.footprint_bytes)
            .sum()
    }

    pub fn device_mib(&self) -> f64 {
        mib(self.device_bytes())
    }

    pub fn host_mib(&self) -> f64 {
        mib(self.host_bytes())
    }

    pub fn uses_host(&self) -> bool {
        self.layers.iter().any(|p| p.location == Location::Host)
    }

    /// Raw (un-inflated) weight bytes by location — the device cost model
    /// streams these.
    pub fn raw_weight_bytes(&self, loc: Location) -> u64 {
        self.layers
            .iter()
            .filter(|p| p.location == loc)
            .map(|p| p.layer.weight_bytes())
            .sum()
    }
}

/// Per-layer storage footprint (compiler metadata + instructions).
pub fn layer_footprint(layer: &Layer, cfg: &DeviceConfig) -> u64 {
    (layer.weight_bytes() as f64 * cfg.footprint_ratio).ceil() as u64
        + cfg.per_layer_fixed_bytes
}

/// Greedy whole-layer placement of a segment onto one TPU, in layer order —
/// the observed `edgetpu_compiler` behaviour.
///
/// The segment's **input activation tensor** is reserved on-chip before any
/// weights are placed: a pipelined segment must buffer the tensor it
/// receives from the previous TPU.  This is negligible for FC (n bytes)
/// but large for CONV (`W·H·f` bytes) and is what makes the paper's
/// Table IV spill at f=592 with only ~6.5 MiB of weights.
pub fn place(layers: &[Layer], cfg: &DeviceConfig) -> Placement {
    let mut used = layers.first().map_or(0, |l| l.input_elems());
    let placed = layers
        .iter()
        .map(|l| {
            let fp = layer_footprint(l, cfg);
            let location = if used + fp <= cfg.usable_mem_bytes {
                used += fp;
                Location::Device
            } else {
                Location::Host
            };
            PlacedLayer { layer: *l, location, footprint_bytes: fp }
        })
        .collect();
    Placement { layers: placed }
}

/// Compile report for a whole partition: one placement per TPU/segment.
#[derive(Debug, Clone, PartialEq)]
pub struct CompileReport {
    pub segments: Vec<Placement>,
}

impl CompileReport {
    pub fn total_host_mib(&self) -> f64 {
        self.segments.iter().map(Placement::host_mib).sum()
    }

    pub fn uses_host(&self) -> bool {
        self.segments.iter().any(Placement::uses_host)
    }
}

/// Place each segment of a partition on its own TPU.
pub fn place_partition(segments: &[&[Layer]], cfg: &DeviceConfig) -> CompileReport {
    CompileReport { segments: segments.iter().map(|s| place(s, cfg)).collect() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;
    use crate::model::synthetic::{conv_model, fc_model};

    fn cfg() -> DeviceConfig {
        DeviceConfig::default()
    }

    #[test]
    fn small_model_all_on_device() {
        let m = fc_model(100);
        let p = place(&m.layers, &cfg());
        assert!(!p.uses_host());
        assert_eq!(p.layers.len(), 5);
    }

    /// Table I row 1: n~1580 (0.76e7 MACs) fits, reported ~7.43 MiB device.
    #[test]
    fn table1_pre_spill() {
        let p = place(&fc_model(1580).layers, &cfg());
        assert!(!p.uses_host(), "must fit on device");
        assert!((p.device_mib() - 7.43).abs() < 0.15, "dev={}", p.device_mib());
    }

    /// Table I row 2: n~1620 spills exactly one big layer (~2.63 MiB host).
    #[test]
    fn table1_first_spill() {
        let p = place(&fc_model(1620).layers, &cfg());
        assert!(p.uses_host());
        assert!((p.host_mib() - 2.63).abs() < 0.15, "host={}", p.host_mib());
        assert!((p.device_mib() - 5.27).abs() < 0.2, "dev={}", p.device_mib());
        // the spilled layer is L4 (greedy keeps L1..L3, L5 still fits)
        let locs: Vec<_> = p.layers.iter().map(|l| l.location).collect();
        assert_eq!(
            locs,
            vec![
                Location::Device,
                Location::Device,
                Location::Device,
                Location::Host,
                Location::Device
            ]
        );
    }

    /// Table I row 3: n~1974, device keeps TWO big layers (7.66 MiB),
    /// ONE big layer on host (3.82 MiB).  (Our greedy also parks the tiny
    /// 10n output layer on the host — 0.02 MiB, invisible in the report.)
    #[test]
    fn table1_second_step() {
        let p = place(&fc_model(1980).layers, &cfg());
        let host_big = p
            .layers
            .iter()
            .filter(|l| l.location == Location::Host && l.footprint_bytes > 1_000_000)
            .count();
        assert_eq!(host_big, 1, "exactly one big host layer");
        assert!((p.device_mib() - 7.66).abs() < 0.35, "dev={}", p.device_mib());
        assert!((p.host_mib() - 3.82).abs() < 0.3, "host={}", p.host_mib());
    }

    /// Table I row 4: n~2016, two layers on host (~8.04 MiB), device ~4.04.
    #[test]
    fn table1_third_step() {
        let p = place(&fc_model(2020).layers, &cfg());
        let host = p.layers.iter().filter(|l| l.location == Location::Host).count();
        assert_eq!(host, 2);
        assert!((p.host_mib() - 8.04).abs() < 0.4, "host={}", p.host_mib());
        assert!((p.device_mib() - 4.04).abs() < 0.3, "dev={}", p.device_mib());
    }

    /// Table II row 1: f~442 (2.88e10 MACs) still fits on device (~6.86 MiB).
    #[test]
    fn table2_pre_spill() {
        let p = place(&conv_model(442).layers, &cfg());
        assert!(!p.uses_host());
        assert!((p.device_mib() - 6.86).abs() < 0.2, "dev={}", p.device_mib());
    }

    /// CONV spill begins one step later than FC in relative terms; by
    /// f=492 the model must use host memory (paper: between 2.88e10 and
    /// 3.01e10 MACs; our calibrated capacity puts it within ~8%).
    #[test]
    fn table2_spill_onset_nearby() {
        let spill_f = (442..520)
            .step_by(10)
            .find(|&f| place(&conv_model(f).layers, &cfg()).uses_host());
        let f = spill_f.expect("spill must occur in range");
        let macs = conv_model(f).macs() as f64;
        assert!(
            (macs - 3.01e10).abs() / 3.01e10 < 0.15,
            "spill at f={f}, macs={macs:.3e}"
        );
    }

    #[test]
    fn footprint_exceeding_capacity_goes_host_even_alone() {
        let big = Layer::Fc { in_features: 4000, out_features: 4000 };
        let p = place(&[big], &cfg());
        assert!(p.uses_host());
        assert_eq!(p.device_bytes(), 0);
    }

    #[test]
    fn partition_report_sums() {
        let m = fc_model(2100);
        let segs: Vec<&[Layer]> = vec![&m.layers[..2], &m.layers[2..]];
        let rep = place_partition(&segs, &cfg());
        assert_eq!(rep.segments.len(), 2);
        // segmentation across 2 TPUs reduces host usage vs single TPU
        let single = place(&m.layers, &cfg());
        assert!(rep.total_host_mib() < single.host_mib());
    }

    #[test]
    fn property_placement_never_exceeds_capacity() {
        crate::util::proptest::forall(128, |rng| {
            let c = cfg();
            let nlayers = rng.below(8) as usize + 1;
            let layers: Vec<Layer> = (0..nlayers)
                .map(|_| Layer::Fc {
                    in_features: rng.below(3000) + 1,
                    out_features: rng.below(3000) + 1,
                })
                .collect();
            // fabricate a consistent chain (placement ignores shapes)
            let p = place(&layers, &c);
            let dev: u64 = p
                .layers
                .iter()
                .filter(|l| l.location == Location::Device)
                .map(|l| l.footprint_bytes)
                .sum();
            crate::check!(dev <= c.usable_mem_bytes, "dev={dev}");
            crate::check!(p.layers.len() == nlayers, "len");
            Ok(())
        });
    }
}
