//! Calibration lock-in: re-derive the paper's Tables I–II rows from the
//! cost model and assert we land within tolerance.  If someone retunes a
//! constant in `config`, these tests say which paper row broke.

#[cfg(test)]
mod tests {
    use crate::compiler::place;
    use crate::config::SystemConfig;
    use crate::device::CostModel;
    use crate::model::synthetic::{conv_model, fc_model};

    fn exec_ms(n_or_f: u64, fc: bool) -> f64 {
        let cm = CostModel::new(SystemConfig::default());
        let model = if fc { fc_model(n_or_f) } else { conv_model(n_or_f) };
        let p = place(&model.layers, &cm.cfg.device);
        cm.stage_cost(&p).exec_s() * 1e3
    }

    fn assert_close(got: f64, want: f64, rel_tol: f64, what: &str) {
        let rel = (got - want).abs() / want;
        assert!(rel <= rel_tol, "{what}: got {got:.2} ms, paper {want} ms ({rel:.0?} rel)");
    }

    /// Table I: FC memory/latency before+after each step.
    #[test]
    fn table1_fc_inference_times() {
        // row 1: 0.76e7 MACs (n~1580), all on device: 0.17 ms
        assert_close(exec_ms(1580, true), 0.17, 0.10, "Table I row 1");
        // row 2: 0.79e7 MACs (n~1620), 2.63 MiB host: 7.42 ms
        assert_close(exec_ms(1620, true), 7.42, 0.10, "Table I row 2");
        // row 3: 1.19e7 MACs (n~1980), 3.82 MiB host: 10.62 ms
        assert_close(exec_ms(1980, true), 10.62, 0.10, "Table I row 3");
        // row 4: 1.24e7 MACs (n~2020), 8.04 MiB host: 21.83 ms
        assert_close(exec_ms(2020, true), 21.83, 0.10, "Table I row 4");
    }

    /// Table II: CONV rows.  Step positions land within ~10% in f, so we
    /// compare by placement shape (host-layer count), then time.
    #[test]
    fn table2_conv_inference_times() {
        // row 1: 2.88e10 MACs (f~442), all on device: 41.34 ms
        assert_close(exec_ms(442, false), 41.34, 0.10, "Table II row 1");
        // one-host-layer regime (paper row 2: 61.60 ms at 3.01e10 MACs).
        // our spill onset is f~470 (+8% MACs) -> compare at our onset
        assert_close(exec_ms(480, false), 61.60, 0.25, "Table II row 2");
        // three-host-layers regime (paper row 6: 232.82 ms at 6.08e10)
        assert_close(exec_ms(670, false), 232.82, 0.25, "Table II row 6");
    }

    /// GOPS ratio CONV/FC ~ 17x (paper §III-B).
    #[test]
    fn gops_ratio() {
        let fc_gops = fc_model(1580).macs() as f64 / (exec_ms(1580, true) / 1e3) / 1e9;
        let conv_gops = conv_model(442).macs() as f64 / (exec_ms(442, false) / 1e3) / 1e9;
        let ratio = conv_gops / fc_gops;
        assert!((12.0..22.0).contains(&ratio), "ratio={ratio:.1}");
    }

    /// The FC step delta (~10 ms) dwarfs the CPU time of the slowest FC
    /// model (~3 ms) — §IV's argument for why host memory hurts FC so much.
    #[test]
    fn fc_step_delta_vs_cpu() {
        let cfg = SystemConfig::default();
        let delta_ms = exec_ms(1620, true) - exec_ms(1580, true);
        let cpu_ms = fc_model(2640).macs() as f64 / cfg.cpu.rate_fc * 1e3;
        assert!(delta_ms > 2.0 * cpu_ms, "delta={delta_ms:.2} cpu={cpu_ms:.2}");
    }
}
