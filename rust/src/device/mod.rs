//! Edge TPU cost model: converts a placed segment into per-inference time.
//!
//! Model (DESIGN.md §6):
//!
//! ```text
//! t_exec = max(t_compute, t_dev_stream) + t_host_stream + t_invoke
//!   t_compute     = MACs / mxu_rate            (systolic array)
//!   t_dev_stream  = device-resident weight bytes / dev_weight_bw
//!   t_host_stream = Σ host-resident layer bytes / host_bw(layer kind)
//! ```
//!
//! Compute overlaps the on-chip weight stream (weight-stationary systolic
//! flow); host streaming over PCIe serializes with execution — that
//! non-overlap is exactly the cliff the paper measures (Table I: 0.17 ms ->
//! 7.42 ms the moment 2.63 MiB of weights move to the host).

pub mod calib;

use crate::compiler::{Location, Placement};
use crate::config::SystemConfig;
use crate::model::LayerKind;

/// Per-inference cost breakdown for one segment on one TPU.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageCost {
    pub compute_s: f64,
    pub dev_stream_s: f64,
    pub host_stream_s: f64,
    pub invoke_s: f64,
}

impl StageCost {
    /// Total on-TPU execution time for one inference.
    pub fn exec_s(&self) -> f64 {
        self.compute_s.max(self.dev_stream_s) + self.host_stream_s + self.invoke_s
    }

    /// Attained performance in MAC/s given the segment's MAC count.
    pub fn gops(&self, macs: u64) -> f64 {
        macs as f64 / self.exec_s() / 1e9
    }
}

/// The device cost model, parameterized by the system config.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub cfg: SystemConfig,
}

impl CostModel {
    pub fn new(cfg: SystemConfig) -> Self {
        CostModel { cfg }
    }

    /// Cost of executing a placed segment once.
    pub fn stage_cost(&self, placement: &Placement) -> StageCost {
        let d = &self.cfg.device;
        let macs: u64 = placement.layers.iter().map(|p| p.layer.macs()).sum();
        let dev_bytes = placement.raw_weight_bytes(Location::Device);
        let host_stream_s: f64 = placement
            .layers
            .iter()
            .filter(|p| p.location == Location::Host)
            .map(|p| {
                let bw = match p.layer.kind() {
                    LayerKind::Fc => self.cfg.link.host_weight_bw_fc,
                    LayerKind::Conv => self.cfg.link.host_weight_bw_conv,
                };
                p.layer.weight_bytes() as f64 / bw
            })
            .sum();
        StageCost {
            compute_s: macs as f64 / d.mxu_rate,
            dev_stream_s: dev_bytes as f64 / d.dev_weight_bw,
            host_stream_s,
            invoke_s: d.invoke_overhead_s,
        }
    }

    /// Fraction of theoretical peak attained (roofline position).
    pub fn peak_fraction(&self, placement: &Placement) -> f64 {
        let macs: u64 = placement.layers.iter().map(|p| p.layer.macs()).sum();
        let cost = self.stage_cost(placement);
        (macs as f64 / cost.exec_s()) / self.cfg.device.peak_macs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::place;
    use crate::model::synthetic::{conv_model, fc_model};

    fn model() -> CostModel {
        CostModel::new(SystemConfig::default())
    }

    #[test]
    fn exec_composition() {
        let c = StageCost { compute_s: 2.0, dev_stream_s: 3.0, host_stream_s: 1.0, invoke_s: 0.5 };
        assert_eq!(c.exec_s(), 3.0 + 1.0 + 0.5);
    }

    #[test]
    fn fc_is_weight_stream_bound() {
        let m = model();
        let p = place(&fc_model(1500).layers, &m.cfg.device);
        let c = m.stage_cost(&p);
        assert!(c.dev_stream_s > c.compute_s, "{c:?}");
        assert_eq!(c.host_stream_s, 0.0);
    }

    #[test]
    fn conv_is_compute_bound() {
        let m = model();
        let p = place(&conv_model(400).layers, &m.cfg.device);
        let c = m.stage_cost(&p);
        assert!(c.compute_s > c.dev_stream_s, "{c:?}");
    }

    #[test]
    fn conv_gops_much_higher_than_fc() {
        // paper §III-B: peak CONV GOPS ~17x FC GOPS
        let m = model();
        let fc = place(&fc_model(1580).layers, &m.cfg.device);
        let conv = place(&conv_model(442).layers, &m.cfg.device);
        let fc_gops = m.stage_cost(&fc).gops(fc_model(1580).macs());
        let conv_gops = m.stage_cost(&conv).gops(conv_model(442).macs());
        let ratio = conv_gops / fc_gops;
        assert!((10.0..25.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn host_spill_causes_cliff() {
        let m = model();
        let before = place(&fc_model(1580).layers, &m.cfg.device);
        let after = place(&fc_model(1620).layers, &m.cfg.device);
        let t0 = m.stage_cost(&before).exec_s();
        let t1 = m.stage_cost(&after).exec_s();
        assert!(t1 / t0 > 20.0, "cliff missing: {t0} -> {t1}");
    }

    #[test]
    fn attained_far_below_peak() {
        // paper §III-B: attained performance dramatically below 4 TOPS
        let m = model();
        let p = place(&conv_model(442).layers, &m.cfg.device);
        let frac = m.peak_fraction(&p);
        assert!(frac < 0.5, "frac={frac}");
        assert!(frac > 0.1, "frac={frac}");
    }

    #[test]
    fn property_cost_monotone_in_model_size() {
        crate::util::proptest::forall(64, |rng| {
            let m = model();
            let n1 = 100 + rng.below(1000);
            let n2 = n1 + 40 + rng.below(1000);
            let p1 = place(&fc_model(n1).layers, &m.cfg.device);
            let p2 = place(&fc_model(n2).layers, &m.cfg.device);
            // same host-layer count => strictly more time for bigger model
            let h1 = p1.layers.iter().filter(|l| l.location == Location::Host).count();
            let h2 = p2.layers.iter().filter(|l| l.location == Location::Host).count();
            if h1 == h2 {
                crate::check!(
                    m.stage_cost(&p2).exec_s() >= m.stage_cost(&p1).exec_s(),
                    "n1={n1} n2={n2}"
                );
            }
            Ok(())
        });
    }
}
