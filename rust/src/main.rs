//! `repro` — the leader binary: paper-reproduction harness + serving
//! entrypoints.  Run `repro help` for the command list.

use anyhow::Result;

use tpu_pipeline::cli::{self, Args};
use tpu_pipeline::config::SystemConfig;
use tpu_pipeline::model::synthetic::{conv_model, fc_model};
use tpu_pipeline::pipeline::{simulate_partition, SimOptions};
use tpu_pipeline::serving;
use tpu_pipeline::sweep::Kind;
use tpu_pipeline::trace;
use tpu_pipeline::util::fmt_seconds;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", cli::USAGE);
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "serve" => cmd_serve(&args),
        "serve-pool" => cmd_serve_pool(&args),
        "loadgen" => cmd_loadgen(&args),
        "gantt" => cmd_gantt(&args),
        _ => cli::run(&args).map(|out| print!("{out}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `repro serve`: pipelined serving of a real artifact model over PJRT.
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let artifact_dir = std::path::PathBuf::from(
        args.str_flag("artifacts", serving::default_artifact_dir().to_str().unwrap()),
    );
    let model_name = args.str_flag("model", "fc_n256");
    let n_tpus = args.usize_flag("tpus", 3)?;
    let batch = args.batch()?;
    let strategy = args.strategy()?;

    let manifest = serving::load_manifest(&artifact_dir)?;
    let entry = manifest.model(&model_name)?;
    let plan = serving::plan(entry, n_tpus, strategy, &cfg)?;
    println!(
        "model {} on {} simulated TPUs, split {} ({})",
        model_name,
        n_tpus,
        plan.partition.label(),
        strategy.name()
    );

    // data-parallel deployment: N full pipeline copies behind the
    // round-robin ReplicaRouter (the paper's §V-C alternative)
    let replicas = args.usize_flag("replicas", 1)?;
    if replicas > 1 {
        let router =
            serving::spawn_replicated_pipeline(&artifact_dir, entry, &plan, replicas, 64)?;
        for p in &router.replicas {
            p.wait_ready()?;
        }
        let requests = serving::synth_requests(&plan, batch, 0xC0FFEE);
        let t0 = std::time::Instant::now();
        let responses = router.serve_batch(requests)?;
        let wall = t0.elapsed().as_secs_f64();
        let sim_makespan = responses.iter().map(|r| r.sim_done_s).fold(0.0, f64::max);
        println!("batch {} served over {replicas} replicas:", responses.len());
        println!("  real wall (PJRT CPU):  {}", fmt_seconds(wall));
        println!("  real throughput:       {:.0} inf/s", responses.len() as f64 / wall);
        println!("  sim makespan (per-replica clock): {}", fmt_seconds(sim_makespan));
        router.shutdown();
        return Ok(());
    }

    let pipeline = serving::spawn_pipeline(&artifact_dir, entry, &plan, 64)?;
    let requests = serving::synth_requests(&plan, batch, 0xC0FFEE);
    let report = serving::serve_batch(&pipeline, &plan, requests)?;

    println!("batch {} served:", report.batch);
    println!("  real wall (PJRT CPU):  {}", fmt_seconds(report.wall_s));
    println!("  real throughput:       {:.0} inf/s", report.real_throughput);
    println!("  sim Edge TPU makespan: {}", fmt_seconds(report.sim_makespan_s));
    println!("  sim per-inference:     {}", fmt_seconds(report.sim_per_item_s));
    println!("  sim single-TPU baseline: {}", fmt_seconds(plan.single_tpu_s));
    println!("  sim speedup vs 1 TPU:  {:.1}x", report.sim_speedup_vs_one_tpu);
    for (i, sm) in pipeline.stage_metrics.iter().enumerate() {
        let s = sm.snapshot();
        println!(
            "  stage {i}: {} items, mean exec {} (real)",
            s.items,
            fmt_seconds(s.mean_exec_s)
        );
    }
    pipeline.shutdown();
    Ok(())
}

/// `repro serve-pool`: schedule a multi-tenant pool, deploy one pipeline
/// (or replica set) per admitted model, and serve synthetic traffic for
/// every tenant concurrently through the per-model router.
///
/// Stages run on the deterministic native backend, so this works without
/// artifacts; responses are verified against each tenant's serial
/// reference.
fn cmd_serve_pool(args: &Args) -> Result<()> {
    use anyhow::Context;
    use std::sync::Arc;
    use tpu_pipeline::obs::{metric_line_from, MetricSource, TraceFile, Tracer};
    use tpu_pipeline::report;
    use tpu_pipeline::scheduler::{allocate, plan_table, BackendKind, DeployOptions, PoolRouter};
    use tpu_pipeline::util::json::Json;

    let cfg = args.config()?;
    let batch = args.batch()?;
    // same flag grammar as `repro schedule` (incl. --weights / --slo-ms),
    // so the deployed plan always matches the one `schedule` prints
    let (registry, alloc) = cli::pool_spec(args, "fc_big,fc_small")?;
    let plan = allocate(&registry, &cfg, &alloc)?;
    print!("{}", plan_table(&plan).render());

    let tracer: Option<Arc<Tracer>> =
        args.flags.contains_key("trace-out").then(|| Arc::new(Tracer::new()));
    let mut opts = DeployOptions::new().with_queue_capacity(64);
    if let Some(t) = tracer.clone() {
        opts = opts.with_tracer(t);
    }
    let router = PoolRouter::deploy(&plan, &registry, &cfg, &BackendKind::Synthetic, opts)?;
    let reports = serving::serve_pool(&router, batch, 0xC0FFEE, true)?;
    println!("\nserved {} tenant(s) x {batch} requests concurrently:", reports.len());
    for r in &reports {
        println!(
            "  {:10} {} TPU(s) x{} [{}] ({}): wall {} | {:>6.0} inf/s | sim p99 {} \
             (predicted {}) | verified {}",
            r.name,
            r.tpu_count,
            r.replicas,
            r.partition_label,
            r.grant_label,
            fmt_seconds(r.wall_s),
            r.real_throughput,
            fmt_seconds(r.sim_p99_s),
            fmt_seconds(r.predicted_p99_s),
            r.verified,
        );
    }
    // end-of-run metrics: one MetricSource snapshot pass feeds both the
    // human table and the optional --metrics-out JSONL (identical fields)
    let mut metrics: Vec<(String, String, Json)> = Vec::new();
    for t in router.tenants() {
        let src = &*t.metrics;
        metrics.push((src.metric_kind().to_string(), t.name.clone(), src.metric_json()));
    }
    let sched = &*router.metrics;
    metrics.push((sched.metric_kind().to_string(), "pool".to_string(), sched.metric_json()));
    let dp = &*router.data_plane;
    metrics.push((dp.metric_kind().to_string(), "pool".to_string(), dp.metric_json()));
    print!("{}", report::metrics_table(&metrics).render());
    if let Some(path) = args.flags.get("metrics-out") {
        let jsonl: String =
            metrics.iter().map(|(k, n, j)| metric_line_from(k, n, j.clone())).collect();
        std::fs::write(path, jsonl)
            .with_context(|| format!("writing --metrics-out {path:?}"))?;
    }
    router.shutdown();
    // drain the tracer after shutdown: all stage workers have joined, so
    // every recorded span is visible
    if let (Some(path), Some(tr)) = (args.flags.get("trace-out"), &tracer) {
        std::fs::write(path, TraceFile::from_tracer("repro serve-pool", tr).to_json())
            .with_context(|| format!("writing --trace-out {path:?}"))?;
    }
    Ok(())
}

/// Parse a `--join MODEL@T_S` / `--leave MODEL@T_S` churn flag.
fn churn_flag(args: &Args, key: &str) -> Result<Option<(String, f64)>> {
    let Some(spec) = args.flags.get(key) else { return Ok(None) };
    let (model, at) = spec
        .split_once('@')
        .ok_or_else(|| anyhow::anyhow!("--{key} wants MODEL@T_S, got {spec:?}"))?;
    let at_s: f64 = at
        .parse()
        .map_err(|_| anyhow::anyhow!("bad time {at:?} in --{key} {spec:?}"))?;
    anyhow::ensure!(at_s >= 0.0, "--{key} time must be non-negative");
    Ok(Some((model.to_string(), at_s)))
}

/// `repro loadgen`: seeded open-loop load generation.
///
/// Prints the deterministic per-tenant table (same `--seed` renders the
/// bit-identical table — the queueing numbers come from the seeded
/// open-loop simulation, not from wall clocks), then drives the *same*
/// seeds against a live open-loop `ServingPool`: per-tenant ingress
/// queues + dynamic batchers, responses verified bit-for-bit against the
/// serial reference.  `--join`/`--leave` register/deregister a tenant
/// mid-run to exercise online re-planning with drain.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use tpu_pipeline::scheduler::{
        resolve_model, BackendKind, DeployOptions, ServingPool, Tenant,
    };
    use tpu_pipeline::util::fmt_seconds;
    use tpu_pipeline::workload::TenantLoad;

    // parse the spec and plan once: the printed table, the skip decisions
    // below and the deployed pool all come from the same inputs.  CSV mode
    // prints only the reproducible table, so two runs of one seed diff
    // clean
    let cfg = args.config()?;
    let (registry, alloc, spec) = cli::loadgen_spec(args)?;
    let (table, plan, obs) = cli::loadgen_table_obs(&registry, &cfg, &alloc, &spec)?;
    // exports come from the deterministic simulation, so they are written
    // before any live serving (and in --csv mode too): two runs of one
    // seed produce byte-identical files — `make smoke-trace` diffs them
    cli::write_loadgen_exports(args, &obs)?;
    // --calibrate appends the deterministic calibration report after the
    // unchanged loadgen output (flag off: byte-identical to before)
    let calibration = cli::loadgen_calibration(args, &registry, &cfg, &alloc, &spec)?;
    if args.csv() {
        print!("{}", table.csv());
        if let Some(report) = calibration {
            print!("{report}");
        }
        return Ok(());
    }
    print!("{}", table.render());
    print!("{}", cli::loadgen_summary(&plan));
    if let Some(report) = calibration {
        print!("{report}");
    }
    if args.bool_flag("no-live") {
        return Ok(());
    }

    let join = churn_flag(args, "join")?;
    let leave = churn_flag(args, "leave")?;

    // only admitted tenants have a live deployment to drive; queued or
    // rejected ones already show their status in the table above
    let live_loads: Vec<TenantLoad> = spec
        .loads
        .iter()
        .filter(|l| plan.assignment(&l.model).is_some())
        .cloned()
        .collect();
    for l in &spec.loads {
        if plan.assignment(&l.model).is_none() {
            println!("  (skipping {:?} in the live run: not admitted)", l.model);
        }
    }
    if live_loads.is_empty() && join.is_none() {
        println!("  no admitted tenants — nothing to serve live");
        return Ok(());
    }

    let pool = ServingPool::deploy(
        registry,
        cfg,
        alloc,
        BackendKind::Synthetic,
        DeployOptions { policy: spec.policy, queue_capacity: 64, ..Default::default() },
    )?;
    println!("\nlive open-loop run (synthetic backend, bit-exact verification):");

    let mut reports = Vec::new();
    std::thread::scope(|scope| -> Result<()> {
        let driver = {
            let pool = &pool;
            let loads = &live_loads;
            scope.spawn(move || serving::serve_open_loop(pool, loads, spec.seed, true))
        };
        if let Some((model, at_s)) = join {
            let pool = &pool;
            scope.spawn(move || {
                std::thread::sleep(std::time::Duration::from_secs_f64(at_s));
                match resolve_model(&model)
                    .and_then(|m| pool.register(Tenant::new(model.clone(), m)))
                {
                    Ok(report) => println!(
                        "  [t={at_s}s] registered {model:?}: re-plan drained {} \
                         deployment(s), admitted {:?}",
                        report.drained, report.admitted
                    ),
                    Err(e) => println!("  [t={at_s}s] register {model:?} failed: {e:#}"),
                }
            });
        }
        if let Some((model, at_s)) = leave {
            let pool = &pool;
            scope.spawn(move || {
                std::thread::sleep(std::time::Duration::from_secs_f64(at_s));
                match pool.deregister(&model) {
                    Ok(report) => println!(
                        "  [t={at_s}s] deregistered {model:?}: re-plan drained {} \
                         deployment(s), admitted {:?}",
                        report.drained, report.admitted
                    ),
                    Err(e) => println!("  [t={at_s}s] deregister {model:?} failed: {e:#}"),
                }
            });
        }
        reports = driver.join().expect("open-loop driver panicked")?;
        Ok(())
    })?;

    for r in &reports {
        println!(
            "  {:10} {:18} submitted {:4} completed {:4} verified {} | wall {}",
            r.name,
            r.arrivals,
            r.submitted,
            r.completed,
            r.verified,
            fmt_seconds(r.wall_s),
        );
    }
    for name in pool.names() {
        if let Some(m) = pool.tenant_metrics(&name) {
            let s = m.snapshot();
            // cache counters only exist on cache-enabled deployments;
            // cache-off runs print today's line byte-for-byte
            let cache = if s.cache_hits + s.cache_misses > 0 {
                format!(
                    " | cache hits {} misses {} prefetches {}",
                    s.cache_hits, s.cache_misses, s.prefetches
                )
            } else {
                String::new()
            };
            println!(
                "  {:10} batches {} (size {} / deadline {} / closed {}) mean batch {:.1} \
                 max queue depth {} | swaps {} (skipped {}, overhead {}){} | real p50 {} p99 {}",
                name,
                s.batches,
                s.flush_size,
                s.flush_deadline,
                s.flush_closed,
                s.mean_batch,
                s.max_queue_depth,
                s.swaps,
                s.swaps_skipped,
                fmt_seconds(s.swap_overhead_s),
                cache,
                fmt_seconds(s.real_p50_s),
                fmt_seconds(s.real_p99_s),
            );
        }
    }
    let s = pool.metrics.snapshot();
    println!(
        "  scheduler: admitted {} ({} shared) queued {} rejected {} | routed {} requests | \
         re-plans {} (drained {} deployments)",
        s.admitted,
        s.shared,
        s.queued,
        s.rejected,
        s.routed_requests,
        s.replans,
        s.drained_deployments
    );
    pool.shutdown();
    Ok(())
}

/// `repro gantt`: ASCII pipeline schedule for a simulated configuration.
fn cmd_gantt(args: &Args) -> Result<()> {
    let cfg: SystemConfig = args.config()?;
    let kind = args.kind()?;
    let x = args.usize_flag("x", 2100)? as u64;
    let n_tpus = args.usize_flag("tpus", 3)?;
    let batch = args.usize_flag("batch", 8)?;
    let model = match kind {
        Kind::Fc => fc_model(x),
        Kind::Conv => conv_model(x),
    };
    let strategy = args.strategy()?;
    let part = if n_tpus == 1 {
        tpu_pipeline::segment::Partition::whole(model.len())
    } else {
        strategy.partition(&model, n_tpus, &cfg)
    };
    let result = simulate_partition(
        &model,
        &part,
        &cfg,
        &SimOptions { batch, queue_capacity: None, record_gantt: true },
    );
    println!(
        "{} split {} over {n_tpus} TPUs, batch {batch} (strategy {}):",
        model.name,
        part.label(),
        strategy.name()
    );
    print!("{}", trace::gantt_ascii(&result, 100));
    println!(
        "makespan {} | per-item {} | bottleneck stage {}",
        fmt_seconds(result.makespan_s),
        fmt_seconds(result.makespan_s / batch as f64),
        result.bottleneck()
    );
    Ok(())
}
