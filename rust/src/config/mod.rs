//! Configuration system: every calibrated constant of the simulated testbed
//! in one place, loadable from a JSON config file with per-field overrides.
//!
//! Defaults are calibrated against the paper's own Tables I–II (DESIGN.md
//! §6): invert the reported (device MiB, host MiB, inference ms) rows to
//! recover effective rates, then check the sweep reproduces the cliffs.

use std::path::Path;

use crate::util::json::Json;

/// Edge TPU device model constants.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceConfig {
    /// Total on-chip memory (datasheet: 8 MiB).
    pub total_mem_bytes: u64,
    /// Usable for weights after runtime/instruction reserve (calibrated so
    /// the first FC spill lands between n=1580 and n=1620 AND the n~1980
    /// placement keeps two big layers on-device, per Table I rows 1–3;
    /// feasible window is [8191284, 8209070) bytes, ~7.82 MiB).
    pub usable_mem_bytes: u64,
    /// Per-layer storage overhead ratio (compiler metadata; Table I row 1:
    /// 7.25 MiB of raw weights reported as 7.43 MiB device usage).
    pub footprint_ratio: f64,
    /// Fixed per-layer bytes (instructions etc.).
    pub per_layer_fixed_bytes: u64,
    /// Effective MXU rate, MACs/s (CONV pre-spill: 2.88e10 MACs / 41.34 ms).
    pub mxu_rate: f64,
    /// Effective on-chip weight-stream bandwidth, B/s (FC pre-spill:
    /// 7.6e6 B / (0.17 ms - invoke overhead)).
    pub dev_weight_bw: f64,
    /// Per-invocation overhead, s (dispatch + driver).
    pub invoke_overhead_s: f64,
    /// Theoretical peak, MACs/s (datasheet 4 TOPS = 2e12 MACs/s).
    pub peak_macs: f64,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig {
            total_mem_bytes: 8 * 1024 * 1024,
            usable_mem_bytes: 8_200_000,
            footprint_ratio: 1.025,
            per_layer_fixed_bytes: 8 * 1024,
            mxu_rate: 697e9,
            dev_weight_bw: 63e9,
            invoke_overhead_s: 50e-6,
            peak_macs: 2e12,
        }
    }
}

/// PCIe link + host-memory streaming constants.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkConfig {
    /// Host->device weight streaming for FC layers, B/s (Table I deltas).
    pub host_weight_bw_fc: f64,
    /// Same for CONV layers, B/s.  Lower effective rate: conv weight tiles
    /// are re-streamed across spatial passes (Table II deltas give
    /// 80–170 MB/s; we use the fitted midpoint).
    pub host_weight_bw_conv: f64,
    /// Activation/intermediate-tensor DMA bandwidth, B/s.  The DMA
    /// occupies the TPU (no compute/transfer overlap on this device), so
    /// it enters the pipeline stage service time — this is why CONV
    /// segmentation is a net loss for small models even batched (§V-B).
    pub act_bw: f64,
    /// Fixed per-hop latency through the host queue, s.
    pub hop_latency_s: f64,
    /// Per-item per-stage host overhead: Python worker thread wakeup +
    /// queue handoff + invocation.  The paper's stages are Python
    /// *threads*, so this work is GIL-SERIALIZED across all stages — the
    /// pipeline can never exceed one item per `n_stages * stage_overhead`
    /// (modeled as a shared host server in `pipeline::simulate`).
    /// Calibrated so §V-B/V-C speedups land at the paper's magnitudes
    /// (~36x FC default / 46x FC profiled / ~6x CONV profiled).
    pub stage_overhead_s: f64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        LinkConfig {
            host_weight_bw_fc: 370e6,
            host_weight_bw_conv: 110e6,
            act_bw: 320e6,
            hop_latency_s: 150e-6,
            stage_overhead_s: 280e-6,
        }
    }
}

/// Host CPU baseline (Fig 2c).
#[derive(Debug, Clone, PartialEq)]
pub struct CpuConfig {
    /// Effective int8 MAC rate for FC on the host CPU, MACs/s
    /// (paper: slowest FC models ~3 ms on a high-end CPU).
    pub rate_fc: f64,
    /// Same for CONV (better cache reuse).
    pub rate_conv: f64,
    /// Per-inference overhead, s.
    pub overhead_s: f64,
}

impl Default for CpuConfig {
    fn default() -> Self {
        CpuConfig { rate_fc: 7e9, rate_conv: 30e9, overhead_s: 200e-6 }
    }
}

/// Whole-system configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SystemConfig {
    pub device: DeviceConfig,
    pub link: LinkConfig,
    pub cpu: CpuConfig,
}

impl SystemConfig {
    /// Load from a JSON file; any subset of fields may be present, the rest
    /// keep their calibrated defaults.
    pub fn from_file(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        Ok(Self::from_json(&json))
    }

    pub fn from_json(j: &Json) -> Self {
        let mut cfg = SystemConfig::default();
        let f = |j: &Json, sect: &str, key: &str, dst: &mut f64| {
            if let Some(v) = j.at(&[sect, key]).and_then(Json::as_f64) {
                *dst = v;
            }
        };
        let u = |j: &Json, sect: &str, key: &str, dst: &mut u64| {
            if let Some(v) = j.at(&[sect, key]).and_then(Json::as_u64) {
                *dst = v;
            }
        };
        u(j, "device", "total_mem_bytes", &mut cfg.device.total_mem_bytes);
        u(j, "device", "usable_mem_bytes", &mut cfg.device.usable_mem_bytes);
        f(j, "device", "footprint_ratio", &mut cfg.device.footprint_ratio);
        u(j, "device", "per_layer_fixed_bytes", &mut cfg.device.per_layer_fixed_bytes);
        f(j, "device", "mxu_rate", &mut cfg.device.mxu_rate);
        f(j, "device", "dev_weight_bw", &mut cfg.device.dev_weight_bw);
        f(j, "device", "invoke_overhead_s", &mut cfg.device.invoke_overhead_s);
        f(j, "device", "peak_macs", &mut cfg.device.peak_macs);
        f(j, "link", "host_weight_bw_fc", &mut cfg.link.host_weight_bw_fc);
        f(j, "link", "host_weight_bw_conv", &mut cfg.link.host_weight_bw_conv);
        f(j, "link", "act_bw", &mut cfg.link.act_bw);
        f(j, "link", "hop_latency_s", &mut cfg.link.hop_latency_s);
        f(j, "link", "stage_overhead_s", &mut cfg.link.stage_overhead_s);
        f(j, "cpu", "rate_fc", &mut cfg.cpu.rate_fc);
        f(j, "cpu", "rate_conv", &mut cfg.cpu.rate_conv);
        f(j, "cpu", "overhead_s", &mut cfg.cpu.overhead_s);
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_calibrated() {
        let c = SystemConfig::default();
        // FC pre-spill: 7.6e6 weight bytes + invoke overhead ~ 0.17 ms
        let t = 7.6e6 / c.device.dev_weight_bw + c.device.invoke_overhead_s;
        assert!((t - 0.17e-3).abs() < 0.02e-3, "t={t}");
        // CONV pre-spill: 2.88e10 MACs at MXU rate ~ 41.3 ms
        let t = 2.88e10 / c.device.mxu_rate;
        assert!((t - 41.3e-3).abs() < 1e-3, "t={t}");
    }

    #[test]
    fn overrides_apply() {
        let j = Json::parse(
            r#"{"device": {"mxu_rate": 1e12, "usable_mem_bytes": 1000000},
                "link": {"hop_latency_s": 0.001},
                "cpu": {"rate_fc": 1e9}}"#,
        )
        .unwrap();
        let c = SystemConfig::from_json(&j);
        assert_eq!(c.device.mxu_rate, 1e12);
        assert_eq!(c.device.usable_mem_bytes, 1_000_000);
        assert_eq!(c.link.hop_latency_s, 0.001);
        assert_eq!(c.cpu.rate_fc, 1e9);
        // untouched fields keep defaults
        assert_eq!(c.device.total_mem_bytes, 8 * 1024 * 1024);
        assert_eq!(c.link.act_bw, 320e6);
    }

    #[test]
    fn from_file_roundtrip() {
        let dir = std::env::temp_dir().join("tpu_pipeline_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"device": {"invoke_overhead_s": 1e-4}}"#).unwrap();
        let c = SystemConfig::from_file(&p).unwrap();
        assert_eq!(c.device.invoke_overhead_s, 1e-4);
    }
}
