//! Seeded fault injection: reproducible device-death, straggler-delay and
//! overload-spike schedules, plus a deterministic chaos queueing sim.
//!
//! A [`FaultPlan`] is a pure function of `(seed, spec, pool shape)`: the
//! same inputs always generate the identical event list, on every
//! platform (the PRNG is the in-repo xoshiro256++, salted so the fault
//! stream never aliases the arrival or payload streams).  The same plan
//! drives both halves of `repro chaos`:
//!
//! * **sim mode** — [`simulate_chaos`] replays the plan against a
//!   deterministic replicated-server model of one tenant's deployment
//!   (kills force drained work onto survivors, stragglers trigger hedged
//!   duplicates, overload spikes force priority-tiered shedding) and
//!   yields bit-reproducible counters and latency percentiles;
//! * **live mode** — the CLI walks the same events against a real
//!   [`ServingPool`](crate::scheduler::ServingPool): `DeviceKill` becomes
//!   `kill_device` (re-plan + drain replay), `Straggler` becomes an
//!   injected replica delay (hedged dispatch in the `ReplicaRouter`), and
//!   `OverloadSpike` becomes a tiered submit burst (admission shedding).
//!
//! The fault *model* is intentionally coarse — events fire at plan time
//! regardless of what the pool is doing — because the point is coverage
//! of the reaction paths, not failure realism (DESIGN.md §14).

use std::collections::VecDeque;

use crate::coordinator::StageSim;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::workload::{arrival_times, Arrivals, DeploymentSim};

/// Salt separating the fault-schedule PRNG stream from the arrival
/// (`ARRIVAL_STREAM_SALT`) and request-payload streams.
pub const CHAOS_STREAM_SALT: u64 = 0xC4A0_5F17_0D1E_FEED;

/// One kind of injected fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// A TPU device dies: the pool must re-plan around it and replay the
    /// drained in-flight work on the survivors.
    DeviceKill {
        /// Device index in `0..total_tpus`.
        device: usize,
    },
    /// One replica slows down by `factor` for `duration_s` seconds —
    /// the hedging trigger.
    Straggler {
        /// Replica ordinal in `0..replicas`.
        replica: usize,
        /// Service-time multiplier while the window is open (> 1).
        factor: f64,
        /// Window length in seconds.
        duration_s: f64,
    },
    /// Offered load multiplies by `rate_mult` for `duration_s` seconds —
    /// the shedding trigger.
    OverloadSpike {
        /// Arrival-rate multiplier while the window is open (> 1).
        rate_mult: f64,
        /// Window length in seconds.
        duration_s: f64,
    },
    /// The control plane crashes and warm-restarts from its recovery
    /// journal after `outage_s` seconds (DESIGN.md §17).  During the
    /// outage no new submission is admitted (the ingress is gone — they
    /// are turned away, counted as shed) and dispatch pauses: every
    /// surviving replica resumes, on the *same* plan, once the restart
    /// completes.
    CrashRestart {
        /// Control-plane downtime in seconds.
        outage_s: f64,
    },
}

impl FaultKind {
    /// Stable label for tables / CSV
    /// (`kill` / `straggler` / `overload` / `crash`).
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::DeviceKill { .. } => "kill",
            FaultKind::Straggler { .. } => "straggler",
            FaultKind::OverloadSpike { .. } => "overload",
            FaultKind::CrashRestart { .. } => "crash",
        }
    }

    /// Tie-break ordering for events sharing one timestamp.
    fn code(&self) -> u8 {
        match self {
            FaultKind::DeviceKill { .. } => 0,
            FaultKind::Straggler { .. } => 1,
            FaultKind::OverloadSpike { .. } => 2,
            FaultKind::CrashRestart { .. } => 3,
        }
    }
}

/// One scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Injection instant, seconds from run start.
    pub t_s: f64,
    pub kind: FaultKind,
}

/// How many of each fault to draw, and over what horizon.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Scheduling horizon: every event lands inside `[0, horizon_s)`.
    pub horizon_s: f64,
    /// Device deaths to schedule (skipped when the pool has no devices).
    pub kills: usize,
    /// Straggler windows to schedule (skipped without replicas).
    pub stragglers: usize,
    /// Overload spikes to schedule.
    pub overloads: usize,
    /// Control-plane crash/restart drills to schedule (DESIGN.md §17).
    /// Defaults to 0, and crash draws come *after* every other kind, so
    /// crash-free plans are byte-identical to plans generated before the
    /// kind existed.
    pub crashes: usize,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec { horizon_s: 1.0, kills: 1, stragglers: 1, overloads: 1, crashes: 0 }
    }
}

/// A reproducible fault schedule: [`FaultPlan::generate`] with the same
/// `(seed, spec, devices, replicas)` always yields the identical plan.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// The user-facing seed the schedule was drawn from.
    pub seed: u64,
    /// Events sorted by `(t_s, kind)`.
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Draw a fault schedule.  Draw order is fixed (kills, then
    /// stragglers, then overloads) so the PRNG stream — and therefore the
    /// plan — is a pure function of the arguments.
    pub fn generate(seed: u64, spec: &FaultSpec, devices: usize, replicas: usize) -> FaultPlan {
        let mut rng = Rng::new(seed ^ CHAOS_STREAM_SALT);
        let h = spec.horizon_s.max(f64::MIN_POSITIVE);
        let mut events = Vec::new();
        for _ in 0..spec.kills {
            // mid-run, so there is always in-flight work to drain
            let t_s = rng.f64_range(0.25, 0.75) * h;
            if devices > 0 {
                let device = rng.below(devices as u64) as usize;
                events.push(FaultEvent { t_s, kind: FaultKind::DeviceKill { device } });
            }
        }
        for _ in 0..spec.stragglers {
            let t_s = rng.f64_range(0.1, 0.6) * h;
            let factor = rng.f64_range(3.0, 8.0);
            let duration_s = rng.f64_range(0.15, 0.35) * h;
            if replicas > 0 {
                let replica = rng.below(replicas as u64) as usize;
                events.push(FaultEvent {
                    t_s,
                    kind: FaultKind::Straggler { replica, factor, duration_s },
                });
            }
        }
        for _ in 0..spec.overloads {
            let t_s = rng.f64_range(0.1, 0.5) * h;
            let rate_mult = rng.f64_range(2.0, 5.0);
            let duration_s = rng.f64_range(0.05, 0.2) * h;
            events.push(FaultEvent { t_s, kind: FaultKind::OverloadSpike { rate_mult, duration_s } });
        }
        // crashes draw LAST: a crash-free spec consumes exactly the same
        // PRNG stream as before the kind existed (seeded goldens hold)
        for _ in 0..spec.crashes {
            let t_s = rng.f64_range(0.3, 0.7) * h;
            let outage_s = rng.f64_range(0.05, 0.15) * h;
            events.push(FaultEvent { t_s, kind: FaultKind::CrashRestart { outage_s } });
        }
        events.sort_by(|a, b| {
            a.t_s
                .partial_cmp(&b.t_s)
                .expect("fault times are finite")
                .then(a.kind.code().cmp(&b.kind.code()))
        });
        FaultPlan { seed, events }
    }

    /// Count of events of the given label
    /// (`kill`/`straggler`/`overload`/`crash`).
    pub fn count(&self, label: &str) -> usize {
        self.events.iter().filter(|e| e.kind.label() == label).count()
    }
}

/// Knobs of the deterministic chaos sim (mirrors the live pool's
/// admission/hedging defaults so sim and live exercise the same policy).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosConfig {
    /// Ingress queue capacity the shed thresholds are fractions of.
    pub queue_capacity: usize,
    /// Seconds a killed replica's drained work waits before replaying on
    /// the survivors (models the drain/redeploy pause).
    pub drain_s: f64,
    /// When false, stragglers slow requests down but nothing hedges.
    pub hedge: bool,
    /// Relative deadline per request: a request whose dispatch would start
    /// more than this many seconds after its arrival expires instead of
    /// occupying a server — the sim analogue of the live pool's
    /// flush-time deadline shed (DESIGN.md §17).  `None` (the default)
    /// disables expiry and keeps deadline-free runs byte-identical.
    pub deadline_s: Option<f64>,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig { queue_capacity: 64, drain_s: 2e-3, hedge: true, deadline_s: None }
    }
}

/// Number of priority tiers the shedding policy distinguishes.
pub const SHED_TIERS: u8 = 3;

/// Deterministic priority tier for request `id`: round-robin over
/// `0..SHED_TIERS`, so every tier sees the same arrival process.  Tier 0
/// is never shed; the live `submit_with_priority` uses the same policy.
pub fn priority_tier(id: usize) -> u8 {
    (id % SHED_TIERS as usize) as u8
}

/// Backlog ceiling for a tier, as a fraction of queue capacity: tier 0 is
/// unsheddable, tier 1 sheds at 3/4 occupancy, tier 2 at 1/2 — lower
/// tiers are turned away *before* the backlog can breach anyone's SLO.
pub fn shed_threshold(tier: u8, queue_capacity: usize) -> usize {
    match tier {
        0 => usize::MAX,
        1 => (queue_capacity * 3) / 4,
        _ => queue_capacity / 2,
    }
}

/// Outcome of one [`simulate_chaos`] run.  `submitted == admitted + shed`
/// and `completed == admitted - expired` always hold — equivalently
/// `submitted == completed + shed + expired`: every offered request gets
/// exactly one verdict (served, turned away, or expired), none is lost
/// silently — the accounting invariant the live chaos smoke enforces
/// bit-exactly.  Without deadlines `expired == 0` and the pre-§17
/// `completed == admitted` form still holds.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosRun {
    /// Total requests offered (base schedule + overload extras).
    pub submitted: usize,
    /// Requests past admission.
    pub admitted: usize,
    /// Requests turned away — by tiered shedding, or at the door while
    /// the control plane was down during a crash outage.
    pub shed: usize,
    /// Requests completed (== admitted - expired).
    pub completed: usize,
    /// Admitted requests that expired past their deadline before their
    /// dispatch could start (0 unless [`ChaosConfig::deadline_s`] is set).
    pub expired: usize,
    /// Dispatches replayed onto survivors after a device kill.
    pub replayed: usize,
    /// Requests duplicated onto a healthy replica by hedged dispatch.
    pub hedged: usize,
    /// Device kills that actually removed a replica.
    pub kills: usize,
    /// Control-plane crash/restart cycles the run survived.
    pub recoveries: usize,
    /// Final per-request latency (offered instant to completion, across
    /// any kill replays), ordered by request id.
    pub latencies_s: Vec<f64>,
    /// Completion time of the last request.
    pub makespan_s: f64,
}

impl ChaosRun {
    fn summary(&self) -> Summary {
        let mut s = Summary::new();
        for &v in &self.latencies_s {
            s.add(v);
        }
        s
    }

    /// Exact nearest-rank p50 over the final latencies.
    pub fn p50_s(&self) -> f64 {
        self.summary().p50()
    }

    /// Exact nearest-rank p99 over the final latencies.
    pub fn p99_s(&self) -> f64 {
        self.summary().p99()
    }
}

/// Per-item service model of one replica: the pipeline's end-to-end
/// traversal (latency) and its bottleneck stage (server occupancy — the
/// steady-state spacing between completions of a full pipeline).
fn service_model(sims: &[StageSim]) -> (f64, f64) {
    let latency: f64 = sims.iter().map(|s| s.overhead_s + s.exec_s + s.hop_out_s).sum();
    let bottleneck = sims
        .iter()
        .map(|s| s.overhead_s + s.exec_s)
        .fold(0.0f64, f64::max)
        .max(f64::MIN_POSITIVE);
    (latency, bottleneck)
}

/// A replica server in the chaos sim.
#[derive(Debug, Clone, Copy)]
struct Replica {
    alive: bool,
    free_t: f64,
    slow_until: f64,
    slow_factor: f64,
}

impl Replica {
    fn slowdown(&self, at_s: f64) -> f64 {
        if at_s < self.slow_until {
            self.slow_factor
        } else {
            1.0
        }
    }
}

/// One queued submission.  `arrival_s` is the original offered instant —
/// latency is measured from it even across a kill replay — and `replay`
/// marks drained work, which skips admission (it was already admitted).
#[derive(Debug, Clone, Copy)]
struct Item {
    t_s: f64,
    id: usize,
    arrival_s: f64,
    replay: bool,
}

/// Deterministic chaos queueing sim: seeded open arrivals (plus overload
/// extras) against `dep.replicas` replicated servers, reacting to the
/// fault plan with kill-drain-replay, hedged dispatch and tiered
/// shedding.  Pure function of its arguments — same inputs, bit-identical
/// [`ChaosRun`] — which is what makes the `repro chaos` CSV a golden
/// artifact.
///
/// Device kills map onto replicas as `device % replicas` (the sim models
/// one tenant; the live pool re-plans the real device set instead).  A
/// kill that would remove the last live replica is ignored, mirroring the
/// live allocator queueing the tenant rather than serving on nothing.
///
/// # Panics
/// On [`Arrivals::Closed`]: chaos runs are open-loop by construction.
pub fn simulate_chaos(
    dep: &DeploymentSim,
    arrivals: &Arrivals,
    n: usize,
    seed: u64,
    plan: &FaultPlan,
    cfg: &ChaosConfig,
) -> ChaosRun {
    assert!(!dep.sims.is_empty());
    assert!(dep.replicas >= 1);
    let (latency_s, bottleneck_s) = service_model(&dep.sims);
    // a shared grant's swap tax rides on every item: the chaos sim does
    // not model quantum phase, it charges the amortized per-stage re-load
    // like the allocator's own p99 estimate does
    let latency_s = latency_s + dep.switch_s.iter().sum::<f64>();

    // offered schedule: base arrivals + overload-spike extras (ids keep
    // growing, so every request has a stable identity and tier)
    let mut offered: Vec<Item> = arrival_times(arrivals, n, seed)
        .into_iter()
        .enumerate()
        .map(|(id, t_s)| Item { t_s, id, arrival_s: t_s, replay: false })
        .collect();
    let base_rate = arrivals.offered_rate_hz().unwrap_or(0.0);
    let mut extra_rng = Rng::new(seed ^ CHAOS_STREAM_SALT ^ 0x5EED);
    let mut next_id = n;
    for ev in &plan.events {
        if let FaultKind::OverloadSpike { rate_mult, duration_s } = ev.kind {
            let extra = ((rate_mult - 1.0) * base_rate * duration_s).round() as usize;
            for _ in 0..extra {
                let t_s = ev.t_s + extra_rng.f64() * duration_s;
                offered.push(Item { t_s, id: next_id, arrival_s: t_s, replay: false });
                next_id += 1;
            }
        }
    }
    offered.sort_by(|a, b| {
        a.t_s.partial_cmp(&b.t_s).expect("arrival times are finite").then(a.id.cmp(&b.id))
    });
    let submitted = offered.len();

    let mut replicas: Vec<Replica> = vec![
        Replica { alive: true, free_t: 0.0, slow_until: f64::NEG_INFINITY, slow_factor: 1.0 };
        dep.replicas
    ];
    // per-replica in-flight/finished ledger for kill replay:
    // (id, arrival, done); a kill moves its owed entries to `replays`
    let mut ledgers: Vec<Vec<(usize, f64, f64)>> = vec![Vec::new(); dep.replicas];
    // samples of work that can no longer be disturbed by a kill
    let mut finished: Vec<(usize, f64, f64)> = Vec::new();
    let mut replays: VecDeque<Item> = VecDeque::new();
    let (mut shed, mut replayed, mut hedged, mut kills) = (0usize, 0usize, 0usize, 0usize);
    let (mut expired, mut recoveries) = (0usize, 0usize);
    // control plane down until this instant (crash/restart outages)
    let mut down_until = f64::NEG_INFINITY;
    let mut rr = 0usize; // round-robin cursor over live replicas
    let mut makespan = 0.0f64;
    let mut cursor = 0usize;
    let mut next_event = 0usize;

    loop {
        // strict event-driven merge: the earliest of (fault event, replay,
        // offered arrival) is handled next, so time only moves forward
        let t_offered = offered.get(cursor).map(|p| p.t_s);
        let t_replay = replays.front().map(|p| p.t_s);
        let t_item = match (t_offered, t_replay) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        let fire_event = next_event < plan.events.len()
            && match t_item {
                Some(t) => plan.events[next_event].t_s <= t,
                None => true,
            };
        if fire_event {
            let ev = plan.events[next_event];
            next_event += 1;
            match ev.kind {
                FaultKind::DeviceKill { device } => {
                    let r = device % replicas.len();
                    let live = replicas.iter().filter(|x| x.alive).count();
                    if !replicas[r].alive || live <= 1 {
                        continue; // never kill the last live replica
                    }
                    replicas[r].alive = false;
                    kills += 1;
                    // drain: completions this replica still owed replay on
                    // the survivors after the drain pause, keeping their
                    // original arrival (latency accrues across the replay,
                    // like the live pool's drained requests)
                    let ledger = std::mem::take(&mut ledgers[r]);
                    for (id, arrival_s, done) in ledger {
                        if done > ev.t_s {
                            replayed += 1;
                            replays.push_back(Item {
                                t_s: ev.t_s + cfg.drain_s,
                                id,
                                arrival_s,
                                replay: true,
                            });
                        } else {
                            finished.push((id, arrival_s, done));
                        }
                    }
                }
                FaultKind::Straggler { replica, factor, duration_s } => {
                    let r = replica % replicas.len();
                    replicas[r].slow_until = ev.t_s + duration_s;
                    replicas[r].slow_factor = factor;
                }
                FaultKind::OverloadSpike { .. } => {} // folded into arrivals
                FaultKind::CrashRestart { outage_s } => {
                    // controller crash: the ingress is gone for the outage
                    // (arrivals in the window are turned away below) and
                    // the workers are torn down — dispatch resumes on the
                    // journal-recovered plan once the restart completes
                    down_until = down_until.max(ev.t_s + outage_s);
                    recoveries += 1;
                    for r in replicas.iter_mut() {
                        if r.alive {
                            r.free_t = r.free_t.max(down_until);
                        }
                    }
                }
            }
            continue;
        }
        // no fireable event: take the earliest item, replays first on ties
        let item = match (t_offered, t_replay) {
            (Some(a), Some(b)) if b <= a => replays.pop_front().expect("peeked"),
            (Some(_), _) => {
                cursor += 1;
                offered[cursor - 1]
            }
            (None, Some(_)) => replays.pop_front().expect("peeked"),
            (None, None) => break,
        };

        // crash outage: the ingress is down, arrivals are turned away at
        // the door (replays were admitted before the crash and survive it)
        if !item.replay && item.t_s < down_until {
            shed += 1;
            continue;
        }
        // tiered admission: backlog = admitted work not yet complete
        if !item.replay {
            let depth = ledgers
                .iter()
                .flat_map(|l| l.iter())
                .filter(|&&(_, _, done)| done > item.t_s)
                .count()
                + replays.len();
            let tier = priority_tier(item.id);
            if depth >= shed_threshold(tier, cfg.queue_capacity) {
                shed += 1;
                continue;
            }
        }

        let live: Vec<usize> = (0..replicas.len()).filter(|&i| replicas[i].alive).collect();
        debug_assert!(!live.is_empty(), "at least one replica always survives");
        let primary = live[rr % live.len()];
        rr += 1;

        let start_p = item.t_s.max(replicas[primary].free_t);
        // deadline check at the moment dispatch would start — the sim
        // analogue of the flush-time shed: an expired request never
        // occupies a server, it is counted and dropped (typed, not silent)
        if let Some(d) = cfg.deadline_s {
            if start_p - item.arrival_s > d {
                expired += 1;
                continue;
            }
        }
        let slow_p = replicas[primary].slowdown(start_p);
        let hedge = cfg.hedge && slow_p > 1.0 && live.len() > 1;
        let (winner, done) = if hedge {
            // duplicate onto the least-loaded healthy alternative; the
            // first response wins, both replicas pay the service time
            let alt = live
                .iter()
                .copied()
                .filter(|&i| i != primary)
                .min_by(|&a, &b| {
                    replicas[a]
                        .free_t
                        .partial_cmp(&replicas[b].free_t)
                        .expect("clocks are finite")
                        .then(a.cmp(&b))
                })
                .expect("live.len() > 1");
            hedged += 1;
            let done_p = start_p + latency_s * slow_p;
            replicas[primary].free_t = start_p + bottleneck_s * slow_p;
            let start_a = item.t_s.max(replicas[alt].free_t);
            let slow_a = replicas[alt].slowdown(start_a);
            let done_a = start_a + latency_s * slow_a;
            replicas[alt].free_t = start_a + bottleneck_s * slow_a;
            if done_a < done_p {
                (alt, done_a)
            } else {
                (primary, done_p)
            }
        } else {
            replicas[primary].free_t = start_p + bottleneck_s * slow_p;
            (primary, start_p + latency_s * slow_p)
        };

        ledgers[winner].push((item.id, item.arrival_s, done));
        if done > makespan {
            makespan = done;
        }
    }

    // every admitted request has exactly one surviving sample: kills moved
    // their replica's owed entries into the replay queue, so ledgers plus
    // `finished` hold one final completion per admitted id
    let mut samples = finished;
    for ledger in ledgers {
        samples.extend(ledger);
    }
    samples.sort_by(|a, b| a.0.cmp(&b.0));
    let admitted = submitted - shed;
    debug_assert_eq!(
        samples.len() + expired,
        admitted,
        "every admitted id either completes or expires"
    );
    let latencies_s: Vec<f64> = samples.iter().map(|&(_, a, d)| d - a).collect();

    ChaosRun {
        submitted,
        admitted,
        shed,
        completed: samples.len(),
        expired,
        replayed,
        hedged,
        kills,
        recoveries,
        latencies_s,
        makespan_s: makespan,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dep(replicas: usize) -> DeploymentSim {
        let sims: Vec<StageSim> = (0..2)
            .map(|i| StageSim {
                exec_s: 1e-3,
                hop_out_s: if i == 1 { 0.0 } else { 1e-4 },
                overhead_s: 2e-4,
            })
            .collect();
        DeploymentSim { sims, replicas, switch_s: Vec::new(), quantum_s: 0.0, cache: None }
    }

    fn arr() -> Arrivals {
        Arrivals::Poisson { rate_hz: 900.0 }
    }

    #[test]
    fn plan_is_seed_deterministic_and_sorted() {
        let spec = FaultSpec { horizon_s: 2.0, kills: 3, stragglers: 3, overloads: 3, crashes: 0 };
        let a = FaultPlan::generate(7, &spec, 4, 2);
        let b = FaultPlan::generate(7, &spec, 4, 2);
        assert_eq!(a, b, "same seed must give the identical plan");
        assert_ne!(a, FaultPlan::generate(8, &spec, 4, 2), "seed must matter");
        assert_eq!(a.events.len(), 9);
        for w in a.events.windows(2) {
            assert!(w[0].t_s <= w[1].t_s, "events must be time-sorted: {a:?}");
        }
        for e in &a.events {
            assert!(e.t_s >= 0.0 && e.t_s < 2.0, "{e:?} outside horizon");
            if let FaultKind::DeviceKill { device } = e.kind {
                assert!(device < 4);
            }
        }
        assert_eq!(a.count("kill") + a.count("straggler") + a.count("overload"), 9);
    }

    #[test]
    fn plan_skips_infeasible_faults() {
        let spec = FaultSpec { horizon_s: 1.0, kills: 2, stragglers: 2, overloads: 1, crashes: 0 };
        let p = FaultPlan::generate(3, &spec, 0, 0);
        assert_eq!(p.count("kill"), 0, "no devices, no kills");
        assert_eq!(p.count("straggler"), 0, "no replicas, no stragglers");
        assert_eq!(p.count("overload"), 1);
    }

    #[test]
    fn chaos_sim_is_bit_deterministic() {
        let spec = FaultSpec { horizon_s: 0.5, kills: 1, stragglers: 1, overloads: 1, crashes: 0 };
        let plan = FaultPlan::generate(7, &spec, 4, 3);
        let d = dep(3);
        let cfg = ChaosConfig::default();
        let a = simulate_chaos(&d, &arr(), 300, 7, &plan, &cfg);
        let b = simulate_chaos(&d, &arr(), 300, 7, &plan, &cfg);
        assert_eq!(a, b, "same inputs must give a bit-identical run");
        let other_plan = FaultPlan::generate(8, &spec, 4, 3);
        let c = simulate_chaos(&d, &arr(), 300, 8, &other_plan, &cfg);
        assert_ne!(a.latencies_s, c.latencies_s, "seed must matter");
    }

    #[test]
    fn accounting_never_loses_admitted_work() {
        for seed in [1u64, 7, 42, 1234] {
            let spec = FaultSpec { horizon_s: 0.5, kills: 2, stragglers: 1, overloads: 2, crashes: 1 };
            let plan = FaultPlan::generate(seed, &spec, 4, 2);
            let run = simulate_chaos(&dep(2), &arr(), 250, seed, &plan, &ChaosConfig::default());
            assert_eq!(run.submitted, run.admitted + run.shed, "seed {seed}: {run:?}");
            assert_eq!(run.completed, run.admitted, "seed {seed}: admitted work must finish");
            assert_eq!(
                run.submitted,
                run.completed + run.shed + run.expired,
                "seed {seed}: every offered request needs a verdict: {run:?}"
            );
            assert_eq!(run.expired, 0, "seed {seed}: no deadlines, no expiry");
            assert_eq!(run.recoveries, plan.count("crash"), "seed {seed}: {run:?}");
            assert_eq!(run.latencies_s.len(), run.completed, "seed {seed}");
            assert!(run.latencies_s.iter().all(|&l| l > 0.0), "seed {seed}");
        }
    }

    #[test]
    fn device_kill_replays_in_flight_work() {
        // one kill into a loaded 2-replica deployment: the dead replica's
        // in-flight completions must replay on the survivor, and latency
        // keeps accruing from the original arrival
        let plan = FaultPlan {
            seed: 0,
            events: vec![FaultEvent { t_s: 0.05, kind: FaultKind::DeviceKill { device: 0 } }],
        };
        let run = simulate_chaos(
            &dep(2),
            &Arrivals::Poisson { rate_hz: 2000.0 },
            400,
            9,
            &plan,
            &ChaosConfig::default(),
        );
        assert_eq!(run.kills, 1);
        assert!(run.replayed > 0, "a loaded replica must have in-flight work: {run:?}");
        assert_eq!(run.completed, run.admitted, "{run:?}");
    }

    #[test]
    fn last_replica_is_never_killed() {
        let plan = FaultPlan {
            seed: 0,
            events: vec![
                FaultEvent { t_s: 0.01, kind: FaultKind::DeviceKill { device: 0 } },
                FaultEvent { t_s: 0.02, kind: FaultKind::DeviceKill { device: 1 } },
            ],
        };
        let run = simulate_chaos(&dep(2), &arr(), 100, 3, &plan, &ChaosConfig::default());
        assert_eq!(run.kills, 1, "second kill would strand the pool: {run:?}");
        assert_eq!(run.completed, run.admitted);
    }

    #[test]
    fn straggler_triggers_hedges_and_hedging_helps() {
        let plan = FaultPlan {
            seed: 0,
            events: vec![FaultEvent {
                t_s: 0.02,
                kind: FaultKind::Straggler { replica: 0, factor: 10.0, duration_s: 0.3 },
            }],
        };
        let hedged = simulate_chaos(&dep(3), &arr(), 300, 5, &plan, &ChaosConfig::default());
        assert!(hedged.hedged > 0, "straggler window must trigger hedges: {hedged:?}");
        let unhedged = simulate_chaos(
            &dep(3),
            &arr(),
            300,
            5,
            &plan,
            &ChaosConfig { hedge: false, ..ChaosConfig::default() },
        );
        assert_eq!(unhedged.hedged, 0);
        assert!(
            hedged.p99_s() <= unhedged.p99_s(),
            "hedging must not hurt the tail: {} vs {}",
            hedged.p99_s(),
            unhedged.p99_s()
        );
    }

    #[test]
    fn overload_sheds_low_tiers_only() {
        // tiny queue + a hard spike: tier 1/2 requests shed, tier 0 never
        let plan = FaultPlan {
            seed: 0,
            events: vec![FaultEvent {
                t_s: 0.05,
                kind: FaultKind::OverloadSpike { rate_mult: 6.0, duration_s: 0.2 },
            }],
        };
        let cfg = ChaosConfig { queue_capacity: 8, ..ChaosConfig::default() };
        let run = simulate_chaos(&dep(1), &arr(), 300, 11, &plan, &cfg);
        assert!(run.submitted > 300, "spike must add offered load: {run:?}");
        assert!(run.shed > 0, "an 8-deep queue under a 6x spike must shed: {run:?}");
        assert_eq!(run.submitted, run.admitted + run.shed);
        assert_eq!(run.completed, run.admitted, "shed is accounted, admitted completes");
    }

    #[test]
    fn crash_free_plans_keep_their_prng_stream() {
        // crash draws come last, so asking for crashes must not perturb
        // the kills/stragglers/overloads any seed generated before the
        // kind existed — seeded golden schedules stay byte-identical
        let base = FaultSpec { horizon_s: 1.5, kills: 2, stragglers: 2, overloads: 2, crashes: 0 };
        let with = FaultSpec { crashes: 2, ..base };
        let a = FaultPlan::generate(7, &base, 4, 3);
        let b = FaultPlan::generate(7, &with, 4, 3);
        assert_eq!(b.count("crash"), 2);
        let b_sans_crash: Vec<FaultEvent> =
            b.events.iter().copied().filter(|e| e.kind.label() != "crash").collect();
        assert_eq!(a.events, b_sans_crash, "crash draws must ride after the legacy stream");
        for e in &b.events {
            if let FaultKind::CrashRestart { outage_s } = e.kind {
                assert!(e.t_s >= 0.3 * 1.5 && e.t_s < 0.7 * 1.5, "{e:?}");
                assert!(outage_s > 0.0, "{e:?}");
            }
        }
    }

    #[test]
    fn crash_outage_sheds_at_the_door_and_recovery_resumes() {
        // a controller crash mid-run: arrivals during the outage are
        // turned away (counted, never lost), drained state survives, and
        // the recovered pool serves everything admitted afterwards
        let plan = FaultPlan {
            seed: 0,
            events: vec![FaultEvent {
                t_s: 0.05,
                kind: FaultKind::CrashRestart { outage_s: 0.1 },
            }],
        };
        let cfg = ChaosConfig::default();
        let run = simulate_chaos(&dep(2), &arr(), 300, 13, &plan, &cfg);
        assert_eq!(run.recoveries, 1, "{run:?}");
        assert!(run.shed > 0, "a 100 ms outage under 900 Hz must turn arrivals away: {run:?}");
        assert_eq!(run.submitted, run.completed + run.shed + run.expired, "{run:?}");
        assert_eq!(run.completed, run.admitted, "no deadlines: admitted work must finish");
        let quiet = FaultPlan { seed: 0, events: Vec::new() };
        let baseline = simulate_chaos(&dep(2), &arr(), 300, 13, &quiet, &cfg);
        assert!(
            run.completed < baseline.completed,
            "the outage must cost throughput: {} vs {}",
            run.completed,
            baseline.completed
        );
        // byte-reproducible per seed — the smoke-drill contract
        let again = simulate_chaos(&dep(2), &arr(), 300, 13, &plan, &cfg);
        assert_eq!(run, again, "crash/restart runs must be bit-deterministic");
    }

    #[test]
    fn deadline_expiry_is_monotone_and_accounted() {
        // tighter deadlines shed more at the flush point, never fewer —
        // and the verdict accounting stays exact at every setting
        let quiet = FaultPlan { seed: 0, events: Vec::new() };
        let overload = Arrivals::Poisson { rate_hz: 2000.0 };
        // queue deep enough that tiered shedding never engages: expiry is
        // the only loss channel under test
        let base = ChaosConfig { queue_capacity: 1_000_000, ..ChaosConfig::default() };
        let run_with = |deadline_s: Option<f64>| {
            simulate_chaos(&dep(1), &overload, 400, 17, &quiet, &ChaosConfig {
                deadline_s,
                ..base
            })
        };
        let unbounded = run_with(None);
        assert_eq!(unbounded.expired, 0);
        let generous = run_with(Some(10.0));
        assert_eq!(generous.expired, 0, "a 10 s deadline never binds here");
        assert_eq!(
            generous.latencies_s, unbounded.latencies_s,
            "an unbinding deadline must not perturb the run"
        );
        let mut last_expired = 0usize;
        for d in [0.2, 0.05, 0.01] {
            let run = run_with(Some(d));
            assert_eq!(run.shed, 0, "deadline {d}: queue never fills: {run:?}");
            assert_eq!(
                run.submitted,
                run.completed + run.shed + run.expired,
                "deadline {d}: {run:?}"
            );
            assert_eq!(
                run.completed,
                run.admitted - run.expired,
                "deadline {d}: never fewer completions than admitted - expired: {run:?}"
            );
            assert!(
                run.expired >= last_expired,
                "deadline {d}: tighter deadlines must never expire less ({} < {last_expired})",
                run.expired
            );
            assert!(
                run.latencies_s.iter().all(|&l| l <= d + 1e-9 + 2.6e-3),
                "deadline {d}: a served request waited past its deadline"
            );
            last_expired = run.expired;
        }
        assert!(last_expired > 0, "a 10 ms deadline under 2.4x overload must expire work");
    }

    #[test]
    fn tier_policy_is_monotone() {
        assert_eq!(shed_threshold(0, 64), usize::MAX);
        assert_eq!(shed_threshold(1, 64), 48);
        assert_eq!(shed_threshold(2, 64), 32);
        assert!(shed_threshold(1, 64) > shed_threshold(2, 64));
        for id in 0..9 {
            assert_eq!(priority_tier(id), (id % 3) as u8);
        }
    }
}
